//! Stub of the `xla` (xla_extension) PJRT binding.
//!
//! The offline build environment cannot link XLA, but the L3 coordinator's
//! PJRT code paths should still *compile* everywhere so the crate presents
//! one API regardless of machine. This stub mirrors the type and method
//! surface `sinq` uses; every entry point that would touch XLA returns
//! [`XlaError`] at runtime. The `sinq` binary's default `--backend native`
//! path never calls into this crate.
//!
//! To execute real artifacts, replace this path dependency with an
//! xla_extension-backed binding (same module paths) and build the workspace
//! with `--features pjrt-artifacts` to re-enable the artifact tests.

use std::fmt;
use std::sync::Arc;

/// Error for every stubbed entry point.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT is unavailable in this build (vendored xla stub); \
         use `--backend native`, or link a real xla_extension binding"
    ))
}

/// Element dtypes of buffers/literals (the subset sinq marshals).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    F32,
    F64,
}

/// Marker for host types that can cross the PJRT boundary.
pub trait NativeType: Copy + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i8 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// Host-side tensor literal.
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_tuple4(&self) -> Result<(Literal, Literal, Literal, Literal)> {
        Err(unavailable("Literal::to_tuple4"))
    }
}

/// Parsed HLO module text.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// A compilable computation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle.
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _private: Arc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn buffer_from_host_raw_bytes(
        &self,
        _ty: ElementType,
        _data: &[u8],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_raw_bytes"))
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled + loaded executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn execute(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_mention_native_backend() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("--backend native"), "{e}");
    }
}
