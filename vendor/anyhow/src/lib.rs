//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides exactly the subset of anyhow's API the `sinq` crate uses:
//! [`Error`], [`Result`], and the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros. Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket `From` conversion
//! below coherent.

use std::fmt;

/// A string-backed error value. The real `anyhow::Error` carries a boxed
/// error plus backtrace; for this repo's purposes (every error is formatted
/// for the CLI or a test assertion) the rendered message is sufficient.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro calls this).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Coherent because `Error` itself does not implement `std::error::Error`
// (mirrors the real anyhow's design).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/xyz")?; // exercises blanket From
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "not ok");
            if !ok {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "not ok");
    }
}
