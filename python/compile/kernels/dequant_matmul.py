"""Pallas kernel: fused W4A16 dequant-matmul — the serving hot path.

Computes ``y = (x ⊙ t) · [s ⊙ (Q + z)]ᵀ`` (Eq. 7) without ever
materializing the dequantized weight matrix in HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation — this is the gemlite/CUDA
kernel rethought for TPU):

* Grid ``(B/bm, N/bn, K/bk)`` with the contraction innermost, so each
  ``(bm, bn)`` output tile stays resident in VMEM across all K steps
  (accumulator revisiting), the schedule a CUDA kernel would express with
  threadblock tiling + shared-memory staging.
* The int4 codes stream HBM→VMEM as ``(bn, bk)`` int8 tiles — ¼ the bytes of
  the f16 weights, which is the entire W4A16 speedup in the memory-bound
  decode regime.
* Dequantization ``s·(q+z)`` happens in registers on the VPU right before
  the MXU-shaped ``jnp.dot``; the second scale ``t`` is applied to the
  *activation* tile (one extra VPU multiply, Table 5's measured overhead)
  rather than to the (much larger) weight tile.

``interpret=True`` everywhere on this image; real-TPU perf is estimated
analytically in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dequant_matmul_kernel(x_ref, q_ref, s_ref, z_ref, t_ref, o_ref, *, group: int,
                           dual: bool, bk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (bm, bk) f32
    if dual:
        x = x * t_ref[...].reshape(1, -1)  # Eq. 7: scale the activation tile
    q = q_ref[...].astype(jnp.float32)  # (bn, bk)
    bn = q.shape[0]
    s = s_ref[...]  # (bn, bk/group)
    z = z_ref[...]
    w = (s[..., None] * (q.reshape(bn, bk // group, group) + z[..., None])).reshape(bn, bk)
    o_ref[...] += jnp.dot(x, w.T, preferred_element_type=jnp.float32)


def dequant_matmul(x, codes, scales, shifts, t=None, group: int = 64,
                   bm: int | None = None, bn: int = 64, bk: int = 64):
    """Pallas entry point.

    x: (B, K) f32; codes: (N, K) int8/int32; scales/shifts: (N, K/group) f32;
    t: optional (K,) f32 — the dual-scale variant when present.
    Returns y: (B, N) f32.
    """
    b, k_dim = x.shape
    n, k2 = codes.shape
    assert k_dim == k2, "x/codes contraction mismatch"
    bm = bm or min(16, b)
    bn = min(bn, n)
    bk = min(bk, k_dim)
    assert bk % group == 0, "k block must hold whole groups"
    assert b % bm == 0 and n % bn == 0 and k_dim % bk == 0, "blocks must tile evenly"
    dual = t is not None
    t_arr = t if dual else jnp.ones((k_dim,), jnp.float32)

    kernel = functools.partial(_dequant_matmul_kernel, group=group, dual=dual, bk=bk)
    grid = (b // bm, n // bn, k_dim // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // group), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // group), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bk,), lambda i, j, kk: (kk,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), codes, scales, shifts, t_arr)


def vmem_bytes(bm: int, bn: int, bk: int, group: int) -> int:
    """Analytic VMEM footprint of one grid step (for the §Perf estimate):
    x tile + q tile (int8) + s/z tiles + t tile + f32 accumulator."""
    return 4 * bm * bk + bn * bk + 2 * 4 * bn * (bk // group) + 4 * bk + 4 * bm * bn


def mxu_utilization_estimate(bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU 128×128×8 tile occupancy for the dot shape — the
    structural efficiency number quoted in DESIGN.md §Perf."""
    eff_m = min(bm, 128) / 128.0 if bm < 128 else 1.0
    eff_n = min(bn, 128) / 128.0 if bn < 128 else 1.0
    return eff_m * eff_n
