"""Pure-jnp oracles for the Pallas kernels (the L1 correctness contract).

Every Pallas kernel in this package has a reference implementation here; the
pytest suite sweeps shapes/dtypes with hypothesis and asserts allclose. These
references also mirror the Rust implementations (`rust/src/quant/{sinq,rtn}`)
— one algorithm, three implementations, cross-checked.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def sinkhorn_normalize_ref(w, iters: int = 24, s_min: float = 0.5, s_max: float = 2.0):
    """Algorithm 1 lines 1-17: returns (s, t) minimizing the imbalance of
    ``W / s[:, None] / t[None, :]`` with best-iterate tracking."""
    w = w.astype(jnp.float32)
    sig_row = jnp.std(w, axis=1)
    sig_col = jnp.std(w, axis=0)
    tau = jnp.maximum(jnp.minimum(jnp.min(sig_row), jnp.min(sig_col)), 1e-12)

    def imbalance(wh):
        sr = jnp.std(wh, axis=1)
        sc = jnp.std(wh, axis=0)
        hi = jnp.maximum(jnp.max(sr), jnp.max(sc))
        lo = jnp.minimum(jnp.min(sr), jnp.min(sc))
        return hi / jnp.maximum(lo, 1e-30)

    def body(_, carry):
        u, v, best_u, best_v, best_i = carry
        # same fp expression as the Pallas kernel (bit-identical tie-breaks)
        wh = w * jnp.exp(-u)[:, None] * jnp.exp(-v)[None, :]
        i_curr = imbalance(wh)
        better = i_curr < best_i
        best_u = jnp.where(better, u, best_u)
        best_v = jnp.where(better, v, best_v)
        best_i = jnp.where(better, i_curr, best_i)
        d_col = jnp.log(jnp.clip(jnp.std(wh, axis=0) / tau, s_min, s_max))
        d_row = jnp.log(jnp.clip(jnp.std(wh, axis=1) / tau, s_min, s_max))
        return u + d_row, v + d_col, best_u, best_v, best_i

    m, n = w.shape
    u0 = jnp.zeros((m,), jnp.float32)
    v0 = jnp.zeros((n,), jnp.float32)
    init = (u0, v0, u0, v0, jnp.asarray(jnp.inf, jnp.float32))
    _, _, bu, bv, _ = lax.fori_loop(0, iters, body, init)
    return jnp.exp(bu), jnp.exp(bv)


def rtn_quantize_ref(w, bits: int = 4, group: int = 64):
    """Grouped asymmetric RTN (Algorithm 1 line 18).

    Returns (codes i32 [N, M], scales f32 [N, M/g], shifts f32 [N, M/g]).
    The representable range always includes 0 (matches the Rust rtn).
    """
    n, m = w.shape
    assert m % group == 0, "ref kernel assumes divisible groups"
    maxq = float(2**bits - 1)
    wg = w.reshape(n, m // group, group)
    lo = jnp.minimum(wg.min(axis=-1), 0.0)
    hi = jnp.maximum(wg.max(axis=-1), 0.0)
    scale = jnp.where(hi > lo, (hi - lo) / maxq, 1.0)
    z = lo / scale
    q = jnp.clip(jnp.round(wg / scale[..., None] - z[..., None]), 0.0, maxq)
    return q.reshape(n, m).astype(jnp.int32), scale, z


def dequantize_ref(codes, scales, shifts, t=None, group: int = 64):
    """W = s ⊙ (Q + z) ⊙ t (Eq. 3)."""
    n, m = codes.shape
    q = codes.astype(jnp.float32).reshape(n, m // group, group)
    w = scales[..., None] * (q + shifts[..., None])
    w = w.reshape(n, m)
    if t is not None:
        w = w * t[None, :]
    return w


def dequant_matmul_ref(x, codes, scales, shifts, t=None, group: int = 64):
    """y = (x ⊙ t) · [s ⊙ (Q + z)]ᵀ (Eq. 7) — the W4A16 hot path."""
    w = dequantize_ref(codes, scales, shifts, None, group)
    xs = x if t is None else x * t[None, :]
    return xs @ w.T


def sinq_quantize_ref(w, bits: int = 4, group: int = 64, iters: int = 24,
                      s_min: float = 0.5, s_max: float = 2.0):
    """Full Algorithm 1: returns (codes, merged scales s_q⊙s, shifts, t)."""
    s, t = sinkhorn_normalize_ref(w, iters, s_min, s_max)
    w_hat = w / s[:, None] / t[None, :]
    codes, s_q, z = rtn_quantize_ref(w_hat, bits, group)
    return codes, s_q * s[:, None], z, t
