"""Pallas kernel: grouped asymmetric RTN rounding (Algorithm 1 line 18).

Grid: one program per (row-block, group). Each program sees an
``(BM, group)`` tile in VMEM, computes the min/max range (VPU reductions),
and emits integer codes plus the per-(row, group) scale/shift. On TPU the
tile shape is picked so the lane dimension is the group (64 or 128 — both
multiples of the 128-lane VPU after padding); rounding is elementwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rtn_kernel(w_ref, q_ref, s_ref, z_ref, *, maxq: float):
    wg = w_ref[...]  # (bm, group)
    lo = jnp.minimum(jnp.min(wg, axis=1), 0.0)
    hi = jnp.maximum(jnp.max(wg, axis=1), 0.0)
    scale = jnp.where(hi > lo, (hi - lo) / maxq, 1.0)
    z = lo / scale
    q = jnp.clip(jnp.round(wg / scale[:, None] - z[:, None]), 0.0, maxq)
    q_ref[...] = q.astype(jnp.int32)
    s_ref[...] = scale[:, None]
    z_ref[...] = z[:, None]


def rtn_quantize(w, bits: int = 4, group: int = 64, block_rows: int = 64):
    """Pallas entry point. Returns (codes i32 [N,M], scales [N,M/g], shifts)."""
    n, m = w.shape
    assert m % group == 0, "kernel requires divisible groups"
    bm = min(block_rows, n)
    assert n % bm == 0, "row count must divide the row block"
    n_groups = m // group
    kernel = functools.partial(_rtn_kernel, maxq=float(2**bits - 1))
    grid = (n // bm, n_groups)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, group), lambda i, g: (i, g))],
        out_specs=(
            pl.BlockSpec((bm, group), lambda i, g: (i, g)),
            pl.BlockSpec((bm, 1), lambda i, g: (i, g)),
            pl.BlockSpec((bm, 1), lambda i, g: (i, g)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n, m), jnp.int32),
            jax.ShapeDtypeStruct((n, n_groups), jnp.float32),
            jax.ShapeDtypeStruct((n, n_groups), jnp.float32),
        ),
        interpret=True,
    )(w.astype(jnp.float32))
