"""Pallas kernel: Algorithm 1's Sinkhorn normalization loop.

TPU mapping (DESIGN.md §Hardware-Adaptation): the whole weight tile lives in
VMEM (our layer shapes are ≤ 1024×256 f32 = 1 MiB, far under the ~16 MiB VMEM
budget), the K-step loop runs on-core with row/column variance reductions on
the VPU — the iteration is reduction-bound, not MXU-bound, so keeping the
matrix resident across all K iterations (instead of K HBM round-trips, as a
naive jnp implementation would) is the entire optimization.

Must run with ``interpret=True`` on this image (CPU PJRT cannot execute
Mosaic custom-calls); the lowered HLO is what `rust/src/runtime` executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _sinkhorn_kernel(w_ref, s_ref, t_ref, *, iters: int, s_min: float, s_max: float):
    w = w_ref[...]

    sig_row = jnp.std(w, axis=1)
    sig_col = jnp.std(w, axis=0)
    tau = jnp.maximum(jnp.minimum(jnp.min(sig_row), jnp.min(sig_col)), 1e-12)

    def imbalance(wh):
        sr = jnp.std(wh, axis=1)
        sc = jnp.std(wh, axis=0)
        return jnp.maximum(jnp.max(sr), jnp.max(sc)) / jnp.maximum(
            jnp.minimum(jnp.min(sr), jnp.min(sc)), 1e-30
        )

    def body(_, carry):
        u, v, best_u, best_v, best_i = carry
        wh = w * jnp.exp(-u)[:, None] * jnp.exp(-v)[None, :]
        i_curr = imbalance(wh)
        better = i_curr < best_i
        best_u = jnp.where(better, u, best_u)
        best_v = jnp.where(better, v, best_v)
        best_i = jnp.where(better, i_curr, best_i)
        d_col = jnp.log(jnp.clip(jnp.std(wh, axis=0) / tau, s_min, s_max))
        d_row = jnp.log(jnp.clip(jnp.std(wh, axis=1) / tau, s_min, s_max))
        return u + d_row, v + d_col, best_u, best_v, best_i

    m, n = w.shape
    u0 = jnp.zeros((m,), jnp.float32)
    v0 = jnp.zeros((n,), jnp.float32)
    init = (u0, v0, u0, v0, jnp.asarray(jnp.inf, jnp.float32))
    _, _, bu, bv, _ = lax.fori_loop(0, iters, body, init)
    s_ref[...] = jnp.exp(bu)
    t_ref[...] = jnp.exp(bv)


def sinkhorn_normalize(w, iters: int = 24, s_min: float = 0.5, s_max: float = 2.0):
    """Pallas entry point: returns (s, t), shapes (N,), (M,)."""
    m, n = w.shape
    kernel = functools.partial(_sinkhorn_kernel, iters=iters, s_min=s_min, s_max=s_max)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=True,  # CPU PJRT path; see module docstring
    )(w.astype(jnp.float32))
