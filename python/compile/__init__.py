"""Build-time Python: JAX model (L2), Pallas kernels (L1), AOT lowering."""
