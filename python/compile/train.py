"""Train the model family with Adam on the synthetic corpora.

Build-time only (`make artifacts`): produces
  artifacts/corpus/{wiki,c4}_{train,eval}.bin   — byte corpora
  artifacts/models/{name}.stz                   — f32 checkpoints + config

The paper's central observation (σ_col(W) predicts μ_x; Fig. 2a/2b) is a
property of *Adam-trained* weights, so checkpoints must be genuinely trained,
not sampled. Training budgets are sized for a single CPU core; the loss
curves are logged into the checkpoint metadata and re-printed by
`sinq table e2e` (EXPERIMENTS.md records the run).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_mod
from . import stz
from .model import FAMILY, Config, init_params, loss_fn

SEQ = 128
BATCH = 4
CORPUS_TRAIN_BYTES = 1 << 21  # 2 MiB per register
CORPUS_EVAL_BYTES = 1 << 17  # 128 KiB per register

#: Adam steps per model (single-core budget; losses plateau well below the
#: byte-entropy of the corpus, which is all the experiments need).
STEPS = {"pico": 600, "tiny": 400, "small": 220, "tiny_moe": 300}


def ensure_corpora(art_dir: str) -> dict[str, bytes]:
    os.makedirs(f"{art_dir}/corpus", exist_ok=True)
    out = {}
    for kind, seed in (("wiki", 1001), ("c4", 2002)):
        tr, ev = corpus_mod.train_eval_split(kind, CORPUS_TRAIN_BYTES, CORPUS_EVAL_BYTES, seed)
        for split, data in (("train", tr), ("eval", ev)):
            path = f"{art_dir}/corpus/{kind}_{split}.bin"
            if not os.path.exists(path):
                with open(path, "wb") as f:
                    f.write(data)
            out[f"{kind}_{split}"] = data
    return out


def batches(data: np.ndarray, rng: np.random.Generator):
    """Endless (BATCH, SEQ+1) windows sampled uniformly."""
    n = len(data) - (SEQ + 1)
    while True:
        idx = rng.integers(0, n, size=BATCH)
        yield np.stack([data[i : i + SEQ + 1] for i in idx]).astype(np.int32)


def adam_init(params):
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    return zeros, {k: np.zeros_like(v) for k, v in params.items()}


def train_model(cfg: Config, corpora: dict[str, bytes], steps: int, art_dir: str,
                lr: float = 3e-3, seed: int = 0) -> dict:
    t0 = time.time()
    params = {k: jnp.asarray(v) for k, v in init_params(cfg, seed).items()}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}

    # 80/20 wiki/c4 mixture, like the paper's models see mixed data.
    wiki = np.frombuffer(corpora["wiki_train"], dtype=np.uint8)
    c4 = np.frombuffer(corpora["c4_train"], dtype=np.uint8)
    rng = np.random.default_rng(seed + 7)
    wiki_it, c4_it = batches(wiki, rng), batches(c4, rng)

    grad_fn = jax.jit(jax.value_and_grad(lambda p, t: loss_fn(p, t, cfg)))
    b1, b2, eps = 0.9, 0.999, 1e-8
    log: list[tuple[int, float]] = []

    @jax.jit
    def adam_update(params, m, v, grads, step):
        new_p, new_m, new_v = {}, {}, {}
        for k in params:
            g = grads[k]
            new_m[k] = b1 * m[k] + (1 - b1) * g
            new_v[k] = b2 * v[k] + (1 - b2) * g * g
            mh = new_m[k] / (1 - b1 ** step)
            vh = new_v[k] / (1 - b2 ** step)
            new_p[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
        return new_p, new_m, new_v

    for step in range(1, steps + 1):
        batch = next(wiki_it) if rng.random() < 0.8 else next(c4_it)
        loss, grads = grad_fn(params, jnp.asarray(batch))
        params, m, v = adam_update(params, m, v, grads, jnp.float32(step))
        if step == 1 or step % 50 == 0 or step == steps:
            log.append((step, float(loss)))
            print(f"  [{cfg.name}] step {step:4d}/{steps}  loss {float(loss):.4f}", flush=True)

    npy = {k: np.asarray(val) for k, val in params.items()}
    meta = {
        "config": cfg.to_meta(),
        "train": {
            "steps": steps, "lr": lr, "batch": BATCH, "seq": SEQ,
            "loss_curve": [[s, round(l, 4)] for s, l in log],
            "wall_seconds": round(time.time() - t0, 1),
        },
    }
    os.makedirs(f"{art_dir}/models", exist_ok=True)
    stz.save(f"{art_dir}/models/{cfg.name}.stz", npy, meta)
    print(f"  [{cfg.name}] saved ({sum(a.size for a in npy.values())/1e6:.2f}M params, "
          f"{time.time()-t0:.0f}s)", flush=True)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--art-dir", default="../artifacts")
    ap.add_argument("--models", default="pico,tiny,small,tiny_moe")
    ap.add_argument("--steps-scale", type=float, default=1.0,
                    help="scale training budgets (tests use ~0.02)")
    args = ap.parse_args()

    corpora = ensure_corpora(args.art_dir)
    for name in args.models.split(","):
        cfg = FAMILY[name]
        path = f"{args.art_dir}/models/{name}.stz"
        if os.path.exists(path):
            print(f"  [{name}] checkpoint exists, skipping")
            continue
        steps = max(2, int(STEPS[name] * args.steps_scale))
        train_model(cfg, corpora, steps, args.art_dir)


if __name__ == "__main__":
    main()
