"""`.stz` tensor archive — Python writer/reader.

Mirror of `rust/src/fmt/stz.rs`: a u64-length-prefixed JSON header naming
each tensor's dtype/shape/offset/nbytes, followed by raw little-endian data.
The trainer writes model checkpoints in this format; the Rust side loads
them without any Python dependency at runtime.
"""

from __future__ import annotations

import json
import struct
from typing import Any

import numpy as np

_DTYPES = {"f32": np.float32, "i32": np.int32, "u8": np.uint8}


def save(path: str, tensors: dict[str, np.ndarray], meta: dict[str, Any] | None = None) -> None:
    """Write tensors (f32/i32/u8) plus optional JSON metadata."""
    header: dict[str, Any] = {}
    blobs: list[bytes] = []
    offset = 0
    for name, arr in tensors.items():
        if name == "__meta__":
            raise ValueError("'__meta__' is a reserved key")
        arr = np.ascontiguousarray(arr)
        if arr.dtype == np.float32:
            dtype = "f32"
        elif arr.dtype == np.int32:
            dtype = "i32"
        elif arr.dtype == np.uint8:
            dtype = "u8"
        else:
            raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
        raw = arr.astype(_DTYPES[dtype]).tobytes(order="C")
        header[name] = {
            "dtype": dtype,
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": len(raw),
        }
        blobs.append(raw)
        offset += len(raw)
    if meta is not None:
        header["__meta__"] = meta
    hjson = json.dumps(header, separators=(",", ":")).encode()
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)


def load(path: str) -> tuple[dict[str, np.ndarray], dict[str, Any] | None]:
    """Read an archive back; returns (tensors, meta)."""
    with open(path, "rb") as f:
        raw = f.read()
    (hlen,) = struct.unpack("<Q", raw[:8])
    header = json.loads(raw[8 : 8 + hlen])
    data = raw[8 + hlen :]
    meta = header.pop("__meta__", None)
    out = {}
    for name, desc in header.items():
        dt = _DTYPES[desc["dtype"]]
        buf = data[desc["offset"] : desc["offset"] + desc["nbytes"]]
        out[name] = np.frombuffer(buf, dtype=dt).reshape(desc["shape"]).copy()
    return out, meta
