"""AOT lowering: JAX → HLO **text** artifacts for the Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax ≥0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the `xla` crate) rejects; the text parser reassigns ids (see
/opt/xla-example/README.md and aot_recipe).

Artifacts (written to ``artifacts/``, indexed in ``manifest.json``):

  fwd_{name}.hlo.txt          full-sequence forward, tokens (4,128) i32 +
                              f32 weights → logits. One artifact serves every
                              quantization method (effective weights are
                              runtime arguments).
  decode_{name}.hlo.txt       single-token decode with KV cache (f32/FP16
                              serving baseline, Table 6).
  decode_{name}_w4.hlo.txt    W4A16 decode: linears run the Pallas fused
                              dequant-matmul on int8 codes (Eq. 7 path).
  dqmm_b{B}_d{D}[_dual].hlo.txt  Table 5 kernel-overhead benchmark pairs.
  sinq_quantize_{R}x{C}.hlo.txt  Algorithm 1 (Pallas sinkhorn + RTN) for each
                              distinct weight shape — the PJRT-accelerated
                              quantization path.

Python runs once; after this the `sinq` binary is self-contained.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.dequant_matmul import dequant_matmul
from .kernels.rtn import rtn_quantize
from .kernels.sinkhorn import sinkhorn_normalize
from .model import FAMILY, Config, decode_step, decode_step_quant, forward, quantizable_names, weight_names
from . import stz

DECODE_CTX = 768  # 256 prompt + 512 generation (Table 6 setting)
FWD_BATCH, FWD_SEQ = 4, 128
GROUP = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)//1024} KiB)", flush=True)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def weight_specs(cfg: Config, params_shape: dict[str, tuple]) -> list:
    return [spec(params_shape[n]) for n in weight_names(cfg)]


def shapes_of(cfg: Config) -> dict[str, tuple]:
    from .model import init_params

    return {k: v.shape for k, v in init_params(cfg, 0).items()}


def lower_forward(cfg: Config, shapes: dict[str, tuple]):
    names = weight_names(cfg)

    def fn(tokens, *flat):
        params = dict(zip(names, flat))
        return (forward(params, tokens, cfg),)

    args = [spec((FWD_BATCH, FWD_SEQ), jnp.int32)] + [spec(shapes[n]) for n in names]
    return jax.jit(fn).lower(*args)


def lower_decode(cfg: Config, shapes: dict[str, tuple]):
    names = weight_names(cfg)
    kv_shape = (cfg.layers, 2, 1, cfg.heads, DECODE_CTX, cfg.head_dim)

    def fn(token, pos, kv, *flat):
        params = dict(zip(names, flat))
        logits, new_kv = decode_step(params, token, pos, kv, cfg)
        # Single flat output: multi-element tuple outputs cannot be
        # downloaded through xla_extension 0.5.1's ToLiteralSync (see
        # rust/src/runtime/exec.rs); rust splits at vocab.
        return (jnp.concatenate([logits.reshape(-1), new_kv.reshape(-1)]),)

    args = [spec((1,), jnp.int32), spec((), jnp.int32), spec(kv_shape)] + [
        spec(shapes[n]) for n in names
    ]
    return jax.jit(fn).lower(*args)


def lower_decode_w4(cfg: Config, shapes: dict[str, tuple]):
    qnames = quantizable_names(cfg)
    fnames = [n for n in weight_names(cfg) if n not in qnames]
    kv_shape = (cfg.layers, 2, 1, cfg.heads, DECODE_CTX, cfg.head_dim)

    def fn(token, pos, kv, *flat):
        fparams = dict(zip(fnames, flat[: len(fnames)]))
        rest = flat[len(fnames):]
        qparams = {}
        for qi, name in enumerate(qnames):
            codes, scales, shifts, t = rest[qi * 4 : qi * 4 + 4]
            qparams[name] = (codes, scales, shifts, t)
        logits, new_kv = decode_step_quant(qparams, fparams, token, pos, kv, cfg, group=GROUP)
        return (jnp.concatenate([logits.reshape(-1), new_kv.reshape(-1)]),)

    args = [spec((1,), jnp.int32), spec(()), spec(kv_shape)]
    args[1] = spec((), jnp.int32)
    args += [spec(shapes[n]) for n in fnames]
    for name in qnames:
        out_d, in_d = shapes[name]
        args += [
            spec((out_d, in_d), jnp.int8),
            spec((out_d, in_d // GROUP)),
            spec((out_d, in_d // GROUP)),
            spec((in_d,)),
        ]
    return jax.jit(fn).lower(*args), fnames, qnames


def lower_dqmm(b: int, d: int, dual: bool):
    def fn(x, codes, scales, shifts, t):
        tt = t if dual else None
        return (dequant_matmul(x, codes, scales, shifts, tt, group=GROUP,
                               bm=min(16, b), bn=64, bk=64),)

    args = [
        spec((b, d)),
        spec((d, d), jnp.int8),
        spec((d, d // GROUP)),
        spec((d, d // GROUP)),
        spec((d,)),
    ]
    return jax.jit(fn).lower(*args)


def lower_sinq_quantize(rows: int, cols: int, bits: int = 4):
    def fn(w):
        s, t = sinkhorn_normalize(w)
        w_hat = w / s[:, None] / t[None, :]
        codes, s_q, z = rtn_quantize(w_hat, bits=bits, group=GROUP,
                                     block_rows=min(64, rows))
        return codes, s_q * s[:, None], z, t

    return jax.jit(fn).lower(spec((rows, cols)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--art-dir", default="../artifacts")
    ap.add_argument("--models", default="pico,tiny,small,tiny_moe")
    ap.add_argument("--skip-w4", action="store_true",
                    help="skip the (slow to lower) W4 decode artifacts")
    args = ap.parse_args()
    os.makedirs(args.art_dir, exist_ok=True)
    manifest: dict = {"group": GROUP, "fwd": {}, "decode": {}, "decode_w4": {},
                      "dqmm": [], "sinq_quantize": []}

    for name in args.models.split(","):
        cfg = FAMILY[name]
        shapes = shapes_of(cfg)
        path = f"{args.art_dir}/fwd_{name}.hlo.txt"
        if not os.path.exists(path):
            write(path, to_hlo_text(lower_forward(cfg, shapes)))
        manifest["fwd"][name] = {
            "tokens": [FWD_BATCH, FWD_SEQ],
            "weights": weight_names(cfg),
        }

        path = f"{args.art_dir}/decode_{name}.hlo.txt"
        if not os.path.exists(path):
            write(path, to_hlo_text(lower_decode(cfg, shapes)))
        manifest["decode"][name] = {
            "ctx": DECODE_CTX,
            "weights": weight_names(cfg),
        }

        if not args.skip_w4 and name in ("tiny", "small"):
            path = f"{args.art_dir}/decode_{name}_w4.hlo.txt"
            if not os.path.exists(path):
                lowered, fnames, qnames = lower_decode_w4(cfg, shapes)
                write(path, to_hlo_text(lowered))
            else:
                qnames = quantizable_names(cfg)
                fnames = [n for n in weight_names(cfg) if n not in qnames]
            manifest["decode_w4"][name] = {
                "ctx": DECODE_CTX, "fweights": fnames, "qweights": qnames,
            }

    # Table 5 kernel pairs.
    for b in (1, 64):
        for d in (1024, 2048):
            for dual in (False, True):
                suffix = "_dual" if dual else ""
                path = f"{args.art_dir}/dqmm_b{b}_d{d}{suffix}.hlo.txt"
                if not os.path.exists(path):
                    write(path, to_hlo_text(lower_dqmm(b, d, dual)))
                manifest["dqmm"].append({"b": b, "d": d, "dual": dual})

    # Algorithm-1 quantization artifacts for every distinct quantizable shape.
    shapes_needed = sorted(
        {
            shapes_of(FAMILY[m])[n]
            for m in args.models.split(",")
            for n in quantizable_names(FAMILY[m])
            if shapes_of(FAMILY[m])[n][1] % GROUP == 0
        }
    )
    for rows, cols in shapes_needed:
        path = f"{args.art_dir}/sinq_quantize_{rows}x{cols}.hlo.txt"
        if not os.path.exists(path):
            write(path, to_hlo_text(lower_sinq_quantize(rows, cols)))
        manifest["sinq_quantize"].append([rows, cols])

    with open(f"{args.art_dir}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print("  manifest.json updated", flush=True)


if __name__ == "__main__":
    main()
