"""Synthetic training/evaluation corpora.

Substitute for WikiText2 / C4 (no dataset downloads in this environment; see
DESIGN.md §3): two *distinct* text distributions produced by a seeded
template-and-Markov generator over a built-in vocabulary.

* ``synthwiki`` — encyclopedic register: declarative sentences, section
  headings, years/numbers, entity repetition within an "article".
* ``synthc4``  — web register: mixed topics, imperative/second-person
  sentences, lists, noisier punctuation.

Both are byte-level tokenizable (ASCII). The generator is pure Python with an
explicit LCG so the corpus is bit-identical across runs and platforms; the
bytes are saved into ``artifacts/`` and the Rust evaluators load exactly the
same data the model was trained on.
"""

from __future__ import annotations


class Lcg:
    """Deterministic 64-bit LCG (platform-independent)."""

    def __init__(self, seed: int):
        self.s = (seed ^ 0x9E3779B97F4A7C15) & ((1 << 64) - 1)

    def next(self) -> int:
        self.s = (self.s * 6364136223846793005 + 1442695040888963407) & ((1 << 64) - 1)
        return self.s >> 33

    def below(self, n: int) -> int:
        return self.next() % n

    def choice(self, xs):
        return xs[self.below(len(xs))]


NOUNS = (
    "system river empire theory engine council valley method garden signal "
    "market temple compiler harbor museum planet circuit forest treaty sensor "
    "archive bridge colony dialect furnace glacier habitat isotope journal "
    "kernel lattice meadow nebula orchard pigment quarry reactor stadium "
    "tunnel vessel windmill zephyr algorithm basin cathedral dynamo estuary"
).split()

ADJS = (
    "ancient rapid quiet northern dense fragile modern hollow distant precise "
    "luminous brittle coastal recursive thermal nomadic austere vivid sturdy "
    "obscure parallel fertile rugged serene volatile compact ornate humid"
).split()

VERBS = (
    "describes contains governs produces connects absorbs predicts regulates "
    "transforms precedes supports measures encodes divides restores observes "
    "balances extends records compresses"
).split()

TOPICS = (
    "history geology music trade physics language agriculture navigation "
    "astronomy medicine weaving metallurgy cartography rhetoric"
).split()


def _arith(rng: Lcg) -> str:
    """Short addition chains — the reasoning-benchmark (Table 7) substrate."""
    a, b = 2 + rng.below(40), 2 + rng.below(40)
    c = 2 + rng.below(20)
    s1 = a + b
    s2 = s1 + c
    return f"{a} + {b} = {s1}. {s1} + {c} = {s2}."


def _sentence(rng: Lcg, register: str) -> str:
    if rng.below(12) == 0:  # ~8% arithmetic in both registers
        return _arith(rng)
    n1, n2 = rng.choice(NOUNS), rng.choice(NOUNS)
    a1, a2 = rng.choice(ADJS), rng.choice(ADJS)
    v = rng.choice(VERBS)
    t = rng.choice(TOPICS)
    year = 1400 + rng.below(600)
    count = 2 + rng.below(96)
    if register == "wiki":
        forms = [
            f"The {a1} {n1} {v} the {n2} of {t}.",
            f"In {year}, the {n1} {v} {count} {n2}s across the {a2} {n2}.",
            f"The {n1} of {t} is a {a1} {n2} that {v} the {a2} {n1}.",
            f"Early {t} {v} the {a1} {n1}, which later {v} the {n2}.",
            f"A {a1} {n1} {v} the {n2}; the {n2} {v} {count} {a2} {n1}s.",
        ]
    else:
        forms = [
            f"You can find the {a1} {n1} near the {n2} - really {a2}!",
            f"Top {count} {n1}s for {t}: the {a1} {n2} {v} everything.",
            f"Why the {n1} {v} your {n2} (and how {t} helps).",
            f"we tested the {a1} {n1} and it {v} the {n2} fast.",
            f"Buy a {a1} {n1} today, {v} the {n2}, save {count} dollars.",
        ]
    return forms[rng.below(len(forms))]


def generate(kind: str, n_bytes: int, seed: int) -> bytes:
    """Generate ~n_bytes of ASCII text of the given register."""
    assert kind in ("wiki", "c4")
    rng = Lcg(seed)
    parts: list[str] = []
    size = 0
    while size < n_bytes:
        if kind == "wiki":
            head = f"== {rng.choice(NOUNS).title()} {rng.choice(TOPICS)} ==\n"
        else:
            head = f"# {rng.choice(ADJS)} {rng.choice(NOUNS)} blog\n"
        para = [head]
        # Entity repetition: one noun recurs within a paragraph (gives the
        # model an in-context copying signal worth learning).
        for _ in range(4 + rng.below(6)):
            para.append(_sentence(rng, kind) + " ")
        para.append("\n\n")
        chunk = "".join(para)
        parts.append(chunk)
        size += len(chunk)
    text = "".join(parts)[:n_bytes]
    return text.encode("ascii", errors="replace")


def train_eval_split(kind: str, n_train: int, n_eval: int, seed: int) -> tuple[bytes, bytes]:
    """Disjoint train/eval streams (different seeds ⇒ different articles)."""
    return generate(kind, n_train, seed), generate(kind, n_eval, seed + 1)
