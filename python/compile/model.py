"""L2: the JAX transformer family used for every experiment.

A decoder-only pre-norm transformer (RMSNorm, RoPE multi-head attention,
SwiGLU MLP, optional switch-style MoE MLP) — the Qwen3-shaped architecture
the paper evaluates, scaled to dimensions trainable on one CPU core. All
model dims are powers of two so the Hadamard baselines apply directly.

The same forward is lowered to HLO three ways by ``aot.py``:
  * full-sequence f32 forward (perplexity evaluation; weights are runtime
    *arguments* so one artifact serves every quantization method via
    effective weights),
  * single-token decode step with KV cache (serving/throughput benches),
  * W4A16 decode step whose linears run the Pallas fused dequant-matmul
    kernel on int4 codes (the paper's Eq. 7 inference path).

The Rust reference forward (`rust/src/model/forward.rs`) mirrors this file
operation-for-operation; `python/tests/test_model.py` and the Rust
integration tests cross-check them through the `.stz` interchange.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.dequant_matmul import dequant_matmul


@dataclass(frozen=True)
class Config:
    name: str
    d: int
    layers: int
    heads: int
    ffn: int
    vocab: int = 256
    n_experts: int = 0  # 0 = dense SwiGLU MLP
    rope_base: float = 10000.0
    eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d // self.heads

    def to_meta(self) -> dict:
        return {
            "name": self.name, "d": self.d, "layers": self.layers,
            "heads": self.heads, "ffn": self.ffn, "vocab": self.vocab,
            "n_experts": self.n_experts, "rope_base": self.rope_base,
            "eps": self.eps,
        }

    @staticmethod
    def from_meta(m: dict) -> "Config":
        return Config(
            name=m["name"], d=int(m["d"]), layers=int(m["layers"]),
            heads=int(m["heads"]), ffn=int(m["ffn"]), vocab=int(m["vocab"]),
            n_experts=int(m.get("n_experts", 0)),
            rope_base=float(m.get("rope_base", 10000.0)),
            eps=float(m.get("eps", 1e-5)),
        )


#: The model family (paper's Qwen3 size sweep, scaled; DESIGN.md §3).
FAMILY: dict[str, Config] = {
    "pico": Config("pico", d=64, layers=2, heads=2, ffn=256),
    "tiny": Config("tiny", d=128, layers=4, heads=4, ffn=512),
    "small": Config("small", d=256, layers=4, heads=8, ffn=1024),
    # MoE variant (Appendix A.16 analogue): 4 experts, top-1 switch routing.
    "tiny_moe": Config("tiny_moe", d=128, layers=2, heads=4, ffn=256, n_experts=4),
}


def weight_names(cfg: Config) -> list[str]:
    """Canonical ordered weight list — the HLO artifact argument order."""
    names = ["embed"]
    for i in range(cfg.layers):
        p = f"layers.{i}"
        names += [f"{p}.ln1", f"{p}.wq", f"{p}.wk", f"{p}.wv", f"{p}.wo", f"{p}.ln2"]
        if cfg.n_experts == 0:
            names += [f"{p}.wg", f"{p}.wu", f"{p}.wd"]
        else:
            names += [f"{p}.router"]
            for e in range(cfg.n_experts):
                names += [f"{p}.expert{e}.wg", f"{p}.expert{e}.wu", f"{p}.expert{e}.wd"]
    names += ["ln_f", "lm_head"]
    return names


def quantizable_names(cfg: Config) -> list[str]:
    """The linear layers PTQ applies to (embeddings/norms stay f16, as in the
    paper's weight-only setting)."""
    return [n for n in weight_names(cfg)
            if n.split(".")[-1].startswith("w") or "lm_head" in n or "router" in n]


def init_params(cfg: Config, seed: int = 0) -> dict[str, np.ndarray]:
    """LeCun-style init as float32 numpy (trainer owns the arrays)."""
    rng = np.random.default_rng(seed)

    def dense(out_dim, in_dim, gain=1.0):
        return (gain * rng.standard_normal((out_dim, in_dim)) / np.sqrt(in_dim)).astype(np.float32)

    p: dict[str, np.ndarray] = {"embed": (0.02 * rng.standard_normal((cfg.vocab, cfg.d))).astype(np.float32)}
    for i in range(cfg.layers):
        pre = f"layers.{i}"
        p[f"{pre}.ln1"] = np.ones(cfg.d, np.float32)
        p[f"{pre}.wq"] = dense(cfg.d, cfg.d)
        p[f"{pre}.wk"] = dense(cfg.d, cfg.d)
        p[f"{pre}.wv"] = dense(cfg.d, cfg.d)
        p[f"{pre}.wo"] = dense(cfg.d, cfg.d, gain=1.0 / np.sqrt(2 * cfg.layers))
        p[f"{pre}.ln2"] = np.ones(cfg.d, np.float32)
        if cfg.n_experts == 0:
            p[f"{pre}.wg"] = dense(cfg.ffn, cfg.d)
            p[f"{pre}.wu"] = dense(cfg.ffn, cfg.d)
            p[f"{pre}.wd"] = dense(cfg.d, cfg.ffn, gain=1.0 / np.sqrt(2 * cfg.layers))
        else:
            p[f"{pre}.router"] = dense(cfg.n_experts, cfg.d)
            for e in range(cfg.n_experts):
                p[f"{pre}.expert{e}.wg"] = dense(cfg.ffn, cfg.d)
                p[f"{pre}.expert{e}.wu"] = dense(cfg.ffn, cfg.d)
                p[f"{pre}.expert{e}.wd"] = dense(cfg.d, cfg.ffn, gain=1.0 / np.sqrt(2 * cfg.layers))
    p["ln_f"] = np.ones(cfg.d, np.float32)
    p["lm_head"] = dense(cfg.vocab, cfg.d)
    return p


# --------------------------------------------------------------------------
# Forward pieces (shared by full-sequence and decode paths).
# --------------------------------------------------------------------------

def rmsnorm(x, gain, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def rope_angles(positions, head_dim, base):
    """(P, hd/2) angles; split-half convention (matches the Rust forward)."""
    inv = base ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32) * 2.0 / head_dim)
    return positions.astype(jnp.float32)[:, None] * inv[None, :]


def apply_rope(x, ang):
    """x: (..., P, hd); rotate the two halves by position-dependent angles."""
    h = x.shape[-1] // 2
    x1, x2 = x[..., :h], x[..., h:]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _mlp(h, p, pre, cfg, linear):
    if cfg.n_experts == 0:
        g = linear(h, f"{pre}.wg")
        u = linear(h, f"{pre}.wu")
        return linear(jax.nn.silu(g) * u, f"{pre}.wd")
    # Switch-style top-1 MoE, computed densely (exact; tiny scale).
    router_logits = linear(h, f"{pre}.router")  # (..., E)
    gates = jax.nn.softmax(router_logits, axis=-1)
    top = jnp.argmax(gates, axis=-1)  # (...,)
    gate_val = jnp.take_along_axis(gates, top[..., None], axis=-1)
    out = 0.0
    for e in range(cfg.n_experts):
        ge = linear(h, f"{pre}.expert{e}.wg")
        ue = linear(h, f"{pre}.expert{e}.wu")
        ye = linear(jax.nn.silu(ge) * ue, f"{pre}.expert{e}.wd")
        out = out + jnp.where((top == e)[..., None], ye, 0.0)
    return out * gate_val


def forward(params, tokens, cfg: Config, linear=None):
    """Full-sequence causal LM forward. tokens: (B, S) int32 → logits f32.

    ``linear(h, name)`` abstracts weight application so the same graph serves
    the f32 path (default) and the quantized Pallas path (`forward_quant`).
    """
    if linear is None:
        def linear(h, name):
            return h @ params[name].T

    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)  # (B, S, d)
    ang = rope_angles(jnp.arange(s), cfg.head_dim, cfg.rope_base)
    mask = jnp.where(
        jnp.arange(s)[None, :] <= jnp.arange(s)[:, None], 0.0, -jnp.inf
    ).astype(jnp.float32)

    for i in range(cfg.layers):
        pre = f"layers.{i}"
        x = rmsnorm(h, params[f"{pre}.ln1"], cfg.eps)
        q = linear(x, f"{pre}.wq").reshape(b, s, cfg.heads, cfg.head_dim).transpose(0, 2, 1, 3)
        k = linear(x, f"{pre}.wk").reshape(b, s, cfg.heads, cfg.head_dim).transpose(0, 2, 1, 3)
        v = linear(x, f"{pre}.wv").reshape(b, s, cfg.heads, cfg.head_dim).transpose(0, 2, 1, 3)
        q, k = apply_rope(q, ang), apply_rope(k, ang)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(cfg.head_dim)
        att = jax.nn.softmax(att + mask[None, None], axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, cfg.d)
        h = h + linear(ctx, f"{pre}.wo")

        x = rmsnorm(h, params[f"{pre}.ln2"], cfg.eps)
        h = h + _mlp(x, params, pre, cfg, linear)

    h = rmsnorm(h, params["ln_f"], cfg.eps)
    return linear(h, "lm_head")


def forward_quant(qparams, fparams, tokens, cfg: Config, group: int = 64):
    """Quantized forward: every linear runs the Pallas fused dequant-matmul
    on int4 codes (Eq. 7). ``qparams[name] = (codes, scales, shifts, t)``;
    ``fparams`` holds the non-quantized tensors (embed, norms)."""

    def linear(h, name):
        codes, scales, shifts, t = qparams[name]
        flat = h.reshape(-1, h.shape[-1])
        y = dequant_matmul(flat, codes, scales, shifts, t, group=group)
        return y.reshape(*h.shape[:-1], codes.shape[0])

    params = dict(fparams)
    return forward(params, tokens, cfg, linear=linear)


def decode_step(params, token, pos, kv, cfg: Config, linear=None):
    """One autoregressive step with a functional KV cache.

    token: (B,) i32; pos: scalar i32; kv: (L, 2, B, H, C, hd) f32.
    Returns (logits (B, V), new kv).
    """
    if linear is None:
        def linear(h, name):
            return h @ params[name].T

    b = token.shape[0]
    cache_len = kv.shape[4]
    h = jnp.take(params["embed"], token, axis=0)  # (B, d)
    ang = rope_angles(pos[None], cfg.head_dim, cfg.rope_base)  # (1, hd/2)
    new_kv = kv

    for i in range(cfg.layers):
        pre = f"layers.{i}"
        x = rmsnorm(h, params[f"{pre}.ln1"], cfg.eps)
        q = linear(x, f"{pre}.wq").reshape(b, cfg.heads, 1, cfg.head_dim)
        k = linear(x, f"{pre}.wk").reshape(b, cfg.heads, 1, cfg.head_dim)
        v = linear(x, f"{pre}.wv").reshape(b, cfg.heads, 1, cfg.head_dim)
        q, k = apply_rope(q, ang), apply_rope(k, ang)
        new_kv = jax.lax.dynamic_update_slice(
            new_kv, k[None, None, :, :, 0, :][:, :, :, :, None, :],
            (i, 0, 0, 0, pos, 0))
        new_kv = jax.lax.dynamic_update_slice(
            new_kv, v[None, None, :, :, 0, :][:, :, :, :, None, :],
            (i, 1, 0, 0, pos, 0))
        keys = new_kv[i, 0]  # (B, H, C, hd)
        vals = new_kv[i, 1]
        att = jnp.einsum("bhd,bhkd->bhk", q[:, :, 0], keys) / np.sqrt(cfg.head_dim)
        live = jnp.arange(cache_len) <= pos
        att = jnp.where(live[None, None, :], att, -jnp.inf)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhk,bhkd->bhd", att, vals).reshape(b, cfg.d)
        h = h + linear(ctx, f"{pre}.wo")
        x = rmsnorm(h, params[f"{pre}.ln2"], cfg.eps)
        h = h + _mlp(x, params, pre, cfg, linear)

    h = rmsnorm(h, params["ln_f"], cfg.eps)
    return linear(h, "lm_head"), new_kv


def decode_step_quant(qparams, fparams, token, pos, kv, cfg: Config, group: int = 64):
    """W4A16 decode step: linears run the Pallas dequant-matmul kernel."""

    def linear(h, name):
        codes, scales, shifts, t = qparams[name]
        flat = h.reshape(-1, h.shape[-1])
        y = dequant_matmul(flat, codes, scales, shifts, t, group=group,
                           bm=min(16, flat.shape[0]))
        return y.reshape(*h.shape[:-1], codes.shape[0])

    return decode_step(dict(fparams), token, pos, kv, cfg, linear=linear)


def loss_fn(params, tokens, cfg: Config):
    """Next-token cross entropy over (B, S+1) token windows."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(params, inp, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
