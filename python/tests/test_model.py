"""L2 correctness: transformer forward/decode shapes and invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.model import (
    FAMILY, decode_step, decode_step_quant, forward, forward_quant,
    init_params, loss_fn, quantizable_names, weight_names,
)

CFG = FAMILY["pico"]


@pytest.fixture(scope="module")
def params():
    return {k: jnp.asarray(v) for k, v in init_params(CFG, 42).items()}


def test_weight_names_cover_params(params):
    assert set(weight_names(CFG)) == set(params.keys())
    qs = quantizable_names(CFG)
    assert "embed" not in qs and "ln_f" not in qs
    assert "lm_head" in qs and "layers.0.wq" in qs


def test_forward_shape_and_finite(params):
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, 256, (2, 16), dtype=np.int32))
    logits = forward(params, tokens, CFG)
    assert logits.shape == (2, 16, 256)
    assert bool(jnp.isfinite(logits).all())


def test_forward_is_causal(params):
    """Changing a future token must not change earlier logits."""
    rng = np.random.default_rng(1)
    t1 = rng.integers(0, 256, (1, 12), dtype=np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 7) % 256
    l1 = np.asarray(forward(params, jnp.asarray(t1), CFG))
    l2 = np.asarray(forward(params, jnp.asarray(t2), CFG))
    assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[0, -1], l2[0, -1])


def test_decode_matches_forward(params):
    """Autoregressive decode with KV cache reproduces the full forward."""
    rng = np.random.default_rng(2)
    toks = rng.integers(0, 256, (1, 10), dtype=np.int32)
    full = np.asarray(forward(params, jnp.asarray(toks), CFG))

    kv = jnp.zeros((CFG.layers, 2, 1, CFG.heads, 16, CFG.head_dim), jnp.float32)
    for pos in range(10):
        logits, kv = decode_step(params, jnp.asarray(toks[:, pos]),
                                 jnp.asarray(pos, jnp.int32), kv, CFG)
        assert_allclose(np.asarray(logits)[0], full[0, pos], rtol=2e-4, atol=2e-4)


def test_loss_decreases_direction(params):
    tokens = jnp.asarray(np.random.default_rng(3).integers(0, 256, (2, 33), dtype=np.int32))
    l = float(loss_fn(params, tokens, CFG))
    # Untrained: near ln(256) ≈ 5.55.
    assert 4.5 < l < 7.0


def test_moe_forward_runs():
    cfg = FAMILY["tiny_moe"]
    params = {k: jnp.asarray(v) for k, v in init_params(cfg, 5).items()}
    tokens = jnp.asarray(np.random.default_rng(5).integers(0, 256, (1, 8), dtype=np.int32))
    logits = forward(params, tokens, cfg)
    assert logits.shape == (1, 8, 256)
    assert bool(jnp.isfinite(logits).all())


def test_quant_forward_close_to_f32_at_8bit(params):
    """forward_quant (Pallas path) ≈ forward with 8-bit codes."""
    qnames = quantizable_names(CFG)
    qparams, fparams = {}, {}
    for k, v in params.items():
        if k in qnames:
            codes, scales, shifts = ref.rtn_quantize_ref(np.asarray(v), bits=8)
            qparams[k] = (jnp.asarray(codes, jnp.int32), scales, shifts, None)
        else:
            fparams[k] = v
    tokens = jnp.asarray(np.random.default_rng(6).integers(0, 256, (1, 8), dtype=np.int32))
    lq = np.asarray(forward_quant(qparams, fparams, tokens, CFG))
    lf = np.asarray(forward(params, tokens, CFG))
    # 8-bit weight quantization shifts logits only slightly.
    assert np.abs(lq - lf).max() < 0.3, np.abs(lq - lf).max()


def test_decode_quant_runs(params):
    qnames = quantizable_names(CFG)
    qparams, fparams = {}, {}
    for k, v in params.items():
        if k in qnames:
            codes, scales, shifts = ref.rtn_quantize_ref(np.asarray(v), bits=4)
            t = np.ones(v.shape[1], np.float32)
            qparams[k] = (jnp.asarray(codes, jnp.int8), scales, shifts, jnp.asarray(t))
        else:
            fparams[k] = v
    kv = jnp.zeros((CFG.layers, 2, 1, CFG.heads, 16, CFG.head_dim), jnp.float32)
    logits, kv2 = decode_step_quant(qparams, fparams, jnp.asarray([65], jnp.int32),
                                    jnp.asarray(0, jnp.int32), kv, CFG)
    assert logits.shape == (1, 256)
    assert bool(jnp.isfinite(logits).all())
    assert not np.allclose(np.asarray(kv2), 0.0)
