"""Interchange + data: .stz round trips and corpus determinism."""

import numpy as np
import pytest

from compile import corpus, stz


def test_stz_round_trip(tmp_path):
    tensors = {
        "w": np.random.default_rng(0).standard_normal((3, 5)).astype(np.float32),
        "codes": np.arange(12, dtype=np.int32).reshape(4, 3),
        "packed": np.frombuffer(b"\x00\xff\x10", dtype=np.uint8),
    }
    meta = {"config": {"name": "pico", "d": 64}, "note": "unit-test"}
    path = str(tmp_path / "t.stz")
    stz.save(path, tensors, meta)
    back, m = stz.load(path)
    assert m == meta
    for k in tensors:
        assert back[k].dtype == tensors[k].dtype
        assert np.array_equal(back[k], tensors[k])


def test_stz_rejects_bad_dtype(tmp_path):
    with pytest.raises(TypeError):
        stz.save(str(tmp_path / "x.stz"), {"w": np.zeros(3, np.float64)})


def test_stz_reserved_key(tmp_path):
    with pytest.raises(ValueError):
        stz.save(str(tmp_path / "x.stz"), {"__meta__": np.zeros(1, np.float32)})


def test_corpus_deterministic():
    a = corpus.generate("wiki", 10_000, 1001)
    b = corpus.generate("wiki", 10_000, 1001)
    assert a == b
    c = corpus.generate("wiki", 10_000, 1002)
    assert a != c


def test_corpus_registers_differ():
    w = corpus.generate("wiki", 50_000, 1)
    c = corpus.generate("c4", 50_000, 1)
    assert w != c
    # Register markers.
    assert b"== " in w and b"# " in c
    # Distributional difference: c4 register uses second person.
    assert c.count(b"you") > w.count(b"you")


def test_corpus_is_ascii():
    data = corpus.generate("c4", 20_000, 3)
    assert all(b < 128 for b in data)


def test_train_eval_split_disjoint_seeds():
    tr, ev = corpus.train_eval_split("wiki", 20_000, 5_000, 9)
    assert len(tr) == 20_000 and len(ev) == 5_000
    assert tr[:1000] != ev[:1000]
