"""AOT lowering smoke tests: HLO text is produced and parseable-shaped."""

import jax.numpy as jnp
import numpy as np

from compile.aot import (
    lower_dqmm, lower_forward, lower_sinq_quantize, shapes_of, to_hlo_text,
)
from compile.model import FAMILY


def test_forward_lowering_produces_hlo_text():
    cfg = FAMILY["pico"]
    text = to_hlo_text(lower_forward(cfg, shapes_of(cfg)))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # tokens + every weight appear as ENTRY parameters.
    entry = text[text.index("ENTRY") :]
    entry_body = entry[: entry.index("ROOT")]
    n_params = entry_body.count("parameter(")
    assert n_params == 1 + len(shapes_of(cfg))
    assert "s32[4,128]" in text.splitlines()[0]


def test_dqmm_lowering_dual_vs_single_differ():
    single = to_hlo_text(lower_dqmm(1, 1024, dual=False))
    dual = to_hlo_text(lower_dqmm(1, 1024, dual=True))
    assert single.startswith("HloModule") and dual.startswith("HloModule")
    # The dual variant carries the extra activation multiply.
    assert len(dual) >= len(single)


def test_sinq_quantize_lowering_executes():
    """Lowered Algorithm-1 HLO must agree with the ref when executed by XLA."""
    import jax
    from compile.kernels import ref

    lowered = lower_sinq_quantize(64, 128)
    compiled = lowered.compile()
    w = (np.random.default_rng(0).standard_t(4, (64, 128)) * 0.02).astype(np.float32)
    codes, scales, shifts, t = compiled(jnp.asarray(w))
    c2, s2, z2, t2 = ref.sinq_quantize_ref(w)
    assert np.array_equal(np.asarray(codes), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(t), np.asarray(t2), rtol=1e-5)
