"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.dequant_matmul import dequant_matmul, mxu_utilization_estimate, vmem_bytes
from compile.kernels.rtn import rtn_quantize
from compile.kernels.sinkhorn import sinkhorn_normalize

SETTINGS = dict(max_examples=12, deadline=None)


def llm_like(rows, cols, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_t(4, (rows, cols)) * 0.02
    w *= 0.3 + 3.0 * rng.random((1, cols))
    w *= 0.5 + 2.0 * rng.random((rows, 1))
    return w.astype(np.float32)


# ---------------------------------------------------------------- sinkhorn --

@settings(**SETTINGS)
@given(
    rows=st.sampled_from([8, 16, 48, 64]),
    cols=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31),
)
def test_sinkhorn_matches_ref(rows, cols, seed):
    w = llm_like(rows, cols, seed)
    s1, t1 = ref.sinkhorn_normalize_ref(w)
    s2, t2 = sinkhorn_normalize(jnp.asarray(w))

    def imb(s, t):
        wh = w / np.asarray(s)[:, None] / np.asarray(t)[None, :]
        sr, sc = wh.std(axis=1), wh.std(axis=0)
        return max(sr.max(), sc.max()) / min(sr.min(), sc.min())

    if np.allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-6):
        assert_allclose(np.asarray(t1), np.asarray(t2), rtol=1e-5, atol=1e-6)
    else:
        # f32 noise can flip the best-iterate argmin between the Pallas and
        # jnp paths when two iterates tie; both are valid Algorithm-1
        # solutions — require equal solution *quality* instead.
        assert abs(imb(s1, t1) - imb(s2, t2)) / imb(s1, t1) < 0.05


def test_sinkhorn_reduces_imbalance():
    w = llm_like(64, 128, 0)
    s, t = sinkhorn_normalize(jnp.asarray(w))
    wh = w / np.asarray(s)[:, None] / np.asarray(t)[None, :]

    def imb(m):
        sr, sc = m.std(axis=1), m.std(axis=0)
        return max(sr.max(), sc.max()) / min(sr.min(), sc.min())

    assert imb(wh) < imb(w) * 0.6


def test_sinkhorn_iters_parameter():
    w = llm_like(32, 64, 1)
    s0, t0 = sinkhorn_normalize(jnp.asarray(w), iters=1)
    s1, t1 = sinkhorn_normalize(jnp.asarray(w), iters=24)
    assert not np.allclose(np.asarray(s0), np.asarray(s1))


# --------------------------------------------------------------------- rtn --

@settings(**SETTINGS)
@given(
    rows=st.sampled_from([4, 16, 64]),
    groups=st.sampled_from([1, 2, 4]),
    bits=st.sampled_from([3, 4, 8]),
    seed=st.integers(0, 2**31),
)
def test_rtn_matches_ref(rows, groups, bits, seed):
    cols = 64 * groups
    w = llm_like(rows, cols, seed)
    q1, s1, z1 = ref.rtn_quantize_ref(w, bits=bits)
    q2, s2, z2 = rtn_quantize(jnp.asarray(w), bits=bits, block_rows=min(64, rows))
    assert np.array_equal(np.asarray(q1), np.asarray(q2))
    assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-5, atol=1e-6)


def test_rtn_codes_in_range():
    w = llm_like(16, 128, 3)
    q, s, z = rtn_quantize(jnp.asarray(w), bits=4)
    q = np.asarray(q)
    assert q.min() >= 0 and q.max() <= 15


def test_rtn_reconstruction_error_small():
    w = llm_like(16, 128, 4)
    q, s, z = rtn_quantize(jnp.asarray(w), bits=8)
    rec = np.asarray(ref.dequantize_ref(q, s, z))
    rel = np.abs(rec - w).max() / np.abs(w).max()
    assert rel < 0.01


# ----------------------------------------------------------- dequant matmul --

@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 4, 16]),
    n=st.sampled_from([64, 128]),
    k=st.sampled_from([64, 128, 256]),
    dual=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_dequant_matmul_matches_ref(b, n, k, dual, seed):
    rng = np.random.default_rng(seed)
    w = llm_like(n, k, seed)
    codes, scales, shifts = ref.rtn_quantize_ref(w, bits=4)
    x = rng.standard_normal((b, k)).astype(np.float32)
    t = (0.5 + rng.random(k)).astype(np.float32) if dual else None
    y_ref = np.asarray(ref.dequant_matmul_ref(x, codes, scales, shifts, t))
    y = np.asarray(
        dequant_matmul(
            jnp.asarray(x), jnp.asarray(codes, jnp.int8), scales, shifts,
            None if t is None else jnp.asarray(t), bm=1 if b == 1 else 4,
        )
    )
    assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)


def test_dequant_matmul_equals_dense_matmul():
    # End-to-end: fused kernel == x @ dequantized_Wᵀ.
    w = llm_like(64, 128, 9)
    codes, scales, shifts = ref.rtn_quantize_ref(w, bits=4)
    w_hat = np.asarray(ref.dequantize_ref(codes, scales, shifts))
    x = np.random.default_rng(9).standard_normal((8, 128)).astype(np.float32)
    y = np.asarray(dequant_matmul(jnp.asarray(x), jnp.asarray(codes, jnp.int8),
                                  scales, shifts, None, bm=8))
    assert_allclose(y, x @ w_hat.T, rtol=2e-4, atol=2e-4)


def test_dqmm_rejects_bad_blocks():
    w = llm_like(64, 128, 10)
    codes, scales, shifts = ref.rtn_quantize_ref(w, bits=4)
    x = np.zeros((3, 128), np.float32)  # 3 % bm(16→3?)  — b=3, bm=16→min→3? 3%3==0 ok
    with pytest.raises(AssertionError):
        dequant_matmul(jnp.asarray(x), jnp.asarray(codes, jnp.int8), scales,
                       shifts, None, bm=2)  # 3 % 2 != 0


def test_vmem_estimate_within_budget():
    # The §Perf structural target: one grid step fits in 16 MiB VMEM easily.
    assert vmem_bytes(16, 64, 64, 64) < 16 * 1024 * 1024
    assert 0.0 < mxu_utilization_estimate(16, 64, 64) <= 1.0


# ----------------------------------------------------------- full Algorithm 1

def test_sinq_quantize_ref_improves_over_rtn():
    w = llm_like(64, 128, 11)
    q, s, z = ref.rtn_quantize_ref(w, bits=4)
    rtn_err = float(((np.asarray(ref.dequantize_ref(q, s, z)) - w) ** 2).mean())
    qq, ss, zz, tt = ref.sinq_quantize_ref(w, bits=4)
    sinq_rec = np.asarray(ref.dequantize_ref(qq, ss, zz, tt))
    sinq_err = float(((sinq_rec - w) ** 2).mean())
    assert sinq_err < rtn_err
