//! Fig. 2b reproduction: Adam training of a single linear layer on a noisy
//! target induces `σ_col(W) ∝ 1/sqrt(s_x)` — the mechanism behind SINQ's
//! calibration-free activation-awareness.
//!
//! ```bash
//! cargo run --release --example adam_scaling
//! ```

use sinq::eval::r2::adam_scaling_experiment;

fn main() {
    println!("Training single linear layers with Adam on noisy targets…\n");
    println!("{:>6} {:>6} {:>7} {:>9} {:>7}", "nout", "nin", "steps", "slope", "R²");
    for (nout, nin, steps, seed) in
        [(32usize, 64usize, 800usize, 1u64), (32, 64, 2000, 2), (64, 128, 2000, 3), (64, 128, 4000, 4)]
    {
        let (slope, r2, _, _) = adam_scaling_experiment(nout, nin, steps, seed);
        println!("{nout:>6} {nin:>6} {steps:>7} {slope:>9.3} {r2:>7.3}");
    }
    println!(
        "\nPaper's prediction: slope → −0.5 at stationarity (σ_W ∝ 1/sqrt(s_x), Eq. 4).\n\
         Short runs are still converging; long runs land near −0.5 with high R²."
    );
}
