//! End-to-end driver (the repo's full-stack validation, recorded in
//! EXPERIMENTS.md): loads the **trained** `tiny` checkpoint produced by
//! `make artifacts`, pushes it through the coordinator pipeline with
//! RTN / HQQ / SINQ at 3 and 4 bits, evaluates held-out perplexity through
//! the PJRT forward artifact (L1 Pallas → L2 JAX → HLO → L3 Rust), and
//! asserts the paper's headline *shape*:
//!
//!   * ppl(SINQ) < ppl(RTN) at both widths, and
//!   * SINQ closes ≥ 25% of RTN's 3-bit gap to the FP baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use sinq::coordinator::pipeline::{self, PipelineOpts};
use sinq::coordinator::scheduler;
use sinq::quant::{Method, QuantConfig};
use sinq::report::tables::Ctx;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new("artifacts", false)?;
    let mw = ctx.load_model("tiny")?;
    println!(
        "model tiny: {} params, {} quantizable linears",
        mw.cfg.n_params(),
        mw.cfg.quantizable_names().len()
    );

    let fp_wiki = ctx.ppl_fp(&mw, "wiki")?;
    let fp_c4 = ctx.ppl_fp(&mw, "c4")?;
    println!("fp32 baseline: wiki {fp_wiki:.3}  c4 {fp_c4:.3}\n");

    let mut results: Vec<(String, u32, f64, f64)> = Vec::new();
    for bits in [3u32, 4] {
        for method in [Method::Rtn, Method::Hqq, Method::Sinq] {
            let cfg = QuantConfig::new(method, bits);
            let (qm, secs) = pipeline::run(&mw, &cfg, &PipelineOpts::default())?;
            let eff = qm.effective_weights();
            let wiki = ctx.ppl_eff(&mw, &eff, &qm.fvectors, "wiki")?;
            let c4 = ctx.ppl_eff(&mw, &eff, &qm.fvectors, "c4")?;
            println!(
                "{bits}-bit {:<6} wiki {wiki:.3}  c4 {c4:.3}  (quantized in {secs:.2}s)",
                method.name()
            );
            results.push((method.name().to_string(), bits, wiki, c4));
        }
    }

    // Headline-shape assertions (the end-to-end contract).
    let get = |m: &str, b: u32| {
        results.iter().find(|(n, bb, _, _)| n == m && *bb == b).map(|r| (r.2, r.3)).unwrap()
    };
    for bits in [3u32, 4] {
        let (rtn_w, rtn_c) = get("rtn", bits);
        let (sinq_w, sinq_c) = get("sinq", bits);
        assert!(
            sinq_w <= rtn_w && sinq_c <= rtn_c,
            "{bits}-bit: SINQ ({sinq_w:.3}/{sinq_c:.3}) must not lose to RTN ({rtn_w:.3}/{rtn_c:.3})"
        );
    }
    let (rtn3_w, _) = get("rtn", 3);
    let (sinq3_w, _) = get("sinq", 3);
    let gap_reduction = (rtn3_w - sinq3_w) / (rtn3_w - fp_wiki).max(1e-9);
    println!("\n3-bit wiki FP-gap reduction by SINQ vs RTN: {:.0}%", 100.0 * gap_reduction);
    assert!(
        gap_reduction >= 0.25,
        "expected ≥25% gap reduction, measured {:.0}%",
        100.0 * gap_reduction
    );

    // Exercise the serving path too: a short decode through the W4 artifact.
    let qcfg = QuantConfig::new(Method::Sinq, 4).with_aux(sinq::quant::AuxPrecision::F32);
    let qm = scheduler::quantize_simple(&mw, &qcfg, None)?;
    let mut dec = sinq::runtime::PjrtDecoder::new_w4(
        ctx.rt()?, &mw.cfg, &qm.layers, &qm.fweights, &qm.fvectors,
    )?;
    let out = dec.generate(b"The ancient river ", 24)?;
    println!("W4A16 decode sample: {:?}", String::from_utf8_lossy(&out));

    println!("\nEND-TO-END OK: all layers composed, headline shape holds.");
    Ok(())
}
