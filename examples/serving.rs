//! Serving demo: dynamic batching router + autoregressive decode on the
//! **native** backend — runs on any machine with zero artifacts (a
//! synthetic checkpoint/corpus stand in when `artifacts/` is absent).
//!
//! Part 1 spawns the [`BatchServer`] over a SINQ-4bit [`NativeBackend`]
//! (scoring requests batched through the fused dequant-matmul kernels) and
//! fires concurrent clients at it. Part 2 compares autoregressive decode
//! throughput, f32 dense vs fused W4 — the Table 6 workload in miniature,
//! no XLA required. Part 3 pushes the same requests through the
//! continuous-batching [`BatchDecoder`]: one weight-tile unpack per step is
//! shared by every live sequence, and the tokens match single-sequence
//! decode exactly. Part 4 boots the real HTTP/SSE endpoint
//! (`sinq::serve::Server`) on a loopback port and streams a generation
//! over a raw `TcpStream` — the same front-end `sinq serve --listen`
//! exposes.
//!
//! ```bash
//! cargo run --release --example serving            # works without artifacts
//! ```

use std::io::{Read, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sinq::backend::{BatchDecoder, NativeBackend};
use sinq::coordinator::scheduler::{load_or_synthetic, quantize_simple};
use sinq::coordinator::server::BatchServer;
use sinq::data::Corpus;
use sinq::quant::{Method, QuantConfig};
use sinq::serve::{ServeOpts, Server};

fn main() -> anyhow::Result<()> {
    let art = "artifacts";
    let model = "tiny";

    // Quantize once; NativeBackend is plain data, so the same packed model
    // feeds both the router (Part 1) and the decode comparison (Part 2).
    let mw = load_or_synthetic(art, model, 42);
    let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None)?;
    let w4 = NativeBackend::from_quantized(&qm);
    println!(
        "quantized: {}/{} linears packed (SINQ 4-bit)",
        w4.quantized_layer_count(),
        mw.cfg.quantizable_names().len()
    );

    // --- Part 1: batched scoring through the router ---------------------
    let server = BatchServer::spawn(
        {
            let qm = qm.clone();
            move || Ok(NativeBackend::from_quantized(&qm))
        },
        64,
        Duration::from_millis(4),
    );
    let corpus = Corpus::load_or_synthetic(art, "wiki", "eval");
    let windows: Vec<Vec<u8>> =
        corpus.eval_windows(128, 32).into_iter().map(|w| w.to_vec()).collect();
    let client = server.client();
    let t0 = Instant::now();
    let handles: Vec<_> = windows
        .into_iter()
        .map(|w| {
            let c = client.clone();
            std::thread::spawn(move || c.score(w).map(|m| m.rows))
        })
        .collect();
    for h in handles {
        h.join().unwrap()?;
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    println!(
        "router (native W4): {} requests in {} batches (avg {:.2}/batch), {:.0} tok/s",
        stats.requests,
        stats.batches,
        stats.requests as f64 / stats.batches.max(1) as f64,
        stats.tokens as f64 / secs,
    );

    // --- Part 2: decode loop, FP32 dense vs fused W4 --------------------
    let prompt = &corpus.data[..64];
    let gen_tokens = 64usize;
    let total = (prompt.len() + gen_tokens) as f64;

    let fp = NativeBackend::from_weights(&mw);
    let t0 = Instant::now();
    let out_fp = fp.generate(prompt, gen_tokens)?;
    let fp_tps = total / t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let out_w4 = w4.generate(prompt, gen_tokens)?;
    let w4_tps = total / t0.elapsed().as_secs_f64();

    println!("decode fp32:   {fp_tps:.0} tok/s  → {:?}", String::from_utf8_lossy(&out_fp[..32]));
    println!("decode W4A16:  {w4_tps:.0} tok/s  → {:?}", String::from_utf8_lossy(&out_w4[..32]));
    println!("W4/FP speed ratio: {:.2}x", w4_tps / fp_tps);

    // --- Part 3: continuous-batched generation --------------------------
    // 16 requests through 8 KV slots: slots are recycled as sequences
    // finish, and each step unpacks every weight tile once for all live
    // sequences instead of once per sequence.
    let n_req = 16usize;
    let (prompt_len, gen) = (16usize, 32usize);
    let reqs: Vec<Vec<u8>> = (0..n_req)
        .map(|i| corpus.data[i * 24..i * 24 + prompt_len].to_vec())
        .collect();

    let t0 = Instant::now();
    let mut sequential: Vec<Vec<u8>> = Vec::new();
    for r in &reqs {
        sequential.push(w4.generate(r, gen)?);
    }
    let seq_secs = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let mut dec = BatchDecoder::new(&w4, 8, prompt_len + gen + 1)?;
    for (i, r) in reqs.iter().enumerate() {
        dec.submit(i, r, gen)?;
    }
    let outs = dec.run()?;
    let batch_secs = t0.elapsed().as_secs_f64();
    for (o, s) in outs.iter().zip(&sequential) {
        assert_eq!(&o.tokens, s, "batched decode must match single-sequence decode");
    }
    let stats = dec.stats();
    println!(
        "decode {n_req} requests sequentially: {seq_secs:.2}s ({:.0} tok/s)",
        stats.tokens as f64 / seq_secs
    );
    println!(
        "decode {n_req} requests, 8 slots:     {batch_secs:.2}s ({:.0} tok/s, \
         peak batch {}, {} fused steps) → {:.2}x",
        stats.tokens as f64 / batch_secs,
        stats.peak_batch,
        stats.steps,
        seq_secs / batch_secs
    );

    // --- Part 4: the HTTP/SSE serving endpoint ---------------------------
    // The same packed weights behind a real network surface: the w4 engine
    // moves into the server (scoring router and streaming engine share it),
    // then one generation streams over a raw TcpStream and the Prometheus
    // metrics are read back — exactly what `sinq serve --listen` exposes.
    let server = Server::start_with_backend(
        Arc::new(w4),
        &ServeOpts { listen: "127.0.0.1:0".into(), ..ServeOpts::default() },
    )?;
    println!("\nHTTP/SSE endpoint listening on http://{}", server.addr);

    let body = r#"{"prompt": "the sinkhorn", "max_new_tokens": 12, "stream": true}"#;
    let mut conn = std::net::TcpStream::connect(server.addr)?;
    write!(
        conn,
        "POST /v1/generate HTTP/1.1\r\nHost: demo\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut sse = String::new();
    conn.read_to_string(&mut sse)?;
    let tokens = sse.matches("event: token").count();
    let done = sse.contains("event: done");
    println!("streamed generation: {tokens} SSE token events, done={done}");

    let mut conn = std::net::TcpStream::connect(server.addr)?;
    write!(conn, "GET /metrics HTTP/1.1\r\nHost: demo\r\n\r\n")?;
    let mut metrics = String::new();
    conn.read_to_string(&mut metrics)?;
    for line in metrics.lines().filter(|l| {
        l.starts_with("sinq_serve_tokens_generated_total")
            || l.starts_with("sinq_serve_tokens_per_sec")
    }) {
        println!("  {line}");
    }
    let shutdown = server.shutdown();
    println!(
        "endpoint served {} generation request(s), {} tokens; shut down cleanly",
        shutdown.gen_requests, shutdown.gen_tokens
    );
    Ok(())
}
