//! Serving demo: dynamic batching router + autoregressive decode.
//!
//! Spawns the [`BatchServer`] (scoring requests batched 4-way into one PJRT
//! execution), fires concurrent clients at it, then runs a W16-vs-W4 decode
//! comparison — the Table 6 workload in miniature.
//!
//! ```bash
//! make artifacts && cargo run --release --example serving
//! ```

use std::time::{Duration, Instant};

use sinq::coordinator::scheduler;
use sinq::coordinator::server::BatchServer;
use sinq::quant::{AuxPrecision, Method, QuantConfig};
use sinq::runtime::{PjrtDecoder, PjrtForward, PjrtRuntime};

fn main() -> anyhow::Result<()> {
    let art = "artifacts";
    let model = "tiny";

    // --- Part 1: batched scoring through the router ---------------------
    let server = BatchServer::spawn(
        {
            let (art, model) = (art.to_string(), model.to_string());
            move || {
                let rt = PjrtRuntime::cpu(&art)?;
                let mw = scheduler::load_family_member(&art, &model)?;
                PjrtForward::new(&rt, &mw.cfg, &mw.tensors, &mw.vectors)
            }
        },
        64,
        Duration::from_millis(4),
    );
    let corpus = sinq::data::Corpus::load(art, "wiki", "eval")?;
    let windows: Vec<Vec<u8>> =
        corpus.eval_windows(128, 32).into_iter().map(|w| w.to_vec()).collect();
    let client = server.client();
    let t0 = Instant::now();
    let handles: Vec<_> = windows
        .into_iter()
        .map(|w| {
            let c = client.clone();
            std::thread::spawn(move || c.score(w).map(|m| m.rows))
        })
        .collect();
    for h in handles {
        h.join().unwrap()?;
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    println!(
        "router: {} requests in {} batches (avg {:.2}/batch), {:.0} tok/s",
        stats.requests,
        stats.batches,
        stats.requests as f64 / stats.batches.max(1) as f64,
        stats.tokens as f64 / secs,
    );

    // --- Part 2: decode loop, FP vs W4A16 -------------------------------
    let rt = PjrtRuntime::cpu(art)?;
    let mw = scheduler::load_family_member(art, model)?;
    let prompt = &corpus.data[..64];

    let mut dec = PjrtDecoder::new_fp(&rt, &mw.cfg, &mw.tensors, &mw.vectors)?;
    let t0 = Instant::now();
    let out_fp = dec.generate(prompt, 64)?;
    let fp_tps = 128.0 / t0.elapsed().as_secs_f64();

    let qcfg = QuantConfig::new(Method::Sinq, 4).with_aux(AuxPrecision::F32);
    let qm = scheduler::quantize_simple(&mw, &qcfg, None)?;
    let mut dec4 = PjrtDecoder::new_w4(&rt, &mw.cfg, &qm.layers, &qm.fweights, &qm.fvectors)?;
    let t0 = Instant::now();
    let out_w4 = dec4.generate(prompt, 64)?;
    let w4_tps = 128.0 / t0.elapsed().as_secs_f64();

    println!("decode fp32:   {fp_tps:.0} tok/s  → {:?}", String::from_utf8_lossy(&out_fp[..32]));
    println!("decode W4A16:  {w4_tps:.0} tok/s  → {:?}", String::from_utf8_lossy(&out_w4[..32]));
    println!("W4/FP speed ratio: {:.2}x", w4_tps / fp_tps);
    Ok(())
}
