//! Quickstart: quantize one layer with SINQ and inspect what Algorithm 1 did.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//! Works with or without `make artifacts` (falls back to a synthetic
//! LLM-like matrix when no checkpoint is present).

use sinq::coordinator::scheduler::load_or_synthetic;
use sinq::quant::sinq::sinkhorn_normalize;
use sinq::quant::{metrics, quantize_matrix, Method, QuantConfig};
use sinq::tensor::stats;

fn main() -> anyhow::Result<()> {
    // 1. Get a weight matrix (a real trained layer if artifacts exist).
    let mw = load_or_synthetic("artifacts", "tiny", 42);
    let name = "layers.0.wo";
    let w = mw.tensors[name].clone();
    println!("layer {name}: {}×{}", w.rows, w.cols);
    println!("  initial imbalance I(W) = {:.2}", stats::imbalance(&w));

    // 2. Algorithm 1's normalization on its own.
    let sk = sinkhorn_normalize(&w, 24, (0.5, 2.0));
    println!("  after Sinkhorn        = {:.2}  (best iterate)", sk.imbalance);

    // 3. Quantize with the baselines and SINQ at 3 and 4 bits.
    for bits in [3u32, 4] {
        println!("\n  {bits}-bit weight reconstruction error (relative Frobenius):");
        for method in [Method::Rtn, Method::HadamardRtn, Method::Hqq, Method::Sinq] {
            let q = quantize_matrix(&w, &QuantConfig::new(method, bits), None)?;
            println!(
                "    {:<14} err = {:.5}   ({:.2} bits/weight incl. aux)",
                method.name(),
                metrics::weight_recon_error(&w, &q),
                q.bits_per_weight()
            );
        }
    }

    // 4. The dual-scale layer is a drop-in: dequantize or run Eq. 7.
    let q = quantize_matrix(&w, &QuantConfig::new(Method::Sinq, 4), None)?;
    let t = q.col_scale.as_ref().unwrap();
    println!(
        "\n  SINQ auxiliary sizes: scales {}×{}, shifts {}×{}, t[{}] (applied to activations, Eq. 7)",
        q.scales.rows, q.scales.cols,
        q.shifts.as_ref().unwrap().rows, q.shifts.as_ref().unwrap().cols,
        t.len(),
    );
    Ok(())
}
