//! Correctness pins for the paged KV allocator and prefix cache
//! (`sinq::backend::paged` through `BatchDecoder`):
//!
//! 1. Paged decode is **bit-identical** to the contiguous single-sequence
//!    KV cache, at kv32 AND kv8, batch sizes 1/3/8 with staggered
//!    completion, across page sizes.
//! 2. A prefix-cache hit reproduces the cold decode exactly while
//!    skipping prefill for the shared span.
//! 3. When the page pool runs dry the youngest sequence is preempted and
//!    re-admitted — everything still completes with unchanged tokens.
//! 4. Prefix-cache eviction under pool pressure never corrupts decode.

use sinq::backend::{BatchDecoder, EngineConfig, KvBits, NativeBackend, NativeDecoder};
use sinq::model::{ModelConfig, ModelWeights};

fn pico_backend(seed: u64) -> NativeBackend {
    let cfg = ModelConfig::family("pico").unwrap();
    NativeBackend::from_weights(&ModelWeights::synthetic(&cfg, seed))
}

/// Contiguous-KV reference tokens via the single-sequence decoder.
fn solo_tokens(be: &NativeBackend, kv: KvBits, prompt: &[u8], n: usize) -> Vec<u8> {
    let cfg = EngineConfig::new().with_max_context(prompt.len() + n + 1).with_kv_bits(kv);
    let mut dec = NativeDecoder::with_config(be, &cfg).expect("solo decoder");
    dec.generate(prompt, n).expect("solo decode")
}

// =====================================================================
// 1. Paged ≡ contiguous, kv32 + kv8, batch 1/3/8, staggered budgets
// =====================================================================

#[test]
fn paged_decode_bit_identical_to_contiguous_kv32_and_kv8() {
    let nb = pico_backend(71);
    // Varied prompt lengths and token budgets: sequences retire at
    // different steps, recycling slots whenever slots < requests.
    let reqs: [(&[u8], usize); 5] = [
        (b"the paged pool" as &[u8], 9),
        (b"sinkhorn", 4),
        (b"a", 12),
        (b"prefix caching decode", 6),
        (b"kv", 8),
    ];
    for kv in [KvBits::F32, KvBits::Q8] {
        let want: Vec<Vec<u8>> =
            reqs.iter().map(|(p, n)| solo_tokens(&nb, kv, p, *n)).collect();
        // Page size 4 forces many page-boundary crossings; 16 is the
        // serving default.
        for ps in [4usize, 16] {
            for slots in [1usize, 3, 8] {
                let cfg = EngineConfig::new()
                    .with_max_batch(slots)
                    .with_max_context(48)
                    .with_kv_bits(kv)
                    .with_page_size(ps);
                let mut dec = BatchDecoder::with_config(&nb, &cfg).unwrap();
                for (i, (p, n)) in reqs.iter().enumerate() {
                    dec.submit(i, p, *n).unwrap();
                }
                let outs = dec.run().unwrap();
                assert_eq!(outs.len(), reqs.len());
                for (i, out) in outs.iter().enumerate() {
                    assert_eq!(
                        out.tokens, want[i],
                        "kv {kv:?} page_size {ps} slots {slots}: request {i} diverged \
                         from the contiguous cache"
                    );
                }
            }
        }
    }
}

// =====================================================================
// 2. Prefix-hit decode ≡ cold decode, prefill skipped for the span
// =====================================================================

#[test]
fn prefix_hit_decode_matches_cold_decode_and_skips_prefill() {
    let nb = pico_backend(72);
    let prompt: &[u8] = b"shared prompt prefix!"; // 21 tokens, 5 full 4-pages
    let cfg =
        EngineConfig::new().with_max_batch(2).with_max_context(64).with_page_size(4);
    let mut dec = BatchDecoder::with_config(&nb, &cfg).unwrap();

    dec.submit(0, prompt, 8).unwrap();
    let cold = dec.run().unwrap().remove(0);
    assert_eq!(dec.stats().prefix_hits, 0, "first decode must be cold");
    assert_eq!(cold.steps, prompt.len() + 8 - 1, "cold decode prefills every position");
    assert!(dec.prefix_cached_pages() > 0, "retired sequence must donate its full pages");
    assert_eq!(cold.tokens, solo_tokens(&nb, KvBits::F32, prompt, 8));

    dec.submit(1, prompt, 8).unwrap();
    let hit = dec.run().unwrap().remove(0);
    assert_eq!(hit.tokens, cold.tokens, "prefix-hit tokens must match the cold decode");
    let stats = dec.stats();
    assert_eq!(stats.prefix_hits, 1);
    // 5 full pages of the 21-token prompt are shared (the 21st token is
    // fed so the engine has logits to continue from).
    assert_eq!(stats.prefix_tokens_reused, 20);
    assert_eq!(hit.steps, cold.steps - 20, "shared span must skip prefill rows");
}

// =====================================================================
// 3. Out-of-pages preemption: youngest re-queued, everything completes
// =====================================================================

#[test]
fn out_of_pages_preempts_youngest_and_all_requests_complete() {
    let nb = pico_backend(73);
    // Each request needs 7 pages of 4 (prompt + generated − 1 ≤ 26
    // positions); two of them cannot share an 8-page pool, so the pool
    // runs dry mid-decode and the younger sequence must be preempted.
    let cfg = EngineConfig::new()
        .with_max_batch(2)
        .with_max_context(32)
        .with_page_size(4)
        .with_pages(Some(8));
    let reqs: [(&[u8], usize); 2] =
        [(b"first long request" as &[u8], 9), (b"second long one!!", 9)];
    let mut dec = BatchDecoder::with_config(&nb, &cfg).unwrap();
    for (i, (p, n)) in reqs.iter().enumerate() {
        dec.submit(i, p, *n).unwrap();
    }
    let outs = dec.run().unwrap();
    assert_eq!(outs.len(), 2, "preemption must re-queue, not drop");
    for (i, (p, n)) in reqs.iter().enumerate() {
        assert_eq!(
            outs[i].tokens,
            solo_tokens(&nb, KvBits::F32, p, *n),
            "request {i} diverged after preemption/re-admission"
        );
    }
    let stats = dec.stats();
    assert_eq!(stats.completed, 2);
    assert!(stats.preempted >= 1, "an 8-page pool cannot hold both sequences");
}

// =====================================================================
// 4. Prefix eviction under pool pressure stays correct
// =====================================================================

#[test]
fn prefix_cache_eviction_under_pressure_never_corrupts_decode() {
    let nb = pico_backend(74);
    let cfg = EngineConfig::new()
        .with_max_batch(2)
        .with_max_context(24)
        .with_page_size(4)
        .with_pages(Some(8));
    let mut dec = BatchDecoder::with_config(&nb, &cfg).unwrap();
    // Ten distinct prompts through an 8-page pool: every retirement
    // donates pages, so later admissions must evict cached pages to claim.
    let mut want = Vec::new();
    for i in 0..10usize {
        let prompt = format!("distinct prompt {i:02}").into_bytes();
        want.push(solo_tokens(&nb, KvBits::F32, &prompt, 5));
        dec.submit(i, &prompt, 5).unwrap();
    }
    let outs = dec.run().unwrap();
    assert_eq!(outs.len(), 10);
    for (i, out) in outs.iter().enumerate() {
        assert_eq!(out.tokens, want[i], "request {i} diverged under cache pressure");
    }
    // Accounting invariant once the queue drains: every page is either
    // free or held by exactly one prefix-cache entry.
    assert_eq!(dec.live(), 0);
    assert_eq!(dec.pages_free() + dec.prefix_cached_pages(), dec.pages_total());

    // A repeat of an early (likely evicted) prompt still decodes exactly.
    dec.submit(100, b"distinct prompt 00", 5).unwrap();
    let out = dec.run().unwrap().remove(0);
    assert_eq!(out.tokens, want[0], "post-eviction repeat diverged");
}
