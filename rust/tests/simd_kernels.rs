//! Scalar-vs-SIMD parity suite for the dispatched dequant kernels.
//!
//! The scalar kernels are the oracle. Contracts held here:
//!
//! * unpacked codes and decoded grid levels are **bit-identical** across
//!   every supported ISA, for every bit width 2..=8 and awkward shapes
//!   (lengths not divisible by the lane width, 0/1 rows, group-boundary
//!   straddles);
//! * dot reductions agree to float tolerance and are deterministic, and
//!   the 2-/4-row dot microkernels are **bit-identical** per lane to the
//!   single-row `dot` within every ISA;
//! * the tensor-level entry points (`to_dense`, `dequant_matmul`,
//!   `dequant_matvec`, `dequant_matmul_shared`) agree across ISAs, and the
//!   matvec ≡ shared-row bitwise contract holds *within* each ISA;
//! * greedy decode through `BatchDecoder` emits **exactly** the same
//!   tokens under the scalar and SIMD kernels.
//!
//! Tests that flip the process-wide dispatch (`simd::force`) serialize on
//! one mutex and restore automatic selection on drop, so they cannot
//! interfere with each other or with the ISA-explicit tests.

use std::sync::{Mutex, MutexGuard, OnceLock};

use sinq::backend::simd::{self, Isa, KernelScratch};
use sinq::backend::{BatchDecoder, NativeBackend, QuantizedTensor};
use sinq::coordinator::scheduler::quantize_simple;
use sinq::fmt::pack;
use sinq::model::{ModelConfig, ModelWeights};
use sinq::quant::{quantize_matrix, Method, QuantConfig};
use sinq::tensor::{Matrix, Rng};

/// Serializes every test that calls `simd::force` (process-wide state).
fn isa_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Forces an ISA for the guard's lifetime; restores auto-selection on drop.
struct ForceGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for ForceGuard {
    fn drop(&mut self) {
        simd::force(None);
    }
}

fn force_isa(isa: Isa) -> ForceGuard {
    let guard = isa_lock();
    simd::force(Some(isa));
    ForceGuard(guard)
}

/// Every non-scalar ISA this host can execute.
fn simd_isas() -> Vec<Isa> {
    [Isa::Avx2, Isa::Neon].into_iter().filter(|&isa| simd::supported(isa)).collect()
}

// =====================================================================
// Kernel-level parity: bit-identical unpack and level decode
// =====================================================================

#[test]
fn unpack_and_levels_bit_identical_across_isas() {
    let mut rng = Rng::new(5);
    // Arbitrary non-trivial LUT covering all 256 codes.
    let lut: Vec<f32> = (0..256).map(|i| ((i * 37 + 11) % 101) as f32 * 0.173 - 8.5).collect();
    for bits in 2u32..=8 {
        // Lengths chosen to straddle lane widths (8/16/32), byte
        // boundaries for odd widths, and the degenerate 0/1 cases.
        for n in [0usize, 1, 2, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100, 127, 257] {
            let codes: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
            let packed = pack::pack(&codes, bits);

            let mut want_codes = vec![0u8; n];
            let mut want_levels = vec![0.0f32; n];
            simd::decode_levels_with(
                Isa::Scalar,
                &packed,
                bits,
                &lut,
                &mut want_codes,
                &mut want_levels,
            );
            assert_eq!(want_codes, codes, "scalar unpack disagrees with fmt::pack");

            for isa in simd_isas() {
                let mut got_codes = vec![0u8; n];
                let mut got_levels = vec![0.0f32; n];
                simd::decode_levels_with(isa, &packed, bits, &lut, &mut got_codes, &mut got_levels);
                assert_eq!(got_codes, codes, "{isa:?} unpack bits={bits} n={n}");
                let want_bits: Vec<u32> = want_levels.iter().map(|v| v.to_bits()).collect();
                let got_bits: Vec<u32> = got_levels.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got_bits, want_bits, "{isa:?} levels differ: bits={bits} n={n}");
            }
        }
    }
}

#[test]
fn standalone_unpack_dispatch_matches_scalar() {
    let mut rng = Rng::new(6);
    for bits in 2u32..=8 {
        for n in [1usize, 3, 16, 33, 64, 129] {
            let codes: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
            let packed = pack::pack(&codes, bits);
            for isa in simd_isas() {
                let mut out = vec![0u8; n];
                simd::unpack_into_with(isa, &packed, bits, &mut out);
                assert_eq!(out, codes, "{isa:?} bits={bits} n={n}");
            }
        }
    }
}

// =====================================================================
// Dot reduction: tolerance parity + determinism
// =====================================================================

#[test]
fn dot_matches_scalar_within_tolerance_and_is_deterministic() {
    let mut rng = Rng::new(9);
    for n in [0usize, 1, 3, 7, 8, 9, 31, 32, 33, 100, 500, 1024] {
        let a: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let want = simd::dot_with(Isa::Scalar, &a, &b);
        for isa in simd_isas() {
            let got = simd::dot_with(isa, &a, &b);
            let again = simd::dot_with(isa, &a, &b);
            assert_eq!(got.to_bits(), again.to_bits(), "{isa:?} dot must be deterministic");
            let tol = 1e-4 * (1.0 + (n as f32).sqrt());
            assert!(
                (got - want).abs() <= tol,
                "{isa:?} n={n}: dot {got} vs scalar {want} (tol {tol})"
            );
        }
    }
}

// =====================================================================
// Tensor-level parity under forced dispatch
// =====================================================================

#[test]
fn forced_isa_tensor_paths_agree_with_scalar() {
    let mut rng = Rng::new(77);
    // cols=100 with the default group size 64 → ragged tail group;
    // rows=37 → ragged 8-row tile; rows 0 and 1 of x exercise tiny m.
    let w = Matrix::randn(37, 100, 0.05, &mut rng);
    let x = Matrix::randn(5, 100, 1.0, &mut rng);
    for bits in 2u32..=8 {
        for method in [Method::Rtn, Method::Sinq] {
            let q = quantize_matrix(&w, &QuantConfig::new(method, bits), None).unwrap();
            let qt = QuantizedTensor::from_linear(&q).expect("packable layer");
            let label = format!("{} {bits}b", method.name());

            let guard = force_isa(Isa::Scalar);
            let dense_scalar = qt.to_dense();
            let mm_scalar = qt.dequant_matmul(&x, 2);
            let mv_scalar = qt.dequant_matvec(x.row(0));
            let sh_scalar = qt.dequant_matmul_shared(&x, 2);
            drop(guard);

            for isa in simd_isas() {
                let _guard = force_isa(isa);
                // Dense dequantization involves only unpack + LUT + the
                // scalar scale loop → must be bit-identical.
                assert_eq!(qt.to_dense().data, dense_scalar.data, "{label} {isa:?} to_dense");

                let mm = qt.dequant_matmul(&x, 2);
                let sh = qt.dequant_matmul_shared(&x, 2);
                let mv = qt.dequant_matvec(x.row(0));
                for (got, want) in [(&mm, &mm_scalar), (&sh, &sh_scalar)] {
                    let max_diff = got
                        .data
                        .iter()
                        .zip(&want.data)
                        .map(|(g, s)| (g - s).abs())
                        .fold(0.0f32, f32::max);
                    assert!(max_diff < 1e-3, "{label} {isa:?}: diverged by {max_diff}");
                }
                let max_diff = mv
                    .iter()
                    .zip(&mv_scalar)
                    .map(|(g, s)| (g - s).abs())
                    .fold(0.0f32, f32::max);
                assert!(max_diff < 1e-3, "{label} {isa:?} matvec: diverged by {max_diff}");

                // The batched-decode contract must hold within the ISA:
                // shared rows bitwise equal to per-row matvec.
                for r in 0..x.rows {
                    assert_eq!(
                        sh.row(r),
                        qt.dequant_matvec(x.row(r)).as_slice(),
                        "{label} {isa:?} row {r}: shared kernel drifted from matvec"
                    );
                }
            }
        }
    }
}

#[test]
fn forced_isa_handles_zero_and_one_row_activations() {
    let mut rng = Rng::new(78);
    let w = Matrix::randn(9, 48, 0.05, &mut rng);
    let q = quantize_matrix(&w, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
    let qt = QuantizedTensor::from_linear(&q).unwrap();
    let x1 = Matrix::randn(1, 48, 1.0, &mut rng);
    let x0 = Matrix::zeros(0, 48);
    for isa in std::iter::once(Isa::Scalar).chain(simd_isas()) {
        let _guard = force_isa(isa);
        let y1 = qt.dequant_matmul_shared(&x1, 1);
        assert_eq!((y1.rows, y1.cols), (1, 9), "{isa:?}");
        assert_eq!(y1.row(0), qt.dequant_matvec(x1.row(0)).as_slice(), "{isa:?}");
        let y0 = qt.dequant_matmul(&x0, 1);
        assert_eq!((y0.rows, y0.cols), (0, 9), "{isa:?}");
    }
}

// =====================================================================
// Multi-row microkernels: bitwise parity with the single-row oracle
// =====================================================================

/// The 2-/4-row dot kernels amortize the shared `a` operand but must keep
/// each lane's accumulator structure identical to the single-row `dot` —
/// bit-for-bit, per ISA — or batched decode drifts from single-sequence.
#[test]
fn multi_row_dots_bitwise_equal_single_row_dot() {
    let mut rng = Rng::new(41);
    for n in [1usize, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 64, 100, 257] {
        let a: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let xs: Vec<Vec<f32>> =
            (0..4).map(|_| (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
        for isa in std::iter::once(Isa::Scalar).chain(simd_isas()) {
            let want: Vec<u32> = xs.iter().map(|x| simd::dot_with(isa, &a, x).to_bits()).collect();
            let (d0, d1) = simd::dot2_with(isa, &a, &xs[0], &xs[1]);
            assert_eq!(d0.to_bits(), want[0], "{isa:?} n={n} dot2 lane 0");
            assert_eq!(d1.to_bits(), want[1], "{isa:?} n={n} dot2 lane 1");
            let d4 = simd::dot4_with(isa, &a, &xs[0], &xs[1], &xs[2], &xs[3]);
            for (lane, d) in d4.iter().enumerate() {
                assert_eq!(d.to_bits(), want[lane], "{isa:?} n={n} dot4 lane {lane}");
            }
        }
    }
}

/// The batched-decode contract across the whole dispatch surface: shared
/// matmul ≡ per-row matvec bit-for-bit at every forced ISA, thread count,
/// and batch size, on ragged shapes (cols=100 → tail group at g=64,
/// rows=37 → ragged row tile, batches 1/2/3/5 → 4-/2-/1-row microkernel
/// mixes).
#[test]
fn shared_matmul_bitwise_equals_matvec_across_threads_and_batches() {
    let mut rng = Rng::new(42);
    let w = Matrix::randn(37, 100, 0.05, &mut rng);
    let q = quantize_matrix(&w, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
    let qt = QuantizedTensor::from_linear(&q).unwrap();
    for isa in std::iter::once(Isa::Scalar).chain(simd_isas()) {
        let _guard = force_isa(isa);
        for batch in [1usize, 2, 3, 5] {
            let x = Matrix::randn(batch, 100, 1.0, &mut rng);
            for threads in [1usize, 2, 8] {
                let y = qt.dequant_matmul_shared(&x, threads);
                for r in 0..batch {
                    assert_eq!(
                        y.row(r),
                        qt.dequant_matvec(x.row(r)).as_slice(),
                        "{isa:?} batch={batch} threads={threads} row {r}: \
                         shared kernel drifted from matvec"
                    );
                }
            }
        }
    }
}

/// Same contract on a shape big enough (8·256·128 = 2^18) to cross the
/// parallel threshold, so the row tiles really run on the persistent
/// worker pool rather than inline.
#[test]
fn pooled_shared_matmul_bitwise_equals_matvec() {
    let mut rng = Rng::new(43);
    let w = Matrix::randn(256, 128, 0.05, &mut rng);
    let q = quantize_matrix(&w, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
    let qt = QuantizedTensor::from_linear(&q).unwrap();
    let x = Matrix::randn(8, 128, 1.0, &mut rng);
    let want = qt.dequant_matmul_shared(&x, 1);
    for threads in [2usize, 8] {
        let y = qt.dequant_matmul_shared(&x, threads);
        assert_eq!(y.data, want.data, "threads={threads} changed pooled tiling results");
    }
    for r in 0..x.rows {
        assert_eq!(want.row(r), qt.dequant_matvec(x.row(r)).as_slice(), "row {r}");
    }
}

/// Scratch reuse across interleaved shapes must not change results (the
/// batch decoder threads one `KernelScratch` through every layer's shared
/// matmul each step).
#[test]
fn shared_matmul_scratch_reuse_is_bitwise_stable() {
    let mut rng = Rng::new(44);
    let w_wide = Matrix::randn(19, 96, 0.05, &mut rng);
    let w_narrow = Matrix::randn(23, 48, 0.05, &mut rng);
    let qw = QuantizedTensor::from_linear(
        &quantize_matrix(&w_wide, &QuantConfig::new(Method::Sinq, 4), None).unwrap(),
    )
    .unwrap();
    let qn = QuantizedTensor::from_linear(
        &quantize_matrix(&w_narrow, &QuantConfig::new(Method::Rtn, 3), None).unwrap(),
    )
    .unwrap();
    let xw = Matrix::randn(5, 96, 1.0, &mut rng);
    let xn = Matrix::randn(3, 48, 1.0, &mut rng);
    let mut scratch = KernelScratch::new();
    for _ in 0..3 {
        let got = qw.dequant_matmul_shared_with(&xw, 2, &mut scratch);
        assert_eq!(got.data, qw.dequant_matmul_shared(&xw, 2).data, "wide layer");
        let got = qn.dequant_matmul_shared_with(&xn, 1, &mut scratch);
        assert_eq!(got.data, qn.dequant_matmul_shared(&xn, 1).data, "narrow layer");
    }
}

// =====================================================================
// Exact-token greedy parity through BatchDecoder
// =====================================================================

fn decode_tokens(nb: &NativeBackend) -> Vec<Vec<u8>> {
    let mut dec = BatchDecoder::new(nb, 2, 32).expect("batch decoder");
    let prompts: [&[u8]; 3] = [b"hello simd", b"kernel", b"dispatch!"];
    for (i, p) in prompts.iter().enumerate() {
        dec.submit(i, p, 6).expect("submit");
    }
    dec.run().expect("decode").into_iter().map(|o| o.tokens).collect()
}

#[test]
fn greedy_tokens_identical_scalar_vs_simd_through_batch_decoder() {
    let best = simd::detect();
    if best == Isa::Scalar {
        return; // nothing to compare against on this host
    }
    let cfg = ModelConfig::family("pico").unwrap();
    let mw = ModelWeights::synthetic(&cfg, 31);
    for method in [Method::Rtn, Method::Sinq] {
        let qm = quantize_simple(&mw, &QuantConfig::new(method, 4), None).unwrap();
        let nb = NativeBackend::from_quantized(&qm);
        assert!(nb.quantized_layer_count() > 0);

        let guard = force_isa(Isa::Scalar);
        let scalar_tokens = decode_tokens(&nb);
        drop(guard);

        let _guard = force_isa(best);
        let simd_tokens = decode_tokens(&nb);
        assert_eq!(
            scalar_tokens, simd_tokens,
            "greedy decode changed tokens between scalar and {best:?} ({method:?})"
        );
    }
}

// =====================================================================
// Dispatch bookkeeping
// =====================================================================

#[test]
fn forcing_an_isa_is_reflected_and_reverts() {
    {
        let _guard = force_isa(Isa::Scalar);
        assert_eq!(simd::active(), Isa::Scalar);
        assert_eq!(simd::kernel_name(), "scalar");
    }
    let _lock = isa_lock();
    assert!(simd::supported(simd::active()), "auto selection must be executable");
}

/// CI leg hook: with `SINQ_REQUIRE_SIMD=avx2` (set by the
/// `target-cpu=native` matrix leg on the x86_64 runner) this fails loudly
/// if the dispatcher silently fell back to scalar — the SIMD paths can
/// never rot unnoticed behind the fallback.
#[test]
fn required_kernel_is_active() {
    let Ok(want) = std::env::var("SINQ_REQUIRE_SIMD") else {
        return;
    };
    if want.trim().is_empty() {
        return;
    }
    let _lock = isa_lock();
    assert_eq!(
        simd::kernel_name(),
        want.trim(),
        "SINQ_REQUIRE_SIMD demands the '{}' kernel but the dispatcher selected '{}'",
        want.trim(),
        simd::kernel_name()
    );
}
