//! Parity suite for the unified transformer core (`backend/fwd.rs`).
//!
//! The refactor collapsed four hand-synchronized copies of the block math
//! into one core; this suite is the gate that the collapse changed nothing:
//!
//! * **Frozen golden oracle** — `frozen_forward` below is a verbatim copy
//!   of the *pre-refactor* `Forward::forward` loop (and its fused-kernel
//!   twin). The refactored paths must reproduce it **bit for bit**: the
//!   f32 reference, the dense native backend, and the fused quantized
//!   forward (same process, same dispatched ISA, so bitwise comparison is
//!   well-defined).
//! * **Decode parity at `--kv-bits 32`** — single-sequence and batched
//!   decode emit exactly the same greedy tokens, as before the refactor.
//! * **`--kv-bits 8` tolerance gates** — teacher-forced decoder perplexity
//!   within 5 % of the f32 cache, greedy-argmax flips ≤ 10 %, and ≥ 3×
//!   smaller KV slots; kv8 decodes end to end.
//! * **Seeded sampling** — deterministic across runs and across batch
//!   placements; greedy stays the bit-identical default.

use std::collections::BTreeMap;

use sinq::backend::{
    BatchDecoder, EngineConfig, KvBits, NativeBackend, NativeDecoder, QuantizedTensor, SampleCfg,
};
use sinq::coordinator::scheduler::quantize_simple;
use sinq::eval::log_prob;
use sinq::model::forward::Forward;
use sinq::model::{ModelConfig, ModelWeights};
use sinq::quant::{Method, QuantConfig};
use sinq::tensor::Matrix;

// =====================================================================
// The frozen pre-refactor forward (golden oracle — do not "improve")
// =====================================================================

/// One linear of the frozen forward: dense f32 or a packed tensor driven
/// by the fused kernels (exactly what the pre-refactor
/// `NativeBackend::forward_with` dispatched per layer).
enum FrozenLinear {
    Dense(Matrix),
    Quant(QuantizedTensor),
}

impl FrozenLinear {
    fn matmul(&self, x: &Matrix) -> Matrix {
        match self {
            FrozenLinear::Dense(w) => x.matmul_nt(w),
            FrozenLinear::Quant(q) => q.dequant_matmul(x, 1),
        }
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn add_inplace(a: &mut Matrix, b: &Matrix) {
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

fn rmsnorm(x: &Matrix, gain: &[f32], eps: f32) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let row = x.row(i);
        let ms: f32 = row.iter().map(|&v| v * v).sum::<f32>() / x.cols as f32;
        let r = 1.0 / (ms + eps).sqrt();
        for (j, (&v, &g)) in row.iter().zip(gain).enumerate() {
            out.data[i * x.cols + j] = v * r * g;
        }
    }
    out
}

fn rope(x: &Matrix, cos: &Matrix, sin: &Matrix, heads: usize) -> Matrix {
    let s = x.rows;
    let hd = x.cols / heads;
    let half = hd / 2;
    let mut out = Matrix::zeros(s, x.cols);
    for p in 0..s {
        for h in 0..heads {
            let off = h * hd;
            for i in 0..half {
                let (c, sn) = (cos.at(p, i), sin.at(p, i));
                let x1 = x.at(p, off + i);
                let x2 = x.at(p, off + half + i);
                *out.at_mut(p, off + i) = x1 * c - x2 * sn;
                *out.at_mut(p, off + half + i) = x2 * c + x1 * sn;
            }
        }
    }
    out
}

/// Verbatim pre-refactor full-sequence forward: head-outer attention loop,
/// reused `att_row` buffer, MoE routing inline. Any bitwise drift in the
/// unified core shows up against this.
fn frozen_forward(
    cfg: &ModelConfig,
    weights: &BTreeMap<String, FrozenLinear>,
    vectors: &BTreeMap<String, Vec<f32>>,
    tokens: &[u8],
) -> Matrix {
    let s = tokens.len();
    let d = cfg.d;
    let hd = cfg.head_dim();

    let embed = match &weights["embed"] {
        FrozenLinear::Dense(m) => m,
        FrozenLinear::Quant(_) => panic!("embedding stays dense"),
    };
    let mut h = Matrix::zeros(s, d);
    for (p, &tok) in tokens.iter().enumerate() {
        h.row_mut(p).copy_from_slice(embed.row(tok as usize));
    }

    let half = hd / 2;
    let mut cos = Matrix::zeros(s, half);
    let mut sin = Matrix::zeros(s, half);
    for p in 0..s {
        for i in 0..half {
            let inv = (cfg.rope_base as f64).powf(-(i as f64) * 2.0 / hd as f64);
            let ang = p as f64 * inv;
            *cos.at_mut(p, i) = ang.cos() as f32;
            *sin.at_mut(p, i) = ang.sin() as f32;
        }
    }

    for l in 0..cfg.layers {
        let pre = format!("layers.{l}");
        let x = rmsnorm(&h, &vectors[&format!("{pre}.ln1")], cfg.eps);
        let q = weights[&format!("{pre}.wq")].matmul(&x);
        let k = weights[&format!("{pre}.wk")].matmul(&x);
        let v = weights[&format!("{pre}.wv")].matmul(&x);
        let (q, k) = (rope(&q, &cos, &sin, cfg.heads), rope(&k, &cos, &sin, cfg.heads));

        let mut ctx = Matrix::zeros(s, d);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut att_row = vec![0.0f32; s];
        for head in 0..cfg.heads {
            let off = head * hd;
            for qi in 0..s {
                let qrow = &q.row(qi)[off..off + hd];
                let mut maxv = f32::NEG_INFINITY;
                for (ki, a) in att_row.iter_mut().enumerate().take(qi + 1) {
                    let krow = &k.row(ki)[off..off + hd];
                    let mut dot = 0.0f32;
                    for t in 0..hd {
                        dot += qrow[t] * krow[t];
                    }
                    *a = dot * scale;
                    maxv = maxv.max(*a);
                }
                let mut denom = 0.0f32;
                for a in att_row.iter_mut().take(qi + 1) {
                    *a = (*a - maxv).exp();
                    denom += *a;
                }
                let out = ctx.row_mut(qi);
                for ki in 0..=qi {
                    let wgt = att_row[ki] / denom;
                    let vrow = &v.row(ki)[off..off + hd];
                    for t in 0..hd {
                        out[off + t] += wgt * vrow[t];
                    }
                }
            }
        }
        let o = weights[&format!("{pre}.wo")].matmul(&ctx);
        add_inplace(&mut h, &o);

        let x = rmsnorm(&h, &vectors[&format!("{pre}.ln2")], cfg.eps);
        let y = if cfg.n_experts == 0 {
            let g = weights[&format!("{pre}.wg")].matmul(&x);
            let u = weights[&format!("{pre}.wu")].matmul(&x);
            let mut act = Matrix::zeros(s, cfg.ffn);
            for i in 0..s * cfg.ffn {
                act.data[i] = silu(g.data[i]) * u.data[i];
            }
            weights[&format!("{pre}.wd")].matmul(&act)
        } else {
            let logits = weights[&format!("{pre}.router")].matmul(&x);
            let mut out = Matrix::zeros(x.rows, cfg.d);
            for i in 0..x.rows {
                let row = logits.row(i);
                let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> = row.iter().map(|&v| (v - maxv).exp()).collect();
                let denom: f32 = exps.iter().sum();
                let (top, _) = exps
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap();
                let gate = exps[top] / denom;
                let xr = Matrix::from_vec(1, x.cols, x.row(i).to_vec());
                let g = weights[&format!("{pre}.expert{top}.wg")].matmul(&xr);
                let u = weights[&format!("{pre}.expert{top}.wu")].matmul(&xr);
                let mut act = Matrix::zeros(1, cfg.ffn);
                for j in 0..cfg.ffn {
                    act.data[j] = silu(g.data[j]) * u.data[j];
                }
                let yv = weights[&format!("{pre}.expert{top}.wd")].matmul(&act);
                for (o, &val) in out.row_mut(i).iter_mut().zip(yv.row(0)) {
                    *o = gate * val;
                }
            }
            out
        };
        add_inplace(&mut h, &y);
    }

    let hf = rmsnorm(&h, &vectors["ln_f"], cfg.eps);
    weights["lm_head"].matmul(&hf)
}

fn dense_map(tensors: &BTreeMap<String, Matrix>) -> BTreeMap<String, FrozenLinear> {
    tensors
        .iter()
        .map(|(n, m)| (n.clone(), FrozenLinear::Dense(m.clone())))
        .collect()
}

fn assert_bitwise(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape mismatch");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} differs ({x} vs {y}) — the unified core drifted \
             from the pre-refactor arithmetic"
        );
    }
}

fn pico() -> ModelWeights {
    ModelWeights::synthetic(&ModelConfig::family("pico").unwrap(), 21)
}

// =====================================================================
// Bitwise golden gates
// =====================================================================

#[test]
fn reference_forward_is_bitwise_identical_to_pre_refactor_golden() {
    for (family, seed) in [("pico", 21u64), ("tiny_moe", 14)] {
        let cfg = ModelConfig::family(family).unwrap();
        let mw = ModelWeights::synthetic(&cfg, seed);
        let tokens = b"golden oracle: unified core parity";
        let golden = frozen_forward(&mw.cfg, &dense_map(&mw.tensors), &mw.vectors, tokens);
        let refactored = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors).forward(tokens, None);
        assert_bitwise(&golden, &refactored, &format!("{family}: Forward::forward"));
    }
}

#[test]
fn dense_native_forward_is_bitwise_identical_to_pre_refactor_golden() {
    for (family, seed) in [("pico", 21u64), ("tiny_moe", 14)] {
        let cfg = ModelConfig::family(family).unwrap();
        let mw = ModelWeights::synthetic(&cfg, seed);
        let tokens = b"native dense bitwise";
        let golden = frozen_forward(&mw.cfg, &dense_map(&mw.tensors), &mw.vectors, tokens);
        let nb = NativeBackend::from_weights(&mw);
        let refactored = nb.forward(tokens).unwrap();
        assert_bitwise(&golden, &refactored, &format!("{family}: NativeBackend::forward"));
    }
}

#[test]
fn fused_quantized_forward_is_bitwise_identical_to_pre_refactor_golden() {
    let mw = pico();
    for method in [Method::Rtn, Method::Sinq] {
        for bits in [4u32, 8] {
            let qm = quantize_simple(&mw, &QuantConfig::new(method, bits), None).unwrap();
            // Rebuild the frozen weight map exactly as the pre-refactor
            // backend did: dense fweights, packed codes where packable.
            let mut weights = dense_map(&qm.fweights);
            for (n, q) in &qm.layers {
                let lin = match QuantizedTensor::from_linear(q) {
                    Some(t) => FrozenLinear::Quant(t),
                    None => FrozenLinear::Dense(q.effective_weight()),
                };
                weights.insert(n.clone(), lin);
            }
            let tokens = b"fused golden";
            let golden = frozen_forward(&qm.cfg, &weights, &qm.fvectors, tokens);
            let nb = NativeBackend::from_quantized(&qm);
            assert!(nb.quantized_layer_count() > 0);
            let refactored = nb.forward(tokens).unwrap();
            assert_bitwise(
                &golden,
                &refactored,
                &format!("{} {bits}b quantized forward", method.name()),
            );
        }
    }
}

// =====================================================================
// Decode parity at --kv-bits 32
// =====================================================================

#[test]
fn kv32_decode_parity_native_vs_batched_vs_forward() {
    let mw = pico();
    let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
    let nb = NativeBackend::from_quantized(&qm);
    let tokens = b"decode parity gate";

    // Incremental decode tracks the full forward (pre-refactor gate).
    let full = nb.forward(tokens).unwrap();
    let cfg = EngineConfig::new().with_max_context(tokens.len() + 1).with_kv_bits(KvBits::F32);
    let mut dec = NativeDecoder::with_config(&nb, &cfg).unwrap();
    let mut last = Vec::new();
    for &t in tokens.iter() {
        last = dec.step(t).unwrap();
    }
    let drift = last
        .iter()
        .zip(full.row(tokens.len() - 1))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(drift < 1e-3, "incremental decode drifted {drift} from the full forward");

    // Exact-token parity: batched greedy == single-sequence greedy, at
    // every batch size and with staggered completion.
    for slots in [1usize, 3, 8] {
        let cfg = EngineConfig::new()
            .with_max_batch(slots)
            .with_max_context(48)
            .with_kv_bits(KvBits::F32);
        let mut batch = BatchDecoder::with_config(&nb, &cfg).unwrap();
        let reqs: [(&[u8], usize); 5] =
            [(b"one" as &[u8], 7), (b"second prompt", 3), (b"3rd", 9), (b"four!", 5), (b"5", 6)];
        for (i, (p, n)) in reqs.iter().enumerate() {
            batch.submit(i, p, *n).unwrap();
        }
        let outs = batch.run().unwrap();
        for (i, (p, n)) in reqs.iter().enumerate() {
            let single_cfg = EngineConfig::new().with_max_context(48).with_kv_bits(KvBits::F32);
            let mut single = NativeDecoder::with_config(&nb, &single_cfg).unwrap();
            let want = single.generate(p, *n).unwrap();
            assert_eq!(outs[i].tokens, want, "slots={slots} request {i}");
        }
    }
}

// =====================================================================
// --kv-bits 8 tolerance gates
// =====================================================================

/// Teacher-forced NLL + argmax stream of the incremental decoder at one
/// KV precision.
fn decoder_nll(be: &NativeBackend, windows: &[&[u8]], kv: KvBits) -> (f64, Vec<usize>) {
    let mut nll = 0.0f64;
    let mut count = 0usize;
    let mut tops = Vec::new();
    for w in windows {
        let cfg = EngineConfig::new().with_max_context(w.len() + 1).with_kv_bits(kv);
        let mut dec = NativeDecoder::with_config(be, &cfg).unwrap();
        for p in 0..w.len() - 1 {
            let logits = dec.step(w[p]).unwrap();
            nll -= log_prob(&logits, w[p + 1]);
            count += 1;
            let top = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            tops.push(top);
        }
    }
    (nll / count as f64, tops)
}

#[test]
fn kv8_perplexity_and_flip_rate_within_tolerance() {
    let mw = pico();
    let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
    for nb in [NativeBackend::from_weights(&mw), NativeBackend::from_quantized(&qm)] {
        // Deterministic synthetic "corpus": byte windows with mixed content.
        let data: Vec<u8> = (0..192u32).map(|i| (i * 37 % 96 + 32) as u8).collect();
        let windows: Vec<&[u8]> = data.chunks_exact(48).collect();
        let (nll32, top32) = decoder_nll(&nb, &windows, KvBits::F32);
        let (nll8, top8) = decoder_nll(&nb, &windows, KvBits::Q8);
        let (ppl32, ppl8) = (nll32.exp(), nll8.exp());
        let rel = (ppl8 - ppl32).abs() / ppl32;
        assert!(
            rel < 0.05,
            "kv8 perplexity gate: {ppl8:.4} vs {ppl32:.4} ({:.2}% > 5%)",
            100.0 * rel
        );
        let flips = top32.iter().zip(&top8).filter(|(a, b)| a != b).count();
        let flip_rate = flips as f64 / top32.len() as f64;
        assert!(
            flip_rate <= 0.10,
            "kv8 flip gate: {flips}/{} argmax flips ({:.1}% > 10%)",
            top32.len(),
            100.0 * flip_rate
        );
    }
}

#[test]
fn kv8_quarters_kv_memory_and_decodes_end_to_end() {
    let mw = pico();
    let nb = NativeBackend::from_weights(&mw)
        .with_engine(EngineConfig::new().with_kv_bits(KvBits::Q8));
    let cfg = EngineConfig::new().with_max_context(256);
    let d32 = NativeDecoder::with_config(&nb, &cfg.with_kv_bits(KvBits::F32)).unwrap();
    let d8 = NativeDecoder::with_config(&nb, &cfg.with_kv_bits(KvBits::Q8)).unwrap();
    let ratio = d32.kv_bytes() as f64 / d8.kv_bytes() as f64;
    assert!(ratio >= 3.0, "kv8 slot reduction only {ratio:.2}x (gate: ≥ 3x)");

    // The backend flag flows through generate and generate_batch.
    let single = nb.generate(b"kv8 end to end", 10).unwrap();
    assert_eq!(single.len(), 10);
    let prompts: Vec<&[u8]> = vec![b"kv8 end to end", b"second kv8"];
    let batched = nb.generate_batch(&prompts, &[10, 6]).unwrap();
    assert_eq!(batched[0], single, "batched kv8 decode must match single kv8 decode");
    assert_eq!(batched[1].len(), 6);
}

// =====================================================================
// Seeded sampling determinism
// =====================================================================

#[test]
fn seeded_sampling_deterministic_across_runs_and_placements() {
    let mw = pico();
    let nb = NativeBackend::from_weights(&mw);
    let sample = Some(SampleCfg { temperature: 0.7, top_k: 20, seed: 424242 });

    let run = |slots: usize, noise_first: bool| -> Vec<u8> {
        let mut dec = BatchDecoder::new(&nb, slots, 64).unwrap();
        let mut next = 0usize;
        if noise_first {
            dec.submit(next, b"noise traffic", 9).unwrap();
            next += 1;
        }
        let target = next;
        dec.submit_sampled(target, b"sample this prompt", 12, sample).unwrap();
        if !noise_first {
            dec.submit(target + 1, b"noise traffic", 9).unwrap();
        }
        let outs = dec.run().unwrap();
        outs.into_iter().find(|o| o.id == target).unwrap().tokens
    };

    let a = run(1, false);
    let b = run(1, false);
    assert_eq!(a, b, "same seed, same run shape: tokens must repeat");
    let c = run(4, true);
    assert_eq!(a, c, "batch placement and admission order must not change sampled tokens");
    assert_eq!(a.len(), 12);

    // Greedy requests remain bit-identical regardless of sampled neighbors.
    let greedy_solo = nb.generate(b"noise traffic", 9).unwrap();
    let mut dec = BatchDecoder::new(&nb, 2, 64).unwrap();
    dec.submit_sampled(0, b"sample this prompt", 12, sample).unwrap();
    dec.submit(1, b"noise traffic", 9).unwrap();
    let outs = dec.run().unwrap();
    assert_eq!(outs[1].tokens, greedy_solo);
}

// =====================================================================
// Per-phase profiler must observe, never perturb
// =====================================================================

#[test]
fn profiler_on_off_greedy_tokens_bit_identical() {
    use sinq::obs::profiler;

    let mw = pico();
    let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
    let nb = NativeBackend::from_quantized(&qm);
    let prompt = b"profiler parity gate";
    let gen = 14;

    // The scoped timers only read clocks around the unchanged math, so the
    // decoded stream must be bit-identical with profiling on and off —
    // both through the single-sequence decoder and the batched engine.
    let mut off_dec = NativeDecoder::new(&nb, 64).unwrap();
    let off = off_dec.generate(prompt, gen).unwrap();

    profiler::set_enabled(true);
    profiler::reset();
    let mut on_dec = NativeDecoder::new(&nb, 64).unwrap();
    let on = on_dec.generate(prompt, gen).unwrap();

    let mut batch = BatchDecoder::new(&nb, 2, 64).unwrap();
    batch.submit(0, prompt, gen).unwrap();
    let batched_on = batch.run().unwrap().remove(0).tokens;

    let snap = profiler::snapshot();
    profiler::set_enabled(false);

    assert_eq!(on, off, "profiling must not change greedy decode tokens");
    assert_eq!(batched_on, off, "profiling must not change batched decode tokens");

    // While enabled, the timers actually accumulated a sane breakdown.
    assert!(snap.enabled);
    assert!(snap.total_nanos > 0, "enabled profiler recorded nothing");
    assert!(!snap.phases.is_empty());
    let pct_sum: f64 = snap.phases.iter().map(|p| p.pct).sum();
    assert!((pct_sum - 100.0).abs() < 1e-6, "phase percentages sum to {pct_sum}");
    profiler::reset();
}
