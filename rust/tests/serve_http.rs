//! End-to-end tests for the HTTP/SSE serving front-end (`sinq::serve`):
//! boot the listener on port 0, talk to it over raw `TcpStream`s, and hold
//! the streamed token path to the exactness contract — the concatenated
//! SSE token events must be bit-identical to `NativeDecoder::generate`
//! (via `NativeBackend::generate`) for the same prompt and weights.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use sinq::backend::{self, BackendKind, BackendSpec, NativeBackend};
use sinq::quant::{Method, QuantConfig};
use sinq::serve::{ServeOpts, Server};
use sinq::util::json::Json;

/// Spec for a deterministic synthetic pico model (no artifacts anywhere),
/// optionally quantized in-process.
fn pico_spec(method: Option<Method>) -> BackendSpec {
    let mut spec = BackendSpec::new(BackendKind::Native, "/nonexistent", "pico");
    spec.quantize = method.map(|m| QuantConfig::new(m, 4));
    spec
}

fn start_server(spec: &BackendSpec, opts: &ServeOpts) -> Server {
    Server::start(spec, opts).expect("server start")
}

/// One parsed HTTP response.
struct Response {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Response {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        Json::parse(std::str::from_utf8(&self.body).expect("utf8 body")).expect("json body")
    }
}

/// Issue one request over a raw TcpStream and read the whole response
/// (every server response is `Connection: close`, so EOF delimits it).
fn request(addr: &str, method: &str, path: &str, body: &str) -> Response {
    let mut stream = TcpStream::connect(addr).expect("connect");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("write request");
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    parse_response(&raw)
}

fn parse_response(raw: &[u8]) -> Response {
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("header/body separator");
    let head = std::str::from_utf8(&raw[..split]).expect("utf8 headers");
    let mut lines = head.split("\r\n");
    let status_line = lines.next().expect("status line");
    let status: u16 = status_line.split_whitespace().nth(1).expect("code").parse().unwrap();
    let headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        .collect();
    Response { status, headers, body: raw[split + 4..].to_vec() }
}

/// One SSE event: `(event name, parsed data)`.
type SseEvent = (String, Json);

fn parse_sse_events(body: &[u8]) -> Vec<SseEvent> {
    let text = std::str::from_utf8(body).expect("utf8 SSE body");
    text.split("\n\n")
        .filter(|chunk| !chunk.trim().is_empty())
        .map(|chunk| {
            let mut event = String::new();
            let mut data = String::new();
            for line in chunk.lines() {
                if let Some(v) = line.strip_prefix("event: ") {
                    event = v.to_string();
                } else if let Some(v) = line.strip_prefix("data: ") {
                    data = v.to_string();
                }
            }
            (event, Json::parse(&data).expect("event data json"))
        })
        .collect()
}

/// Collect the token bytes out of a streamed-generation SSE body.
fn sse_tokens(events: &[SseEvent]) -> Vec<u8> {
    events
        .iter()
        .filter(|(name, _)| name == "token")
        .map(|(_, data)| data.get("token").and_then(Json::as_usize).expect("token field") as u8)
        .collect()
}

fn generate_body(prompt: &str, max_new: usize, stream: bool) -> String {
    Json::obj(vec![
        ("prompt", Json::Str(prompt.into())),
        ("max_new_tokens", Json::Num(max_new as f64)),
        ("stream", Json::Bool(stream)),
    ])
    .to_string_compact()
}

// =====================================================================
// Streamed-token exactness: SSE events vs NativeDecoder::generate
// =====================================================================

#[test]
fn streamed_sse_tokens_bit_identical_to_native_decoder() {
    // RTN and SINQ at 4 bits, per the acceptance criteria.
    for method in [Method::Rtn, Method::Sinq] {
        let spec = pico_spec(Some(method));
        // Reference: the same spec built directly; `NativeBackend::generate`
        // runs the single-sequence NativeDecoder path.
        let reference = backend::build_native(&spec).expect("reference backend");
        let prompt = "the quantized stream";
        let expected = reference.generate(prompt.as_bytes(), 9).expect("reference tokens");

        let server = start_server(&spec, &ServeOpts::default());
        let addr = server.addr.to_string();
        let res = request(&addr, "POST", "/v1/generate", &generate_body(prompt, 9, true));
        assert_eq!(res.status, 200, "{:?}", String::from_utf8_lossy(&res.body));
        assert_eq!(res.header("content-type"), Some("text/event-stream"));

        let events = parse_sse_events(&res.body);
        assert_eq!(
            sse_tokens(&events),
            expected,
            "SSE tokens diverged from NativeDecoder::generate ({method:?})"
        );
        let (last_name, last_data) = events.last().expect("terminal event");
        assert_eq!(last_name, "done");
        assert_eq!(last_data.get("finish_reason").and_then(Json::as_str), Some("length"));
        assert_eq!(last_data.get("generated_tokens").and_then(Json::as_usize), Some(9));
        assert_eq!(
            last_data.get("prompt_tokens").and_then(Json::as_usize),
            Some(prompt.len())
        );

        // Non-streamed response carries the identical token sequence.
        let res = request(&addr, "POST", "/v1/generate", &generate_body(prompt, 9, false));
        assert_eq!(res.status, 200);
        let tokens: Vec<u8> = res
            .json()
            .get("tokens")
            .and_then(Json::as_arr)
            .expect("tokens array")
            .iter()
            .map(|v| v.as_usize().unwrap() as u8)
            .collect();
        assert_eq!(tokens, expected);

        // The metrics endpoint must show the engine actually moved.
        let res = request(&addr, "GET", "/metrics", "");
        assert_eq!(res.status, 200);
        let text = String::from_utf8(res.body).unwrap();
        let tps = metric_value(&text, "sinq_serve_tokens_per_sec");
        assert!(tps > 0.0, "tokens/sec not reported:\n{text}");
        let generated = metric_value(&text, "sinq_serve_tokens_generated_total");
        assert_eq!(generated as usize, 18, "two 9-token generations");
        assert!(text.contains("sinq_serve_ttft_seconds_count 2"), "{text}");

        let stats = server.shutdown();
        assert_eq!(stats.gen_completed, 2);
        assert_eq!(stats.gen_tokens, 18);
    }
}

fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find(|l| l.starts_with(name) && !l.starts_with(&format!("{name}_")))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("metric {name} missing:\n{text}"))
}

/// Extract `error.message` from the unified error envelope
/// `{"error": {"message": ..., "type": ...}}`.
fn error_message(res: &Response) -> String {
    res.json()
        .get("error")
        .and_then(|e| e.get("message"))
        .and_then(Json::as_str)
        .unwrap_or("")
        .to_string()
}

/// Extract `error.type` from the unified error envelope.
fn error_type(res: &Response) -> String {
    res.json()
        .get("error")
        .and_then(|e| e.get("type"))
        .and_then(Json::as_str)
        .unwrap_or("")
        .to_string()
}

// =====================================================================
// Structured errors: malformed JSON and over-KV-capacity → 400
// =====================================================================

#[test]
fn malformed_json_body_returns_400_with_error_field() {
    let server = start_server(&pico_spec(None), &ServeOpts::default());
    let addr = server.addr.to_string();
    for (path, body) in [
        ("/v1/generate", "{not json"),
        ("/v1/generate", "{\"max_new_tokens\": 4}"), // missing prompt
        ("/v1/score", "[1,2,"),
        ("/v1/score", "{\"tokens\": [1, 999]}"), // out-of-range byte
    ] {
        let res = request(&addr, "POST", path, body);
        assert_eq!(res.status, 400, "{path} body {body:?}");
        let err = error_message(&res);
        assert!(!err.is_empty(), "{path}: error.message field missing");
        assert_eq!(error_type(&res), "invalid_request_error", "{path}");
    }
    // The connection-level failure path must also answer 400, not hang up.
    let res = request(&addr, "POST", "/v1/generate", "");
    assert_eq!(res.status, 400);
    server.shutdown();
}

#[test]
fn over_capacity_prompt_returns_400_with_kv_error_text() {
    let opts = ServeOpts { max_context: 8, ..ServeOpts::default() };
    let server = start_server(&pico_spec(None), &opts);
    let addr = server.addr.to_string();
    let res = request(
        &addr,
        "POST",
        "/v1/generate",
        &generate_body("a prompt far longer than eight positions", 4, false),
    );
    assert_eq!(res.status, 400);
    let err = error_message(&res);
    assert!(err.contains("KV"), "expected the decoder's KV-capacity text, got: {err}");
    assert!(err.contains("capacity"), "{err}");

    // A fitting request on the same server still works afterwards.
    let res = request(&addr, "POST", "/v1/generate", &generate_body("ok", 3, false));
    assert_eq!(res.status, 200);
    server.shutdown();
}

// =====================================================================
// Backpressure: 503 + Retry-After when --max-queue is saturated
// =====================================================================

#[test]
fn backpressure_503_when_max_queue_saturated() {
    let opts = ServeOpts {
        max_batch: 1,      // one KV slot: the second request must queue
        max_queue: 1,      // ... and the third must be refused
        max_context: 4096, // room for a generation long enough to pin the slot
        ..ServeOpts::default()
    };
    let server = start_server(&pico_spec(None), &opts);
    let addr = server.addr.to_string();

    // Request A: long streamed generation occupying the only slot. Read
    // its SSE preamble + first token so we know it is decoding (4000 steps
    // keep the slot busy for the rest of the test).
    let a = TcpStream::connect(&addr).expect("connect A");
    let mut a_writer = a.try_clone().unwrap();
    let body = generate_body("aaaa", 4000, true);
    write!(
        a_writer,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut a_reader = BufReader::new(a);
    let mut line = String::new();
    a_reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("HTTP/1.1 200"), "{line}");
    loop {
        line.clear();
        a_reader.read_line(&mut line).unwrap();
        if line.starts_with("event: token") {
            break;
        }
    }

    // Request B: accepted into the queue (slot busy). Its SSE status line
    // is written as soon as the submission is accepted, so reading it
    // guarantees B occupies the backlog before C is sent.
    let b = TcpStream::connect(&addr).expect("connect B");
    let mut b_writer = b.try_clone().unwrap();
    let body = generate_body("bbbb", 5, true);
    write!(
        b_writer,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut b_reader = BufReader::new(b);
    let mut line = String::new();
    b_reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("HTTP/1.1 200"), "B must be accepted: {line}");

    // Request C: the backlog (B) sits at --max-queue = 1 → 503 + Retry-After.
    let res = request(&addr, "POST", "/v1/generate", &generate_body("cccc", 5, false));
    assert_eq!(res.status, 503, "{}", String::from_utf8_lossy(&res.body));
    let retry_after: u64 = res
        .header("retry-after")
        .expect("503 must carry Retry-After")
        .parse()
        .expect("Retry-After must be an integer number of seconds");
    assert!(
        (1..=60).contains(&retry_after),
        "Retry-After must sit in the documented 1..=60s band, got {retry_after}"
    );
    let err = error_message(&res);
    assert!(err.contains("queue"), "{err}");
    assert_eq!(error_type(&res), "overloaded_error");

    // Drain A and B: the refused request must not poison queued work.
    let mut rest = Vec::new();
    a_reader.read_to_end(&mut rest).unwrap();
    let a_events = parse_sse_events(&rest); // headers were consumed line-wise
    assert_eq!(sse_tokens(&a_events).len(), 4000 - 1, "one token was read manually");
    let mut b_rest = Vec::new();
    b_reader.read_to_end(&mut b_rest).unwrap();
    let b_events = parse_sse_events(&b_rest);
    assert_eq!(sse_tokens(&b_events).len(), 5, "queued request must still complete");
    assert!(b_events.iter().any(|(name, _)| name == "done"));
    server.shutdown();
}

// =====================================================================
// SSE keep-alive heartbeats while a stream sits queued behind a hog
// =====================================================================

#[test]
fn queued_stream_receives_ping_heartbeats_without_corrupting_frames() {
    let opts = ServeOpts {
        max_batch: 1,       // one KV slot: the heartbeat request must queue
        max_queue: 4,
        max_context: 4096,
        keepalive_idle_ms: 5, // force pings while the backlog waits
        ..ServeOpts::default()
    };
    let spec = pico_spec(None);
    let reference = backend::build_native(&spec).expect("reference backend");
    let expected = reference.generate(b"heartbeat", 3).expect("reference tokens");
    let server = start_server(&spec, &opts);
    let addr = server.addr.to_string();

    // Hog: a long streamed generation pinning the only slot.
    let a = TcpStream::connect(&addr).expect("connect hog");
    let mut a_writer = a.try_clone().unwrap();
    let body = generate_body("aaaa", 4000, true);
    write!(
        a_writer,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut a_reader = BufReader::new(a);
    let mut line = String::new();
    a_reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("HTTP/1.1 200"), "{line}");
    loop {
        line.clear();
        a_reader.read_line(&mut line).unwrap();
        if line.starts_with("event: token") {
            break;
        }
    }

    // Heartbeat request: queued behind the hog, its SSE stream idles past
    // the 5ms keep-alive window, so the handler must emit `: ping`
    // comment frames until tokens start flowing.
    let b = TcpStream::connect(&addr).expect("connect queued");
    let mut b_writer = b.try_clone().unwrap();
    let body = generate_body("heartbeat", 3, true);
    write!(
        b_writer,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut b_reader = BufReader::new(b);
    let mut line = String::new();
    b_reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("HTTP/1.1 200"), "queued stream must be accepted: {line}");
    loop {
        line.clear();
        b_reader.read_line(&mut line).unwrap();
        assert!(line.ends_with("\n"), "headers must not truncate");
        if line == "\r\n" {
            break; // end of response headers; SSE frames follow
        }
    }
    let mut raw = Vec::new();
    b_reader.read_to_end(&mut raw).unwrap();
    let text = std::str::from_utf8(&raw).expect("utf8 SSE body");

    // Heartbeats arrived, and every frame is either entirely a comment
    // (`: ping`) or entirely an event — a ping must never split a token
    // frame's `event:`/`data:` lines.
    let chunks: Vec<&str> =
        text.split("\n\n").filter(|c| !c.trim().is_empty()).collect();
    let pings = chunks.iter().filter(|c| c.lines().all(|l| l.starts_with(':'))).count();
    assert!(pings >= 1, "expected at least one `: ping` frame, body:\n{text}");
    for chunk in &chunks {
        let comment_lines = chunk.lines().filter(|l| l.starts_with(':')).count();
        assert!(
            comment_lines == 0 || comment_lines == chunk.lines().count(),
            "heartbeat interleaved inside an event frame:\n{chunk}"
        );
    }

    // Stripping comment frames leaves a well-formed, token-exact stream.
    let event_body: String = chunks
        .iter()
        .filter(|c| !c.lines().all(|l| l.starts_with(':')))
        .map(|c| format!("{c}\n\n"))
        .collect();
    let events = parse_sse_events(event_body.as_bytes());
    assert_eq!(sse_tokens(&events), expected, "heartbeats must not perturb tokens");
    let (last_name, last_data) = events.last().expect("terminal event");
    assert_eq!(last_name, "done");
    assert_eq!(last_data.get("finish_reason").and_then(Json::as_str), Some("length"));

    // Drain the hog so shutdown is clean.
    let mut rest = Vec::new();
    a_reader.read_to_end(&mut rest).unwrap();
    server.shutdown();
}

// =====================================================================
// Scoring through the BatchServer queue + health endpoint
// =====================================================================

#[test]
fn score_endpoint_matches_direct_logprobs() {
    let spec = pico_spec(None);
    let server = start_server(&spec, &ServeOpts::default());
    let addr = server.addr.to_string();
    let text = "hello scoring endpoint";
    let body = Json::obj(vec![("text", Json::Str(text.into()))]).to_string_compact();
    let res = request(&addr, "POST", "/v1/score", &body);
    assert_eq!(res.status, 200, "{}", String::from_utf8_lossy(&res.body));
    let json = res.json();
    assert_eq!(json.get("tokens").and_then(Json::as_usize), Some(text.len()));
    let logprobs = json.get("logprobs").and_then(Json::as_arr).expect("logprobs");
    assert_eq!(logprobs.len(), text.len() - 1);

    // Same arithmetic as computing from the backend's own logits.
    let mut reference = backend::build_native(&spec).expect("backend");
    let logits = sinq::eval::LogitsEngine::logits(&mut reference, text.as_bytes()).unwrap();
    let tokens = text.as_bytes();
    for (p, lp) in logprobs.iter().enumerate() {
        let want = sinq::eval::log_prob(logits.row(p), tokens[p + 1]);
        let got = lp.as_f64().unwrap();
        assert!((got - want).abs() < 1e-9, "logprob[{p}]: {got} vs {want}");
    }
    let ppl = json.get("ppl").and_then(Json::as_f64).unwrap();
    assert!(ppl.is_finite() && ppl > 1.0);

    // Single-token sequences cannot be scored.
    let res = request(&addr, "POST", "/v1/score", "{\"tokens\": [65]}");
    assert_eq!(res.status, 400);
    server.shutdown();
}

#[test]
fn healthz_reports_engine_shape_and_unknown_paths_404() {
    let opts = ServeOpts { max_batch: 3, max_context: 64, ..ServeOpts::default() };
    let server = start_server(&pico_spec(None), &opts);
    let addr = server.addr.to_string();
    let res = request(&addr, "GET", "/healthz", "");
    assert_eq!(res.status, 200);
    let json = res.json();
    assert_eq!(json.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(json.get("backend").and_then(Json::as_str), Some("native"));
    assert_eq!(json.get("slots").and_then(Json::as_usize), Some(3));
    assert_eq!(json.get("kv_capacity").and_then(Json::as_usize), Some(64));
    assert_eq!(json.get("kv_bits").and_then(Json::as_usize), Some(32));
    let kv_bytes = json.get("kv_bytes_per_page").and_then(Json::as_usize).unwrap();
    assert!(kv_bytes > 0, "healthz must report resident KV bytes per page");
    // Page-pool accounting: 3 slots x ceil(64/16) pages each, all free.
    assert_eq!(json.get("kv_page_size").and_then(Json::as_usize), Some(16));
    assert_eq!(json.get("kv_pages_total").and_then(Json::as_usize), Some(12));
    assert_eq!(json.get("kv_pages_free").and_then(Json::as_usize), Some(12));
    assert_eq!(json.get("prefix_cached_pages").and_then(Json::as_usize), Some(0));

    let res = request(&addr, "GET", "/nope", "");
    assert_eq!(res.status, 404);
    assert_eq!(error_type(&res), "not_found_error");
    let res = request(&addr, "GET", "/v1/generate", "");
    assert_eq!(res.status, 405);
    assert_eq!(error_type(&res), "method_not_allowed");
    assert_eq!(request(&addr, "GET", "/v1/completions", "").status, 405);
    assert_eq!(request(&addr, "POST", "/healthz", "").status, 405);
    server.shutdown();
}

#[test]
fn kv8_server_reports_smaller_pages_and_generates() {
    let opts = ServeOpts { max_batch: 2, max_context: 64, ..ServeOpts::default() };
    // Baseline: f32 cache.
    let server32 = start_server(&pico_spec(None), &opts);
    let bytes32 = request(&server32.addr.to_string(), "GET", "/healthz", "")
        .json()
        .get("kv_bytes_per_page")
        .and_then(Json::as_usize)
        .unwrap();
    server32.shutdown();

    // Same shape at --kv-bits 8.
    let mut spec = pico_spec(None);
    spec.engine = spec.engine.with_kv_bits(sinq::backend::KvBits::Q8);
    let server = start_server(&spec, &opts);
    let addr = server.addr.to_string();
    let json = request(&addr, "GET", "/healthz", "").json();
    assert_eq!(json.get("kv_bits").and_then(Json::as_usize), Some(8));
    let bytes8 = json.get("kv_bytes_per_page").and_then(Json::as_usize).unwrap();
    assert!(
        bytes32 as f64 / bytes8 as f64 >= 3.0,
        "kv8 page {bytes8}B not ≥3x smaller than f32 page {bytes32}B"
    );

    // End-to-end decode through the quantized cache.
    let res = request(&addr, "POST", "/v1/generate", &generate_body("kv8 over http", 6, true));
    assert_eq!(res.status, 200, "{:?}", String::from_utf8_lossy(&res.body));
    let events = parse_sse_events(&res.body);
    assert_eq!(sse_tokens(&events).len(), 6);
    let text = String::from_utf8(request(&addr, "GET", "/metrics", "").body).unwrap();
    assert_eq!(metric_value(&text, "sinq_serve_kv_bits") as usize, 8);
    assert_eq!(metric_value(&text, "sinq_serve_kv_bytes_per_page") as usize, bytes8);
    server.shutdown();
}

// =====================================================================
// Seeded sampling over HTTP
// =====================================================================

fn sampled_body(prompt: &str, max_new: usize, temperature: f64, top_k: usize, seed: u64) -> String {
    Json::obj(vec![
        ("prompt", Json::Str(prompt.into())),
        ("max_new_tokens", Json::Num(max_new as f64)),
        ("temperature", Json::Num(temperature)),
        ("top_k", Json::Num(top_k as f64)),
        ("seed", Json::Num(seed as f64)),
    ])
    .to_string_compact()
}

fn response_tokens(res: &Response) -> Vec<u8> {
    res.json()
        .get("tokens")
        .and_then(Json::as_arr)
        .expect("tokens array")
        .iter()
        .map(|v| v.as_usize().unwrap() as u8)
        .collect()
}

#[test]
fn sampled_generation_is_seeded_and_greedy_stays_default() {
    let spec = pico_spec(None);
    let server = start_server(&spec, &ServeOpts::default());
    let addr = server.addr.to_string();

    // High temperature + no top-k cut keeps the distribution flat enough
    // that two independent seed streams cannot plausibly coincide for 12
    // straight tokens.
    let a = request(&addr, "POST", "/v1/generate", &sampled_body("sample me", 12, 1.8, 0, 7));
    assert_eq!(a.status, 200, "{:?}", String::from_utf8_lossy(&a.body));
    let b = request(&addr, "POST", "/v1/generate", &sampled_body("sample me", 12, 1.8, 0, 7));
    assert_eq!(response_tokens(&a), response_tokens(&b), "same seed must repeat");

    let c = request(&addr, "POST", "/v1/generate", &sampled_body("sample me", 12, 1.8, 0, 8));
    assert_ne!(response_tokens(&a), response_tokens(&c), "different seed should diverge");

    // temperature 0 (and omitting it) both stay exactly greedy.
    let greedy = backend::build_native(&spec).unwrap().generate(b"sample me", 8).unwrap();
    let t0 = request(&addr, "POST", "/v1/generate", &sampled_body("sample me", 8, 0.0, 16, 7));
    assert_eq!(response_tokens(&t0), greedy);
    let plain = request(&addr, "POST", "/v1/generate", &generate_body("sample me", 8, false));
    assert_eq!(response_tokens(&plain), greedy);

    // Malformed sampling fields answer 400.
    let res = request(&addr, "POST", "/v1/generate", "{\"prompt\":\"x\",\"temperature\":-1}");
    assert_eq!(res.status, 400);
    let res = request(&addr, "POST", "/v1/generate", "{\"prompt\":\"x\",\"top_k\":1.5}");
    assert_eq!(res.status, 400);
    server.shutdown();
}

// =====================================================================
// Client disconnect mid-stream → slot eviction at the step boundary
// =====================================================================

#[test]
fn disconnected_sse_client_evicts_slot_instead_of_decoding_to_max_new() {
    let opts = ServeOpts { max_batch: 1, max_context: 8192, ..ServeOpts::default() };
    let server = start_server(&pico_spec(None), &opts);
    let addr = server.addr.to_string();

    // Start a very long streamed generation and hang up after the first
    // token: decoding all 8000 tokens would take far longer than this test
    // allows, so completion of the test itself proves eviction worked.
    {
        let stream = TcpStream::connect(&addr).expect("connect");
        let mut writer = stream.try_clone().unwrap();
        let body = generate_body("disconnect me", 8000, true);
        write!(
            writer,
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("HTTP/1.1 200"), "{line}");
        loop {
            line.clear();
            reader.read_line(&mut line).unwrap();
            if line.starts_with("event: token") {
                break;
            }
        }
        // Dropping reader/writer closes the socket with unread data queued.
    }

    // The engine evicts at the next step boundary once the handler's SSE
    // write fails; poll the metrics until the eviction lands.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        let text = String::from_utf8(request(&addr, "GET", "/metrics", "").body).unwrap();
        let evicted = metric_value(&text, "sinq_serve_evicted_total") as usize;
        if evicted >= 1 {
            assert_eq!(evicted, 1);
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "slot was never evicted after client disconnect:\n{text}"
        );
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    // The freed slot serves new work immediately (it would otherwise be
    // pinned for the rest of the 8000-token decode).
    let res = request(&addr, "POST", "/v1/generate", &generate_body("after evict", 3, false));
    assert_eq!(res.status, 200);
    let stats = server.shutdown();
    assert_eq!(stats.gen_completed, 1, "only the post-eviction request completes");
}

// =====================================================================
// HTTP keep-alive: one connection, many requests, idle timeout
// =====================================================================

/// Read one Content-Length-framed response (keep-alive framing: the
/// connection stays open, so EOF cannot delimit the body).
fn read_framed_response(r: &mut BufReader<TcpStream>) -> Response {
    let mut head = String::new();
    loop {
        let mut line = String::new();
        r.read_line(&mut line).expect("read header line");
        assert!(!line.is_empty(), "connection closed mid-headers");
        if line == "\r\n" {
            break;
        }
        head.push_str(&line);
    }
    let mut lines = head.lines();
    let status_line = lines.next().expect("status line");
    let status: u16 = status_line.split_whitespace().nth(1).expect("code").parse().unwrap();
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        .collect();
    let res = Response { status, headers, body: Vec::new() };
    let len: usize = res.header("content-length").expect("content-length").parse().unwrap();
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).expect("read body");
    Response { body, ..res }
}

#[test]
fn keep_alive_serves_many_requests_on_one_connection() {
    let server = start_server(&pico_spec(None), &ServeOpts::default());
    let addr = server.addr.to_string();
    let mut stream = TcpStream::connect(&addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // Three health checks over the same socket.
    for i in 0..3 {
        write!(stream, "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\r\n")
            .unwrap();
        let res = read_framed_response(&mut reader);
        assert_eq!(res.status, 200, "request {i} on the shared connection");
        assert_eq!(res.header("connection"), Some("keep-alive"), "request {i}");
        assert_eq!(res.json().get("status").and_then(Json::as_str), Some("ok"));
    }

    // A non-streamed generation works over the same socket too, and its
    // tokens match a fresh-connection request exactly.
    let body = generate_body("keepalive", 4, false);
    write!(
        stream,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let res = read_framed_response(&mut reader);
    assert_eq!(res.status, 200);
    assert_eq!(res.header("connection"), Some("keep-alive"));
    let kept_tokens: Vec<u8> = res
        .json()
        .get("tokens")
        .and_then(Json::as_arr)
        .expect("tokens")
        .iter()
        .map(|v| v.as_usize().unwrap() as u8)
        .collect();
    let fresh = request(&addr, "POST", "/v1/generate", &generate_body("keepalive", 4, false));
    let fresh_tokens: Vec<u8> = fresh
        .json()
        .get("tokens")
        .and_then(Json::as_arr)
        .expect("tokens")
        .iter()
        .map(|v| v.as_usize().unwrap() as u8)
        .collect();
    assert_eq!(kept_tokens, fresh_tokens, "keep-alive must not change decode results");

    // An error response also keeps the connection when asked to.
    write!(stream, "GET /nope HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\r\n").unwrap();
    let res = read_framed_response(&mut reader);
    assert_eq!(res.status, 404);
    assert_eq!(res.header("connection"), Some("keep-alive"));

    // Without the header the server answers Connection: close and hangs up.
    write!(stream, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let res = read_framed_response(&mut reader);
    assert_eq!(res.status, 200);
    assert_eq!(res.header("connection"), Some("close"));
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).expect("read to close");
    assert!(rest.is_empty(), "server must close after a non-keep-alive request");
    server.shutdown();
}

#[test]
fn keep_alive_idle_timeout_closes_the_connection() {
    let opts = ServeOpts { keepalive_idle_ms: 150, ..ServeOpts::default() };
    let server = start_server(&pico_spec(None), &opts);
    let addr = server.addr.to_string();
    let mut stream = TcpStream::connect(&addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    write!(stream, "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\r\n").unwrap();
    let res = read_framed_response(&mut reader);
    assert_eq!(res.status, 200);
    assert_eq!(res.header("connection"), Some("keep-alive"));
    // Send nothing: the idle timeout must close the socket server-side
    // (read_to_end returning 0 extra bytes) well before the 30s request
    // timeout.
    let t0 = std::time::Instant::now();
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).expect("read to close");
    assert!(rest.is_empty());
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(20),
        "idle keep-alive connection was not closed promptly"
    );
    server.shutdown();
}

#[test]
fn streamed_sse_over_keep_alive_request_still_closes() {
    let server = start_server(&pico_spec(None), &ServeOpts::default());
    let addr = server.addr.to_string();
    let mut stream = TcpStream::connect(&addr).expect("connect");
    let body = generate_body("stream me", 3, true);
    write!(
        stream,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    // SSE is close-delimited: despite the keep-alive request header, the
    // server must finish the stream and hang up, so read_to_end returns.
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let res = parse_response(&raw);
    assert_eq!(res.status, 200);
    assert_eq!(res.header("connection"), Some("close"));
    let events = parse_sse_events(&res.body);
    assert_eq!(sse_tokens(&events).len(), 3);
    assert!(events.iter().any(|(name, _)| name == "done"));
    server.shutdown();
}

#[test]
fn healthz_reports_active_simd_kernel() {
    let server = start_server(&pico_spec(None), &ServeOpts::default());
    let addr = server.addr.to_string();
    let res = request(&addr, "GET", "/healthz", "");
    assert_eq!(res.status, 200);
    let simd = res.json().get("simd").and_then(Json::as_str).unwrap_or("").to_string();
    assert!(
        ["scalar", "avx2", "neon"].contains(&simd.as_str()),
        "unexpected simd kernel name {simd:?}"
    );
    server.shutdown();
}

// =====================================================================
// Observability: /v1/stats shape + usage accounting on every response
// =====================================================================

#[test]
fn stats_endpoint_reports_spans_profile_and_quant_report() {
    // Quantized in-process so the build-time QuantReport is attached.
    let server = start_server(&pico_spec(Some(Method::Sinq)), &ServeOpts::default());
    let addr = server.addr.to_string();
    let res = request(&addr, "POST", "/v1/generate", &generate_body("warm the stats", 6, false));
    assert_eq!(res.status, 200);

    let res = request(&addr, "GET", "/v1/stats", "");
    assert_eq!(res.status, 200, "{}", String::from_utf8_lossy(&res.body));
    let json = res.json();
    assert!(json.get("uptime_secs").and_then(Json::as_f64).unwrap() >= 0.0);
    assert!(json.get("kernel").and_then(Json::as_str).is_some());

    let requests = json.get("requests").expect("requests object");
    assert_eq!(requests.get("total").and_then(Json::as_usize), Some(1));
    assert_eq!(requests.get("completed").and_then(Json::as_usize), Some(1));

    let throughput = json.get("throughput").expect("throughput object");
    assert_eq!(throughput.get("tokens_generated").and_then(Json::as_usize), Some(6));
    assert!(throughput.get("tokens_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(throughput.get("tokens_per_sec_lifetime").and_then(Json::as_f64).unwrap() > 0.0);

    let latency = json.get("latency").expect("latency object");
    for hist in ["ttft", "queue_wait"] {
        let h = latency.get(hist).unwrap_or_else(|| panic!("latency.{hist} missing"));
        assert_eq!(h.get("count").and_then(Json::as_usize), Some(1), "latency.{hist}");
        assert!(h.get("p99_ms").and_then(Json::as_f64).is_some(), "latency.{hist}");
    }
    assert!(latency.get("step").and_then(|h| h.get("count")).and_then(Json::as_usize).unwrap() > 0);

    // Profiler off by default: present, disabled, empty breakdown.
    let profile = json.get("profile").expect("profile object");
    assert_eq!(profile.get("enabled"), Some(&Json::Bool(false)));

    // The per-layer quantization-quality report rides along.
    let quant = json.get("quant").expect("quant report");
    assert!(quant.get("mean_nmse").and_then(Json::as_f64).unwrap() > 0.0);
    let layers = quant.get("layers").and_then(Json::as_arr).expect("quant layers");
    assert!(!layers.is_empty());
    for l in layers {
        assert!(l.get("nmse").and_then(Json::as_f64).unwrap().is_finite());
        assert!(l.get("sinkhorn_iters").and_then(Json::as_usize).is_some());
    }

    let model = json.get("model").expect("model shape");
    assert!(model.get("layers").and_then(Json::as_usize).unwrap() > 0);
    assert!(model.get("dim").and_then(Json::as_usize).unwrap() > 0);
    assert!(model.get("heads").and_then(Json::as_usize).unwrap() > 0);
    let build = json.get("build").expect("build info");
    assert!(build.get("git_sha").and_then(Json::as_str).is_some());
    assert!(["debug", "release"]
        .contains(&build.get("profile").and_then(Json::as_str).unwrap()));
    server.shutdown();
}

#[test]
fn stats_endpoint_is_stable_under_concurrent_requests() {
    let server = start_server(&pico_spec(None), &ServeOpts::default());
    let addr = server.addr.to_string();
    let gen_threads: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let prompt = format!("concurrent stats {i}");
                let res =
                    request(&addr, "POST", "/v1/generate", &generate_body(&prompt, 8, false));
                assert_eq!(res.status, 200);
            })
        })
        .collect();
    // Hammer /v1/stats while generations are in flight: every response must
    // stay 200 and parse as a complete JSON document.
    let stats_threads: Vec<_> = (0..3)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                for _ in 0..10 {
                    let res = request(&addr, "GET", "/v1/stats", "");
                    assert_eq!(res.status, 200);
                    let json = res.json();
                    assert!(json.get("requests").is_some());
                    assert!(json.get("latency").is_some());
                }
            })
        })
        .collect();
    for t in gen_threads.into_iter().chain(stats_threads) {
        t.join().expect("no panics under concurrency");
    }
    let json = request(&addr, "GET", "/v1/stats", "").json();
    let requests = json.get("requests").expect("requests object");
    assert_eq!(requests.get("completed").and_then(Json::as_usize), Some(4));
    let latency = json.get("latency").expect("latency object");
    let ttft = latency.get("ttft").and_then(|h| h.get("count")).and_then(Json::as_usize);
    assert_eq!(ttft, Some(4), "one TTFT observation per completed request");
    server.shutdown();
}

#[test]
fn usage_object_reported_on_json_and_sse_responses() {
    let server = start_server(&pico_spec(None), &ServeOpts::default());
    let addr = server.addr.to_string();
    let prompt = "usage accounting";

    // JSON body.
    let res = request(&addr, "POST", "/v1/generate", &generate_body(prompt, 7, false));
    assert_eq!(res.status, 200);
    let json = res.json();
    let usage = json.get("usage").expect("usage object on JSON response");
    assert_eq!(usage.get("prompt_tokens").and_then(Json::as_usize), Some(prompt.len()));
    assert_eq!(usage.get("completion_tokens").and_then(Json::as_usize), Some(7));
    let ttft = usage.get("ttft_ms").and_then(Json::as_f64).unwrap();
    let total = usage.get("total_ms").and_then(Json::as_f64).unwrap();
    assert!(ttft > 0.0, "TTFT must be measured, got {ttft}");
    assert!(total >= ttft, "total {total} < ttft {ttft}");
    assert!(usage.get("queue_wait_ms").and_then(Json::as_f64).unwrap() >= 0.0);
    assert!(usage.get("tokens_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
    // The legacy top-level counts agree with the usage object.
    assert_eq!(json.get("generated_tokens").and_then(Json::as_usize), Some(7));

    // SSE done event.
    let res = request(&addr, "POST", "/v1/generate", &generate_body(prompt, 5, true));
    assert_eq!(res.status, 200);
    let events = parse_sse_events(&res.body);
    let (name, done) = events.last().expect("terminal event");
    assert_eq!(name, "done");
    let usage = done.get("usage").expect("usage object on SSE done event");
    assert_eq!(usage.get("prompt_tokens").and_then(Json::as_usize), Some(prompt.len()));
    assert_eq!(usage.get("completion_tokens").and_then(Json::as_usize), Some(5));
    assert!(usage.get("total_ms").and_then(Json::as_f64).unwrap() > 0.0);
    server.shutdown();
}

// =====================================================================
// OpenAI-compatible /v1/completions
// =====================================================================

fn completions_body(prompt: &str, max_tokens: usize, stream: bool) -> String {
    Json::obj(vec![
        ("prompt", Json::Str(prompt.into())),
        ("max_tokens", Json::Num(max_tokens as f64)),
        ("stream", Json::Bool(stream)),
    ])
    .to_string_compact()
}

#[test]
fn completions_endpoint_matches_native_decode_and_reports_usage() {
    let spec = pico_spec(None);
    let reference = backend::build_native(&spec).expect("reference backend");
    let prompt = "openai compatible";
    let expected = reference.generate(prompt.as_bytes(), 6).expect("reference tokens");

    let server = start_server(&spec, &ServeOpts::default());
    let addr = server.addr.to_string();
    let res = request(&addr, "POST", "/v1/completions", &completions_body(prompt, 6, false));
    assert_eq!(res.status, 200, "{}", String::from_utf8_lossy(&res.body));
    let json = res.json();
    assert_eq!(json.get("object").and_then(Json::as_str), Some("text_completion"));
    assert!(json.get("id").and_then(Json::as_str).unwrap().starts_with("cmpl-"));
    assert!(json.get("created").and_then(Json::as_usize).unwrap() > 0);
    let choices = json.get("choices").and_then(Json::as_arr).expect("choices array");
    assert_eq!(choices.len(), 1);
    let choice = &choices[0];
    assert_eq!(choice.get("index").and_then(Json::as_usize), Some(0));
    assert_eq!(choice.get("finish_reason").and_then(Json::as_str), Some("length"));
    assert_eq!(
        choice.get("text").and_then(Json::as_str).unwrap(),
        String::from_utf8_lossy(&expected),
        "completion text diverged from NativeDecoder::generate"
    );
    let usage = json.get("usage").expect("usage object");
    assert_eq!(usage.get("prompt_tokens").and_then(Json::as_usize), Some(prompt.len()));
    assert_eq!(usage.get("completion_tokens").and_then(Json::as_usize), Some(6));
    assert_eq!(usage.get("total_tokens").and_then(Json::as_usize), Some(prompt.len() + 6));

    // Invalid bodies answer through the unified envelope, naming the
    // OpenAI field.
    let res = request(&addr, "POST", "/v1/completions", "{\"max_tokens\": 4}");
    assert_eq!(res.status, 400);
    assert_eq!(error_type(&res), "invalid_request_error");
    let res = request(&addr, "POST", "/v1/completions", "{\"prompt\":\"x\",\"max_tokens\":-1}");
    assert_eq!(res.status, 400);
    assert!(error_message(&res).contains("max_tokens"), "{}", error_message(&res));
    server.shutdown();
}

#[test]
fn streamed_completions_send_data_chunks_and_done_terminator() {
    let spec = pico_spec(None);
    let reference = backend::build_native(&spec).expect("reference backend");
    let prompt = "stream compat";
    let expected = reference.generate(prompt.as_bytes(), 5).expect("reference tokens");

    let server = start_server(&spec, &ServeOpts::default());
    let addr = server.addr.to_string();
    let res = request(&addr, "POST", "/v1/completions", &completions_body(prompt, 5, true));
    assert_eq!(res.status, 200, "{}", String::from_utf8_lossy(&res.body));
    assert_eq!(res.header("content-type"), Some("text/event-stream"));

    // OpenAI wire format: bare `data:` frames (no `event:` line), closed
    // by the literal `data: [DONE]`.
    let text = std::str::from_utf8(&res.body).expect("utf8 SSE body");
    let frames: Vec<&str> = text
        .split("\n\n")
        .filter(|c| !c.trim().is_empty())
        .map(|c| c.strip_prefix("data: ").expect("bare data frame"))
        .collect();
    assert_eq!(*frames.last().unwrap(), "[DONE]", "stream must end with [DONE]");
    let chunks: Vec<Json> =
        frames[..frames.len() - 1].iter().map(|f| Json::parse(f).expect("chunk json")).collect();
    // One chunk per token plus the final finish_reason/usage chunk.
    assert_eq!(chunks.len(), expected.len() + 1);
    let streamed: String = chunks[..expected.len()]
        .iter()
        .map(|c| {
            c.get("choices").and_then(Json::as_arr).unwrap()[0]
                .get("text")
                .and_then(Json::as_str)
                .unwrap()
                .to_string()
        })
        .collect();
    let want: String =
        expected.iter().map(|&b| String::from_utf8_lossy(&[b]).into_owned()).collect();
    assert_eq!(streamed, want, "streamed completion text diverged");
    let last = chunks.last().unwrap();
    let choice = &last.get("choices").and_then(Json::as_arr).unwrap()[0];
    assert_eq!(choice.get("finish_reason").and_then(Json::as_str), Some("length"));
    let usage = last.get("usage").expect("usage on final chunk");
    assert_eq!(usage.get("completion_tokens").and_then(Json::as_usize), Some(5));
    server.shutdown();
}

// =====================================================================
// The server reuses one backend for scoring and generation
// =====================================================================

#[test]
fn shared_backend_server_via_start_with_backend() {
    use sinq::model::{ModelConfig, ModelWeights};
    let cfg = ModelConfig::family("pico").unwrap();
    let be = Arc::new(NativeBackend::from_weights(&ModelWeights::synthetic(&cfg, 42)));
    let expected = be.generate(b"shared", 4).unwrap();
    let server = Server::start_with_backend(be, &ServeOpts::default()).expect("server");
    let addr = server.addr.to_string();
    let res = request(&addr, "POST", "/v1/generate", &generate_body("shared", 4, true));
    assert_eq!(res.status, 200);
    assert_eq!(sse_tokens(&parse_sse_events(&res.body)), expected);
    let stats = server.shutdown();
    assert_eq!(stats.gen_requests, 1);
}
