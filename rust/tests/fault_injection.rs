//! Chaos tests for the supervised engine: arm real production fault sites
//! via `sinq::obs::fault` and hold the supervisor to its contract — every
//! in-flight request gets exactly one terminal `Failed`, the engine
//! restarts on a fresh decoder, and post-restart decode is bit-identical
//! to the unsupervised backend.
//!
//! The fault registry is process-global, so every test here serializes on
//! one mutex and disarms before returning. Sites armed in this binary are
//! never armed by the lib unit tests (which only use `Site::Test`), so the
//! two binaries cannot perturb each other.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use sinq::backend::{self, BackendKind, BackendSpec, EngineConfig, NativeBackend};
use sinq::obs::fault;
use sinq::serve::engine::{GenEngine, StreamEvent, StreamHandle, SubmitError, SubmitErrorKind};
use sinq::serve::metrics::ServeMetrics;
use sinq::serve::supervisor::SupervisorCfg;

static LOCK: Mutex<()> = Mutex::new(());

/// Serialize tests that touch the global fault registry; a previous test
/// that panicked mid-fault poisons the lock, which is fine — the registry
/// is re-disarmed on entry.
fn registry_guard() -> MutexGuard<'static, ()> {
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::disarm_all();
    guard
}

fn pico_arc() -> Arc<NativeBackend> {
    let spec = BackendSpec::new(BackendKind::Native, "/nonexistent", "pico");
    Arc::new(backend::build_native(&spec).expect("pico backend"))
}

/// Fast-backoff supervisor so crash-recovery tests finish in milliseconds.
fn fast_sup(max_restarts: usize) -> SupervisorCfg {
    SupervisorCfg { max_restarts, backoff_base_ms: 1, backoff_cap_ms: 4 }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig::new().with_max_batch(2).with_max_context(128)
}

/// Consume a stream to the very end, splitting tokens from terminals.
fn drain_all(h: StreamHandle) -> (Vec<u8>, Vec<StreamEvent>) {
    let mut tokens = Vec::new();
    let mut terminals = Vec::new();
    for ev in h.rx.iter() {
        match ev {
            StreamEvent::Token(t) => tokens.push(t),
            terminal => terminals.push(terminal),
        }
    }
    (tokens, terminals)
}

#[test]
fn decode_panic_fails_inflight_once_then_engine_recovers_bit_identically() {
    let _g = registry_guard();
    let be = pico_arc();
    let expected = be.generate(b"after the crash", 12).expect("reference tokens");
    let metrics = Arc::new(ServeMetrics::new());
    let eng = GenEngine::start_supervised(
        be,
        engine_cfg(),
        8,
        metrics.clone(),
        false,
        fast_sup(3),
    )
    .expect("engine start");
    let client = eng.client();

    // `@once`: the first decode step panics; the hit counter persists
    // across the restart so the next incarnation decodes cleanly.
    fault::arm_str("decode_step:panic@once").unwrap();

    // The panic unwinds out of `BatchDecoder::step` with this request
    // admitted, so the supervisor's roster drain must deliver exactly one
    // terminal `Failed` carrying the request's own id.
    let doomed = client.submit(b"doomed request".to_vec(), 6, None, None).expect("submit");
    let doomed_id = doomed.id;
    let (tokens, terminals) = drain_all(doomed);
    assert!(tokens.is_empty(), "no token precedes the first (panicking) step");
    match &terminals[..] {
        [StreamEvent::Failed { request_id, message }] => {
            assert_eq!(*request_id, doomed_id, "Failed must carry the submission's id");
            assert!(message.contains("engine crashed"), "{message}");
            assert!(message.contains("injected fault: decode_step panic"), "{message}");
        }
        other => panic!("expected exactly one Failed, got {other:?}"),
    }
    assert_eq!(fault::fired(fault::Site::DecodeStep), 1);

    // Recovery: the next submission decodes on a rebuilt decoder and the
    // tokens are bit-identical to the unsupervised backend path.
    let handle = client.submit(b"after the crash".to_vec(), 12, None, None).expect("resubmit");
    let (tokens, terminals) = drain_all(handle);
    assert_eq!(tokens, expected, "post-restart decode diverged from backend::generate");
    assert!(
        matches!(&terminals[..], [StreamEvent::Done { finish_reason: "length", .. }]),
        "{terminals:?}"
    );

    eng.shutdown();
    assert_eq!(metrics.engine_panics_total.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.engine_restarts_total.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.engine_degraded.load(Ordering::Relaxed), 0);
    assert_eq!(metrics.queued.load(Ordering::Relaxed), 0, "crash drain must release backlog");
    fault::disarm_all();
}

#[test]
fn exhausted_restart_budget_degrades_and_refuses_submissions() {
    let _g = registry_guard();
    let be = pico_arc();
    let metrics = Arc::new(ServeMetrics::new());
    // Zero restart budget: the very first crash is terminal.
    let eng = GenEngine::start_supervised(
        be,
        engine_cfg(),
        8,
        metrics.clone(),
        false,
        fast_sup(0),
    )
    .expect("engine start");
    let client = eng.client();
    fault::arm_str("decode_step:panic").unwrap();

    let doomed = client.submit(b"no budget".to_vec(), 6, None, None).expect("submit");
    let (_, terminals) = drain_all(doomed);
    assert!(
        matches!(&terminals[..], [StreamEvent::Failed { .. }]),
        "crash must fail the in-flight request: {terminals:?}"
    );

    // The supervisor flips degraded just after draining the roster; give
    // it a moment, then every new submission must answer Unavailable with
    // the degraded message (the HTTP layer maps this to 503).
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match client.submit(b"too late".to_vec(), 2, None, None) {
            Err(SubmitError { kind: SubmitErrorKind::Unavailable(msg), .. })
                if msg.contains("degraded") =>
            {
                break;
            }
            Err(SubmitError { kind: SubmitErrorKind::Unavailable(_), .. }) => {
                // Raced the drain: dead flag set, degraded store pending.
            }
            Ok(h) => {
                // Accepted in the window before the supervisor exited; it
                // must still get its terminal Failed, never a silent drop.
                let (_, t) = drain_all(h);
                assert!(matches!(&t[..], [StreamEvent::Failed { .. }]), "{t:?}");
            }
            Err(other) => panic!("expected Unavailable, got {other:?}"),
        }
        assert!(Instant::now() < deadline, "engine never reported degraded");
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(metrics.engine_degraded.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.engine_restarts_total.load(Ordering::Relaxed), 0, "budget was zero");
    assert_eq!(metrics.engine_panics_total.load(Ordering::Relaxed), 1);
    eng.shutdown();
    fault::disarm_all();
}

#[test]
fn submit_and_admit_error_actions_take_the_non_crash_paths() {
    let _g = registry_guard();
    let be = pico_arc();
    let expected = be.generate(b"errors are soft", 5).expect("reference tokens");
    let metrics = Arc::new(ServeMetrics::new());
    let eng = GenEngine::start_supervised(
        be,
        engine_cfg(),
        8,
        metrics.clone(),
        false,
        fast_sup(3),
    )
    .expect("engine start");
    let client = eng.client();

    // `submit:error@once` is rejected synchronously as Unavailable — the
    // request never reaches the queue, so nothing needs a terminal event.
    fault::arm_str("submit:error@once").unwrap();
    match client.submit(b"refused at the door".to_vec(), 3, None, None) {
        Err(SubmitError { kind: SubmitErrorKind::Unavailable(msg), .. }) => {
            assert!(msg.contains("injected fault: submit error"), "{msg}");
        }
        other => panic!("expected Unavailable, got {other:?}"),
    }

    // `admit:error@once` fires on the engine thread after acceptance: the
    // accepted request must get a terminal Failed (exactly once), and the
    // engine must keep running — no panic, no restart.
    fault::arm_str("admit:error@once").unwrap();
    let h = client.submit(b"refused at admission".to_vec(), 3, None, None).expect("submit");
    let (tokens, terminals) = drain_all(h);
    assert!(tokens.is_empty());
    match &terminals[..] {
        [StreamEvent::Failed { message, .. }] => {
            assert!(message.contains("admission failed"), "{message}");
            assert!(message.contains("injected fault: admit error"), "{message}");
        }
        other => panic!("expected exactly one Failed, got {other:?}"),
    }

    // Both faults were @once and are spent: the engine decodes normally.
    let h = client.submit(b"errors are soft".to_vec(), 5, None, None).expect("submit");
    let (tokens, terminals) = drain_all(h);
    assert_eq!(tokens, expected);
    assert!(matches!(&terminals[..], [StreamEvent::Done { .. }]));

    eng.shutdown();
    assert_eq!(metrics.engine_panics_total.load(Ordering::Relaxed), 0);
    assert_eq!(metrics.engine_restarts_total.load(Ordering::Relaxed), 0);
    assert_eq!(metrics.queued.load(Ordering::Relaxed), 0);
    assert_eq!(metrics.completed_total.load(Ordering::Relaxed), 1);
    fault::disarm_all();
}
