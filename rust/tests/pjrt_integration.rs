//! Integration tests across the three layers: the Rust reference forward,
//! the PJRT-executed HLO artifacts (lowered from the JAX/Pallas stack), and
//! the quantization pipeline. All tests require `make artifacts` *and* a
//! real xla_extension-backed `xla` binding, so the whole file is gated
//! behind the `pjrt-artifacts` feature (the default build links a stub
//! `xla` that cannot execute anything):
//!
//! ```text
//! cargo test --features pjrt-artifacts
//! ```
//!
//! Even with the feature on, tests skip (with a notice) when artifacts are
//! missing so the suite stays green on a fresh checkout. The artifact-free
//! counterpart of this file is `tests/native_backend.rs`.
#![cfg(feature = "pjrt-artifacts")]

use sinq::coordinator::pipeline::{self, PipelineOpts};
use sinq::coordinator::scheduler;
use sinq::eval::LogitsEngine;
use sinq::model::forward::Forward;
use sinq::quant::{AuxPrecision, Method, QuantConfig};
use sinq::report::tables::Ctx;
use sinq::runtime::{PjrtDecoder, PjrtForward, PjrtRuntime};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn rust_forward_matches_pjrt_artifact() {
    require_artifacts!();
    let rt = PjrtRuntime::cpu("artifacts").unwrap();
    let mw = scheduler::load_family_member("artifacts", "pico").unwrap();
    let mut pjrt = PjrtForward::new(&rt, &mw.cfg, &mw.tensors, &mw.vectors).unwrap();
    let rust_fwd = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);

    let tokens = b"The ancient river describes the empire of history.";
    let l_pjrt = pjrt.logits(tokens).unwrap();
    let l_rust = rust_fwd.forward(tokens, None);
    assert_eq!((l_pjrt.rows, l_pjrt.cols), (l_rust.rows, l_rust.cols));
    let mut max_diff = 0.0f32;
    for (a, b) in l_pjrt.data.iter().zip(&l_rust.data) {
        max_diff = max_diff.max((a - b).abs());
    }
    // Same math, different op orders: agreement to ~1e-3 logits.
    assert!(max_diff < 2e-2, "rust vs PJRT logits max diff {max_diff}");
}

#[test]
fn rust_forward_matches_pjrt_artifact_moe() {
    require_artifacts!();
    let rt = PjrtRuntime::cpu("artifacts").unwrap();
    let mw = scheduler::load_family_member("artifacts", "tiny_moe").unwrap();
    let mut pjrt = PjrtForward::new(&rt, &mw.cfg, &mw.tensors, &mw.vectors).unwrap();
    let rust_fwd = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
    let tokens = b"Top 12 systems for physics.";
    let l_pjrt = pjrt.logits(tokens).unwrap();
    let l_rust = rust_fwd.forward(tokens, None);
    let mut max_diff = 0.0f32;
    for (a, b) in l_pjrt.data.iter().zip(&l_rust.data) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 5e-2, "moe rust vs PJRT max diff {max_diff}");
}

#[test]
fn decode_artifact_matches_full_forward() {
    require_artifacts!();
    let rt = PjrtRuntime::cpu("artifacts").unwrap();
    let mw = scheduler::load_family_member("artifacts", "pico").unwrap();
    let mut fwd = PjrtForward::new(&rt, &mw.cfg, &mw.tensors, &mw.vectors).unwrap();
    let mut dec = PjrtDecoder::new_fp(&rt, &mw.cfg, &mw.tensors, &mw.vectors).unwrap();

    let tokens = b"hello decode";
    let full = fwd.logits(tokens).unwrap();
    let mut last = Vec::new();
    for &t in tokens.iter() {
        last = dec.step(t).unwrap();
    }
    // Compare final-position logits.
    let frow = full.row(tokens.len() - 1);
    let mut max_diff = 0.0f32;
    for (a, b) in frow.iter().zip(&last) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 2e-2, "decode vs forward max diff {max_diff}");
}

#[test]
fn w4_decode_matches_effective_weight_forward() {
    require_artifacts!();
    let rt = PjrtRuntime::cpu("artifacts").unwrap();
    let mw = scheduler::load_family_member("artifacts", "tiny").unwrap();
    let qcfg = QuantConfig::new(Method::Sinq, 4).with_aux(AuxPrecision::F32);
    let qm = scheduler::quantize_simple(&mw, &qcfg, None).unwrap();

    // Eq. 7 equivalence: the W4 decode (Pallas fused dequant-matmul on int4
    // codes) must compute the same function as the f32 forward over the
    // *effective* (dequantized) weights.
    let eff = qm.effective_weights();
    let mut eff_fwd = PjrtForward::new(&rt, &mw.cfg, &eff, &qm.fvectors).unwrap();
    let mut w4 =
        PjrtDecoder::new_w4(&rt, &mw.cfg, &qm.layers, &qm.fweights, &qm.fvectors).unwrap();
    let prompt = b"The quiet market";
    let full = eff_fwd.logits(prompt).unwrap();
    let mut l_w4 = Vec::new();
    for &t in prompt.iter() {
        l_w4 = w4.step(t).unwrap();
    }
    let frow = full.row(prompt.len() - 1);
    let mut max_diff = 0.0f32;
    for (a, b) in frow.iter().zip(&l_w4) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 5e-2, "W4 decode vs effective forward max diff {max_diff}");
}

#[test]
fn pjrt_sinq_quantize_matches_rust() {
    require_artifacts!();
    let rt = PjrtRuntime::cpu("artifacts").unwrap();
    let mw = scheduler::load_family_member("artifacts", "tiny").unwrap();
    let w = &mw.tensors["layers.0.wq"]; // 128×128, covered by the artifacts
    let q_pjrt = pipeline::sinq_quantize_pjrt(&rt, w).unwrap();
    let mut cfg = QuantConfig::new(Method::Sinq, 4).with_aux(AuxPrecision::F32);
    cfg.sinq_iters = 24;
    cfg.sinq_clamp = (0.5, 2.0);
    let q_rust = sinq::quant::sinq::quantize(w, &cfg);

    // The two implementations share the algorithm; fp noise may flip a code
    // occasionally, so compare reconstructions rather than raw codes.
    let (da, db) = (q_pjrt.dequantize(), q_rust.dequantize());
    let rel = da.dist(&db)
        / w.data.iter().map(|&x| x * x).sum::<f32>().sqrt();
    assert!(rel < 2e-2, "pjrt vs rust sinq reconstruction rel diff {rel}");
    // And both reconstruct the layer well.
    assert!(da.mse(w) < 1e-4, "pjrt sinq mse {}", da.mse(w));
}

#[test]
fn quantize_save_load_eval_round_trip() {
    require_artifacts!();
    let ctx = Ctx::new("artifacts", true).unwrap();
    let mw = ctx.load_model("pico").unwrap();
    let cfg = QuantConfig::new(Method::Sinq, 4);
    let path = std::env::temp_dir().join("sinq_integration_qm.stz");
    let (qm, _) =
        pipeline::run_and_save(&mw, &cfg, &PipelineOpts::default(), &path).unwrap();
    let back = sinq::model::QuantizedModel::load(&path).unwrap();
    let eff_a = qm.effective_weights();
    let eff_b = back.effective_weights();
    let ppl_a = ctx.ppl_eff(&mw, &eff_a, &qm.fvectors, "wiki").unwrap();
    let ppl_b = ctx.ppl_eff(&mw, &eff_b, &back.fvectors, "wiki").unwrap();
    assert!((ppl_a - ppl_b).abs() < 1e-6, "ppl drift across save/load: {ppl_a} vs {ppl_b}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn batch_server_scores_concurrently() {
    require_artifacts!();
    use sinq::coordinator::server::BatchServer;
    let server = BatchServer::spawn(
        || {
            let rt = PjrtRuntime::cpu("artifacts")?;
            let mw = scheduler::load_family_member("artifacts", "pico")?;
            PjrtForward::new(&rt, &mw.cfg, &mw.tensors, &mw.vectors)
        },
        16,
        std::time::Duration::from_millis(2),
    );
    let client = server.client();
    let handles: Vec<_> = (0..12)
        .map(|i| {
            let c = client.clone();
            std::thread::spawn(move || {
                let toks = format!("request number {i} padded out to length");
                c.score(toks.into_bytes()).map(|m| m.rows)
            })
        })
        .collect();
    for h in handles {
        let rows = h.join().unwrap().unwrap();
        assert!(rows > 10);
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 12);
    assert!(stats.batches <= 12, "batching should aggregate at least sometimes");
}

#[test]
fn no_overhead_fold_preserves_fp_ppl_through_pjrt() {
    require_artifacts!();
    let ctx = Ctx::new("artifacts", true).unwrap();
    let mw = ctx.load_model("pico").unwrap();
    let folded = sinq::model::fold::fold_model(&mw, 16, (0.5, 2.0));
    let a = ctx.ppl_fp(&mw, "wiki").unwrap();
    let b = ctx.ppl_eff(&mw, &folded.tensors, &folded.vectors, "wiki").unwrap();
    assert!((a - b).abs() / a < 1e-3, "fold changed FP ppl: {a} vs {b}");
}
