//! Artifact-free integration tests for the native inference backend: the
//! fused dequant-matmul engine must reproduce the f32 reference forward on
//! the tiny model, and the serving coordinator must run end-to-end over it
//! — no `artifacts/`, no XLA, no Python.

use std::time::Duration;

use sinq::backend::{self, BackendKind, BackendSpec, InferenceBackend, NativeBackend};
use sinq::coordinator::scheduler::{load_or_synthetic, quantize_simple};
use sinq::coordinator::server::BatchServer;
use sinq::data::Corpus;
use sinq::eval::ppl;
use sinq::model::forward::Forward;
use sinq::quant::{Method, QuantConfig};

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// NativeBackend logits must match the reference forward over the model's
/// *effective* (dequantized) weights within 1e-4 — i.e. the fused kernels
/// introduce no error beyond float associativity.
#[test]
fn tiny_model_logits_match_reference_rtn_and_sinq_4_and_8_bit() {
    let mw = load_or_synthetic("/nonexistent", "tiny", 1001);
    let tokens = b"The fused kernels must agree with the reference.";
    for method in [Method::Rtn, Method::Sinq] {
        for bits in [4u32, 8] {
            let cfg = QuantConfig::new(method, bits);
            let qm = quantize_simple(&mw, &cfg, None).unwrap();
            let eff = qm.effective_weights();
            let reference = Forward::new(&mw.cfg, &eff, &qm.fvectors);
            let l_ref = reference.forward(tokens, None);

            let nb = NativeBackend::from_quantized(&qm);
            assert!(
                nb.quantized_layer_count() == mw.cfg.quantizable_names().len(),
                "{} {}b: every linear should run packed",
                method.name(),
                bits
            );
            let l_nat = nb.forward(tokens).unwrap();
            let diff = max_abs_diff(&l_nat.data, &l_ref.data);
            assert!(
                diff < 1e-4,
                "{} {}b: native vs reference logits max diff {diff}",
                method.name(),
                bits
            );
        }
    }
}

/// The dense (f32) native backend is the exact reference math.
#[test]
fn tiny_model_dense_native_matches_fp_reference() {
    let mw = load_or_synthetic("/nonexistent", "tiny", 1002);
    let reference = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
    let nb = NativeBackend::from_weights(&mw);
    let tokens = b"fp32 parity";
    let diff = max_abs_diff(
        &nb.forward(tokens).unwrap().data,
        &reference.forward(tokens, None).data,
    );
    assert!(diff < 1e-5, "dense native diverged: {diff}");
}

/// BatchServer end-to-end over a NativeBackend: the batching loop finally
/// runs without artifacts. Results must equal a direct forward.
#[test]
fn batch_server_runs_over_native_backend() {
    let server = BatchServer::spawn(
        || {
            let mw = load_or_synthetic("/nonexistent", "pico", 1003);
            let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None)?;
            Ok(NativeBackend::from_quantized(&qm))
        },
        32,
        Duration::from_millis(2),
    );
    let corpus = Corpus::synthetic("serve", 4096, 5);
    let windows: Vec<Vec<u8>> =
        corpus.eval_windows(48, 8).into_iter().map(|w| w.to_vec()).collect();
    assert_eq!(windows.len(), 8);

    let client = server.client();
    let handles: Vec<_> = windows
        .iter()
        .map(|w| {
            let c = client.clone();
            let toks = w.clone();
            std::thread::spawn(move || c.score(toks))
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap().unwrap()).collect();
    let stats = server.shutdown();
    assert_eq!(stats.requests, 8);
    assert!(stats.batches <= 8 && stats.batches >= 2, "batches {}", stats.batches);
    assert_eq!(stats.tokens, 8 * 48);

    // Server answers must equal a direct (unbatched) forward.
    let mw = load_or_synthetic("/nonexistent", "pico", 1003);
    let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
    let nb = NativeBackend::from_quantized(&qm);
    for (w, served) in windows.iter().zip(&results) {
        let direct = nb.forward(w).unwrap();
        assert_eq!((served.rows, served.cols), (48, 256));
        assert!(max_abs_diff(&served.data, &direct.data) < 1e-6);
    }
}

/// `eval --backend native` path: build via the factory, score a synthetic
/// corpus through the trait, get a finite perplexity.
#[test]
fn backend_factory_eval_path_end_to_end() {
    let spec = BackendSpec::new(BackendKind::Native, "/nonexistent", "pico");
    let mut be = backend::build(&spec).unwrap();
    let corpus = Corpus::synthetic("eval", 8192, 6);
    let ppl_value = ppl::perplexity_backend(&mut *be, &corpus, 64, 6).unwrap();
    assert!(ppl_value.is_finite() && ppl_value > 1.0, "ppl {ppl_value}");
}

/// Native generation: prompt in, deterministic bytes out, zero artifacts.
#[test]
fn native_generate_end_to_end() {
    let mut spec = BackendSpec::new(BackendKind::Native, "/nonexistent", "pico");
    spec.quantize = Some(QuantConfig::new(Method::Sinq, 4));
    let mut be = backend::build(&spec).unwrap();
    let out = be.generate(b"sinkhorn ", 16).unwrap();
    assert_eq!(out.len(), 16);
    let again = be.generate(b"sinkhorn ", 16).unwrap();
    assert_eq!(out, again);
}
