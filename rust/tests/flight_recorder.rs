//! End-to-end pins for the flight-recorder layer (`sinq::obs::{journal,
//! trace, drift}` wired through `BatchDecoder`):
//!
//! 1. A preempted-then-resumed run journals the full lifecycle in order:
//!    enqueue → admit → (page claims / steps) → preempt → resume →
//!    complete, with monotone sequence numbers and timestamps.
//! 2. The Chrome-trace export of that run is valid JSON (re-parsed with
//!    the crate's own parser, the same shape the CI smoke checks with
//!    python) carrying the preemption slices and lifecycle instants.
//! 3. The drift sentinel on a SINQ 4-bit model samples steps without
//!    perturbing decode: tokens are bit-identical with the sentinel on or
//!    off, and at kv32 the scalar recomputation produces zero argmax
//!    flips.
//! 4. `sinq analyze trace` (trace_table) folds the journal into one row
//!    per request with the preemption visible.
//!
//! The journal and drift counters are process-global, so every test here
//! serializes on one lock and resets the state it reads.

use std::sync::Mutex;

use sinq::backend::{BackendKind, BatchDecoder, EngineConfig, KvBits, NativeBackend, NativeDecoder};
use sinq::coordinator::scheduler;
use sinq::model::{ModelConfig, ModelWeights};
use sinq::obs::{drift, journal, trace, Event, EventKind};
use sinq::quant::{Method, QuantConfig};
use sinq::report::tables::{trace_table, Ctx};
use sinq::util::json::Json;

/// Serializes the tests in this binary: they all read/reset the
/// process-global journal and drift counters.
static GLOBALS: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GLOBALS.lock().unwrap_or_else(|e| e.into_inner())
}

fn pico_backend(seed: u64) -> NativeBackend {
    let cfg = ModelConfig::family("pico").unwrap();
    NativeBackend::from_weights(&ModelWeights::synthetic(&cfg, seed))
}

/// Reference tokens from the single-sequence decoder.
fn solo_tokens(be: &NativeBackend, prompt: &[u8], n: usize) -> Vec<u8> {
    let cfg = EngineConfig::new().with_max_context(prompt.len() + n + 1);
    NativeDecoder::with_config(be, &cfg).unwrap().generate(prompt, n).unwrap()
}

/// A pool two 7-page requests cannot share: the youngest is preempted
/// mid-decode and later resumed (same shape the paged-KV pins use).
fn preempting_config() -> EngineConfig {
    EngineConfig::new()
        .with_max_batch(2)
        .with_max_context(32)
        .with_page_size(4)
        .with_pages(Some(8))
}

/// Run the two-request out-of-pages scenario with the journal on and
/// return (events oldest-first, decoder outputs sorted by id).
fn journaled_preemption_run(seed: u64) -> (Vec<Event>, Vec<sinq::backend::GenOutput>) {
    let nb = pico_backend(seed);
    journal::reset();
    journal::set_enabled(true);
    let mut dec = BatchDecoder::with_config(&nb, &preempting_config()).unwrap();
    dec.submit(0, b"first long request", 9).unwrap();
    dec.submit(1, b"second long one!!", 9).unwrap();
    let outs = dec.run().unwrap();
    journal::set_enabled(false);
    assert!(dec.stats().preempted >= 1, "an 8-page pool cannot hold both sequences");
    (journal::snapshot(usize::MAX), outs)
}

fn kinds_for(events: &[Event], id: usize) -> Vec<EventKind> {
    events.iter().filter(|e| e.id == id).map(|e| e.kind).collect()
}

// =====================================================================
// 1. Lifecycle ordering through a forced preemption
// =====================================================================

#[test]
fn journal_orders_the_full_lifecycle_around_preemption() {
    let _g = lock();
    let (events, outs) = journaled_preemption_run(73);

    // Decode itself is unperturbed by the recorder.
    let nb = pico_backend(73);
    assert_eq!(outs[0].tokens, solo_tokens(&nb, b"first long request", 9));
    assert_eq!(outs[1].tokens, solo_tokens(&nb, b"second long one!!", 9));

    // Sequence numbers and timestamps come out monotone (snapshot sorts
    // by seq; times are stamped from one monotonic epoch).
    for w in events.windows(2) {
        assert!(w[0].seq < w[1].seq, "duplicate or unsorted seq: {w:?}");
        assert!(w[0].t_us <= w[1].t_us, "time ran backwards: {w:?}");
    }

    // Exactly one of the two requests was preempted; it must show the
    // full enqueue → admit → preempt → resume → complete arc in order.
    let victims: Vec<usize> =
        (0..2).filter(|&id| kinds_for(&events, id).contains(&EventKind::Preempt)).collect();
    assert_eq!(victims.len(), 1, "youngest-victim policy preempts exactly one of two");
    let victim = victims[0];
    let arc: Vec<EventKind> = kinds_for(&events, victim)
        .into_iter()
        .filter(|k| !matches!(k, EventKind::PageClaim | EventKind::PrefixHit))
        .collect();
    let expect = [
        EventKind::Enqueue,
        EventKind::Admit,
        EventKind::Preempt,
        EventKind::Resume,
        EventKind::Complete,
    ];
    // Preemption may repeat; collapse adjacent preempt/resume pairs by
    // checking subsequence order instead of exact equality.
    let mut want = expect.iter();
    let mut next = want.next();
    for k in &arc {
        if Some(k) == next {
            next = want.next();
        }
    }
    assert!(next.is_none(), "lifecycle out of order for request {victim}: {arc:?}");
    assert_eq!(*arc.last().unwrap(), EventKind::Complete);

    // The survivor never leaves the running state.
    let other = 1 - victim;
    let arc = kinds_for(&events, other);
    assert!(!arc.contains(&EventKind::Preempt));
    assert_eq!(arc.first(), Some(&EventKind::Enqueue));
    assert_eq!(arc.last(), Some(&EventKind::Complete));

    // Page claims and engine-lane step spans were captured too.
    assert!(events.iter().any(|e| e.kind == EventKind::PageClaim));
    let steps: Vec<&Event> = events.iter().filter(|e| e.kind == EventKind::Step).collect();
    assert!(!steps.is_empty(), "step spans missing");
    assert!(steps.iter().all(|e| e.id == 0), "steps live on the engine lane");
    assert!(steps.iter().any(|e| e.aux == 2), "some step must have run both sequences");
}

// =====================================================================
// 2. Chrome-trace export round-trips as JSON with the preemption visible
// =====================================================================

#[test]
fn chrome_trace_of_preempted_run_parses_with_lifecycle_slices() {
    let _g = lock();
    let (events, _) = journaled_preemption_run(74);
    let doc = trace::chrome_trace(&events).to_string_compact();

    let parsed = Json::parse(&doc).expect("chrome trace must be valid JSON");
    assert_eq!(parsed.get("displayTimeUnit").and_then(|j| j.as_str()), Some("ms"));
    let trace_events = parsed.get("traceEvents").and_then(|j| j.as_arr()).unwrap();
    assert!(!trace_events.is_empty());
    for e in trace_events {
        for key in ["name", "ph", "ts", "pid", "tid"] {
            assert!(e.get(key).is_some(), "trace event missing '{key}': {e:?}");
        }
    }

    let count = |name: &str, ph: &str| {
        trace_events
            .iter()
            .filter(|e| {
                e.get("name").and_then(|n| n.as_str()) == Some(name)
                    && e.get("ph").and_then(|p| p.as_str()) == Some(ph)
            })
            .count()
    };
    // The preempted request renders a "preempted" duration slice between
    // its running slices, and every transition lands as an instant.
    assert!(count("preempted", "X") >= 1);
    assert!(count("running", "X") >= 3, "victim runs twice, survivor once");
    assert!(count("step", "X") >= 1);
    for name in ["enqueue", "admit", "preempt", "resume", "complete"] {
        assert!(count(name, "i") >= 1, "missing instant '{name}'");
    }
    // Lanes: metadata names the engine thread and one lane per request.
    assert!(count("thread_name", "M") >= 3);
}

// =====================================================================
// 2b. Preemption storm: repeated preempt/resume cycles stay exact
// =====================================================================

#[test]
fn preemption_storm_keeps_tokens_exact_and_lifecycles_ordered() {
    let _g = lock();
    let nb = pico_backend(76);
    // Four page-hungry requests through two slots and an 8-page pool:
    // every overlapping pair runs the pool dry, so preemption recurs as
    // each completion admits the next waiter — a storm, not a one-off.
    let reqs: [(&[u8], usize); 4] = [
        (b"storm request aa" as &[u8], 9),
        (b"storm request bb!", 9),
        (b"storm request cc!!", 9),
        (b"storm request dd", 9),
    ];
    let want: Vec<Vec<u8>> = reqs.iter().map(|(p, n)| solo_tokens(&nb, p, *n)).collect();

    journal::reset();
    journal::set_enabled(true);
    let mut dec = BatchDecoder::with_config(&nb, &preempting_config()).unwrap();
    for (i, (p, n)) in reqs.iter().enumerate() {
        dec.submit(i, p, *n).unwrap();
    }
    let outs = dec.run().unwrap();
    journal::set_enabled(false);
    let events = journal::snapshot(usize::MAX);

    // Token-exact completion for every request despite the churn.
    assert_eq!(outs.len(), reqs.len(), "the storm must re-queue, never drop");
    for (i, out) in outs.iter().enumerate() {
        assert_eq!(out.tokens, want[i], "request {i} diverged in the preemption storm");
    }
    let stats = dec.stats();
    assert_eq!(stats.completed, reqs.len());
    assert!(stats.preempted >= 2, "expected repeated preemptions, got {}", stats.preempted);

    // Journal invariants across the whole storm: preempts and resumes
    // pair up globally, and per request the lifecycle stays ordered —
    // admit before the first preempt, each preempt answered by a resume,
    // and a final Complete after the last resume.
    let count = |k: EventKind| events.iter().filter(|e| e.kind == k).count();
    assert_eq!(count(EventKind::Preempt), count(EventKind::Resume));
    assert_eq!(count(EventKind::Complete), reqs.len());
    for id in 0..reqs.len() {
        let arc: Vec<EventKind> = kinds_for(&events, id)
            .into_iter()
            .filter(|k| !matches!(k, EventKind::PageClaim | EventKind::PrefixHit))
            .collect();
        assert_eq!(arc.first(), Some(&EventKind::Enqueue), "request {id}: {arc:?}");
        assert_eq!(arc.last(), Some(&EventKind::Complete), "request {id}: {arc:?}");
        let mut depth = 0i64; // +1 preempt, -1 resume; never negative, ends 0
        let mut admitted = false;
        for k in &arc {
            match k {
                EventKind::Admit => admitted = true,
                EventKind::Preempt => {
                    assert!(admitted, "request {id} preempted before admission: {arc:?}");
                    depth += 1;
                    assert_eq!(depth, 1, "request {id} preempted twice in a row: {arc:?}");
                }
                EventKind::Resume => {
                    depth -= 1;
                    assert_eq!(depth, 0, "request {id} resumed while running: {arc:?}");
                }
                EventKind::Complete => {
                    assert_eq!(depth, 0, "request {id} completed while preempted: {arc:?}");
                }
                _ => {}
            }
        }
    }
}

// =====================================================================
// 3. Drift sentinel: samples accumulate, decode stays bit-identical
// =====================================================================

#[test]
fn drift_sentinel_samples_sinq4_without_flips_or_token_changes() {
    let _g = lock();
    let mw = ModelWeights::synthetic(&ModelConfig::family("pico").unwrap(), 75);
    let qm = scheduler::quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
    let nb = NativeBackend::from_quantized(&qm);
    let cfg = EngineConfig::new().with_max_batch(2).with_max_context(32);
    assert_eq!(cfg.kv_bits, KvBits::F32, "this pin is about the kv32 path");

    let run = |cfg: &EngineConfig| {
        let mut dec = BatchDecoder::with_config(&nb, cfg).unwrap();
        dec.submit(0, b"sinq four bit", 8).unwrap();
        dec.submit(1, b"second req", 6).unwrap();
        dec.run().unwrap()
    };
    let plain = run(&cfg);

    drift::reset();
    let sentinel = run(&cfg.with_drift_sample(2));
    let snap = drift::snapshot();
    drift::reset();

    assert_eq!(sentinel, plain, "the sentinel must observe, never perturb");
    assert!(snap.samples >= 4, "1-in-2 sampling over ~13 steps: got {}", snap.samples);
    // At kv32 the sampled row's scalar recomputation sees the same cache
    // the fused path wrote, so the argmax never flips (acceptance
    // criterion); the numeric drift itself is ISA-dependent and may be
    // exactly zero on hosts that already dispatch the scalar kernels.
    assert_eq!(snap.argmax_flips, 0, "argmax flipped under kv32: {snap:?}");
    assert!(snap.max_abs_diff.is_finite() && snap.max_abs_diff >= 0.0);
    assert!(snap.max_rel_err.is_finite() && snap.max_rel_err >= 0.0);
}

// =====================================================================
// 4. The analyze-trace table folds the journal into per-request rows
// =====================================================================

#[test]
fn trace_table_reports_preemption_and_completion_per_request() {
    let _g = lock();
    journal::reset();
    let ctx = Ctx::with_backend("/nonexistent", true, BackendKind::Native).unwrap();
    let t = trace_table(&ctx, "pico").unwrap();
    assert_eq!(t.rows.len(), 3, "one row per submitted request");
    let mut preempts = 0u64;
    for (row, want_tokens) in t.rows.iter().zip(["9", "9", "5"]) {
        assert_eq!(row[5], want_tokens, "token count wrong: {row:?}");
        assert_eq!(row[7], "complete", "every request must finish: {row:?}");
        assert_ne!(row[6], "-", "completed rows carry a total latency");
        preempts += row[3].parse::<u64>().unwrap();
    }
    assert!(preempts >= 1, "the 8-page pool must force at least one preemption");
}
