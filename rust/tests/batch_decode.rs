//! Batched-vs-single decode parity: greedy tokens from the
//! continuous-batching `BatchDecoder` must **exactly** match the
//! single-sequence `NativeDecoder` per sequence — for RTN and SINQ at 4 and
//! 8 bits, at batch sizes 1/3/8, including staggered completion where slots
//! are recycled mid-run. Plus the serving-stack path (`BatchServer`
//! generation queue) and the KV-capacity rejection regression.

use std::time::Duration;

use sinq::backend::{BatchDecoder, EngineConfig, InferenceBackend, NativeBackend, NativeDecoder};
use sinq::coordinator::scheduler::{load_or_synthetic, quantize_simple};
use sinq::coordinator::server::BatchServer;
use sinq::quant::{Method, QuantConfig};

/// Varied prompts and staggered token budgets: sequences finish at
/// different steps, so slots are recycled whenever `slots < requests`.
fn requests() -> Vec<(Vec<u8>, usize)> {
    vec![
        (b"the quantized model".to_vec(), 9),
        (b"sinkhorn".to_vec(), 17),
        (b"fused kernels serve packed weights".to_vec(), 4),
        (b"a".to_vec(), 12),
        (b"batch decode parity".to_vec(), 7),
        (b"low bit precision".to_vec(), 15),
        (b"kv cache slots".to_vec(), 2),
        (b"native backend".to_vec(), 11),
    ]
}

fn single_tokens(be: &NativeBackend, prompt: &[u8], n: usize) -> Vec<u8> {
    let mut dec = NativeDecoder::new(be, prompt.len() + n + 1).expect("decoder");
    dec.generate(prompt, n).expect("single decode")
}

fn assert_parity(be: &NativeBackend, slots: usize, label: &str) {
    let reqs = requests();
    let capacity = reqs.iter().map(|(p, n)| p.len() + n + 1).max().unwrap();
    let mut dec = BatchDecoder::new(be, slots, capacity).expect("batch decoder");
    for (i, (prompt, n)) in reqs.iter().enumerate() {
        dec.submit(i, prompt, *n).expect("submit");
    }
    let outs = dec.run().expect("batched decode");
    assert_eq!(outs.len(), reqs.len(), "{label}: lost requests");
    for out in &outs {
        let (prompt, n) = &reqs[out.id];
        assert_eq!(out.tokens.len(), *n, "{label}: request {} short", out.id);
        assert_eq!(
            out.tokens,
            single_tokens(be, prompt, *n),
            "{label}: batched tokens diverged from NativeDecoder on request {}",
            out.id
        );
    }
    let stats = dec.stats();
    assert_eq!(stats.completed, reqs.len());
    let want_peak = slots.min(reqs.len());
    assert_eq!(stats.peak_batch, want_peak, "{label}: slots should fill completely");
}

/// The headline guarantee: RTN and SINQ at 4/8-bit on the tiny model,
/// batch sizes 1, 3 (slot recycling: 8 requests through 3 slots), and 8.
#[test]
fn batched_tokens_match_single_sequence_rtn_sinq_4_8_bit() {
    let mw = load_or_synthetic("/nonexistent", "tiny", 2001);
    for method in [Method::Rtn, Method::Sinq] {
        for bits in [4u32, 8] {
            let qm = quantize_simple(&mw, &QuantConfig::new(method, bits), None).unwrap();
            let be = NativeBackend::from_quantized(&qm);
            for slots in [1usize, 3, 8] {
                assert_parity(&be, slots, &format!("{} {}b batch {}", method.name(), bits, slots));
            }
        }
    }
}

/// Dense f32 weights take the per-row dot path in the batched kernels;
/// parity must hold there too (and on the MoE routing arm).
#[test]
fn batched_tokens_match_single_sequence_dense_and_moe() {
    for (family, seed) in [("pico", 2002u64), ("tiny_moe", 2003)] {
        let mw = load_or_synthetic("/nonexistent", family, seed);
        let be = NativeBackend::from_weights(&mw);
        assert_parity(&be, 3, &format!("{family} fp32 batch 3"));
    }
}

/// End-to-end through the serving stack: the `BatchServer` generation queue
/// groups concurrent clients into one continuous-batching dispatch, and the
/// answers still equal single-sequence decode exactly.
#[test]
fn server_generation_queue_matches_single_sequence() {
    let server = BatchServer::spawn(
        || {
            let mw = load_or_synthetic("/nonexistent", "tiny", 2001);
            let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None)?;
            Ok(NativeBackend::from_quantized(&qm)
                .with_engine(EngineConfig::new().with_max_batch(3)))
        },
        32,
        Duration::from_millis(2),
    );
    let client = server.client();
    let reqs = requests();
    let handles: Vec<_> = reqs
        .iter()
        .map(|(prompt, n)| {
            let c = client.clone();
            let (p, n) = (prompt.clone(), *n);
            std::thread::spawn(move || c.generate(p, n))
        })
        .collect();
    let served: Vec<Vec<u8>> = handles.into_iter().map(|h| h.join().unwrap().unwrap()).collect();
    let stats = server.shutdown();
    assert_eq!(stats.gen_requests, reqs.len());
    assert_eq!(stats.generated, reqs.iter().map(|(_, n)| n).sum::<usize>());

    let mw = load_or_synthetic("/nonexistent", "tiny", 2001);
    let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
    let be = NativeBackend::from_quantized(&qm);
    for ((prompt, n), got) in reqs.iter().zip(&served) {
        assert_eq!(got, &single_tokens(&be, prompt, *n), "served generation diverged");
    }
}

/// Regression: over-long requests are rejected with a clear error by both
/// decoders instead of overflowing the preallocated KV cache.
#[test]
fn both_decoders_reject_prompts_beyond_kv_capacity() {
    let mw = load_or_synthetic("/nonexistent", "pico", 2004);
    let be = NativeBackend::from_weights(&mw);

    let mut single = NativeDecoder::new(&be, 6).unwrap();
    let err = single.generate(b"this prompt is far too long", 4).unwrap_err();
    assert!(err.to_string().contains("KV"), "unclear single-decoder error: {err}");
    assert_eq!(single.pos, 0, "failed request must not consume cache positions");

    let mut batch = BatchDecoder::new(&be, 2, 6).unwrap();
    let err = batch.submit(0, b"this prompt is far too long", 4).unwrap_err();
    assert!(err.to_string().contains("KV"), "unclear batch-decoder error: {err}");
    batch.submit(1, b"fits", 3).unwrap();
    let outs = batch.run().unwrap();
    assert_eq!(outs.len(), 1, "the fitting request must still complete");
    assert_eq!(outs[0].tokens.len(), 3);
}

/// `generate` through the `InferenceBackend` trait object must agree with
/// the batched entry point (the server dispatches through the latter).
#[test]
fn trait_generate_and_generate_batch_agree() {
    let mw = load_or_synthetic("/nonexistent", "tiny", 2005);
    let qm = quantize_simple(&mw, &QuantConfig::new(Method::Rtn, 4), None).unwrap();
    let mut be: Box<dyn InferenceBackend> = Box::new(
        NativeBackend::from_quantized(&qm).with_engine(EngineConfig::new().with_max_batch(4)),
    );
    let prompts: Vec<Vec<u8>> = vec![b"alpha".to_vec(), b"bravo charlie".to_vec()];
    let prompt_refs: Vec<&[u8]> = prompts.iter().map(|p| p.as_slice()).collect();
    let batched = be.generate_batch(&prompt_refs, &[10, 6]).unwrap();
    assert_eq!(batched[0], be.generate(b"alpha", 10).unwrap());
    assert_eq!(batched[1], be.generate(b"bravo charlie", 6).unwrap());
}
