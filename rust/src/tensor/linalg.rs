//! Dense linear algebra needed by GPTQ: symmetric positive-definite Cholesky
//! factorization, triangular solves, and SPD inversion.
//!
//! GPTQ quantizes weight columns in sequence and compensates the remaining
//! columns through the inverse Hessian `H⁻¹ = (2XᵀX + λI)⁻¹`; its reference
//! implementation works with the upper Cholesky factor of `H⁻¹`, which is
//! exactly what [`cholesky_inverse_upper`] produces.

use crate::tensor::Matrix;

/// Cholesky factorization A = L·Lᵀ (L lower-triangular). `A` must be
/// symmetric positive definite; returns `None` if a pivot is non-positive.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols, "cholesky needs square input");
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = s.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (s / l.at(j, j) as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Solve L·y = b for lower-triangular L.
pub fn solve_lower(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= l.at(i, k) as f64 * y[k] as f64;
        }
        y[i] = (s / l.at(i, i) as f64) as f32;
    }
    y
}

/// Solve Lᵀ·x = y for lower-triangular L.
pub fn solve_lower_t(l: &Matrix, y: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = y[i] as f64;
        for k in i + 1..n {
            s -= l.at(k, i) as f64 * x[k] as f64;
        }
        x[i] = (s / l.at(i, i) as f64) as f32;
    }
    x
}

/// Invert an SPD matrix via Cholesky. Returns `None` if not SPD.
pub fn spd_inverse(a: &Matrix) -> Option<Matrix> {
    let l = cholesky(a)?;
    let n = a.rows;
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for j in 0..n {
        e.fill(0.0);
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for i in 0..n {
            *inv.at_mut(i, j) = x[i];
        }
    }
    Some(inv)
}

/// Upper Cholesky factor `U` of `A⁻¹` with `A⁻¹ = Uᵀ·U`... specifically the
/// factor GPTQ uses: compute `A⁻¹`, then return `C` upper-triangular with
/// `A⁻¹ = CᵀC` is *not* what GPTQ wants — GPTQ uses `A⁻¹ = C·Cᵀ` with `C`
/// upper triangular, i.e. the reverse-ordered Cholesky. We obtain it by
/// Cholesky-factorizing the reversed-permutation of `A⁻¹`.
pub fn cholesky_inverse_upper(a: &Matrix) -> Option<Matrix> {
    let inv = spd_inverse(a)?;
    let n = inv.rows;
    // P·inv·P with P the reversal permutation.
    let rev = Matrix::from_fn(n, n, |i, j| inv.at(n - 1 - i, n - 1 - j));
    let l = cholesky(&rev)?;
    // Undo the reversal: U[i,j] = L[n-1-i, n-1-j] is upper-triangular and
    // satisfies inv = U·Uᵀ.
    Some(Matrix::from_fn(n, n, |i, j| l.at(n - 1 - i, n - 1 - j)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let mut a = b.matmul_nt(&b); // B·Bᵀ is PSD
        for i in 0..n {
            *a.at_mut(i, i) += 0.5 * n as f32; // make strictly PD
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 1);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul_nt(&l);
        assert!(a.dist(&rec) / a.dist(&Matrix::zeros(12, 12)) < 1e-4);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solves_are_consistent() {
        let a = random_spd(8, 2);
        let l = cholesky(&a).unwrap();
        let b: Vec<f32> = (0..8).map(|i| i as f32 - 3.0).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // Check A·x ≈ b.
        for i in 0..8 {
            let got: f32 = (0..8).map(|j| a.at(i, j) * x[j]).sum();
            assert!((got - b[i]).abs() < 1e-3, "row {i}: {got} vs {}", b[i]);
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let a = random_spd(10, 3);
        let inv = spd_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        for i in 0..10 {
            for j in 0..10 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - expect).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn inverse_upper_factor_property() {
        // cholesky_inverse_upper returns upper-triangular U with A⁻¹ = U·Uᵀ.
        let a = random_spd(9, 4);
        let u = cholesky_inverse_upper(&a).unwrap();
        for i in 0..9 {
            for j in 0..i {
                assert_eq!(u.at(i, j), 0.0, "U not upper triangular at ({i},{j})");
            }
        }
        let inv = spd_inverse(&a).unwrap();
        let rec = u.matmul_nt(&u);
        assert!(inv.dist(&rec) < 1e-3 * (1.0 + inv.dist(&Matrix::zeros(9, 9))));
    }
}
