//! Row-major dense `f32` matrix with the operations the quantizer zoo and the
//! reference transformer need.
//!
//! Performance notes (single CPU core, no SIMD intrinsics): `matmul_nt`
//! (A·Bᵀ) is the workhorse — its inner loop is a dot product of two
//! contiguous rows which LLVM auto-vectorizes; `matmul` uses the i-k-j order
//! so the innermost loop streams both `B` and `C` rows. Benchmarked in
//! `benches/lib_micro.rs` and tuned in EXPERIMENTS.md §Perf.

use crate::tensor::Rng;

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// i.i.d. normal entries (LeCun-style scale by default callers choose).
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| rng.normal_f32(0.0, std))
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    /// Reuse this matrix's allocation for a new shape, zero-filling the
    /// contents — the decode-step scratch buffers call this every step so
    /// the hot path reallocates only when a shape grows past its high-water
    /// mark.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// C = self · other, shapes (m,k)×(k,n).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let c_row = &mut c.data[i * n..(i + 1) * n];
            for (p, &a) in a_row.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[p * n..(p + 1) * n];
                for (cv, &bv) in c_row.iter_mut().zip(b_row.iter()) {
                    *cv += a * bv;
                }
            }
        }
        c
    }

    /// C = self · otherᵀ, shapes (m,k)×(n,k) → (m,n). The linear-layer form
    /// `y = x·Wᵀ`.
    ///
    /// §Perf: 2×4 register blocking — two A rows and four B rows are
    /// streamed together so each B row (the weight matrix, usually the
    /// larger operand) is read once per *pair* of activations instead of
    /// once per activation, and the 8 accumulators give the scalar pipeline
    /// enough ILP to auto-vectorize. Measured 4.79 → ~11 GFLOP/s on the
    /// bench shape (see EXPERIMENTS.md §Perf).
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut c = Matrix::zeros(m, n);
        let mut i = 0;
        while i + 2 <= m {
            let a0 = self.row(i);
            let a1 = self.row(i + 1);
            let mut j = 0;
            while j + 4 <= n {
                let b0 = other.row(j);
                let b1 = other.row(j + 1);
                let b2 = other.row(j + 2);
                let b3 = other.row(j + 3);
                let mut acc = [0.0f32; 8];
                for p in 0..k {
                    let (x0, x1) = (a0[p], a1[p]);
                    acc[0] += x0 * b0[p];
                    acc[1] += x0 * b1[p];
                    acc[2] += x0 * b2[p];
                    acc[3] += x0 * b3[p];
                    acc[4] += x1 * b0[p];
                    acc[5] += x1 * b1[p];
                    acc[6] += x1 * b2[p];
                    acc[7] += x1 * b3[p];
                }
                c.data[i * n + j..i * n + j + 4].copy_from_slice(&acc[..4]);
                c.data[(i + 1) * n + j..(i + 1) * n + j + 4].copy_from_slice(&acc[4..]);
                j += 4;
            }
            while j < n {
                let b = other.row(j);
                c.data[i * n + j] = dot(a0, b, k);
                c.data[(i + 1) * n + j] = dot(a1, b, k);
                j += 1;
            }
            i += 2;
        }
        if i < m {
            let a_row = self.row(i);
            for j in 0..n {
                c.data[i * n + j] = dot(a_row, other.row(j), k);
            }
        }
        c
    }

    /// Frobenius norm of the difference.
    pub fn dist(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = a - b;
                d * d
            })
            .sum::<f32>()
            .sqrt()
    }

    /// Mean squared error against `other`.
    pub fn mse(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / self.data.len() as f64
    }

    /// Multiply row `i` by `s[i]` in place.
    pub fn scale_rows(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.rows);
        for i in 0..self.rows {
            let f = s[i];
            for v in self.row_mut(i) {
                *v *= f;
            }
        }
    }

    /// Multiply column `j` by `t[j]` in place.
    pub fn scale_cols(&mut self, t: &[f32]) {
        assert_eq!(t.len(), self.cols);
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            for (v, &f) in row.iter_mut().zip(t.iter()) {
                *v *= f;
            }
        }
    }

    /// Divide rows / cols (used by the Sinkhorn loop).
    pub fn div_rows(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.rows);
        for i in 0..self.rows {
            let f = 1.0 / s[i];
            for v in self.row_mut(i) {
                *v *= f;
            }
        }
    }

    pub fn div_cols(&mut self, t: &[f32]) {
        assert_eq!(t.len(), self.cols);
        let inv: Vec<f32> = t.iter().map(|&x| 1.0 / x).collect();
        self.scale_cols(&inv);
    }

    /// Slice of columns `[j0, j1)` as a new matrix (a weight-group view).
    pub fn col_slice(&self, j0: usize, j1: usize) -> Matrix {
        assert!(j0 <= j1 && j1 <= self.cols);
        let mut m = Matrix::zeros(self.rows, j1 - j0);
        for i in 0..self.rows {
            m.row_mut(i).copy_from_slice(&self.row(i)[j0..j1]);
        }
        m
    }

    /// Write `block` back into columns `[j0, ...)`.
    pub fn set_col_slice(&mut self, j0: usize, block: &Matrix) {
        assert_eq!(block.rows, self.rows);
        assert!(j0 + block.cols <= self.cols);
        for i in 0..self.rows {
            self.row_mut(i)[j0..j0 + block.cols].copy_from_slice(block.row(i));
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Contiguous dot product, 4-way unrolled so LLVM vectorizes it.
#[inline]
pub fn dot(a: &[f32], b: &[f32], k: usize) -> f32 {
    let (a, b) = (&a[..k], &b[..k]);
    let mut acc = [0.0f32; 4];
    let chunks = k / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..k {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_manual() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_nt_agrees_with_matmul() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(5, 7, 1.0, &mut rng);
        let b = Matrix::randn(7, 4, 1.0, &mut rng);
        let c1 = a.matmul(&b);
        let c2 = a.matmul_nt(&b.transpose());
        assert!(c1.dist(&c2) < 1e-4);
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(33, 65, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn scale_and_div_are_inverse() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(8, 6, 1.0, &mut rng);
        let s: Vec<f32> = (0..8).map(|i| 1.0 + i as f32).collect();
        let t: Vec<f32> = (0..6).map(|j| 0.5 + j as f32).collect();
        let mut b = a.clone();
        b.scale_rows(&s);
        b.scale_cols(&t);
        b.div_cols(&t);
        b.div_rows(&s);
        assert!(a.dist(&b) < 1e-4);
    }

    #[test]
    fn col_slice_round_trip() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(4, 10, 1.0, &mut rng);
        let block = a.col_slice(2, 7);
        assert_eq!(block.cols, 5);
        let mut b = Matrix::zeros(4, 10);
        b.set_col_slice(2, &block);
        for i in 0..4 {
            for j in 2..7 {
                assert_eq!(b.at(i, j), a.at(i, j));
            }
        }
    }

    #[test]
    fn dot_handles_remainders() {
        for k in [0usize, 1, 3, 4, 5, 17] {
            let a: Vec<f32> = (0..k).map(|i| i as f32).collect();
            let b: Vec<f32> = (0..k).map(|i| (i * 2) as f32).collect();
            let expect: f32 = (0..k).map(|i| (i * i * 2) as f32).sum();
            assert_eq!(dot(&a, &b, k), expect, "k={k}");
        }
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_check() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
