//! Statistics used throughout the paper's analysis: row/column standard
//! deviations (the quantities Algorithm 1 normalizes), kurtosis (Fig. 2c /
//! Fig. 7), the matrix imbalance `I(W)` (Eq. 5), quantiles, and the
//! coefficient of determination R² (Fig. 2a / Fig. 6).

use crate::tensor::Matrix;

/// Mean of a slice.
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f32]) -> f64 {
    variance(xs).sqrt()
}

/// Excess-free (Pearson) kurtosis: E[(x-μ)⁴]/σ⁴. Normal = 3.
pub fn kurtosis(xs: &[f32]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let n = xs.len() as f64;
    let m2 = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / n;
    let m4 = xs.iter().map(|&x| (x as f64 - m).powi(4)).sum::<f64>() / n;
    if m2 <= 0.0 {
        0.0
    } else {
        m4 / (m2 * m2)
    }
}

/// Per-row standard deviations σ_i^row(W).
pub fn row_stds(w: &Matrix) -> Vec<f64> {
    (0..w.rows).map(|i| std_dev(w.row(i))).collect()
}

/// Per-column standard deviations σ_j^col(W).
pub fn col_stds(w: &Matrix) -> Vec<f64> {
    let mut sums = vec![0.0f64; w.cols];
    let mut sqs = vec![0.0f64; w.cols];
    for i in 0..w.rows {
        for (j, &v) in w.row(i).iter().enumerate() {
            sums[j] += v as f64;
            sqs[j] += (v as f64) * (v as f64);
        }
    }
    let n = w.rows as f64;
    sums.iter()
        .zip(&sqs)
        .map(|(&s, &q)| {
            let m = s / n;
            (q / n - m * m).max(0.0).sqrt()
        })
        .collect()
}

/// Mean per-row kurtosis (Fig. 2c / Fig. 7 metric).
pub fn mean_row_kurtosis(w: &Matrix) -> f64 {
    let ks: Vec<f64> = (0..w.rows).map(|i| kurtosis(w.row(i))).collect();
    ks.iter().sum::<f64>() / ks.len().max(1) as f64
}

/// Matrix imbalance (Eq. 5):
/// `I(W) = max(max_i σ_row_i, max_j σ_col_j) / min(min_i σ_row_i, min_j σ_col_j)`.
pub fn imbalance(w: &Matrix) -> f64 {
    let rs = row_stds(w);
    let cs = col_stds(w);
    let hi = rs
        .iter()
        .chain(cs.iter())
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max);
    let lo = rs.iter().chain(cs.iter()).cloned().fold(f64::INFINITY, f64::min);
    if lo <= 0.0 {
        f64::INFINITY
    } else {
        hi / lo
    }
}

/// q-quantile (0..=1) by sorting a copy (fine at our sizes).
pub fn quantile(xs: &[f32], q: f64) -> f32 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = (pos - lo as f64) as f32;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Pearson correlation of two equally-long sequences.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx <= 0.0 || syy <= 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Coefficient of determination of the best linear fit y ≈ a·x + b
/// (equals pearson² for simple linear regression; this is the R² the paper
/// reports between 1/σ_col and μ_x).
pub fn r_squared(x: &[f64], y: &[f64]) -> f64 {
    let r = pearson(x, y);
    r * r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn std_of_constant_is_zero() {
        assert_eq!(std_dev(&[2.0; 10]), 0.0);
    }

    #[test]
    fn known_variance() {
        // Population variance of [1..5] is 2.
        let xs = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        assert!((variance(&xs) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn kurtosis_of_gaussian_near_3() {
        let mut rng = Rng::new(10);
        let xs: Vec<f32> = (0..100_000).map(|_| rng.normal() as f32).collect();
        let k = kurtosis(&xs);
        assert!((k - 3.0).abs() < 0.15, "kurtosis {k}");
    }

    #[test]
    fn kurtosis_of_heavy_tail_exceeds_3() {
        let mut rng = Rng::new(11);
        let xs: Vec<f32> = (0..50_000).map(|_| rng.laplace(1.0) as f32).collect();
        assert!(kurtosis(&xs) > 4.5); // Laplace kurtosis = 6
    }

    #[test]
    fn row_col_stds_agree_with_direct() {
        let mut rng = Rng::new(12);
        let w = Matrix::randn(13, 9, 2.0, &mut rng);
        let rs = row_stds(&w);
        let cs = col_stds(&w);
        for i in 0..13 {
            assert!((rs[i] - std_dev(w.row(i))).abs() < 1e-9);
        }
        for j in 0..9 {
            assert!((cs[j] - std_dev(&w.col(j))).abs() < 1e-6);
        }
    }

    #[test]
    fn imbalance_of_scaled_rows_grows() {
        let mut rng = Rng::new(13);
        let w = Matrix::randn(16, 16, 1.0, &mut rng);
        let base = imbalance(&w);
        let mut scaled = w.clone();
        scaled.scale_rows(&(0..16).map(|i| 1.0 + i as f32).collect::<Vec<_>>());
        assert!(imbalance(&scaled) > base * 2.0);
    }

    #[test]
    fn quantile_endpoints() {
        let xs = [3.0f32, 1.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 3.0);
        assert_eq!(quantile(&xs, 0.5), 2.0);
    }

    #[test]
    fn r2_of_linear_relation_is_one() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v - 7.0).collect();
        assert!((r_squared(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r2_of_noise_is_small() {
        let mut rng = Rng::new(14);
        let x: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        assert!(r_squared(&x, &y) < 0.01);
    }
}
