//! Dense tensor substrate: matrices, RNG, statistics, and linear algebra.
//!
//! Everything downstream (the quantizer zoo, the reference transformer
//! forward, the evaluators) is built on these primitives. The only storage
//! type is `f32`; reduced-precision behaviour is modelled by round-tripping
//! through [`crate::util::half`] or the quantization grids in [`crate::fmt`].

pub mod linalg;
pub mod matrix;
pub mod rng;
pub mod stats;

pub use matrix::Matrix;
pub use rng::Rng;
