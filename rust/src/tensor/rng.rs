//! Deterministic pseudo-random number generation (no `rand` offline).
//!
//! A SplitMix64-seeded xoshiro256** generator with normal/uniform sampling.
//! All experiments are seeded so every table in `EXPERIMENTS.md` is exactly
//! reproducible.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from the Box–Muller pair.
    spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 expansion of `seed`.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare: None }
    }

    /// Derive an independent stream (for per-layer / per-worker seeding).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Normal with mean/std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Laplace(0, b) — heavy-tailed weights for outlier experiments.
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.uniform() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Student-t with `nu` degrees of freedom (heavy tails for synthetic
    /// LLM-like weight matrices; nu≈4 matches observed LLM kurtosis).
    pub fn student_t(&mut self, nu: f64) -> f64 {
        // t = Z / sqrt(ChiSq(nu)/nu); ChiSq via sum of squared normals for
        // integer nu (small nu only, which is all we use).
        let z = self.normal();
        let k = nu.round().max(1.0) as usize;
        let chi: f64 = (0..k).map(|_| self.normal().powi(2)).sum();
        z / (chi / nu).sqrt()
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(123);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn student_t_heavier_tails_than_normal() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let t_big = (0..n).filter(|_| r.student_t(4.0).abs() > 4.0).count();
        let z_big = (0..n).filter(|_| r.normal().abs() > 4.0).count();
        assert!(t_big > z_big * 5, "t tails {t_big} vs normal {z_big}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "frac {frac2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }
}
