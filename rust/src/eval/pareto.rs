//! Memory-perplexity Pareto fronts (Figs. 4 and 5).

/// One evaluated configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ParetoPoint {
    pub label: String,
    pub memory_gb: f64,
    pub ppl: f64,
}

/// Return the non-dominated subset, sorted by memory: a point survives if no
/// other point has both ≤ memory and ≤ ppl (with at least one strict).
pub fn pareto_front(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    let mut front: Vec<ParetoPoint> = points
        .iter()
        .filter(|p| {
            !points.iter().any(|q| {
                (q.memory_gb <= p.memory_gb && q.ppl <= p.ppl)
                    && (q.memory_gb < p.memory_gb || q.ppl < p.ppl)
            })
        })
        .cloned()
        .collect();
    front.sort_by(|a, b| a.memory_gb.partial_cmp(&b.memory_gb).unwrap());
    front.dedup();
    front
}

/// Max vertical (ppl) distance of `method`'s points from the front built
/// over *all* points — the "< 0.01 ppl from the 4-bit Pareto front" claim.
pub fn distance_from_front(all: &[ParetoPoint], method_points: &[ParetoPoint]) -> f64 {
    let front = pareto_front(all);
    method_points
        .iter()
        .map(|p| {
            // Best ppl achievable on the front at ≤ the same memory.
            let best = front
                .iter()
                .filter(|f| f.memory_gb <= p.memory_gb + 1e-12)
                .map(|f| f.ppl)
                .fold(f64::INFINITY, f64::min);
            (p.ppl - best).max(0.0)
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(label: &str, m: f64, p: f64) -> ParetoPoint {
        ParetoPoint { label: label.into(), memory_gb: m, ppl: p }
    }

    #[test]
    fn dominated_points_removed() {
        let pts = vec![pt("a", 1.0, 10.0), pt("b", 2.0, 9.0), pt("c", 1.5, 12.0), pt("d", 0.9, 15.0)];
        let front = pareto_front(&pts);
        let labels: Vec<&str> = front.iter().map(|p| p.label.as_str()).collect();
        assert_eq!(labels, vec!["d", "a", "b"]); // c dominated by a
    }

    #[test]
    fn front_of_front_is_identity() {
        let pts = vec![pt("a", 1.0, 10.0), pt("b", 2.0, 9.0)];
        assert_eq!(pareto_front(&pareto_front(&pts)), pareto_front(&pts));
    }

    #[test]
    fn distance_zero_when_on_front() {
        let pts = vec![pt("a", 1.0, 10.0), pt("b", 2.0, 9.0)];
        assert_eq!(distance_from_front(&pts, &[pts[0].clone()]), 0.0);
        let off = pt("c", 2.0, 9.5);
        let mut all = pts.clone();
        all.push(off.clone());
        assert!((distance_from_front(&all, &[off]) - 0.5).abs() < 1e-9);
    }
}
