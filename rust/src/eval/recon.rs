//! Reconstruction analysis over a real model's layers (Fig. 3) and the
//! kurtosis diagnostics (Figs. 2c, 7).

use crate::model::forward::{Capture, Forward};
use crate::model::ModelWeights;
use crate::quant::{metrics, quantize_matrix, Calibration, Method, QuantConfig};
use crate::tensor::{stats, Matrix};

/// Per-layer Fig. 3 record: matrix and activation reconstruction error
/// deltas of a method vs RTN (negative = better than RTN).
#[derive(Debug, Clone)]
pub struct ReconRow {
    pub layer: String,
    pub matrix_delta: f64,
    pub activation_delta: f64,
}

/// Capture activations on a corpus sample, then compare `method` vs RTN on
/// the named layers (the paper uses the attention layers).
pub fn recon_analysis(
    mw: &ModelWeights,
    sample: &[u8],
    layers: &[String],
    method: Method,
    bits: u32,
) -> anyhow::Result<Vec<ReconRow>> {
    let mut cap = Capture::new(64);
    let fwd = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
    // A couple of windows is enough for stable estimates at this scale.
    for w in sample.chunks(128).take(4) {
        let _ = fwd.forward(w, Some(&mut cap));
    }

    let mut rows = Vec::new();
    for name in layers {
        let w = &mw.tensors[name];
        let x = cap
            .calibration(name)
            .ok_or_else(|| anyhow::anyhow!("no capture for layer {name}"))?;
        let calib = Calibration::from_activations(x.clone());

        let q_rtn = quantize_matrix(w, &QuantConfig::new(Method::Rtn, bits), Some(&calib))?;
        let q_m = quantize_matrix(w, &QuantConfig::new(method, bits), Some(&calib))?;

        rows.push(ReconRow {
            layer: name.clone(),
            matrix_delta: metrics::weight_recon_error(w, &q_m)
                - metrics::weight_recon_error(w, &q_rtn),
            activation_delta: metrics::activation_recon_error(&x, w, &q_m)
                - metrics::activation_recon_error(&x, w, &q_rtn),
        });
    }
    Ok(rows)
}

/// Mean row-wise kurtosis of the matrix each method actually rounds
/// (Fig. 2c / Fig. 7): original, naive column-scaled, SINQ-normalized, and
/// AWQ- vs ASINQ-scaled when calibration is available.
#[derive(Debug, Clone)]
pub struct KurtosisRow {
    pub layer: String,
    pub original: f64,
    pub naive_col: f64,
    pub sinq: f64,
    pub awq: f64,
    pub asinq: f64,
}

pub fn kurtosis_analysis(
    mw: &ModelWeights,
    sample: &[u8],
    layers: &[String],
) -> anyhow::Result<Vec<KurtosisRow>> {
    let mut cap = Capture::new(64);
    let fwd = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
    for w in sample.chunks(128).take(4) {
        let _ = fwd.forward(w, Some(&mut cap));
    }

    let mut rows = Vec::new();
    for name in layers {
        let w = &mw.tensors[name];
        let original = stats::mean_row_kurtosis(w);

        let cs: Vec<f32> = stats::col_stds(w).iter().map(|&x| x.max(1e-9) as f32).collect();
        let mut naive = w.clone();
        naive.div_cols(&cs);

        let sk = crate::quant::sinq::sinkhorn_normalize(w, 24, (0.5, 2.0));
        let mut sq = w.clone();
        sq.div_rows(&sk.row);
        sq.div_cols(&sk.col);

        // AWQ scaling (α=0.5 operating point) vs ASINQ (sinq-then-awq).
        let mu = cap
            .mean_abs(name)
            .ok_or_else(|| anyhow::anyhow!("no capture for layer {name}"))?;
        let c = crate::quant::awq::awq_scales(&mu, 0.5);
        let mut awq_m = w.clone();
        awq_m.scale_cols(&c);
        let mut asinq_m = sq.clone();
        asinq_m.scale_cols(&c);

        rows.push(KurtosisRow {
            layer: name.clone(),
            original,
            naive_col: stats::mean_row_kurtosis(&naive),
            sinq: stats::mean_row_kurtosis(&sq),
            awq: stats::mean_row_kurtosis(&awq_m),
            asinq: stats::mean_row_kurtosis(&asinq_m),
        });
    }
    Ok(rows)
}

/// Fig. 1 demo: single-scale vs dual-scale quantization error on a small
/// matrix with row/column scale structure plus an outlier (the setting the
/// figure illustrates; on a tiny *i.i.d.* matrix there is no structure for
/// the second scale to exploit). Returns (single_mse, dual_mse, W).
pub fn dual_scale_demo() -> (f64, f64, Matrix) {
    use crate::tensor::Rng;
    let n = 16;
    let mut rng = Rng::new(7);
    let r: Vec<f32> = (0..n).map(|_| 0.25 + 2.0 * rng.uniform() as f32).collect();
    let c: Vec<f32> = (0..n).map(|_| 0.25 + 2.0 * rng.uniform() as f32).collect();
    let mut w = Matrix::from_fn(n, n, |_, _| rng.normal_f32(0.0, 1.0));
    w.scale_rows(&r);
    w.scale_cols(&c);
    *w.at_mut(1, 2) = 6.0; // the outlier of Fig. 1's right panel
    let cfg3 = QuantConfig::new(Method::Rtn, 3).with_group(n);
    let single = quantize_matrix(&w, &cfg3, None).unwrap().dequantize().mse(&w);
    let cfg3s = QuantConfig::new(Method::Sinq, 3).with_group(n);
    let dual = quantize_matrix(&w, &cfg3s, None).unwrap().dequantize().mse(&w);
    (single, dual, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn fig1_dual_scale_beats_single_on_outlier_matrix() {
        let (single, dual, _) = dual_scale_demo();
        assert!(dual < single, "dual {dual:.4} vs single {single:.4}");
    }

    #[test]
    fn recon_rows_cover_requested_layers() {
        let cfg = ModelConfig::family("pico").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 41);
        let layers = vec!["layers.0.wq".to_string(), "layers.1.wo".to_string()];
        let rows =
            recon_analysis(&mw, &b"sample text for capture ".repeat(30), &layers, Method::Sinq, 3)
                .unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.matrix_delta.is_finite()));
    }

    #[test]
    fn kurtosis_rows_finite() {
        let cfg = ModelConfig::family("pico").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 42);
        let layers = vec!["layers.0.wq".to_string()];
        let rows = kurtosis_analysis(&mw, &b"kurtosis capture sample ".repeat(30), &layers).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        for v in [r.original, r.naive_col, r.sinq, r.awq, r.asinq] {
            assert!(v.is_finite() && v > 0.0);
        }
    }
}
