//! Fig. 2a / 2b / Fig. 6: the pseudo-activation-awareness statistics.
//!
//! * `r2_analysis` — per-layer R² between `log(1/σ_col(W))` and `log(μ_x)`
//!   on a real (trained) model, plus the shuffled-baseline control the paper
//!   plots, and R² of the SINQ-derived `t` with `μ_x`.
//! * `adam_scaling_experiment` — the single-layer Adam stationarity
//!   experiment behind Fig. 2b, reporting the fitted power-law exponent of
//!   `σ_W` vs `s_x` (paper: −1/2).

use crate::model::forward::{Capture, Forward};
use crate::model::ModelWeights;
use crate::quant::sinq::sinkhorn_normalize;
use crate::tensor::{stats, Matrix, Rng};

/// One layer's Fig. 2a record.
#[derive(Debug, Clone)]
pub struct R2Row {
    pub layer: String,
    /// R²(log 1/σ_col, log μ_x) — the paper's headline statistic.
    pub r2_std: f64,
    /// Shuffled control (should be ≈ 0).
    pub r2_shuffled: f64,
    /// R²(log t_sinq, log μ_x) — the paper finds this ≥ r2_std.
    pub r2_t: f64,
}

/// Compute Fig. 2a statistics for every quantizable layer of a model.
pub fn r2_analysis(mw: &ModelWeights, sample: &[u8], seed: u64) -> anyhow::Result<Vec<R2Row>> {
    let mut cap = Capture::new(32);
    let fwd = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
    for w in sample.chunks(128).take(6) {
        let _ = fwd.forward(w, Some(&mut cap));
    }
    let mut rng = Rng::new(seed);
    let mut rows = Vec::new();
    for name in mw.cfg.quantizable_names() {
        let Some(mu) = cap.mean_abs(&name) else { continue };
        let w = &mw.tensors[&name];
        let cs = stats::col_stds(w);
        let log_inv_std: Vec<f64> = cs.iter().map(|&s| -(s.max(1e-12)).ln()).collect();
        let log_mu: Vec<f64> = mu.iter().map(|&m| (m.max(1e-12) as f64).ln()).collect();

        let mut shuffled = log_inv_std.clone();
        rng.shuffle(&mut shuffled);

        let sk = sinkhorn_normalize(w, 24, (0.5, 2.0));
        let log_t: Vec<f64> = sk.col.iter().map(|&t| (t.max(1e-12) as f64).ln()).collect();

        rows.push(R2Row {
            layer: name,
            r2_std: stats::r_squared(&log_inv_std, &log_mu),
            r2_shuffled: stats::r_squared(&shuffled, &log_mu),
            r2_t: stats::r_squared(&log_t, &log_mu),
        });
    }
    Ok(rows)
}

/// Fig. 2b: train one linear layer with Adam on a pure-noise target with
/// per-channel input scales; fit `log σ_col(W) = a·log s_x + b` and return
/// `(a, R²)`. The paper's prediction: `a ≈ −1/2`.
pub fn adam_scaling_experiment(
    nout: usize,
    nin: usize,
    steps: usize,
    seed: u64,
) -> (f64, f64, Vec<f32>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let bs = 16usize;
    let s_x: Vec<f32> =
        (0..nin).map(|_| (0.1f64 + rng.laplace(0.6).abs().exp()) as f32 * 0.3).collect();
    let mut w = Matrix::randn(nout, nin, 0.01, &mut rng);
    let (mut m, mut v) = (Matrix::zeros(nout, nin), Matrix::zeros(nout, nin));
    let (b1, b2, lr, eps) = (0.9f32, 0.999f32, 2e-3f32, 1e-8f32);
    for t in 1..=steps as i32 {
        let mut x = Matrix::from_fn(bs, nin, |_, _| rng.normal_f32(0.0, 1.0));
        x.scale_cols(&s_x);
        let yh = x.matmul_nt(&w);
        let mut d = Matrix::zeros(bs, nout);
        for i in 0..bs * nout {
            d.data[i] = yh.data[i] + rng.normal_f32(0.0, 1.0);
        }
        let g = d.transpose().matmul(&x);
        for idx in 0..w.data.len() {
            let gi = g.data[idx] / bs as f32;
            m.data[idx] = b1 * m.data[idx] + (1.0 - b1) * gi;
            v.data[idx] = b2 * v.data[idx] + (1.0 - b2) * gi * gi;
            let mh = m.data[idx] / (1.0 - b1.powi(t));
            let vh = v.data[idx] / (1.0 - b2.powi(t));
            w.data[idx] -= lr * mh / (vh.sqrt() + eps);
        }
    }
    let cs = stats::col_stds(&w);
    let lx: Vec<f64> = s_x.iter().map(|&s| (s as f64).ln()).collect();
    let ly: Vec<f64> = cs.iter().map(|&c| c.max(1e-12).ln()).collect();
    let slope = fit_slope(&lx, &ly);
    let r2 = stats::r_squared(&lx, &ly);
    (slope, r2, s_x, cs)
}

/// Least-squares slope of y on x.
pub fn fit_slope(x: &[f64], y: &[f64]) -> f64 {
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let sxy: f64 = x.iter().zip(y).map(|(&a, &b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|&a| (a - mx) * (a - mx)).sum();
    sxy / sxx.max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn fit_slope_exact_line() {
        let x: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| -0.5 * v + 3.0).collect();
        assert!((fit_slope(&x, &y) + 0.5).abs() < 1e-12);
    }

    #[test]
    fn adam_experiment_recovers_minus_half() {
        // Fig. 2b: the stationary exponent is ≈ −1/2.
        let (slope, r2, _, _) = adam_scaling_experiment(32, 64, 1200, 99);
        assert!(r2 > 0.5, "R² {r2}");
        assert!((slope + 0.5).abs() < 0.22, "slope {slope}");
    }

    #[test]
    fn r2_rows_on_synthetic_model() {
        let cfg = ModelConfig::family("pico").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 51);
        let rows = r2_analysis(&mw, &b"r2 capture text sample ".repeat(40), 1).unwrap();
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(r.r2_std.is_finite() && r.r2_shuffled.is_finite() && r.r2_t.is_finite());
            assert!((0.0..=1.0).contains(&r.r2_std));
        }
    }
}
