//! Perplexity evaluation (the Wiki2 / C4 columns of Tables 1, 3, 4, 8, 9).

use super::{log_prob, LogitsEngine};
use crate::backend::InferenceBackend;
use crate::data::Corpus;

/// Perplexity over non-overlapping `seq`-length windows of a corpus:
/// `exp(mean NLL)` of next-token prediction, teacher-forced.
pub fn perplexity(
    engine: &mut dyn LogitsEngine,
    corpus: &Corpus,
    seq: usize,
    max_windows: usize,
) -> anyhow::Result<f64> {
    let windows = corpus.eval_windows(seq, max_windows);
    anyhow::ensure!(!windows.is_empty(), "corpus too small for seq {seq}");
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for w in windows {
        let logits = engine.logits(w)?;
        for p in 0..w.len() - 1 {
            nll -= log_prob(logits.row(p), w[p + 1]);
            count += 1;
        }
    }
    Ok((nll / count as f64).exp())
}

/// Perplexity through an [`InferenceBackend`], batching windows up to the
/// backend's `max_batch` per dispatch — the serving-path equivalent of
/// [`perplexity`], used by `sinq eval --backend native|pjrt`.
pub fn perplexity_backend(
    backend: &mut dyn InferenceBackend,
    corpus: &Corpus,
    seq: usize,
    max_windows: usize,
) -> anyhow::Result<f64> {
    let windows = corpus.eval_windows(seq, max_windows);
    anyhow::ensure!(!windows.is_empty(), "corpus too small for seq {seq}");
    let batch = backend.max_batch().max(1);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for chunk in windows.chunks(batch) {
        let outs = backend.forward_batch(chunk)?;
        anyhow::ensure!(outs.len() == chunk.len(), "backend returned short batch");
        for (w, logits) in chunk.iter().zip(&outs) {
            for p in 0..w.len() - 1 {
                nll -= log_prob(logits.row(p), w[p + 1]);
                count += 1;
            }
        }
    }
    Ok((nll / count as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::RustEngine;
    use crate::model::forward::Forward;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::tensor::{Matrix, Rng};

    /// A fake engine that always predicts the next byte perfectly.
    struct Oracle;
    impl LogitsEngine for Oracle {
        fn logits(&mut self, tokens: &[u8]) -> anyhow::Result<Matrix> {
            let mut m = Matrix::zeros(tokens.len(), 256);
            for p in 0..tokens.len() - 1 {
                *m.at_mut(p, tokens[p + 1] as usize) = 100.0;
            }
            Ok(m)
        }
    }

    /// Uniform predictor: ppl must be exactly 256.
    struct Uniform;
    impl LogitsEngine for Uniform {
        fn logits(&mut self, tokens: &[u8]) -> anyhow::Result<Matrix> {
            Ok(Matrix::zeros(tokens.len(), 256))
        }
    }

    #[test]
    fn oracle_ppl_is_one() {
        let c = Corpus::from_bytes("t", b"hello world, hello world!".repeat(20).to_vec());
        let ppl = perplexity(&mut Oracle, &c, 32, 4).unwrap();
        assert!(ppl < 1.001, "{ppl}");
    }

    #[test]
    fn uniform_ppl_is_vocab() {
        let c = Corpus::from_bytes("t", vec![7u8; 500]);
        let ppl = perplexity(&mut Uniform, &c, 64, 3).unwrap();
        assert!((ppl - 256.0).abs() < 0.1, "{ppl}");
    }

    #[test]
    fn backend_perplexity_matches_engine_perplexity() {
        use crate::backend::NativeBackend;
        let cfg = ModelConfig::family("pico").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 33);
        let mut rng = Rng::new(33);
        let data: Vec<u8> = (0..768).map(|_| (32 + rng.below(90)) as u8).collect();
        let c = Corpus::from_bytes("rand", data);
        let mut eng = RustEngine { fwd: Forward::new(&mw.cfg, &mw.tensors, &mw.vectors) };
        let a = perplexity(&mut eng, &c, 64, 4).unwrap();
        let mut be = NativeBackend::from_weights(&mw);
        let b = perplexity_backend(&mut be, &c, 64, 4).unwrap();
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }

    #[test]
    fn untrained_model_near_uniform() {
        let cfg = ModelConfig::family("pico").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 31);
        let mut rng = Rng::new(31);
        let data: Vec<u8> = (0..512).map(|_| (32 + rng.below(90)) as u8).collect();
        let c = Corpus::from_bytes("rand", data);
        let mut eng = RustEngine { fwd: Forward::new(&mw.cfg, &mw.tensors, &mw.vectors) };
        let ppl = perplexity(&mut eng, &c, 64, 2).unwrap();
        assert!(ppl > 30.0 && ppl < 3000.0, "{ppl}");
    }
}
