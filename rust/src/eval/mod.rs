//! Evaluation harnesses: perplexity, flip rate / accuracy, reconstruction
//! analysis, activation capture statistics (R²), and Pareto fronts.
//!
//! All evaluators run against the [`LogitsEngine`] trait so the same harness
//! drives the pure-Rust reference forward, the native fused-kernel backend
//! (`backend::NativeBackend`), and the PJRT runtime (`runtime::PjrtForward`)
//! — Python is never involved. Batched serving-path evaluation goes through
//! [`ppl::perplexity_backend`] over `backend::InferenceBackend`.

pub mod flips;
pub mod pareto;
pub mod ppl;
pub mod r2;
pub mod recon;

use crate::tensor::Matrix;

/// Anything that maps a token sequence to per-position logits.
pub trait LogitsEngine {
    /// tokens (length S) → logits (S, vocab); row p scores token p+1.
    fn logits(&mut self, tokens: &[u8]) -> anyhow::Result<Matrix>;

    fn vocab(&self) -> usize {
        256
    }
}

/// The reference engine: pure-Rust forward over effective weights.
pub struct RustEngine<'a> {
    pub fwd: crate::model::forward::Forward<'a>,
}

impl<'a> LogitsEngine for RustEngine<'a> {
    fn logits(&mut self, tokens: &[u8]) -> anyhow::Result<Matrix> {
        Ok(self.fwd.forward(tokens, None))
    }
}

/// Log-softmax over a logits row; returns log p(target).
pub fn log_prob(logits_row: &[f32], target: u8) -> f64 {
    let maxv = logits_row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let denom: f64 = logits_row.iter().map(|&v| ((v as f64) - maxv).exp()).sum();
    (logits_row[target as usize] as f64 - maxv) - denom.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_prob_normalized() {
        let row = vec![0.0f32; 256];
        let lp = log_prob(&row, 7);
        assert!((lp - (1.0f64 / 256.0).ln()).abs() < 1e-9);
    }

    #[test]
    fn log_prob_prefers_high_logit() {
        let mut row = vec![0.0f32; 256];
        row[65] = 10.0;
        assert!(log_prob(&row, 65) > -0.02);
        assert!(log_prob(&row, 66) < -9.0);
    }
}
