//! Persistent fixed-size worker pool (std-only; no rayon in the offline
//! environment).
//!
//! Workers are spawned once and condvar-parked between jobs, so parallel
//! callers pay a queue push + wake instead of a `thread::spawn` per call.
//! Two entry points share the pool:
//!
//! * [`ThreadPool::submit`] / [`ThreadPool::wait_idle`] — fire-and-forget
//!   jobs (the quantization coordinator submits one per model layer).
//! * [`ThreadPool::for_each_index`] — a *scoped* parallel-for: the caller
//!   hands out indices `0..n` to itself plus up to `width - 1` pool
//!   workers and blocks until every shard has finished, so the shard
//!   closure may borrow from the caller's stack. [`map_indexed`] builds an
//!   order-preserving map on top of it.
//!
//! The process-wide pool behind [`global`] is created on first use (or
//! explicitly sized by [`init_global`] at engine start) with
//! [`resolve_threads`] worker threads. Shard and job panics are isolated:
//! a panicking job can neither kill a worker nor hang a waiting caller —
//! the caller observes the panic after all shards have drained.
//!
//! Nested parallelism runs inline: a `for_each_index` issued *from* a pool
//! worker executes single-threaded on that worker. Workers therefore never
//! block waiting on other workers, which makes caller-blocks-on-latch
//! deadlock-free by construction.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

use crate::obs::profiler::{self, Phase};

/// A pool of `n` OS threads executing boxed jobs from a FIFO queue.
pub struct ThreadPool {
    inner: Arc<Inner>,
    handles: Vec<thread::JoinHandle<()>>,
}

struct Inner {
    queue: Mutex<Queue>,
    cond: Condvar,
    active: AtomicUsize,
}

struct Queue {
    jobs: std::collections::VecDeque<Box<dyn FnOnce() + Send + 'static>>,
    shutdown: bool,
}

thread_local! {
    /// True while the current thread is a pool worker running a job; used
    /// to run nested parallel-fors inline instead of deadlocking on the
    /// queue (see module docs).
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Effective worker count for a requested thread setting: the
/// `SINQ_THREADS` environment override wins when set to a positive
/// integer, then an explicit non-zero `requested`, then every available
/// core. The old `.min(8)` cap is gone on purpose — parked workers cost
/// nothing while idle, so there is no reason to leave cores on the table.
pub fn resolve_threads(requested: usize) -> usize {
    if let Ok(v) = std::env::var("SINQ_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    if requested > 0 {
        return requested;
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide persistent pool, created on first use with
/// [`resolve_threads`]`(0)` workers (every core, unless `SINQ_THREADS`
/// says otherwise).
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(resolve_threads(0)))
}

/// Size the process-wide pool explicitly (engine start calls this with
/// the resolved `EngineConfig::threads`). The first sizing wins — the
/// pool is persistent — so later calls just report the existing size.
pub fn init_global(n: usize) -> usize {
    GLOBAL.get_or_init(|| ThreadPool::new(n)).size()
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (min 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let inner = Arc::new(Inner {
            queue: Mutex::new(Queue { jobs: Default::default(), shutdown: false }),
            cond: Condvar::new(),
            active: AtomicUsize::new(0),
        });
        let handles = (0..n)
            .map(|i| {
                let inner = inner.clone();
                thread::Builder::new()
                    .name(format!("sinq-worker-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { inner, handles }
    }

    /// Submit a job for asynchronous execution.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = self.inner.queue.lock().unwrap();
        q.jobs.push_back(Box::new(f));
        drop(q);
        self.inner.cond.notify_one();
    }

    /// Block until the queue is empty and all workers are idle.
    pub fn wait_idle(&self) {
        loop {
            let q = self.inner.queue.lock().unwrap();
            let empty = q.jobs.is_empty();
            drop(q);
            if empty && self.inner.active.load(Ordering::SeqCst) == 0 {
                return;
            }
            thread::yield_now();
            thread::sleep(std::time::Duration::from_micros(100));
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Scoped parallel-for: run `f(i)` for every `i in 0..n` across the
    /// calling thread plus up to `width - 1` pool workers, returning once
    /// every index has completed. Indices are handed out through a shared
    /// atomic counter, so shards load-balance; `f` may borrow from the
    /// caller's stack because the caller blocks on a completion latch
    /// before returning.
    ///
    /// Panic contract: if any shard panics, the remaining shards still
    /// drain (workers survive), and the panic surfaces on the calling
    /// thread after the latch releases — never a hang, never a dead
    /// worker.
    ///
    /// Called from a pool worker (nested parallelism), this runs inline
    /// single-threaded; the outer parallel level already owns the cores.
    pub fn for_each_index(&self, n: usize, width: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        let width = width.max(1).min(self.size() + 1).min(n);
        if width == 1 || IN_POOL_WORKER.with(|w| w.get()) {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let helpers = width - 1;
        let scope = Arc::new(ParFor {
            f: f as *const (dyn Fn(usize) + Sync),
            n,
            next: AtomicUsize::new(0),
            pending: Mutex::new(helpers),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        // Hand-off: queue one shard-runner job per helper.
        let t0 = profiler::start();
        for _ in 0..helpers {
            let s = scope.clone();
            self.submit(move || {
                if catch_unwind(AssertUnwindSafe(|| run_shards(&s))).is_err() {
                    s.panicked.store(true, Ordering::SeqCst);
                }
                let mut left = s.pending.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    s.done.notify_all();
                }
            });
        }
        profiler::stop(Phase::ParDispatch, t0);
        // The caller is a full participant in the shard loop.
        let caller = catch_unwind(AssertUnwindSafe(|| run_shards(&scope)));
        // Join: wait for every helper before touching the panic state —
        // this latch is what makes the borrow of `f` sound.
        let t1 = profiler::start();
        {
            let mut left = scope.pending.lock().unwrap();
            while *left != 0 {
                left = scope.done.wait(left).unwrap();
            }
        }
        profiler::stop(Phase::ParDispatch, t1);
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if scope.panicked.load(Ordering::SeqCst) {
            panic!("worker shard panicked in ThreadPool::for_each_index");
        }
    }
}

/// Shared state of one `for_each_index` call. `f` is a raw pointer (not a
/// transmuted `'static` reference) so the copies still held by worker-job
/// closures after the caller returns are inert, not dangling references.
struct ParFor {
    f: *const (dyn Fn(usize) + Sync),
    n: usize,
    next: AtomicUsize,
    pending: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

// SAFETY: `f` is only dereferenced inside `run_shards`, which can only
// execute while the originating `for_each_index` call is blocked on the
// completion latch — the closure it points at is alive for every deref.
unsafe impl Send for ParFor {}
unsafe impl Sync for ParFor {}

fn run_shards(s: &ParFor) {
    // SAFETY: see the `Send`/`Sync` impls above — the caller outlives
    // every shard by construction of the latch.
    let f = unsafe { &*s.f };
    loop {
        let i = s.next.fetch_add(1, Ordering::SeqCst);
        if i >= s.n {
            break;
        }
        f(i);
    }
}

fn worker_loop(inner: &Inner) {
    IN_POOL_WORKER.with(|w| w.set(true));
    loop {
        let job = {
            let mut q = inner.queue.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = inner.cond.wait(q).unwrap();
            }
        };
        inner.active.fetch_add(1, Ordering::SeqCst);
        // Isolate job panics: a poisoned closure must not take the worker
        // (and with it every future parallel caller) down with it.
        if catch_unwind(AssertUnwindSafe(job)).is_err() {
            eprintln!("sinq-worker: job panicked (worker kept alive)");
        }
        inner.active.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.inner.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.inner.cond.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw-pointer wrapper that asserts cross-thread use is externally
/// synchronized (each parallel shard touches a disjoint slot). Shared
/// with the kernel layer so scoped parallel loops can write disjoint
/// output ranges without `'static` gymnastics.
pub struct SendPtr<T>(pub *mut T);
// SAFETY: callers guarantee disjoint access per index; see `map_indexed`.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Apply `f` to each item of `items` across up to `threads` lanes of the
/// persistent [`global`] pool, returning outputs in input order. `f` may
/// borrow from the caller (the call is scoped — see
/// [`ThreadPool::for_each_index`]). `threads <= 1` runs inline with no
/// pool traffic at all.
pub fn map_indexed<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    let slots = SendPtr(out.as_mut_ptr());
    global().for_each_index(n, threads, &|i| {
        let v = f(i, &items[i]);
        // SAFETY: `for_each_index` hands each index to exactly one shard,
        // so this is the only access to slot `i` for the whole call, and
        // the latch orders it before the caller reads `out` back.
        unsafe { *slots.0.add(i) = Some(v) };
    });
    out.into_iter().map(|o| o.expect("worker produced value")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_indexed_preserves_order() {
        let items: Vec<u64> = (0..57).collect();
        let out = map_indexed(&items, 3, |i, &x| {
            assert_eq!(i as u64, x);
            x * x
        });
        assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_empty() {
        let items: Vec<u32> = vec![];
        let out = map_indexed(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.submit(|| thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn pool_drop_joins_workers_after_task_panic() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        pool.submit(|| panic!("injected job panic"));
        let c = counter.clone();
        // The worker that ate the panic (or its sibling) must still be
        // alive to run this.
        pool.submit(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1);
        drop(pool); // must not hang or panic
    }

    #[test]
    fn for_each_index_covers_every_index_once() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..101).map(|_| AtomicU64::new(0)).collect();
        pool.for_each_index(hits.len(), 8, &|i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i} hit count");
        }
    }

    #[test]
    fn for_each_index_propagates_shard_panic_without_hanging() {
        let pool = ThreadPool::new(2);
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.for_each_index(64, 3, &|i| {
                if i == 17 {
                    panic!("injected shard panic");
                }
            });
        }));
        assert!(err.is_err(), "shard panic must reach the caller");
        // Pool must still work afterwards: the panicking shard may have
        // run on a worker (kept alive) or on the caller (caught above).
        let n = AtomicU64::new(0);
        pool.for_each_index(10, 3, &|_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 10);
        drop(pool); // must not hang
    }

    #[test]
    fn map_indexed_panic_reaches_caller() {
        let items: Vec<u32> = (0..40).collect();
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            map_indexed(&items, 4, |i, &x| {
                if i == 11 {
                    panic!("injected map panic");
                }
                x
            })
        }));
        assert!(err.is_err());
        // The global pool survives for the next caller.
        let ok = map_indexed(&items, 4, |_, &x| x + 1);
        assert_eq!(ok.len(), items.len());
    }

    #[test]
    fn nested_for_each_index_runs_inline_and_completes() {
        let items: Vec<u32> = (0..12).collect();
        // Outer map uses the global pool; the inner parallel-for issued
        // from worker threads must run inline rather than deadlock.
        let out = map_indexed(&items, 4, |_, &x| {
            let acc = AtomicU64::new(0);
            global().for_each_index(8, 4, &|j| {
                acc.fetch_add(j as u64, Ordering::SeqCst);
            });
            acc.load(Ordering::SeqCst) + x as u64
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, 28 + i as u64);
        }
    }

    #[test]
    fn resolve_threads_precedence() {
        // The suite may itself run under a CI `SINQ_THREADS` matrix leg,
        // so assert the override when present and the fallback when not
        // (never mutate the process environment from a test).
        match std::env::var("SINQ_THREADS").ok().and_then(|v| v.trim().parse::<usize>().ok()) {
            Some(n) if n > 0 => {
                assert_eq!(resolve_threads(0), n);
                assert_eq!(resolve_threads(3), n, "env override beats explicit request");
            }
            _ => {
                assert_eq!(resolve_threads(3), 3);
                assert!(resolve_threads(0) >= 1, "auto resolves to at least one core");
            }
        }
    }
}
