//! Fixed-size worker pool (std-only; no rayon in the offline environment).
//!
//! The quantization coordinator submits one job per model layer; workers pull
//! from a shared queue so large layers do not serialize the pipeline. A scoped
//! `map_indexed` helper preserves output order without allocation games.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// A pool of `n` OS threads executing boxed jobs from a FIFO queue.
pub struct ThreadPool {
    inner: Arc<Inner>,
    handles: Vec<thread::JoinHandle<()>>,
}

struct Inner {
    queue: Mutex<Queue>,
    cond: Condvar,
    active: AtomicUsize,
}

struct Queue {
    jobs: std::collections::VecDeque<Box<dyn FnOnce() + Send + 'static>>,
    shutdown: bool,
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (min 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let inner = Arc::new(Inner {
            queue: Mutex::new(Queue { jobs: Default::default(), shutdown: false }),
            cond: Condvar::new(),
            active: AtomicUsize::new(0),
        });
        let handles = (0..n)
            .map(|i| {
                let inner = inner.clone();
                thread::Builder::new()
                    .name(format!("sinq-worker-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { inner, handles }
    }

    /// Submit a job for asynchronous execution.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = self.inner.queue.lock().unwrap();
        q.jobs.push_back(Box::new(f));
        drop(q);
        self.inner.cond.notify_one();
    }

    /// Block until the queue is empty and all workers are idle.
    pub fn wait_idle(&self) {
        loop {
            let q = self.inner.queue.lock().unwrap();
            let empty = q.jobs.is_empty();
            drop(q);
            if empty && self.inner.active.load(Ordering::SeqCst) == 0 {
                return;
            }
            thread::yield_now();
            thread::sleep(std::time::Duration::from_micros(100));
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.inner.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.inner.cond.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        let job = {
            let mut q = inner.queue.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = inner.cond.wait(q).unwrap();
            }
        };
        inner.active.fetch_add(1, Ordering::SeqCst);
        job();
        inner.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Apply `f` to each item of `items` across `threads` scoped threads,
/// returning outputs in input order. Uses `std::thread::scope`, so `f` may
/// borrow from the caller.
pub fn map_indexed<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<U>> = (0..items.len()).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<U>>> = out.iter_mut().map(Mutex::new).collect();
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= items.len() {
                    break;
                }
                let v = f(i, &items[i]);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    drop(slots);
    out.into_iter().map(|o| o.expect("worker produced value")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_indexed_preserves_order() {
        let items: Vec<u64> = (0..57).collect();
        let out = map_indexed(&items, 3, |i, &x| {
            assert_eq!(i as u64, x);
            x * x
        });
        assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_empty() {
        let items: Vec<u32> = vec![];
        let out = map_indexed(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.submit(|| thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }
}
