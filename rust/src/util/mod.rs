//! Small self-contained utilities the rest of the crate builds on.
//!
//! The offline build environment ships only the `xla` crate's dependency
//! closure, so conveniences that would normally come from crates.io
//! (criterion, clap, rayon, serde, half) are implemented here from scratch:
//!
//! * [`bench`] — a criterion-style micro-benchmark harness (warmup, timed
//!   iterations, mean/std/median reporting).
//! * [`cli`] — a tiny declarative flag parser for the `sinq` binary.
//! * [`half`] — IEEE binary16 and bfloat16 conversion (for auxiliary-variable
//!   precision ablations, Fig. 5a).
//! * [`json`] — a minimal JSON value + writer used by report emitters.
//! * [`threadpool`] — a fixed-size worker pool with a scoped `map` used by the
//!   quantization coordinator.

pub mod bench;
pub mod cli;
pub mod half;
pub mod json;
pub mod threadpool;
