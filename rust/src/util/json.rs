//! Minimal JSON value model + writer + reader.
//!
//! Used for (a) report emitters (`report/`) that dump machine-readable rows
//! next to the pretty tables, and (b) the model metadata sidecar written by
//! the Python training script (`python/compile/train.py`). Only the subset of
//! JSON the repo produces is supported; the parser is strict about it.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as `f64`; object keys are ordered for
/// deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize to a compact JSON string.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns `Err` with a human-readable message on
    /// malformed input.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = rest.get(..ch_len).ok_or("bad utf8")?;
                    s.push_str(std::str::from_utf8(chunk).map_err(|_| "bad utf8")?);
                    self.i += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_object() {
        let j = Json::obj(vec![
            ("name", Json::Str("tiny".into())),
            ("layers", Json::Num(4.0)),
            ("ppl", Json::Num(17.14)),
            ("tags", Json::Arr(vec![Json::Str("a\"b".into()), Json::Bool(true), Json::Null])),
        ]);
        let s = j.to_string_compact();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_nested_whitespace() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5 , { \"b\" : \"x\\ny\" } ] } ").unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse("\"\\u00e9\"").unwrap();
        assert_eq!(j.as_str(), Some("é"));
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::Num(4.0).to_string_compact(), "4");
        assert_eq!(Json::Num(4.5).to_string_compact(), "4.5");
    }
}
