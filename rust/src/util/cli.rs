//! Tiny declarative command-line flag parser (no clap offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and positional
//! arguments. The `sinq` binary builds one [`Args`] per subcommand.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key value` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse raw arguments. Boolean flags are flags followed by another flag
    /// or end-of-line; everything else consumes the next token as its value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let toks: Vec<String> = raw.into_iter().collect();
        let mut a = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    a.flags.insert(stripped.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    a.bools.push(stripped.to_string());
                }
            } else {
                a.positional.push(t.clone());
            }
            i += 1;
        }
        a
    }

    /// String flag with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Numeric flag with default; panics with a clear message on junk input.
    pub fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(key) {
            None => default,
            Some(v) => match v.parse() {
                Ok(x) => x,
                Err(e) => panic!("--{key}: cannot parse '{v}': {e}"),
            },
        }
    }

    /// True if a boolean `--flag` was present.
    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }

    /// Comma-separated list flag.
    pub fn list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = parse("quantize --method sinq --bits 4 model.stz --verbose");
        assert_eq!(a.positional, vec!["quantize", "model.stz"]);
        assert_eq!(a.get("method", "rtn"), "sinq");
        assert_eq!(a.num::<u32>("bits", 8), 4);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("--group-size=64 --out=x.stz");
        assert_eq!(a.num::<usize>("group-size", 0), 64);
        assert_eq!(a.get("out", ""), "x.stz");
    }

    #[test]
    fn list_flag() {
        let a = parse("--methods rtn,hqq,sinq");
        assert_eq!(a.list("methods", &[]), vec!["rtn", "hqq", "sinq"]);
        assert_eq!(a.list("bits", &["3", "4"]), vec!["3", "4"]);
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = parse("--fast --method sinq");
        assert!(a.has("fast"));
        assert_eq!(a.get("method", ""), "sinq");
    }
}
