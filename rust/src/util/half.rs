//! IEEE 754 binary16 (`f16`) and bfloat16 (`bf16`) conversions.
//!
//! The paper stores auxiliary quantization parameters (scales/shifts) either
//! in half precision or quantized to int8 (Fig. 5a ablation). The model
//! checkpoints written by the Python side are f32; these conversions are used
//! when accounting memory and when round-tripping auxiliaries through reduced
//! precision to measure the quality impact.

/// Convert an `f32` to IEEE binary16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | m | ((mant >> 13) as u16);
    }
    // Re-bias: f32 exp bias 127 -> f16 bias 15.
    let new_exp = exp - 127 + 15;
    if new_exp >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if new_exp <= 0 {
        // Subnormal or zero.
        if new_exp < -10 {
            return sign;
        }
        let m = mant | 0x0080_0000; // implicit leading 1
        let shift = (14 - new_exp) as u32;
        let half_mant = m >> shift;
        // round to nearest even
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = if rem > halfway || (rem == halfway && (half_mant & 1) == 1) {
            half_mant + 1
        } else {
            half_mant
        };
        return sign | rounded as u16;
    }
    let half_mant = (mant >> 13) as u16;
    let rem = mant & 0x1fff;
    let mut out = sign | ((new_exp as u16) << 10) | half_mant;
    if rem > 0x1000 || (rem == 0x1000 && (half_mant & 1) == 1) {
        out = out.wrapping_add(1); // may carry into exponent: correct behaviour
    }
    out
}

/// Convert IEEE binary16 bits to `f32`.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize.
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            sign | (((127 - 15 + e + 1) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip an `f32` through binary16 precision.
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Convert an `f32` to bfloat16 bits (round-to-nearest-even).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quiet the NaN
    }
    let lsb = (bits >> 16) & 1;
    let rounding = 0x7fff + lsb;
    ((bits + rounding) >> 16) as u16
}

/// Convert bfloat16 bits to `f32`.
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Round-trip an `f32` through bfloat16 precision.
pub fn round_bf16(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_round_trip_exact_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.099975586] {
            assert_eq!(round_f16(v), v, "value {v} should be f16-exact");
        }
    }

    #[test]
    fn f16_handles_overflow_to_inf() {
        assert!(round_f16(1e6).is_infinite());
        assert!(round_f16(-1e6).is_infinite());
    }

    #[test]
    fn f16_subnormals() {
        let tiny = 5.96e-8f32; // near smallest f16 subnormal
        let rt = round_f16(tiny);
        assert!((rt - tiny).abs() / tiny < 0.5);
        assert_eq!(round_f16(1e-12), 0.0); // flush below subnormal range
    }

    #[test]
    fn f16_precision_error_is_bounded() {
        // Relative error of binary16 round-trip is <= 2^-11 for normal range.
        let mut x = 1.0e-4f32;
        while x < 1.0e4 {
            let rt = round_f16(x);
            assert!(((rt - x) / x).abs() <= 1.0 / 2048.0 + 1e-7, "x={x} rt={rt}");
            x *= 1.37;
        }
    }

    #[test]
    fn bf16_round_trip() {
        for &v in &[0.0f32, 1.0, -2.5, 3.140625, 1e30, -1e-30] {
            let rt = round_bf16(v);
            if v == 0.0 {
                assert_eq!(rt, 0.0);
            } else {
                assert!(((rt - v) / v).abs() <= 1.0 / 256.0, "v={v} rt={rt}");
            }
        }
    }

    #[test]
    fn bf16_nan_stays_nan() {
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_nan_stays_nan() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }
}
