//! Criterion-style micro-benchmark harness (criterion is unavailable offline).
//!
//! Every `benches/*.rs` target uses `harness = false` and drives this module:
//! warmup, adaptive iteration count targeting a wall-clock budget, and
//! mean / std / median / min reporting. Results can be appended to a JSON
//! lines file so `EXPERIMENTS.md` numbers are regenerable.

use std::time::{Duration, Instant};

/// Statistics of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    /// Throughput in "items"/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

/// Benchmark runner with a per-case time budget.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub max_iters: usize,
    pub min_iters: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Budgets kept modest: everything runs on a single CPU core.
        Bencher {
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(700),
            max_iters: 10_000,
            min_iters: 5,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(20),
            budget: Duration::from_millis(200),
            max_iters: 2_000,
            min_iters: 3,
            results: Vec::new(),
        }
    }

    /// Time `f`, which should perform one full unit of work per call.
    /// Use `std::hint::black_box` inside `f` to defeat DCE.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchStats {
        // Warmup & pilot measurement.
        let wstart = Instant::now();
        let mut pilot_iters = 0u32;
        while wstart.elapsed() < self.warmup || pilot_iters == 0 {
            f();
            pilot_iters += 1;
            if pilot_iters > 1000 {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_secs_f64() / pilot_iters as f64;
        let iters = ((self.budget.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let stats = summarize(name, &mut samples);
        println!(
            "{:<48} {:>10.3} ms  ±{:>8.3}  (median {:.3}, min {:.3}, n={})",
            stats.name,
            stats.mean_ms(),
            stats.std_ns / 1e6,
            stats.median_ns / 1e6,
            stats.min_ns / 1e6,
            stats.iters
        );
        self.results.push(stats.clone());
        stats
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Append results as JSON lines to `path`.
    pub fn dump_jsonl(&self, path: &str) -> std::io::Result<()> {
        use crate::util::json::Json;
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        for r in &self.results {
            let j = Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("iters", Json::Num(r.iters as f64)),
                ("mean_ns", Json::Num(r.mean_ns)),
                ("std_ns", Json::Num(r.std_ns)),
                ("median_ns", Json::Num(r.median_ns)),
                ("min_ns", Json::Num(r.min_ns)),
            ]);
            writeln!(f, "{}", j.to_string_compact())?;
        }
        Ok(())
    }
}

fn summarize(name: &str, samples: &mut [f64]) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n.max(1.0);
    BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        std_ns: var.sqrt(),
        median_ns: samples[samples.len() / 2],
        min_ns: samples[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let mut b = Bencher::quick();
        let s = b.bench("sleep-1ms", || {
            std::thread::sleep(Duration::from_millis(1));
        });
        assert!(s.mean_ms() >= 0.9, "mean {} ms", s.mean_ms());
        assert!(s.iters >= 3);
    }

    #[test]
    fn results_accumulate_and_dump() {
        let mut b = Bencher::quick();
        b.bench("noop-a", || {
            std::hint::black_box(1 + 1);
        });
        b.bench("noop-b", || {
            std::hint::black_box(2 + 2);
        });
        assert_eq!(b.results().len(), 2);
        let tmp = std::env::temp_dir().join("sinq_bench_test.jsonl");
        let path = tmp.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        b.dump_jsonl(path).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_file(path);
    }
}
