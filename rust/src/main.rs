//! `sinq` — the L3 coordinator CLI.
//!
//! ```text
//! sinq quantize --model tiny --method sinq --bits 4 [--no-overhead] [--out q.stz]
//! sinq eval     --model tiny [--backend native|pjrt|auto] [--quantized q.stz]
//! sinq analyze  r2|adam|kurtosis|recon|fig1|kv|profile|trace [--model tiny] [--backend auto|native|pjrt]
//! sinq serve    --model tiny [--backend native|pjrt|auto] [--requests 32]
//!               [--max-batch 8] [--max-new-tokens 16]
//! sinq serve    --listen 127.0.0.1:8080 [--max-batch 8] [--max-queue 64]
//!               [--max-context 512] [--kv-bits 32|8] [--page-size 16] [--kv-pages N]
//!               [--drift-sample N] [--method sinq --bits 4 | --quantized q.stz]
//! sinq table    1|2|3|4|5|6|7|8|9|10|16|17|18|19|pareto|ablations|figs|all
//! ```
//!
//! `serve` and `eval` dispatch through the [`sinq::backend::InferenceBackend`]
//! trait. The default `--backend native` executes the pure-Rust fused
//! dequant-matmul engine directly on packed weights — self-contained on any
//! machine (no `artifacts/`, no XLA, no Python; missing checkpoints and
//! corpora fall back to deterministic synthetic stand-ins with a notice).
//! `--backend pjrt` runs the AOT artifacts from `make artifacts`;
//! `--backend auto` probes for artifacts + a usable PJRT client and falls
//! back to native, reporting the chosen engine. The `analyze`/`table`
//! experiment commands default to `auto`, so the paper-table sweep runs
//! artifact-free on the native backend (PJRT-kernel tables 5/6 still need
//! artifacts).
//!
//! `serve` without `--listen` runs the in-process demo sweep (a scoring
//! phase plus a continuous-batched generation phase). With
//! `--listen ADDR:PORT` it becomes a long-running HTTP/SSE endpoint over
//! the continuous batcher (see [`sinq::serve`]): streamed
//! `POST /v1/generate`, batched `POST /v1/score`, `GET /healthz`, and
//! Prometheus `GET /metrics`, with `503` backpressure at `--max-queue` and
//! graceful drain on Ctrl-C. `--fast` trims sweep sizes for smoke runs.

use sinq::backend::{self, BackendKind, BackendSpec, KvBits};
use sinq::coordinator::pipeline::{self, PipelineOpts};
use sinq::coordinator::scheduler::{self, ScheduleOpts};
use sinq::coordinator::server::BatchServer;
use sinq::data::Corpus;
use sinq::eval::ppl;
use sinq::fmt::grids::Grid;
use sinq::model::QuantizedModel;
use sinq::quant::{AuxPrecision, Method, QuantConfig};
use sinq::report::tables::{self, Ctx};
use sinq::report::Table;
use sinq::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".to_string());
    let result = match cmd.as_str() {
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "analyze" => cmd_analyze(&args),
        "serve" => cmd_serve(&args),
        "table" => cmd_table(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "sinq — Sinkhorn-Normalized Quantization (paper reproduction)\n\n\
         USAGE:\n  sinq quantize --model <name> --method <m> --bits <b> [--out f.stz] [--no-overhead]\n  \
         sinq eval --model <name> [--backend native|pjrt|auto] [--quantized f.stz] [--corpus wiki|c4]\n  \
         sinq analyze <r2|adam|kurtosis|recon|fig1|kv|profile|trace> [--model <name>] [--backend auto|native|pjrt]\n  \
         sinq serve --model <name> [--backend native|pjrt|auto] [--requests N] [--quantized f.stz]\n             \
         [--max-batch N] [--max-new-tokens N]\n  \
         sinq serve --listen ADDR:PORT [--model <name>] [--max-batch N] [--max-queue N]\n             \
         [--max-context N] [--max-new-tokens N] [--kv-bits 32|8] [--log-json]\n             \
         [--threads N] [--page-size N] [--kv-pages N] [--drift-sample N]\n             \
         [--request-timeout-ms N] [--max-engine-restarts N]\n             \
         [--method <m> --bits <b> | --quantized f.stz]\n  \
         sinq table <1|2|3|4|5|6|7|8|9|10|16|17|18|19|pareto|ablations|figs|all> [--fast]\n\n\
         Serving endpoint (serve --listen): POST /v1/generate (SSE with \"stream\":true;\n  \
         seeded sampling via temperature/top_k/seed fields, greedy default),\n  \
         OpenAI-compatible POST /v1/completions (prompt/max_tokens/stream; data: chunks\n  \
         ending in data: [DONE]), POST /v1/score, GET /healthz, GET /metrics,\n  \
         GET /v1/stats (span/phase/quant/drift telemetry; per-phase decode profiling\n  \
         via SINQ_PROFILE=1), GET /debug/trace?last=N (flight-recorder events as\n  \
         Chrome-trace JSON for Perfetto); --drift-sample N recomputes every Nth decode\n  \
         step's sampled row on the scalar kernel path and reports drift on /metrics;\n  \
         every generation response carries a usage object and an X-Request-Id header;\n  \
         --log-json prints one JSON line per request; errors use one JSON envelope\n  \
         {{\"error\":{{\"message\",\"type\"}}}}; 503 + Retry-After past --max-queue;\n  \
         --kv-bits 8 packs decode KV caches to u8 with per-head scales (~4x less\n  \
         memory per page; 32 = bit-identical default); --threads N sizes the\n  \
         persistent kernel worker pool (0/absent = all cores; SINQ_THREADS env\n  \
         overrides; tokens are bit-identical at any count); KV memory is a shared pool of\n  \
         --page-size-position pages (--kv-pages overrides the pool size) with prefix\n  \
         caching across shared prompt prefixes (prefix_hit_rate on /metrics);\n  \
         disconnected SSE clients are evicted at the next step boundary;\n  \
         Connection: keep-alive reuses sockets (--keepalive-idle-ms, default 5000;\n  \
         streams idle past it get SSE \": ping\" heartbeats);\n  \
         the decode loop runs supervised: a panicking step fails in-flight requests\n  \
         with a typed engine_error envelope, rebuilds the decoder, and restarts with\n  \
         backoff (--max-engine-restarts, default 3; exhausted -> /healthz degraded +\n  \
         503s); per-request \"deadline_ms\" (clamped by --request-timeout-ms) times\n  \
         requests out with finish_reason \"timeout\", queue wait included;\n  \
         SINQ_FAULTS=site:panic|delay:MS|error[@every=N|@once] arms deterministic\n  \
         fault injection (sites: submit admit page_claim decode_step kv_write\n  \
         sse_write) for chaos drills;\n  \
         Ctrl-C drains live slots.\n\n\
         SIMD: fused kernels dispatch to AVX2/NEON at runtime; SINQ_SIMD=scalar|avx2|neon|auto\n  \
         overrides (serve prints the active kernel; /healthz reports it as \"simd\").\n\n\
         Backends (serve/eval):\n  \
         native  pure-Rust fused dequant-matmul engine on packed weights (default;\n          \
         needs no artifacts/XLA/Python — synthetic fallbacks cover missing files).\n          \
         With --quantized f.stz it executes the packed codes directly; with\n          \
         --method/--bits on `serve` it quantizes in-process first.\n  \
         pjrt    AOT XLA artifacts via PJRT (requires `make artifacts`)\n  \
         auto    pjrt when artifacts + a PJRT client are usable, else native\n\n\
         Common flags: --art-dir artifacts  --models pico,tiny,small\n\
         Methods: rtn hadamard hqq sinq awq a-sinq gptq hadamard+gptq crossquant codebook bnb higgs"
    );
}

/// Parse `--backend` and resolve `auto` to a concrete engine, printing the
/// probe's choice so stats lines always name the engine that actually ran.
/// `default` differs per command: serve/eval default to `native`, the
/// experiment commands to `auto` (prefer artifacts when they exist, stay
/// artifact-free otherwise).
fn backend_kind(args: &Args, art_dir: &str, default: &str) -> anyhow::Result<BackendKind> {
    let name = args.get("backend", default);
    let kind = BackendKind::parse(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown backend '{name}' (expected native|pjrt|auto)"))?;
    let resolved = backend::resolve(kind, art_dir);
    if kind == BackendKind::Auto {
        println!("backend auto: selected '{}' engine", resolved.name());
    }
    Ok(resolved)
}

fn quant_config(args: &Args) -> anyhow::Result<QuantConfig> {
    let method = Method::parse(&args.get("method", "sinq"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let bits: u32 = args.num("bits", 4);
    let mut cfg = QuantConfig::new(method, bits).with_group(args.num("group-size", 64));
    match args.get("grid", "uniform").as_str() {
        "uniform" => {}
        "nf4" => cfg = cfg.with_grid(Grid::nf4()),
        "fp4" => cfg = cfg.with_grid(Grid::fp4()),
        "nf" => cfg = cfg.with_grid(Grid::nf(bits)),
        g => anyhow::bail!("unknown grid '{g}'"),
    }
    match args.get("aux", "f16").as_str() {
        "f32" => cfg = cfg.with_aux(AuxPrecision::F32),
        "f16" => {}
        "i8" => cfg = cfg.with_aux(AuxPrecision::I8),
        a => anyhow::bail!("unknown aux precision '{a}'"),
    }
    if args.has("no-shift") {
        cfg = cfg.with_shift(false);
    }
    Ok(cfg)
}

fn cmd_quantize(args: &Args) -> anyhow::Result<()> {
    let art = args.get("art-dir", "artifacts");
    let model = args.get("model", "tiny");
    let mw = scheduler::load_family_member(&art, &model)?;
    let cfg = quant_config(args)?;
    let calib = if cfg.method.needs_calibration() {
        let c = sinq::data::Corpus::load(&art, "wiki", "train")?;
        Some(c.data[..768.min(c.data.len())].to_vec())
    } else {
        None
    };
    let opts = PipelineOpts {
        schedule: ScheduleOpts {
            threads: args.num("threads", 2),
            calib_sample: calib,
            verbose: true,
        },
        no_overhead: args.has("no-overhead"),
    };
    let out = args.get("out", &format!("{art}/quantized_{model}_{}.stz", cfg.method.name()));
    let (qm, bytes) = pipeline::run_and_save(&mw, &cfg, &opts, &out)?;
    println!(
        "quantized {model} with {} @ {}b → {out} ({:.2} MB, {} layers)",
        qm.method,
        cfg.bits,
        bytes as f64 / 1e6,
        qm.layers.len()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let art = args.get("art-dir", "artifacts");
    let model = args.get("model", "tiny");
    let corpus_kind = args.get("corpus", "wiki");
    let kind = backend_kind(args, &art, "native")?;
    let ppl_value = match kind {
        BackendKind::Native => {
            // Artifact-free path: fused-kernel engine + batched scoring
            // through the InferenceBackend trait.
            let mut spec = BackendSpec::new(kind, &art, &model);
            spec.quantized = args.opt("quantized").map(String::from);
            let mut be = backend::build(&spec)?;
            let corpus = Corpus::load_or_synthetic(&art, &corpus_kind, "eval");
            let windows = if args.has("fast") { 8 } else { 32 };
            ppl::perplexity_backend(&mut *be, &corpus, 128, windows)?
        }
        BackendKind::Pjrt => {
            let ctx = Ctx::with_backend(&art, args.has("fast"), BackendKind::Pjrt)?;
            let mw = ctx.load_model(&model)?;
            if let Some(qpath) = args.opt("quantized") {
                let qm = QuantizedModel::load(qpath)?;
                let eff = qm.effective_weights();
                ctx.ppl_eff(&mw, &eff, &qm.fvectors, &corpus_kind)?
            } else {
                ctx.ppl_fp(&mw, &corpus_kind)?
            }
        }
        BackendKind::Auto => unreachable!("auto is resolved in backend_kind"),
    };
    println!("{model} {corpus_kind} perplexity ({} backend): {ppl_value:.3}", kind.name());
    Ok(())
}

fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let art = args.get("art-dir", "artifacts");
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("r2");
    let kind = backend_kind(args, &art, "auto")?;
    let ctx = Ctx::with_backend(&art, args.has("fast"), kind)?;
    let model = args.get("model", "tiny");
    let t = match which {
        "r2" => tables::fig2a_table(&ctx, &[&model])?,
        "adam" => tables::fig2b_table(&ctx)?,
        "kurtosis" => tables::fig2c_fig7_table(&ctx, &model)?,
        "recon" => tables::fig3_table(&ctx, &model)?,
        "fig1" => tables::fig1_table(&ctx)?,
        "kv" => tables::kv_cache_table(&ctx, &model)?,
        "profile" => tables::quant_profile_table(&ctx, &model)?,
        "trace" => tables::trace_table(&ctx, &model)?,
        other => anyhow::bail!("unknown analysis '{other}'"),
    };
    t.print();
    let _ = t.dump(&art);
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let art = args.get("art-dir", "artifacts");
    let model = args.get("model", "tiny");
    let n_requests: usize = args.num("requests", 32);
    let max_batch: usize = args.num("max-batch", 8);
    let max_new: usize = args.num("max-new-tokens", 16);

    let mut spec = BackendSpec::new(backend_kind(args, &art, "native")?, &art, &model);
    spec.quantized = args.opt("quantized").map(String::from);
    let kv_arg = args.get("kv-bits", "32");
    let kv_bits = KvBits::parse(&kv_arg)
        .ok_or_else(|| anyhow::anyhow!("--kv-bits must be 32 or 8 (got '{kv_arg}')"))?;
    anyhow::ensure!(
        kv_bits == KvBits::F32 || spec.kind == BackendKind::Native,
        "--kv-bits 8 quantizes the native decoders' KV caches; rerun with --backend native"
    );
    spec.engine = spec
        .engine
        .with_max_batch(max_batch)
        .with_kv_bits(kv_bits)
        // 0 = auto (all cores); `SINQ_THREADS` overrides either way.
        .with_threads(args.num("threads", 0));
    let wants_quantize = args.opt("method").is_some() || args.opt("bits").is_some();
    if wants_quantize {
        // `serve --backend native --method sinq --bits 4`: quantize
        // in-process and serve the packed codes through the fused kernels.
        anyhow::ensure!(
            spec.kind == BackendKind::Native && spec.quantized.is_none(),
            "--method/--bits apply only to `serve --backend native` without --quantized; \
             run `sinq quantize` first and pass the .stz via --quantized instead"
        );
        spec.quantize = Some(quant_config(args)?);
    }

    if let Some(listen) = args.opt("listen") {
        // Long-running HTTP/SSE endpoint over the continuous batcher.
        anyhow::ensure!(
            spec.kind == BackendKind::Native,
            "`serve --listen` streams through the native decode engine; \
             rerun with --backend native (got '{}')",
            spec.kind.name()
        );
        let opts = sinq::serve::ServeOpts {
            listen: listen.to_string(),
            max_batch,
            max_context: args.num("max-context", 512),
            page_size: args.num("page-size", backend::config::DEFAULT_PAGE_SIZE),
            kv_pages: args
                .opt("kv-pages")
                .map(|_| args.num::<usize>("kv-pages", 0))
                .filter(|&n| n > 0),
            max_queue: args.num("max-queue", 64),
            default_max_new: max_new.max(1),
            score_queue: args.num("score-queue", 64),
            max_connections: args.num("max-connections", 256),
            keepalive_idle_ms: args.num("keepalive-idle-ms", 5_000),
            log_json: args.has("log-json"),
            drift_sample: args.num("drift-sample", 0),
            request_timeout_ms: args.num("request-timeout-ms", 0),
            max_engine_restarts: args.num("max-engine-restarts", 3),
        };
        return sinq::serve::run(&spec, &opts);
    }

    // The server thread builds its own backend (PJRT handles are not Send;
    // the spec is plain data).
    let server = {
        let spec = spec.clone();
        BatchServer::spawn(
            move || backend::build(&spec),
            64,
            std::time::Duration::from_millis(4),
        )
    };
    let corpus = Corpus::load_or_synthetic(&art, "wiki", "eval");

    // --- Phase 1: batched scoring ---------------------------------------
    let windows = corpus.eval_windows(128, n_requests);
    let client = server.client();
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = windows
        .iter()
        .map(|w| {
            let c = client.clone();
            let toks = w.to_vec();
            std::thread::spawn(move || c.score(toks).map(|m| m.rows))
        })
        .collect();
    let mut ok = 0;
    for h in handles {
        if h.join().unwrap().is_ok() {
            ok += 1;
        }
    }
    let score_secs = t0.elapsed().as_secs_f64();

    // --- Phase 2: continuous-batched generation (native engine only; the
    // PJRT forward executor has no autoregressive entry point) ------------
    let prompts = if spec.kind == BackendKind::Native {
        corpus.eval_windows(32, n_requests)
    } else {
        println!("skipping generation phase: the {} backend does not generate", spec.kind.name());
        Vec::new()
    };
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| {
            let c = client.clone();
            let prompt = p.to_vec();
            std::thread::spawn(move || c.generate(prompt, max_new).map(|t| t.len()))
        })
        .collect();
    let mut gen_ok = 0;
    for h in handles {
        if h.join().unwrap().is_ok() {
            gen_ok += 1;
        }
    }
    let gen_secs = t0.elapsed().as_secs_f64();
    let n_gen = prompts.len();

    let stats = server.shutdown();
    println!(
        "served {ok}/{n_requests} scoring requests on the {} backend in {score_secs:.2}s \
         ({} batches, avg batch {:.2}, {:.0} tok/s)",
        spec.kind.name(),
        stats.batches,
        stats.requests as f64 / stats.batches.max(1) as f64,
        stats.tokens as f64 / score_secs
    );
    if n_gen > 0 {
        println!(
            "generated for {gen_ok}/{n_gen} requests in {gen_secs:.2}s \
             ({} tokens across {} continuous batches of ≤{max_batch} slots, {:.0} gen tok/s)",
            stats.generated,
            stats.gen_batches,
            stats.generated as f64 / gen_secs.max(1e-9)
        );
    }
    Ok(())
}

fn cmd_table(args: &Args) -> anyhow::Result<()> {
    let art = args.get("art-dir", "artifacts");
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("1");
    let kind = backend_kind(args, &art, "auto")?;
    let ctx = Ctx::with_backend(&art, args.has("fast"), kind)?;
    let models_owned = args.list("models", &["pico", "tiny", "small"]);
    let models: Vec<&str> = models_owned.iter().map(|s| s.as_str()).collect();
    let small_set: Vec<&str> = models.iter().copied().take(2).collect();

    let run = |sel: &str, emitted: &mut Vec<Table>| -> anyhow::Result<()> {
        match sel {
            "1" => emitted.push(tables::table1(&ctx, &models)?),
            "2" => {
                let (flip_t, acc) = tables::table2(&ctx, &small_set)?;
                emitted.push(flip_t);
                emitted.push(acc);
            }
            "3" => emitted.push(tables::table3(&ctx, &models)?),
            "4" => emitted.push(tables::table4(&ctx, &small_set)?),
            "5" => emitted.push(tables::table5(&ctx)?),
            "6" => emitted.push(tables::table6(&ctx, &["tiny", "small"])?),
            "7" => emitted.push(tables::table7(&ctx, "tiny")?),
            "8" => emitted.push(tables::table8(&ctx, &small_set)?),
            "9" => emitted.push(tables::table9(&ctx, &small_set)?),
            "10" => emitted.push(tables::table10(&ctx, &small_set)?),
            "16" => emitted.push(tables::table16(&ctx, "tiny")?),
            "17" => emitted.push(tables::table17(&ctx, "tiny")?),
            "18" => emitted.push(tables::table18(&ctx, &small_set)?),
            "19" => emitted.push(tables::table19(&ctx)?),
            "pareto" => emitted.push(tables::pareto_table(&ctx, &models)?),
            "ablations" => emitted.push(tables::ablation_table(&ctx, &small_set)?),
            "figs" => {
                emitted.push(tables::fig1_table(&ctx)?);
                emitted.push(tables::fig2a_table(&ctx, &small_set)?);
                emitted.push(tables::fig2b_table(&ctx)?);
                emitted.push(tables::fig2c_fig7_table(&ctx, "tiny")?);
                emitted.push(tables::fig3_table(&ctx, "tiny")?);
            }
            other => anyhow::bail!("unknown table '{other}'"),
        }
        Ok(())
    };

    let mut emitted: Vec<Table> = Vec::new();
    if which == "all" {
        for sel in [
            "1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "16", "17", "18", "19",
            "pareto", "ablations", "figs",
        ] {
            println!("=== table {sel} ===");
            let before = emitted.len();
            run(sel, &mut emitted)?;
            for t in &emitted[before..] {
                t.print(); // incremental output on long runs
            }
        }
    } else {
        run(which, &mut emitted)?;
        for t in &emitted {
            t.print();
        }
    }
    for t in &emitted {
        let _ = t.dump(&art);
    }
    Ok(())
}
