//! Global per-phase profiler for the transformer core.
//!
//! The decode and forward hot loops in [`crate::backend::fwd`] wrap each
//! phase (embed, per-`LinId` linear dispatch, KV write/attend, MLP, token
//! pick) in [`start`]/[`stop`] pairs. When profiling is off — the default —
//! [`start`] is a single relaxed atomic load returning `None` and [`stop`]
//! is a no-op, so the hot path's cost is one predictable branch per phase.
//! When on (`SINQ_PROFILE=1` or [`set_enabled`]), each pair accumulates
//! elapsed nanoseconds and a call count into lock-free global counters.
//!
//! Timing never touches the arithmetic: greedy decode tokens are
//! bit-identical whether the profiler is on or off (regression-tested in
//! `tests/unified_core.rs`).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Once;
use std::time::Instant;

use crate::util::json::Json;

/// One timed phase of the transformer core. Linear phases mirror the
/// `LinId` dispatch in [`crate::backend::fwd`]; `Moe` covers the whole
/// per-row switch-MoE path (router + expert matvecs route together).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Embed,
    Rope,
    Norm,
    LinWq,
    LinWk,
    LinWv,
    LinWo,
    LinWg,
    LinWu,
    LinWd,
    Moe,
    LinLmHead,
    KvWrite,
    KvAttend,
    Attend,
    Activation,
    TokenPick,
    /// Worker-pool hand-off + join time inside
    /// [`crate::util::threadpool::ThreadPool::for_each_index`]. Unlike the
    /// other phases this one *nests* inside whichever phase dispatched the
    /// parallel loop (a linear phase or `KvAttend`), so its share answers
    /// "how much of decode is parallel overhead vs kernel time" rather
    /// than adding a disjoint slice of wall-clock.
    ParDispatch,
}

pub const PHASE_COUNT: usize = 18;

pub const ALL_PHASES: [Phase; PHASE_COUNT] = [
    Phase::Embed,
    Phase::Rope,
    Phase::Norm,
    Phase::LinWq,
    Phase::LinWk,
    Phase::LinWv,
    Phase::LinWo,
    Phase::LinWg,
    Phase::LinWu,
    Phase::LinWd,
    Phase::Moe,
    Phase::LinLmHead,
    Phase::KvWrite,
    Phase::KvAttend,
    Phase::Attend,
    Phase::Activation,
    Phase::TokenPick,
    Phase::ParDispatch,
];

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Embed => "embed",
            Phase::Rope => "rope",
            Phase::Norm => "norm",
            Phase::LinWq => "lin_wq",
            Phase::LinWk => "lin_wk",
            Phase::LinWv => "lin_wv",
            Phase::LinWo => "lin_wo",
            Phase::LinWg => "lin_wg",
            Phase::LinWu => "lin_wu",
            Phase::LinWd => "lin_wd",
            Phase::Moe => "moe",
            Phase::LinLmHead => "lin_lm_head",
            Phase::KvWrite => "kv_write",
            Phase::KvAttend => "kv_attend",
            Phase::Attend => "attend",
            Phase::Activation => "activation",
            Phase::TokenPick => "token_pick",
            Phase::ParDispatch => "par_dispatch",
        }
    }

    #[inline]
    fn index(&self) -> usize {
        *self as usize
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

// Interior mutability is the point: these consts exist only to const-init
// the static atomic arrays.
#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
static NANOS: [AtomicU64; PHASE_COUNT] = [ZERO; PHASE_COUNT];
static CALLS: [AtomicU64; PHASE_COUNT] = [ZERO; PHASE_COUNT];

fn env_wants_profiling() -> bool {
    matches!(
        std::env::var("SINQ_PROFILE").as_deref(),
        Ok("1") | Ok("on") | Ok("true") | Ok("yes")
    )
}

/// Is the profiler currently recording? First call folds in the
/// `SINQ_PROFILE` environment switch; after that it is one relaxed load.
#[inline]
pub fn enabled() -> bool {
    ENV_INIT.call_once(|| {
        if env_wants_profiling() {
            ENABLED.store(true, Ordering::Relaxed);
        }
    });
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the profiler on or off at runtime (tests, benches, serve startup).
pub fn set_enabled(on: bool) {
    ENV_INIT.call_once(|| {});
    ENABLED.store(on, Ordering::Relaxed);
}

/// Start a phase timer: `None` (one branch, no clock read) when disabled.
#[inline]
pub fn start() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Close a phase timer opened by [`start`]; no-op when it returned `None`.
/// When the flight-recorder journal is also on, the scope is mirrored
/// there as a [`crate::obs::journal::EventKind::PhaseScope`] event so the
/// Chrome-trace export can nest phase timing under decode steps.
#[inline]
pub fn stop(phase: Phase, t0: Option<Instant>) {
    if let Some(t0) = t0 {
        let i = phase.index();
        let nanos = t0.elapsed().as_nanos() as u64;
        NANOS[i].fetch_add(nanos, Ordering::Relaxed);
        CALLS[i].fetch_add(1, Ordering::Relaxed);
        if crate::obs::journal::enabled() {
            use crate::obs::journal::{record_dur, EventKind};
            record_dur(EventKind::PhaseScope, 0, nanos / 1_000, i as u64);
        }
    }
}

/// Zero every accumulator (the enabled switch is left as-is).
pub fn reset() {
    for i in 0..PHASE_COUNT {
        NANOS[i].store(0, Ordering::Relaxed);
        CALLS[i].store(0, Ordering::Relaxed);
    }
}

/// One phase's accumulated totals plus its share of all profiled time.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    pub phase: &'static str,
    pub nanos: u64,
    pub calls: u64,
    pub pct: f64,
}

/// Point-in-time copy of the profiler state. `phases` lists only phases
/// that recorded time, ordered hottest-first; `pct` is each phase's share
/// of `total_nanos`, so the shares sum to ~100 by construction.
#[derive(Debug, Clone)]
pub struct ProfileSnapshot {
    pub enabled: bool,
    /// Active dispatch kernel ISA the timed code ran under.
    pub kernel: &'static str,
    pub total_nanos: u64,
    pub phases: Vec<PhaseStat>,
}

pub fn snapshot() -> ProfileSnapshot {
    let mut phases: Vec<PhaseStat> = ALL_PHASES
        .iter()
        .filter_map(|p| {
            let i = p.index();
            let nanos = NANOS[i].load(Ordering::Relaxed);
            let calls = CALLS[i].load(Ordering::Relaxed);
            (calls > 0).then_some(PhaseStat { phase: p.name(), nanos, calls, pct: 0.0 })
        })
        .collect();
    let total_nanos: u64 = phases.iter().map(|p| p.nanos).sum();
    if total_nanos > 0 {
        for p in &mut phases {
            p.pct = p.nanos as f64 / total_nanos as f64 * 100.0;
        }
    }
    phases.sort_by(|a, b| b.nanos.cmp(&a.nanos));
    ProfileSnapshot {
        enabled: enabled(),
        kernel: crate::backend::simd::kernel_name(),
        total_nanos,
        phases,
    }
}

impl ProfileSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", Json::Bool(self.enabled)),
            ("kernel", Json::Str(self.kernel.to_string())),
            ("total_ms", Json::Num(self.total_nanos as f64 / 1e6)),
            (
                "breakdown",
                Json::Arr(
                    self.phases
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("phase", Json::Str(p.phase.to_string())),
                                ("ms", Json::Num(p.nanos as f64 / 1e6)),
                                ("calls", Json::Num(p.calls as f64)),
                                ("pct", Json::Num((p.pct * 100.0).round() / 100.0)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The global profiler is process-wide state, so every assertion about
    // it lives in this one serialized test (cargo runs tests in the same
    // binary concurrently).
    #[test]
    fn profiler_accumulates_only_when_enabled_and_pcts_sum_to_100() {
        set_enabled(false);
        reset();
        let t = start();
        assert!(t.is_none(), "disabled profiler must not read the clock");
        stop(Phase::Embed, t);
        assert_eq!(snapshot().total_nanos, 0);
        assert!(!snapshot().enabled);

        set_enabled(true);
        let t = start();
        assert!(t.is_some());
        std::thread::sleep(std::time::Duration::from_millis(2));
        stop(Phase::Embed, t);
        let t = start();
        stop(Phase::LinWq, t);
        let snap = snapshot();
        assert!(snap.enabled);
        assert!(snap.total_nanos > 0);
        assert!(!snap.phases.is_empty());
        let pct_sum: f64 = snap.phases.iter().map(|p| p.pct).sum();
        assert!((pct_sum - 100.0).abs() < 1e-6, "pcts sum to {pct_sum}");
        // Hottest-first ordering is maintained.
        for pair in snap.phases.windows(2) {
            assert!(pair[0].nanos >= pair[1].nanos);
        }
        let j = snap.to_json();
        assert_eq!(j.get("enabled"), Some(&Json::Bool(true)));
        assert!(!j.get("breakdown").unwrap().as_arr().unwrap().is_empty());

        set_enabled(false);
        reset();
    }

    #[test]
    fn phase_names_are_unique() {
        let mut names: Vec<&str> = ALL_PHASES.iter().map(|p| p.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), PHASE_COUNT);
    }
}
