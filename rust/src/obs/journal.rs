//! Lock-free flight-recorder journal for sequence lifecycle events.
//!
//! A fixed ring of atomic slots records every scheduling transition a
//! sequence goes through on its way through the engine — enqueue, slot
//! admission, prefix-cache hit, page claim, decode step, preemption,
//! resume, eviction, completion — plus (when the per-phase profiler is
//! also on) the decode-phase scopes, so one buffer holds the full
//! causality picture the trace exporter ([`crate::obs::trace`]) renders.
//!
//! Same discipline as [`crate::obs::profiler`]: a process-global static,
//! **off by default**, and when off every emission site pays exactly one
//! relaxed atomic load. When on, recording is wait-free — writers claim a
//! slot with one `fetch_add`, fill the fields, then publish with a
//! release-store of the slot's sequence stamp; no locks anywhere, so the
//! decode hot loop never blocks on an observer. Readers ([`snapshot`])
//! validate each slot's stamp before and after copying it and drop slots a
//! concurrent writer was overwriting, so a torn read can never surface.
//!
//! Timestamps are microseconds of monotonic time since the journal's
//! process-wide epoch (first use), which keeps events from every thread on
//! one comparable clock — exactly what the Chrome-trace `ts` field wants.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Ring capacity. Power of two so the claim index wraps with a mask; 8192
/// slots hold several hundred decode steps of history even with per-phase
/// scopes flowing in.
pub const JOURNAL_SLOTS: usize = 8192;

/// One sequence lifecycle transition (or engine-side scope) kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Request accepted into a queue (engine accept or decoder submit).
    Enqueue,
    /// Sequence admitted into a KV slot; prefill starts. `aux` = prompt
    /// tokens actually fed (prefix hits shrink it).
    Admit,
    /// Prefix-cache hit at admission. `aux` = tokens skipped.
    PrefixHit,
    /// One pool page claimed. `aux` = pages the slot now maps.
    PageClaim,
    /// One fused decode step over all live rows. `aux` = batch size.
    Step,
    /// Sequence preempted back to the queue (out of pages). `aux` =
    /// tokens already chosen (replayed on resume).
    Preempt,
    /// Preempted sequence re-admitted; replay starts. `aux` = tokens to
    /// replay.
    Resume,
    /// Sequence evicted before completion (cancel / disconnect).
    Evict,
    /// Sequence retired normally. `aux` = generated tokens.
    Complete,
    /// One profiler phase scope (journal + profiler both on). `aux` = the
    /// [`crate::obs::profiler::Phase`] index; `id` is unused.
    PhaseScope,
    /// Sequence evicted because its deadline expired. `aux` = tokens
    /// generated before the timeout.
    Timeout,
    /// The supervised engine loop panicked (or failed). `aux` = in-flight
    /// requests that received a terminal `Failed`; `id` is unused.
    Crash,
    /// The supervisor restarted the engine after a crash. `aux` = restart
    /// ordinal (1-based); `id` is unused.
    Restart,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Enqueue => "enqueue",
            EventKind::Admit => "admit",
            EventKind::PrefixHit => "prefix_hit",
            EventKind::PageClaim => "page_claim",
            EventKind::Step => "step",
            EventKind::Preempt => "preempt",
            EventKind::Resume => "resume",
            EventKind::Evict => "evict",
            EventKind::Complete => "complete",
            EventKind::PhaseScope => "phase",
            EventKind::Timeout => "timeout",
            EventKind::Crash => "crash",
            EventKind::Restart => "restart",
        }
    }

    fn code(self) -> u64 {
        match self {
            EventKind::Enqueue => 0,
            EventKind::Admit => 1,
            EventKind::PrefixHit => 2,
            EventKind::PageClaim => 3,
            EventKind::Step => 4,
            EventKind::Preempt => 5,
            EventKind::Resume => 6,
            EventKind::Evict => 7,
            EventKind::Complete => 8,
            EventKind::PhaseScope => 9,
            EventKind::Timeout => 10,
            EventKind::Crash => 11,
            EventKind::Restart => 12,
        }
    }

    fn from_code(code: u64) -> Option<EventKind> {
        Some(match code {
            0 => EventKind::Enqueue,
            1 => EventKind::Admit,
            2 => EventKind::PrefixHit,
            3 => EventKind::PageClaim,
            4 => EventKind::Step,
            5 => EventKind::Preempt,
            6 => EventKind::Resume,
            7 => EventKind::Evict,
            8 => EventKind::Complete,
            9 => EventKind::PhaseScope,
            10 => EventKind::Timeout,
            11 => EventKind::Crash,
            12 => EventKind::Restart,
            _ => return None,
        })
    }
}

/// One decoded journal entry, as [`snapshot`] returns it (oldest first).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    /// Global emission order (monotonic across the whole process).
    pub seq: u64,
    pub kind: EventKind,
    /// Request span id (0 for engine-lane events like `PhaseScope`).
    pub id: usize,
    /// Microseconds since the journal epoch at which the event *started*.
    pub t_us: u64,
    /// Scope duration in microseconds (0 for instant events).
    pub dur_us: u64,
    /// Kind-specific payload (see [`EventKind`]).
    pub aux: u64,
}

/// One ring slot: `stamp == 0` means never written; otherwise it is the
/// claim sequence + 1, published last with release ordering.
struct Slot {
    stamp: AtomicU64,
    kind_id: AtomicU64,
    t_us: AtomicU64,
    dur_us: AtomicU64,
    aux: AtomicU64,
}

// Interior mutability is the point: this const exists only to const-init
// the static slot array.
#[allow(clippy::declare_interior_mutable_const)]
const EMPTY_SLOT: Slot = Slot {
    stamp: AtomicU64::new(0),
    kind_id: AtomicU64::new(0),
    t_us: AtomicU64::new(0),
    dur_us: AtomicU64::new(0),
    aux: AtomicU64::new(0),
};

static RING: [Slot; JOURNAL_SLOTS] = [EMPTY_SLOT; JOURNAL_SLOTS];
static NEXT: AtomicUsize = AtomicUsize::new(0);
static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Is the journal recording? One relaxed load — the cost every emission
/// site pays when the flight recorder is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the journal on or off at runtime (serve startup, benches, tests).
pub fn set_enabled(on: bool) {
    if on {
        // Pin the epoch before any event can be stamped against it.
        let _ = EPOCH.get_or_init(Instant::now);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Microseconds of monotonic time since the journal epoch.
#[inline]
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Drop every recorded event (the enabled switch is left as-is).
pub fn reset() {
    NEXT.store(0, Ordering::SeqCst);
    for slot in RING.iter() {
        slot.stamp.store(0, Ordering::SeqCst);
    }
}

/// Record an instant event at the current time. No-op when disabled.
#[inline]
pub fn record(kind: EventKind, id: usize, aux: u64) {
    if enabled() {
        publish(kind, id, now_us(), 0, aux);
    }
}

/// Record a scope that started at `t0_us` ([`now_us`] captured earlier)
/// and ends now. No-op when disabled.
#[inline]
pub fn record_span(kind: EventKind, id: usize, t0_us: u64, aux: u64) {
    if enabled() {
        let now = now_us();
        publish(kind, id, t0_us, now.saturating_sub(t0_us), aux);
    }
}

/// Record a scope with an explicit duration ending now (used by the
/// profiler bridge, which already measured the elapsed time).
#[inline]
pub fn record_dur(kind: EventKind, id: usize, dur_us: u64, aux: u64) {
    if enabled() {
        let now = now_us();
        publish(kind, id, now.saturating_sub(dur_us), dur_us, aux);
    }
}

fn publish(kind: EventKind, id: usize, t_us: u64, dur_us: u64, aux: u64) {
    let seq = NEXT.fetch_add(1, Ordering::Relaxed);
    let slot = &RING[seq % JOURNAL_SLOTS];
    // Invalidate the slot first so a concurrent reader cannot pair the old
    // stamp with half-new fields, then publish the new stamp last.
    slot.stamp.store(0, Ordering::Release);
    slot.kind_id.store(kind.code() | ((id as u64) << 8), Ordering::Relaxed);
    slot.t_us.store(t_us, Ordering::Relaxed);
    slot.dur_us.store(dur_us, Ordering::Relaxed);
    slot.aux.store(aux, Ordering::Relaxed);
    slot.stamp.store(seq as u64 + 1, Ordering::Release);
}

/// Copy out up to `last` most-recent events, oldest first. Slots a
/// concurrent writer is mid-overwrite are skipped (stamp re-validation),
/// so the result is always internally consistent.
pub fn snapshot(last: usize) -> Vec<Event> {
    let mut events: Vec<Event> = Vec::with_capacity(JOURNAL_SLOTS.min(last));
    for slot in RING.iter() {
        let stamp = slot.stamp.load(Ordering::Acquire);
        if stamp == 0 {
            continue;
        }
        let kind_id = slot.kind_id.load(Ordering::Relaxed);
        let t_us = slot.t_us.load(Ordering::Relaxed);
        let dur_us = slot.dur_us.load(Ordering::Relaxed);
        let aux = slot.aux.load(Ordering::Relaxed);
        if slot.stamp.load(Ordering::Acquire) != stamp {
            continue; // torn: a writer replaced this slot mid-copy
        }
        let Some(kind) = EventKind::from_code(kind_id & 0xFF) else {
            continue;
        };
        events.push(Event {
            seq: stamp - 1,
            kind,
            id: (kind_id >> 8) as usize,
            t_us,
            dur_us,
            aux,
        });
    }
    events.sort_by_key(|e| e.seq);
    if events.len() > last {
        let cut = events.len() - last;
        events.drain(..cut);
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    // The journal is process-global and other tests in this binary may
    // record events concurrently while it is enabled, so every assertion
    // filters on ids unique to this test instead of exact ring contents.
    #[test]
    fn journal_records_publishes_and_wraps() {
        const ID_A: usize = 990_007;
        const ID_OFF: usize = 990_001;

        set_enabled(false);
        record(EventKind::Enqueue, ID_OFF, 0);
        assert!(
            !snapshot(usize::MAX).iter().any(|e| e.id == ID_OFF),
            "disabled journal must drop events"
        );

        set_enabled(true);
        record(EventKind::Enqueue, ID_A, 0);
        record(EventKind::Admit, ID_A, 5);
        let t0 = now_us();
        record_span(EventKind::Step, ID_A, t0, 3);
        let mine: Vec<Event> = snapshot(usize::MAX)
            .into_iter()
            .filter(|e| e.id == ID_A)
            .collect();
        set_enabled(false);
        assert_eq!(mine.len(), 3);
        assert_eq!(mine[0].kind, EventKind::Enqueue);
        assert_eq!(mine[1].kind, EventKind::Admit);
        assert_eq!(mine[1].aux, 5);
        assert_eq!(mine[2].kind, EventKind::Step);
        assert_eq!(mine[2].aux, 3);
        // Emission order is strictly increasing and times are monotone.
        assert!(mine[0].seq < mine[1].seq && mine[1].seq < mine[2].seq);
        assert!(mine[0].t_us <= mine[1].t_us && mine[1].t_us <= mine[2].t_us);

        // Wraparound: overfill the ring, then confirm the snapshot is
        // bounded by the ring size and `last` trims from the old end.
        set_enabled(true);
        for i in 0..JOURNAL_SLOTS + 100 {
            record(EventKind::Step, ID_A, i as u64);
        }
        let all = snapshot(usize::MAX);
        set_enabled(false);
        assert!(all.len() <= JOURNAL_SLOTS);
        let newest_mine = all.iter().filter(|e| e.id == ID_A).count();
        assert!(
            newest_mine >= JOURNAL_SLOTS - 200,
            "ring should be dominated by the overfill burst (got {newest_mine})"
        );
        let last = snapshot(8);
        assert!(last.len() <= 8);
    }

    #[test]
    fn kind_codes_round_trip() {
        for kind in [
            EventKind::Enqueue,
            EventKind::Admit,
            EventKind::PrefixHit,
            EventKind::PageClaim,
            EventKind::Step,
            EventKind::Preempt,
            EventKind::Resume,
            EventKind::Evict,
            EventKind::Complete,
            EventKind::PhaseScope,
            EventKind::Timeout,
            EventKind::Crash,
            EventKind::Restart,
        ] {
            assert_eq!(EventKind::from_code(kind.code()), Some(kind));
            assert!(!kind.name().is_empty());
        }
        assert_eq!(EventKind::from_code(200), None);
    }
}
