//! Chrome-trace / Perfetto export of the flight-recorder journal.
//!
//! [`chrome_trace`] turns a [`crate::obs::journal`] snapshot into the
//! Trace Event Format JSON that `chrome://tracing` and
//! <https://ui.perfetto.dev> load directly: one timeline lane per request
//! (pid 1, tid = request span id) carrying `queued` / `running` /
//! `preempted` duration slices derived from the lifecycle transitions,
//! instant markers for every transition, and an `engine` lane (tid 0)
//! carrying decode-step slices plus — when the per-phase profiler was on —
//! the phase scopes, which Perfetto nests under their containing step by
//! time containment.
//!
//! [`summarize`] folds the same events into per-sequence timelines
//! (queue wait, preemption count and stall time, lifetime) for the
//! `sinq analyze trace` CLI table and `/debug/trace` consumers that want
//! numbers instead of a UI.

use crate::obs::journal::{Event, EventKind};
use crate::obs::profiler::ALL_PHASES;
use crate::util::json::Json;

/// The single pid every lane lives under.
const TRACE_PID: f64 = 1.0;

fn trace_event(name: &str, ph: &str, ts_us: u64, tid: usize, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str(ph.to_string())),
        ("ts", Json::Num(ts_us as f64)),
        ("pid", Json::Num(TRACE_PID)),
        ("tid", Json::Num(tid as f64)),
    ];
    pairs.extend(extra);
    Json::obj(pairs)
}

fn slice(name: &str, t0_us: u64, dur_us: u64, tid: usize, args: Vec<(&str, Json)>) -> Json {
    let mut extra = vec![("dur", Json::Num(dur_us as f64))];
    if !args.is_empty() {
        extra.push(("args", Json::obj(args)));
    }
    trace_event(name, "X", t0_us, tid, extra)
}

fn instant(name: &str, ts_us: u64, tid: usize, args: Vec<(&str, Json)>) -> Json {
    // "s":"t" scopes the instant marker to its thread lane.
    let mut extra = vec![("s", Json::Str("t".to_string()))];
    if !args.is_empty() {
        extra.push(("args", Json::obj(args)));
    }
    trace_event(name, "i", ts_us, tid, extra)
}

fn thread_name(tid: usize, name: &str) -> Json {
    trace_event(
        "thread_name",
        "M",
        0,
        tid,
        vec![("args", Json::obj(vec![("name", Json::Str(name.to_string()))]))],
    )
}

/// Per-request reconstruction state while walking the event stream.
struct Lane {
    id: usize,
    enqueued_us: Option<u64>,
    running_since_us: Option<u64>,
    preempted_since_us: Option<u64>,
}

impl Lane {
    fn new(id: usize) -> Lane {
        Lane { id, enqueued_us: None, running_since_us: None, preempted_since_us: None }
    }
}

/// Render journal events (oldest first, as [`crate::obs::journal::snapshot`]
/// returns them) as a Chrome-trace JSON document.
pub fn chrome_trace(events: &[Event]) -> Json {
    let mut out: Vec<Json> = Vec::with_capacity(events.len() * 2 + 8);
    out.push(trace_event(
        "process_name",
        "M",
        0,
        0,
        vec![("args", Json::obj(vec![("name", Json::Str("sinq-engine".to_string()))]))],
    ));
    out.push(thread_name(0, "engine"));

    let mut lanes: Vec<Lane> = Vec::new();
    for ev in events {
        match ev.kind {
            // Engine-lane scopes need no per-request state.
            EventKind::Step => {
                out.push(slice(
                    "step",
                    ev.t_us,
                    ev.dur_us,
                    0,
                    vec![("batch", Json::Num(ev.aux as f64))],
                ));
                continue;
            }
            EventKind::PhaseScope => {
                let name =
                    ALL_PHASES.get(ev.aux as usize).map(|p| p.name()).unwrap_or("phase");
                out.push(slice(name, ev.t_us, ev.dur_us, 0, vec![]));
                continue;
            }
            // Supervisor events belong to the engine lane, not a request.
            EventKind::Crash => {
                out.push(instant(
                    "crash",
                    ev.t_us,
                    0,
                    vec![("failed_requests", Json::Num(ev.aux as f64))],
                ));
                continue;
            }
            EventKind::Restart => {
                out.push(instant("restart", ev.t_us, 0, vec![("attempt", Json::Num(ev.aux as f64))]));
                continue;
            }
            _ => {}
        }

        let lane = match lanes.iter_mut().find(|l| l.id == ev.id) {
            Some(l) => l,
            None => {
                out.push(thread_name(ev.id, &format!("req {}", ev.id)));
                lanes.push(Lane::new(ev.id));
                lanes.last_mut().expect("just pushed")
            }
        };
        match ev.kind {
            // The engine accept path and the decoder submit path may both
            // stamp an enqueue for the same request; the earliest wins.
            EventKind::Enqueue => {
                if lane.enqueued_us.is_none() {
                    lane.enqueued_us = Some(ev.t_us);
                    out.push(instant("enqueue", ev.t_us, lane.id, vec![]));
                }
            }
            EventKind::Admit | EventKind::Resume => {
                let (label, from) = if ev.kind == EventKind::Admit {
                    ("queued", lane.enqueued_us.take())
                } else {
                    ("preempted", lane.preempted_since_us.take())
                };
                if let Some(t0) = from {
                    out.push(slice(label, t0, ev.t_us.saturating_sub(t0), lane.id, vec![]));
                }
                lane.running_since_us = Some(ev.t_us);
                out.push(instant(
                    if ev.kind == EventKind::Admit { "admit" } else { "resume" },
                    ev.t_us,
                    lane.id,
                    vec![("tokens", Json::Num(ev.aux as f64))],
                ));
            }
            EventKind::Preempt => {
                if let Some(t0) = lane.running_since_us.take() {
                    out.push(slice("running", t0, ev.t_us.saturating_sub(t0), lane.id, vec![]));
                }
                lane.preempted_since_us = Some(ev.t_us);
                out.push(instant(
                    "preempt",
                    ev.t_us,
                    lane.id,
                    vec![("tokens", Json::Num(ev.aux as f64))],
                ));
            }
            EventKind::Complete | EventKind::Evict | EventKind::Timeout => {
                if let Some(t0) = lane.running_since_us.take() {
                    out.push(slice("running", t0, ev.t_us.saturating_sub(t0), lane.id, vec![]));
                }
                out.push(instant(
                    ev.kind.name(),
                    ev.t_us,
                    lane.id,
                    vec![("tokens", Json::Num(ev.aux as f64))],
                ));
            }
            EventKind::PrefixHit => {
                out.push(instant(
                    "prefix_hit",
                    ev.t_us,
                    lane.id,
                    vec![("tokens_reused", Json::Num(ev.aux as f64))],
                ));
            }
            EventKind::PageClaim => {
                out.push(instant(
                    "page_claim",
                    ev.t_us,
                    lane.id,
                    vec![("pages", Json::Num(ev.aux as f64))],
                ));
            }
            EventKind::Step | EventKind::PhaseScope | EventKind::Crash | EventKind::Restart => {
                unreachable!("handled above")
            }
        }
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// One request's reconstructed timeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SeqSummary {
    pub id: usize,
    /// Epoch-relative enqueue time (first event seen for the request).
    pub start_us: u64,
    /// Time spent waiting for a KV slot before first admission.
    pub queue_us: u64,
    /// Prompt tokens skipped via the prefix cache.
    pub prefix_reused: u64,
    pub preempts: u64,
    /// Total time spent preempted (resume − preempt, summed).
    pub preempted_us: u64,
    /// Generated tokens at completion / eviction (the event's payload).
    pub tokens: u64,
    /// Enqueue (or first event) → terminal event, if the request ended
    /// inside the captured window.
    pub total_us: Option<u64>,
    /// `"complete"`, `"evict"`, or `"live"` if no terminal event captured.
    pub outcome: &'static str,
}

/// Fold journal events into per-request timelines, ordered by first
/// appearance. Engine-lane events (steps, phase scopes) are ignored.
pub fn summarize(events: &[Event]) -> Vec<SeqSummary> {
    struct Acc {
        summary: SeqSummary,
        enqueued_us: Option<u64>,
        preempted_since_us: Option<u64>,
    }
    let mut accs: Vec<Acc> = Vec::new();
    for ev in events {
        if matches!(
            ev.kind,
            EventKind::Step | EventKind::PhaseScope | EventKind::Crash | EventKind::Restart
        ) {
            continue;
        }
        let acc = match accs.iter_mut().find(|a| a.summary.id == ev.id) {
            Some(a) => a,
            None => {
                accs.push(Acc {
                    summary: SeqSummary {
                        id: ev.id,
                        start_us: ev.t_us,
                        queue_us: 0,
                        prefix_reused: 0,
                        preempts: 0,
                        preempted_us: 0,
                        tokens: 0,
                        total_us: None,
                        outcome: "live",
                    },
                    enqueued_us: None,
                    preempted_since_us: None,
                });
                accs.last_mut().expect("just pushed")
            }
        };
        match ev.kind {
            EventKind::Enqueue => {
                if acc.enqueued_us.is_none() {
                    acc.enqueued_us = Some(ev.t_us);
                }
            }
            EventKind::Admit => {
                if let Some(t0) = acc.enqueued_us.take() {
                    acc.summary.queue_us = ev.t_us.saturating_sub(t0);
                }
            }
            EventKind::PrefixHit => acc.summary.prefix_reused += ev.aux,
            EventKind::Preempt => {
                acc.summary.preempts += 1;
                acc.preempted_since_us = Some(ev.t_us);
            }
            EventKind::Resume => {
                if let Some(t0) = acc.preempted_since_us.take() {
                    acc.summary.preempted_us += ev.t_us.saturating_sub(t0);
                }
            }
            EventKind::Complete | EventKind::Evict | EventKind::Timeout => {
                acc.summary.tokens = ev.aux;
                acc.summary.total_us = Some(ev.t_us.saturating_sub(acc.summary.start_us));
                acc.summary.outcome = match ev.kind {
                    EventKind::Complete => "complete",
                    EventKind::Timeout => "timeout",
                    _ => "evict",
                };
            }
            EventKind::PageClaim
            | EventKind::Step
            | EventKind::PhaseScope
            | EventKind::Crash
            | EventKind::Restart => {}
        }
    }
    accs.into_iter().map(|a| a.summary).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(seq: u64, kind: EventKind, id: usize, t_us: u64, dur_us: u64, aux: u64) -> Event {
        Event { seq, kind, id, t_us, dur_us, aux }
    }

    /// A preempted-then-resumed request next to a plain one, with engine
    /// steps interleaved — the acceptance-criteria scenario in miniature.
    fn preemption_story() -> Vec<Event> {
        vec![
            ev(0, EventKind::Enqueue, 1, 100, 0, 0),
            ev(1, EventKind::Admit, 1, 150, 0, 8),
            ev(2, EventKind::PageClaim, 1, 151, 0, 1),
            ev(3, EventKind::Step, 0, 160, 40, 1),
            ev(4, EventKind::Enqueue, 2, 180, 0, 0),
            ev(5, EventKind::Admit, 2, 200, 0, 4),
            ev(6, EventKind::PrefixHit, 2, 200, 0, 4),
            ev(7, EventKind::Preempt, 1, 220, 0, 3),
            ev(8, EventKind::Step, 0, 230, 30, 1),
            ev(9, EventKind::Complete, 2, 260, 0, 4),
            ev(10, EventKind::Resume, 1, 270, 0, 11),
            ev(11, EventKind::Step, 0, 280, 25, 2),
            ev(12, EventKind::PhaseScope, 0, 281, 10, 0),
            ev(13, EventKind::Complete, 1, 300, 0, 6),
        ]
    }

    #[test]
    fn chrome_trace_shape_and_lifecycle_slices() {
        let doc = chrome_trace(&preemption_story());
        let s = doc.to_string_compact();
        // Round-trips through our own parser (what the CI smoke asserts
        // with python's json module).
        let parsed = Json::parse(&s).expect("trace JSON must parse");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        for e in events {
            assert!(e.get("ph").is_some() && e.get("ts").is_some());
            assert!(e.get("pid").is_some() && e.get("tid").is_some());
        }

        let find = |name: &str, ph: &str| -> Vec<&Json> {
            events
                .iter()
                .filter(|e| {
                    e.get("name").and_then(|n| n.as_str()) == Some(name)
                        && e.get("ph").and_then(|p| p.as_str()) == Some(ph)
                })
                .collect()
        };
        // Request 1: queued 100→150, running 150→220, preempted 220→270,
        // running 270→300.
        let queued = find("queued", "X");
        assert_eq!(queued.len(), 2, "one queued slice per admitted request");
        let preempted = find("preempted", "X");
        assert_eq!(preempted.len(), 1);
        assert_eq!(preempted[0].get("ts").unwrap().as_f64(), Some(220.0));
        assert_eq!(preempted[0].get("dur").unwrap().as_f64(), Some(50.0));
        let running = find("running", "X");
        assert_eq!(running.len(), 3, "req 1 twice (around preemption) + req 2 once");
        // Engine lane: steps carry their batch size; the phase scope is
        // named after the profiler phase (index 0 = embed).
        assert_eq!(find("step", "X").len(), 3);
        assert_eq!(find("embed", "X").len(), 1);
        // Every transition also lands as an instant marker.
        for name in ["enqueue", "admit", "preempt", "resume", "complete", "prefix_hit"] {
            assert!(!find(name, "i").is_empty(), "missing instant '{name}'");
        }
    }

    #[test]
    fn duplicate_enqueue_keeps_earliest() {
        let events = vec![
            ev(0, EventKind::Enqueue, 5, 100, 0, 0),
            ev(1, EventKind::Enqueue, 5, 140, 0, 0),
            ev(2, EventKind::Admit, 5, 200, 0, 2),
        ];
        let doc = chrome_trace(&events);
        let s = doc.to_string_compact();
        // One enqueue instant, and the queued slice spans from the first.
        assert_eq!(s.matches("\"enqueue\"").count(), 1);
        let summary = summarize(&events);
        assert_eq!(summary[0].queue_us, 100);
    }

    #[test]
    fn summarize_reconstructs_timelines() {
        let sums = summarize(&preemption_story());
        assert_eq!(sums.len(), 2);
        let r1 = &sums[0];
        assert_eq!(r1.id, 1);
        assert_eq!(r1.queue_us, 50);
        assert_eq!(r1.preempts, 1);
        assert_eq!(r1.preempted_us, 50);
        assert_eq!(r1.tokens, 6);
        assert_eq!(r1.total_us, Some(200));
        assert_eq!(r1.outcome, "complete");
        let r2 = &sums[1];
        assert_eq!(r2.id, 2);
        assert_eq!(r2.queue_us, 20);
        assert_eq!(r2.prefix_reused, 4);
        assert_eq!(r2.preempts, 0);
        assert_eq!(r2.outcome, "complete");
    }

    #[test]
    fn live_requests_stay_open() {
        let events = vec![
            ev(0, EventKind::Enqueue, 9, 10, 0, 0),
            ev(1, EventKind::Admit, 9, 30, 0, 2),
        ];
        let sums = summarize(&events);
        assert_eq!(sums[0].outcome, "live");
        assert_eq!(sums[0].total_us, None);
        assert_eq!(sums[0].queue_us, 20);
    }
}
