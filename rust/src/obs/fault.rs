//! Deterministic fault-injection registry for the serving stack.
//!
//! Production code threads named *fault points* through the paths that can
//! actually fail in the field — request submission, batch admission, page
//! claiming, the decode step, quantized KV writes, SSE socket writes — and
//! tests/CI arm them to rehearse crashes, slowdowns, and error returns
//! without touching the code under test. Disarmed — the default — every
//! [`check`] costs a single relaxed atomic load (the same discipline as
//! `SINQ_PROFILE` in [`crate::obs::profiler`]), so the sites stay compiled
//! in release builds and in the bit-exactness gates.
//!
//! Arm via the `SINQ_FAULTS` environment variable or [`arm_str`]:
//!
//! ```text
//! SINQ_FAULTS=site:action[@once|@every=N][,site:action...]
//!   site   := submit | admit | page_claim | decode_step | kv_write | sse_write
//!   action := panic | error | delay:MS
//! ```
//!
//! `@once` fires on the first hit only (the hit counter persists across
//! engine restarts, so a supervised engine that crashed on an injected
//! panic decodes cleanly after its restart); `@every=N` fires on every
//! N-th hit; with no modifier the fault fires on every hit.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Once;
use std::time::Duration;

/// One named injection site. `Test` is reserved for this module's unit
/// tests — no production code checks it, so arming it cannot perturb
/// concurrently running tests in the same binary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// `EngineClient::submit`, before the request enters the queue.
    Submit,
    /// The engine loop's admission of a queued submission into the batch.
    Admit,
    /// `BatchDecoder` KV page claiming (the preemption pressure path).
    PageClaim,
    /// Top of `BatchDecoder::step` — a panic here exercises supervision.
    DecodeStep,
    /// `PagedKv::write`, the per-token KV append.
    KvWrite,
    /// SSE streaming writes in the HTTP layer.
    SseWrite,
    /// Unit-test-only site; never checked by production code.
    Test,
}

pub const SITE_COUNT: usize = 7;

pub const ALL_SITES: [Site; SITE_COUNT] = [
    Site::Submit,
    Site::Admit,
    Site::PageClaim,
    Site::DecodeStep,
    Site::KvWrite,
    Site::SseWrite,
    Site::Test,
];

impl Site {
    pub fn name(&self) -> &'static str {
        match self {
            Site::Submit => "submit",
            Site::Admit => "admit",
            Site::PageClaim => "page_claim",
            Site::DecodeStep => "decode_step",
            Site::KvWrite => "kv_write",
            Site::SseWrite => "sse_write",
            Site::Test => "test",
        }
    }

    pub fn from_name(name: &str) -> Option<Site> {
        ALL_SITES.iter().copied().find(|s| s.name() == name)
    }

    #[inline]
    fn index(&self) -> usize {
        *self as usize
    }
}

// Per-site action codes. 0 = disarmed.
const ACT_NONE: usize = 0;
const ACT_PANIC: usize = 1;
const ACT_ERROR: usize = 2;
const ACT_DELAY: usize = 3;

// `@every=N` is stored in EVERY (0 = fire on every hit); `@once` is the
// special encoding EVERY = u64::MAX.
const EVERY_ONCE: u64 = u64::MAX;

static ARMED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

// Interior mutability is the point: these consts exist only to const-init
// the static atomic arrays.
#[allow(clippy::declare_interior_mutable_const)]
const ZERO_USIZE: AtomicUsize = AtomicUsize::new(0);
#[allow(clippy::declare_interior_mutable_const)]
const ZERO_U64: AtomicU64 = AtomicU64::new(0);
static ACTION: [AtomicUsize; SITE_COUNT] = [ZERO_USIZE; SITE_COUNT];
static DELAY_MS: [AtomicU64; SITE_COUNT] = [ZERO_U64; SITE_COUNT];
static EVERY: [AtomicU64; SITE_COUNT] = [ZERO_U64; SITE_COUNT];
static HITS: [AtomicU64; SITE_COUNT] = [ZERO_U64; SITE_COUNT];
static FIRED: [AtomicU64; SITE_COUNT] = [ZERO_U64; SITE_COUNT];

/// Is any fault point armed? First call folds in the `SINQ_FAULTS`
/// environment variable; after that it is one relaxed load — the entire
/// disarmed-path cost of every [`check`] in the hot loops.
#[inline]
pub fn armed() -> bool {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("SINQ_FAULTS") {
            if !spec.is_empty() {
                if let Err(e) = arm_str(&spec) {
                    eprintln!("SINQ_FAULTS ignored: {e}");
                }
            }
        }
    });
    ARMED.load(Ordering::Relaxed)
}

/// Arm fault points from a `SINQ_FAULTS`-grammar spec. Additive: sites not
/// named keep their current state. Returns an error (arming nothing from
/// the offending entry) on unknown sites, actions, or modifiers.
pub fn arm_str(spec: &str) -> Result<(), String> {
    // Parse every entry before touching the registry so a bad tail entry
    // cannot leave a half-armed spec behind.
    let mut parsed: Vec<(Site, usize, u64, u64)> = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (body, every) = match entry.split_once('@') {
            None => (entry, 0u64),
            Some((body, "once")) => (body, EVERY_ONCE),
            Some((body, modif)) => {
                let n = modif
                    .strip_prefix("every=")
                    .and_then(|n| n.parse::<u64>().ok())
                    .filter(|&n| n > 0)
                    .ok_or_else(|| format!("bad modifier '@{modif}' in '{entry}'"))?;
                (body, n)
            }
        };
        let (site, action) = body
            .split_once(':')
            .ok_or_else(|| format!("missing ':' in fault entry '{entry}'"))?;
        let site = Site::from_name(site).ok_or_else(|| format!("unknown fault site '{site}'"))?;
        let (code, delay_ms) = match action {
            "panic" => (ACT_PANIC, 0),
            "error" => (ACT_ERROR, 0),
            _ => {
                let ms = action
                    .strip_prefix("delay:")
                    .and_then(|ms| ms.parse::<u64>().ok())
                    .ok_or_else(|| format!("unknown fault action '{action}' in '{entry}'"))?;
                (ACT_DELAY, ms)
            }
        };
        parsed.push((site, code, delay_ms, every));
    }
    if parsed.is_empty() {
        return Err(format!("no fault entries in '{spec}'"));
    }
    for (site, code, delay_ms, every) in parsed {
        let i = site.index();
        DELAY_MS[i].store(delay_ms, Ordering::Relaxed);
        EVERY[i].store(every, Ordering::Relaxed);
        HITS[i].store(0, Ordering::Relaxed);
        FIRED[i].store(0, Ordering::Relaxed);
        ACTION[i].store(code, Ordering::Relaxed);
    }
    ENV_INIT.call_once(|| {});
    ARMED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Disarm every site and zero the hit/fired counters.
pub fn disarm_all() {
    for i in 0..SITE_COUNT {
        ACTION[i].store(ACT_NONE, Ordering::Relaxed);
        DELAY_MS[i].store(0, Ordering::Relaxed);
        EVERY[i].store(0, Ordering::Relaxed);
        HITS[i].store(0, Ordering::Relaxed);
        FIRED[i].store(0, Ordering::Relaxed);
    }
    ENV_INIT.call_once(|| {});
    ARMED.store(false, Ordering::Relaxed);
}

/// How many times `site` has actually fired (panics count: the increment
/// happens before the unwind, so a supervised restart can see it).
pub fn fired(site: Site) -> u64 {
    FIRED[site.index()].load(Ordering::Relaxed)
}

/// Armed sites rendered back in `SINQ_FAULTS` grammar (startup log line).
pub fn list_armed() -> Vec<String> {
    if !armed() {
        return Vec::new();
    }
    ALL_SITES
        .iter()
        .filter_map(|s| {
            let i = s.index();
            let action = match ACTION[i].load(Ordering::Relaxed) {
                ACT_PANIC => "panic".to_string(),
                ACT_ERROR => "error".to_string(),
                ACT_DELAY => format!("delay:{}", DELAY_MS[i].load(Ordering::Relaxed)),
                _ => return None,
            };
            let modif = match EVERY[i].load(Ordering::Relaxed) {
                0 => String::new(),
                EVERY_ONCE => "@once".to_string(),
                n => format!("@every={n}"),
            };
            Some(format!("{}:{action}{modif}", s.name()))
        })
        .collect()
}

/// Pass through a fault point. Disarmed this is one relaxed atomic load.
/// Armed, it panics (`panic` action), sleeps (`delay:MS`), or returns an
/// error (`error`) that the caller routes down its real failure path.
#[inline]
pub fn check(site: Site) -> anyhow::Result<()> {
    if !armed() {
        return Ok(());
    }
    trip(site, false)
}

/// Like [`check`] for sites with no `Result` plumbing (page claiming, KV
/// writes): the `error` action escalates to a panic so the supervisor
/// still sees the failure instead of it being silently swallowed.
#[inline]
pub fn check_hard(site: Site) {
    if !armed() {
        return;
    }
    let _ = trip(site, true);
}

#[cold]
fn trip(site: Site, escalate_error: bool) -> anyhow::Result<()> {
    let i = site.index();
    let action = ACTION[i].load(Ordering::Relaxed);
    if action == ACT_NONE {
        return Ok(());
    }
    let hit = HITS[i].fetch_add(1, Ordering::Relaxed) + 1;
    match EVERY[i].load(Ordering::Relaxed) {
        0 => {}
        EVERY_ONCE => {
            if hit != 1 {
                return Ok(());
            }
        }
        n => {
            if hit % n != 0 {
                return Ok(());
            }
        }
    }
    FIRED[i].fetch_add(1, Ordering::Relaxed);
    match action {
        ACT_PANIC => panic!("injected fault: {} panic (hit {hit})", site.name()),
        ACT_DELAY => {
            std::thread::sleep(Duration::from_millis(DELAY_MS[i].load(Ordering::Relaxed)));
            Ok(())
        }
        _ => {
            if escalate_error {
                panic!("injected fault: {} error (hit {hit})", site.name());
            }
            anyhow::bail!("injected fault: {} error (hit {hit})", site.name())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    // The registry is process-global; serialize the tests that arm it.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn site_names_round_trip_and_are_unique() {
        let mut names: Vec<&str> = ALL_SITES.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), SITE_COUNT);
        for s in ALL_SITES {
            assert_eq!(Site::from_name(s.name()), Some(s));
        }
        assert_eq!(Site::from_name("nope"), None);
    }

    #[test]
    fn bad_specs_are_rejected_without_arming() {
        let _g = LOCK.lock().unwrap();
        disarm_all();
        for bad in [
            "",
            "decode_step",
            "nope:panic",
            "test:explode",
            "test:delay:abc",
            "test:panic@every=0",
            "test:panic@sometimes",
        ] {
            assert!(arm_str(bad).is_err(), "spec {bad:?} should be rejected");
        }
        assert!(!armed(), "rejected specs must not arm the registry");
        // A bad tail entry rejects the whole spec, including the good head.
        assert!(arm_str("test:error,oops").is_err());
        assert!(list_armed().is_empty());
    }

    #[test]
    fn error_once_and_every_modes_fire_deterministically() {
        let _g = LOCK.lock().unwrap();
        disarm_all();
        assert!(check(Site::Test).is_ok(), "disarmed check must pass");

        arm_str("test:error@once").unwrap();
        assert_eq!(list_armed(), vec!["test:error@once".to_string()]);
        let err = check(Site::Test).unwrap_err().to_string();
        assert!(err.contains("injected fault: test error"), "{err}");
        assert!(check(Site::Test).is_ok(), "@once must not fire twice");
        assert_eq!(fired(Site::Test), 1);

        arm_str("test:error@every=3").unwrap();
        let fired_hits: Vec<bool> = (0..6).map(|_| check(Site::Test).is_err()).collect();
        assert_eq!(fired_hits, [false, false, true, false, false, true]);
        assert_eq!(fired(Site::Test), 2);

        // Unconditional mode fires on every hit; delay mode returns Ok.
        arm_str("test:delay:1").unwrap();
        let t0 = std::time::Instant::now();
        assert!(check(Site::Test).is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(1));

        disarm_all();
        assert!(check(Site::Test).is_ok());
        assert_eq!(fired(Site::Test), 0);
    }

    #[test]
    fn panic_action_unwinds_and_hard_check_escalates_errors() {
        let _g = LOCK.lock().unwrap();
        disarm_all();
        arm_str("test:panic").unwrap();
        let caught = std::panic::catch_unwind(|| check(Site::Test));
        assert!(caught.is_err(), "panic action must unwind");
        assert_eq!(fired(Site::Test), 1);

        arm_str("test:error").unwrap();
        let caught = std::panic::catch_unwind(|| check_hard(Site::Test));
        assert!(caught.is_err(), "check_hard must escalate 'error' to panic");

        disarm_all();
        check_hard(Site::Test); // disarmed hard check is a no-op
    }
}
