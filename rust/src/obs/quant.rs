//! Quantization-quality telemetry recorded at model build time.
//!
//! Per-layer error telemetry is how outlier-heavy layers are identified
//! (the OWQ observation); here every quantized linear records its Sinkhorn
//! iterations-to-convergence, final row/col variance imbalance, and
//! reconstruction MSE/NMSE. The scheduler fills one [`LayerQuantStats`]
//! per job, the pipeline folds them into a [`QuantReport`] attached to the
//! native backend, and the report surfaces through `sinq analyze profile`,
//! the serve startup log line, and `GET /v1/stats`.

use crate::util::json::Json;

/// Per-layer quantization outcome (also the scheduler's per-job report).
#[derive(Debug, Clone)]
pub struct LayerQuantStats {
    /// Weight-map key (`layers.0.wq`, `lm_head`, …).
    pub layer: String,
    /// Wall-clock the quantization job took.
    pub millis: f64,
    /// Memory including auxiliaries (the paper's "Mem." accounting).
    pub bits_per_weight: f64,
    pub rows: usize,
    pub cols: usize,
    /// Mean squared reconstruction error `‖W − Ŵ‖²_F / (rows·cols)`.
    pub mse: f64,
    /// Normalized MSE `‖W − Ŵ‖²_F / ‖W‖²_F` (scale-free across layers).
    pub nmse: f64,
    /// Sinkhorn update iterations until the best (lowest-imbalance)
    /// iterate; `None` for methods that do not normalize.
    pub sinkhorn_iters: Option<usize>,
    /// Row/col std imbalance `I(W)` of the input matrix.
    pub imbalance_initial: Option<f64>,
    /// Imbalance of the best normalized iterate.
    pub imbalance_final: Option<f64>,
}

impl LayerQuantStats {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("layer", Json::Str(self.layer.clone())),
            ("rows", Json::Num(self.rows as f64)),
            ("cols", Json::Num(self.cols as f64)),
            ("bits_per_weight", Json::Num(self.bits_per_weight)),
            ("mse", Json::Num(self.mse)),
            ("nmse", Json::Num(self.nmse)),
            ("millis", Json::Num(self.millis)),
        ];
        if let Some(iters) = self.sinkhorn_iters {
            pairs.push(("sinkhorn_iters", Json::Num(iters as f64)));
        }
        if let Some(i0) = self.imbalance_initial {
            pairs.push(("imbalance_initial", Json::Num(i0)));
        }
        if let Some(i1) = self.imbalance_final {
            pairs.push(("imbalance_final", Json::Num(i1)));
        }
        Json::obj(pairs)
    }
}

/// The whole model's quantization-quality report.
#[derive(Debug, Clone)]
pub struct QuantReport {
    pub method: String,
    pub bits: u32,
    pub layers: Vec<LayerQuantStats>,
}

impl QuantReport {
    pub fn new(method: &str, bits: u32, layers: Vec<LayerQuantStats>) -> QuantReport {
        QuantReport { method: method.to_string(), bits, layers }
    }

    pub fn mean_nmse(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.nmse).sum::<f64>() / self.layers.len() as f64
    }

    /// The layer the quantizer hurt most (highest NMSE).
    pub fn worst_layer(&self) -> Option<&LayerQuantStats> {
        self.layers
            .iter()
            .max_by(|a, b| a.nmse.partial_cmp(&b.nmse).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// Median Sinkhorn iterations across layers that normalized.
    pub fn median_sinkhorn_iters(&self) -> Option<usize> {
        let mut iters: Vec<usize> =
            self.layers.iter().filter_map(|l| l.sinkhorn_iters).collect();
        if iters.is_empty() {
            return None;
        }
        iters.sort_unstable();
        Some(iters[iters.len() / 2])
    }

    /// One startup log line summarizing the report.
    pub fn summary_line(&self) -> String {
        let worst = self
            .worst_layer()
            .map(|l| format!("{} ({:.2e})", l.layer, l.nmse))
            .unwrap_or_else(|| "n/a".to_string());
        let iters = self
            .median_sinkhorn_iters()
            .map(|i| format!(", median sinkhorn iters {i}"))
            .unwrap_or_default();
        format!(
            "quant report: {} {}-bit, {} layers, mean NMSE {:.2e}, worst {worst}{iters}",
            self.method,
            self.bits,
            self.layers.len(),
            self.mean_nmse()
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::Str(self.method.clone())),
            ("bits", Json::Num(self.bits as f64)),
            ("mean_nmse", Json::Num(self.mean_nmse())),
            ("layers", Json::Arr(self.layers.iter().map(|l| l.to_json()).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(name: &str, nmse: f64, iters: Option<usize>) -> LayerQuantStats {
        LayerQuantStats {
            layer: name.to_string(),
            millis: 1.0,
            bits_per_weight: 4.5,
            rows: 8,
            cols: 16,
            mse: nmse * 1e-4,
            nmse,
            sinkhorn_iters: iters,
            imbalance_initial: iters.map(|_| 3.0),
            imbalance_final: iters.map(|_| 1.2),
        }
    }

    #[test]
    fn aggregates_and_summary() {
        let r = QuantReport::new(
            "sinq",
            4,
            vec![
                layer("layers.0.wq", 1e-3, Some(10)),
                layer("layers.0.wk", 4e-3, Some(14)),
                layer("lm_head", 2e-3, Some(12)),
            ],
        );
        assert!((r.mean_nmse() - (1e-3 + 4e-3 + 2e-3) / 3.0).abs() < 1e-12);
        assert_eq!(r.worst_layer().unwrap().layer, "layers.0.wk");
        assert_eq!(r.median_sinkhorn_iters(), Some(12));
        let line = r.summary_line();
        assert!(line.contains("sinq 4-bit"), "{line}");
        assert!(line.contains("layers.0.wk"), "{line}");
        assert!(line.contains("median sinkhorn iters 12"), "{line}");
        let j = r.to_json();
        assert_eq!(j.get("layers").and_then(Json::as_arr).unwrap().len(), 3);
        let l0 = &j.get("layers").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(l0.get("sinkhorn_iters").and_then(Json::as_usize), Some(10));
        assert!(l0.get("nmse").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn rtn_style_report_without_sinkhorn_fields() {
        let r = QuantReport::new("rtn", 4, vec![layer("layers.0.wq", 1e-3, None)]);
        assert_eq!(r.median_sinkhorn_iters(), None);
        assert!(!r.summary_line().contains("sinkhorn"));
        let l0 = &r.to_json().get("layers").and_then(Json::as_arr).unwrap()[0];
        assert!(l0.get("sinkhorn_iters").is_none());
    }

    #[test]
    fn empty_report_is_safe() {
        let r = QuantReport::new("sinq", 4, vec![]);
        assert_eq!(r.mean_nmse(), 0.0);
        assert!(r.worst_layer().is_none());
        assert!(r.summary_line().contains("0 layers"));
    }
}
