//! Lock-free fixed-bucket histogram.
//!
//! Replaces the serving layer's mutex-guarded TTFT histogram: observations
//! land in per-bucket `AtomicU64` counters plus an atomic sum kept in
//! microseconds, so the record path is a couple of relaxed atomic adds and
//! never blocks another thread. One type serves every duration-shaped
//! serving metric (TTFT, queue-wait, per-step decode latency).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Upper bounds (seconds) suited to request-scale latencies (TTFT,
/// queue-wait). Observations above the last bound land in `+Inf`.
pub const REQUEST_BUCKETS: [f64; 10] =
    [0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 1.0, 5.0];

/// Upper bounds (seconds) suited to single decode steps, which are one to
/// two orders of magnitude faster than whole requests.
pub const STEP_BUCKETS: [f64; 10] =
    [0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.1, 1.0];

/// Fixed-bound histogram over atomic bucket counters. Bucket counts are
/// stored non-cumulative (the renderer accumulates, matching Prometheus
/// exposition); the sum is kept in integer microseconds so it can live in
/// an `AtomicU64` without losing more than sub-microsecond precision.
pub struct AtomicHistogram {
    bounds: &'static [f64],
    counts: Vec<AtomicU64>,
    sum_micros: AtomicU64,
    count: AtomicU64,
}

impl AtomicHistogram {
    pub fn new(bounds: &'static [f64]) -> AtomicHistogram {
        AtomicHistogram {
            bounds,
            counts: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            sum_micros: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one observation. Lock-free: two relaxed adds plus the bucket
    /// increment.
    pub fn record(&self, d: Duration) {
        self.record_secs(d.as_secs_f64());
    }

    pub fn record_secs(&self, secs: f64) {
        let slot =
            self.bounds.iter().position(|&ub| secs <= ub).unwrap_or(self.bounds.len());
        self.counts[slot].fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add((secs * 1e6) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_secs(&self) -> f64 {
        self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Append the Prometheus text exposition (cumulative `_bucket` lines,
    /// `_sum`, `_count`) for this histogram under `name`.
    pub fn render_prometheus(&self, name: &str, out: &mut String) {
        use std::fmt::Write as _;
        let snap = self.snapshot();
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cumulative = 0u64;
        for (&ub, &c) in self.bounds.iter().zip(&snap.counts) {
            cumulative += c;
            let _ = writeln!(out, "{name}_bucket{{le=\"{ub}\"}} {cumulative}");
        }
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", snap.count);
        let _ = writeln!(out, "{name}_sum {:.6}", snap.sum_secs);
        let _ = writeln!(out, "{name}_count {}", snap.count);
    }

    /// Point-in-time copy of the counters (each bucket loaded individually;
    /// a torn snapshot can be off by in-flight observations, which is fine
    /// for monitoring).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            bounds: self.bounds,
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            count: self.count(),
            sum_secs: self.sum_secs(),
        }
    }
}

/// A consistent-enough copy of an [`AtomicHistogram`] for JSON rendering
/// and quantile estimation.
pub struct HistSnapshot {
    pub bounds: &'static [f64],
    /// Non-cumulative per-bucket counts; last entry is the `+Inf` overflow.
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum_secs: f64,
}

impl HistSnapshot {
    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_secs / self.count as f64
        }
    }

    /// Histogram-quantile estimate: the upper bound of the bucket where the
    /// cumulative count crosses `q * count` (the `+Inf` bucket reports the
    /// last finite bound). Coarse by construction, like PromQL's.
    pub fn quantile_secs(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut cumulative = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cumulative += c;
            if cumulative >= target.max(1) {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.bounds[self.bounds.len() - 1]
                };
            }
        }
        self.bounds[self.bounds.len() - 1]
    }

    /// JSON summary for `/v1/stats` (milliseconds, which is the scale every
    /// serving latency here lives at).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean_ms", Json::Num(self.mean_secs() * 1e3)),
            ("p50_ms", Json::Num(self.quantile_secs(0.5) * 1e3)),
            ("p99_ms", Json::Num(self.quantile_secs(0.99) * 1e3)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_cumulative_counts_and_sum() {
        let h = AtomicHistogram::new(&REQUEST_BUCKETS);
        h.record(Duration::from_micros(500)); // ≤ 0.001
        h.record(Duration::from_millis(30)); // ≤ 0.05
        h.record(Duration::from_secs(60)); // +Inf
        let mut s = String::new();
        h.render_prometheus("x_seconds", &mut s);
        assert!(s.contains("x_seconds_bucket{le=\"0.001\"} 1"), "{s}");
        assert!(s.contains("x_seconds_bucket{le=\"0.05\"} 2"), "{s}");
        assert!(s.contains("x_seconds_bucket{le=\"5\"} 2"), "{s}");
        assert!(s.contains("x_seconds_bucket{le=\"+Inf\"} 3"), "{s}");
        assert!(s.contains("x_seconds_count 3"), "{s}");
        assert_eq!(h.count(), 3);
        let want = 0.0005 + 0.03 + 60.0;
        assert!((h.sum_secs() - want).abs() < 1e-3, "sum {}", h.sum_secs());
    }

    #[test]
    fn cumulative_buckets_are_monotone_and_end_at_count() {
        let h = AtomicHistogram::new(&STEP_BUCKETS);
        for i in 0..100u64 {
            h.record_secs(i as f64 * 0.0004);
        }
        let snap = h.snapshot();
        let mut cumulative = 0u64;
        let mut prev = 0u64;
        for &c in &snap.counts {
            cumulative += c;
            assert!(cumulative >= prev, "cumulative counts must be monotone");
            prev = cumulative;
        }
        assert_eq!(cumulative, snap.count, "buckets (incl. +Inf) must sum to count");
        assert_eq!(snap.count, 100);
    }

    #[test]
    fn concurrent_records_lose_nothing() {
        let h = std::sync::Arc::new(AtomicHistogram::new(&REQUEST_BUCKETS));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        h.record_secs((t * 1000 + i) as f64 * 1e-6);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 8000);
        let snap = h.snapshot();
        assert_eq!(snap.counts.iter().sum::<u64>(), 8000);
    }

    #[test]
    fn quantiles_on_empty_and_single_bucket_histograms() {
        // Empty: every statistic is 0, not NaN, and the JSON summary
        // renders zeros.
        let empty = AtomicHistogram::new(&REQUEST_BUCKETS).snapshot();
        assert_eq!(empty.count, 0);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(empty.quantile_secs(q), 0.0, "q={q}");
        }
        assert_eq!(empty.mean_secs(), 0.0);
        let j = empty.to_json();
        assert_eq!(j.get("count").and_then(Json::as_usize), Some(0));
        assert_eq!(j.get("p99_ms").and_then(Json::as_f64), Some(0.0));

        // Single populated bucket: every quantile — including q=0, whose
        // target count is clamped to the first observation — reports that
        // bucket's upper bound.
        let h = AtomicHistogram::new(&REQUEST_BUCKETS);
        for _ in 0..10 {
            h.record_secs(0.004); // ≤ 0.005
        }
        let snap = h.snapshot();
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            assert_eq!(snap.quantile_secs(q), 0.005, "q={q}");
        }

        // All mass in the +Inf overflow bucket: quantiles cap at the last
        // finite bound instead of inventing an unbounded latency.
        let inf = AtomicHistogram::new(&STEP_BUCKETS);
        inf.record_secs(123.0);
        assert_eq!(inf.snapshot().quantile_secs(0.5), 1.0);
    }

    #[test]
    fn quantiles_and_mean() {
        let h = AtomicHistogram::new(&REQUEST_BUCKETS);
        assert_eq!(h.snapshot().quantile_secs(0.5), 0.0);
        for _ in 0..99 {
            h.record_secs(0.002); // ≤ 0.0025
        }
        h.record_secs(2.0); // ≤ 5.0
        let snap = h.snapshot();
        assert_eq!(snap.quantile_secs(0.5), 0.0025);
        assert_eq!(snap.quantile_secs(0.99), 0.0025);
        assert_eq!(snap.quantile_secs(1.0), 5.0);
        assert!(snap.mean_secs() > 0.0);
        let j = snap.to_json();
        assert_eq!(j.get("count").and_then(Json::as_usize), Some(100));
    }
}
