//! Runtime numerical drift sentinel — accounting side.
//!
//! The repo's core guarantee is that every fast path (SIMD dequant,
//! quantized KV, paged KV) stays bit-identical or tolerance-pinned to the
//! scalar f32 reference. Tests enforce that at CI time; this module makes
//! it observable in production. When `EngineConfig::drift_sample` is N > 0,
//! the batch decoder re-runs one sampled live row's forward pass through
//! the forced-scalar kernel path every N steps and reports the comparison
//! here: max absolute logit difference, relative error, and whether the
//! greedy argmax flipped. `/metrics` and `/v1/stats` render [`snapshot`].
//!
//! All state is process-global lock-free atomics, same as the profiler:
//! recording is a handful of relaxed stores, and the max trackers use
//! compare-exchange loops over the f32 bit patterns (all values are
//! non-negative, so the IEEE-754 ordering matches the numeric ordering).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use crate::util::json::Json;

static SAMPLES: AtomicU64 = AtomicU64::new(0);
static FLIPS: AtomicU64 = AtomicU64::new(0);
static MAX_ABS_BITS: AtomicU32 = AtomicU32::new(0);
static MAX_REL_BITS: AtomicU32 = AtomicU32::new(0);
static LAST_ABS_BITS: AtomicU32 = AtomicU32::new(0);
static LAST_REL_BITS: AtomicU32 = AtomicU32::new(0);

fn store_max(cell: &AtomicU32, value: f32) {
    let bits = value.max(0.0).to_bits();
    let mut cur = cell.load(Ordering::Relaxed);
    // Non-negative f32 bit patterns order the same as the floats they
    // encode, so a plain integer max is a float max.
    while bits > cur {
        match cell.compare_exchange_weak(cur, bits, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => break,
            Err(seen) => cur = seen,
        }
    }
}

/// Record one sentinel comparison: the max absolute logit difference, the
/// relative error (max-abs-diff over the reference's max-abs logit), and
/// whether the greedy argmax disagreed between the fast and scalar paths.
pub fn record(max_abs: f32, rel: f32, flipped: bool) {
    SAMPLES.fetch_add(1, Ordering::Relaxed);
    if flipped {
        FLIPS.fetch_add(1, Ordering::Relaxed);
    }
    store_max(&MAX_ABS_BITS, max_abs);
    store_max(&MAX_REL_BITS, rel);
    LAST_ABS_BITS.store(max_abs.max(0.0).to_bits(), Ordering::Relaxed);
    LAST_REL_BITS.store(rel.max(0.0).to_bits(), Ordering::Relaxed);
}

/// Zero all counters (tests and bench setup).
pub fn reset() {
    SAMPLES.store(0, Ordering::Relaxed);
    FLIPS.store(0, Ordering::Relaxed);
    MAX_ABS_BITS.store(0, Ordering::Relaxed);
    MAX_REL_BITS.store(0, Ordering::Relaxed);
    LAST_ABS_BITS.store(0, Ordering::Relaxed);
    LAST_REL_BITS.store(0, Ordering::Relaxed);
}

/// Point-in-time copy of the sentinel counters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftSnapshot {
    /// Rows compared so far.
    pub samples: u64,
    /// Comparisons whose greedy argmax disagreed with the scalar path.
    pub argmax_flips: u64,
    /// Worst max-abs logit difference seen.
    pub max_abs_diff: f32,
    /// Worst relative error seen.
    pub max_rel_err: f32,
    /// Most recent comparison's max-abs difference.
    pub last_abs_diff: f32,
    /// Most recent comparison's relative error.
    pub last_rel_err: f32,
}

pub fn snapshot() -> DriftSnapshot {
    DriftSnapshot {
        samples: SAMPLES.load(Ordering::Relaxed),
        argmax_flips: FLIPS.load(Ordering::Relaxed),
        max_abs_diff: f32::from_bits(MAX_ABS_BITS.load(Ordering::Relaxed)),
        max_rel_err: f32::from_bits(MAX_REL_BITS.load(Ordering::Relaxed)),
        last_abs_diff: f32::from_bits(LAST_ABS_BITS.load(Ordering::Relaxed)),
        last_rel_err: f32::from_bits(LAST_REL_BITS.load(Ordering::Relaxed)),
    }
}

impl DriftSnapshot {
    /// The `/v1/stats` drift block.
    pub fn to_json(&self, sample_rate: usize) -> Json {
        Json::obj(vec![
            ("sample_rate", Json::Num(sample_rate as f64)),
            ("samples", Json::Num(self.samples as f64)),
            ("argmax_flips", Json::Num(self.argmax_flips as f64)),
            ("max_abs_diff", Json::Num(self.max_abs_diff as f64)),
            ("max_rel_err", Json::Num(self.max_rel_err as f64)),
            ("last_abs_diff", Json::Num(self.last_abs_diff as f64)),
            ("last_rel_err", Json::Num(self.last_rel_err as f64)),
        ])
    }
}

/// Compare a fast-path logit row against its scalar recomputation and fold
/// the result into the global counters. Returns the comparison so callers
/// (tests) can assert on it directly.
pub fn observe_rows(fast: &[f32], reference: &[f32]) -> (f32, f32, bool) {
    debug_assert_eq!(fast.len(), reference.len());
    let mut max_abs = 0.0f32;
    let mut ref_peak = 0.0f32;
    for (&f, &r) in fast.iter().zip(reference.iter()) {
        max_abs = max_abs.max((f - r).abs());
        ref_peak = ref_peak.max(r.abs());
    }
    let rel = if ref_peak > 0.0 { max_abs / ref_peak } else { 0.0 };
    let flipped = argmax_of(fast) != argmax_of(reference);
    record(max_abs, rel, flipped);
    (max_abs, rel, flipped)
}

fn argmax_of(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    // Global counters: one test owns them end to end so concurrent unit
    // tests cannot interleave (no other unit test records drift samples).
    #[test]
    fn drift_counters_accumulate_and_snapshot() {
        reset();
        let base = snapshot();
        assert_eq!(base.samples, 0);
        assert_eq!(base.argmax_flips, 0);
        assert_eq!(base.max_abs_diff, 0.0);

        // Identical rows: a sample with zero diff and no flip.
        let (abs, rel, flip) = observe_rows(&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]);
        assert_eq!((abs, rel, flip), (0.0, 0.0, false));

        // Small perturbation that preserves the argmax.
        let (abs, rel, flip) = observe_rows(&[1.0, 2.0, 3.0 + 1e-3], &[1.0, 2.0, 3.0]);
        assert!(abs > 0.0 && rel > 0.0 && !flip);

        // Perturbation large enough to flip the argmax.
        let (_, _, flip) = observe_rows(&[5.0, 2.0, 3.0], &[1.0, 2.0, 3.0]);
        assert!(flip);

        let s = snapshot();
        assert_eq!(s.samples, 3);
        assert_eq!(s.argmax_flips, 1);
        assert!((s.max_abs_diff - 4.0).abs() < 1e-6);
        assert!(s.max_rel_err >= s.last_rel_err);
        // Last-sample trackers reflect the most recent comparison.
        assert!((s.last_abs_diff - 4.0).abs() < 1e-6);

        let json = s.to_json(16).to_string_compact();
        assert!(json.contains("\"sample_rate\":16"));
        assert!(json.contains("\"samples\":3"));
        assert!(json.contains("\"argmax_flips\":1"));
        reset();
        assert_eq!(snapshot().samples, 0);
    }
}
