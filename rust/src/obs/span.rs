//! Per-request spans: the timestamps a generation request accumulates on
//! its way serve → engine → `BatchDecoder`, and the `usage`/log payloads
//! derived from them.

use std::time::Instant;

use crate::util::json::Json;

/// Lifecycle timestamps for one generation request. The request ID is
/// minted at accept and threads through the engine into the decoder slots,
/// so every span, log line, and SSE stream agrees on identity.
#[derive(Debug, Clone)]
pub struct RequestSpan {
    pub id: usize,
    pub prompt_tokens: usize,
    /// Accepted into the engine queue.
    pub enqueued: Instant,
    /// Admitted into a KV slot (prefill starts here).
    pub admitted: Option<Instant>,
    /// First generated token handed to the stream (prefill ends here).
    pub first_token: Option<Instant>,
}

impl RequestSpan {
    pub fn new(id: usize, prompt_tokens: usize, enqueued: Instant) -> RequestSpan {
        RequestSpan { id, prompt_tokens, enqueued, admitted: None, first_token: None }
    }

    /// Queue wait: accept → KV-slot admission.
    pub fn queue_wait_secs(&self) -> f64 {
        self.admitted.map_or(0.0, |t| t.duration_since(self.enqueued).as_secs_f64())
    }

    /// Client-perceived time to first token: accept → first token.
    pub fn ttft_secs(&self) -> f64 {
        self.first_token.map_or(0.0, |t| t.duration_since(self.enqueued).as_secs_f64())
    }

    /// Close the span: totals from accept to now, with `completion_tokens`
    /// generated.
    pub fn finish(&self, completion_tokens: usize) -> Usage {
        Usage {
            prompt_tokens: self.prompt_tokens,
            completion_tokens,
            queue_wait_ms: self.queue_wait_secs() * 1e3,
            ttft_ms: self.ttft_secs() * 1e3,
            total_ms: self.enqueued.elapsed().as_secs_f64() * 1e3,
        }
    }
}

/// The `usage` object attached to every generation response (JSON body and
/// the SSE `done` event) and to `--log-json` lines.
#[derive(Debug, Clone, PartialEq)]
pub struct Usage {
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
    pub queue_wait_ms: f64,
    pub ttft_ms: f64,
    pub total_ms: f64,
}

impl Usage {
    /// Request-level decode throughput: generated tokens over the decode
    /// window (first token → completion), falling back to the whole request
    /// when the decode window is degenerate (e.g. a 1-token generation).
    pub fn tokens_per_sec(&self) -> f64 {
        let decode_ms = self.total_ms - self.ttft_ms;
        let window_ms = if decode_ms > 1e-3 { decode_ms } else { self.total_ms };
        if window_ms <= 0.0 {
            return 0.0;
        }
        self.completion_tokens as f64 / (window_ms / 1e3)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("prompt_tokens", Json::Num(self.prompt_tokens as f64)),
            ("completion_tokens", Json::Num(self.completion_tokens as f64)),
            ("queue_wait_ms", Json::Num(round3(self.queue_wait_ms))),
            ("ttft_ms", Json::Num(round3(self.ttft_ms))),
            ("total_ms", Json::Num(round3(self.total_ms))),
            ("tokens_per_sec", Json::Num(round3(self.tokens_per_sec()))),
        ])
    }
}

fn round3(v: f64) -> f64 {
    (v * 1e3).round() / 1e3
}

/// One `--log-json` structured log line for a completed request: compact
/// single-line JSON, stable keys, written to stdout by the engine loop.
pub fn request_log_line(id: usize, finish_reason: &str, usage: &Usage) -> String {
    let mut m = match usage.to_json() {
        Json::Obj(m) => m,
        _ => unreachable!("usage serializes to an object"),
    };
    m.insert("event".to_string(), Json::Str("request_done".to_string()));
    m.insert("id".to_string(), Json::Num(id as f64));
    m.insert("finish_reason".to_string(), Json::Str(finish_reason.to_string()));
    Json::Obj(m).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn span_times_are_monotone_and_usage_derives() {
        let t0 = Instant::now();
        let mut span = RequestSpan::new(7, 12, t0);
        assert_eq!(span.queue_wait_secs(), 0.0);
        span.admitted = Some(t0 + Duration::from_millis(5));
        span.first_token = Some(t0 + Duration::from_millis(20));
        assert!((span.queue_wait_secs() - 0.005).abs() < 1e-9);
        assert!((span.ttft_secs() - 0.020).abs() < 1e-9);
        let usage = span.finish(40);
        assert_eq!(usage.prompt_tokens, 12);
        assert_eq!(usage.completion_tokens, 40);
        assert!(usage.total_ms >= usage.ttft_ms);
        assert!(usage.tokens_per_sec() > 0.0);
    }

    #[test]
    fn usage_json_and_log_line_shapes() {
        let usage = Usage {
            prompt_tokens: 3,
            completion_tokens: 9,
            queue_wait_ms: 0.5,
            ttft_ms: 2.0,
            total_ms: 11.0,
        };
        // 9 tokens over the 9ms decode window = 1000 tok/s.
        assert!((usage.tokens_per_sec() - 1000.0).abs() < 1e-6);
        let j = usage.to_json();
        assert_eq!(j.get("prompt_tokens").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("completion_tokens").and_then(Json::as_usize), Some(9));
        assert!(j.get("ttft_ms").and_then(Json::as_f64).is_some());
        assert!(j.get("tokens_per_sec").and_then(Json::as_f64).is_some());

        let line = request_log_line(42, "length", &usage);
        let back = Json::parse(&line).expect("log line parses");
        assert_eq!(back.get("event").and_then(Json::as_str), Some("request_done"));
        assert_eq!(back.get("id").and_then(Json::as_usize), Some(42));
        assert_eq!(back.get("finish_reason").and_then(Json::as_str), Some("length"));
        assert_eq!(back.get("completion_tokens").and_then(Json::as_usize), Some(9));
        assert!(!line.contains('\n'), "log lines must be single-line");
    }
}
