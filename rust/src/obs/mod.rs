//! Zero-dependency observability: lock-free histograms, a global per-phase
//! decode profiler, request spans, a flight-recorder event journal with
//! Chrome-trace export, a runtime drift sentinel, and quantization-quality
//! telemetry.
//!
//! Everything here is std-only and allocation-free on the hot paths:
//!
//! * [`hist::AtomicHistogram`] — fixed-bucket histogram over `AtomicU64`
//!   counters (no lock on the record path); renders Prometheus cumulative
//!   text and JSON snapshots. The serving metrics use it for TTFT,
//!   queue-wait, and per-step decode latency.
//! * [`profiler`] — a global, runtime-switchable phase profiler for the
//!   transformer core ([`crate::backend::fwd`]): scoped `Instant` timers
//!   accumulate nanoseconds per [`profiler::Phase`] (embed, per-`LinId`
//!   linear, KV read/write, MLP, token pick, …). Disabled by default; the
//!   hot path pays a single relaxed atomic load per would-be timer. Enable
//!   with `SINQ_PROFILE=1` (or [`profiler::set_enabled`]).
//! * [`journal`] — the flight recorder: a lock-free ring of sequence
//!   lifecycle events (enqueue, admit, prefix hit, page claim, step,
//!   preempt, resume, evict, complete) stamped with monotonic
//!   microseconds and the request span id, fed by the batch decoder and
//!   the serve engine.
//! * [`trace`] — renders a journal snapshot as Chrome-trace/Perfetto JSON
//!   (`GET /debug/trace`) and per-sequence timeline summaries
//!   (`sinq analyze trace`).
//! * [`drift`] — the runtime numerical drift sentinel: counters for
//!   sampled fast-path vs scalar-path logit comparisons
//!   (`EngineConfig::drift_sample`), surfaced via `/metrics` and
//!   `/v1/stats`.
//! * [`fault`] — the deterministic fault-injection registry: named sites
//!   (`submit`, `admit`, `page_claim`, `decode_step`, `kv_write`,
//!   `sse_write`) armed via `SINQ_FAULTS=site:panic|delay:MS|error`
//!   (`@once` / `@every=N` modifiers), compiled in always but costing one
//!   relaxed atomic load when disarmed. Tests and the CI chaos leg use it
//!   to rehearse the supervisor's panic-recovery and timeout paths.
//! * [`span::RequestSpan`] — per-request timing threaded serve → engine →
//!   `BatchDecoder`: queue-wait, admission, first token, completion; plus
//!   the `usage` payload (`prompt_tokens`, `completion_tokens`, `ttft_ms`,
//!   `tokens_per_sec`) and the `--log-json` structured log line.
//! * [`quant::QuantReport`] — build-time per-layer quantization quality:
//!   Sinkhorn iterations-to-convergence, row/col variance imbalance, and
//!   quant MSE/NMSE, surfaced by `sinq analyze profile`, the serve startup
//!   log, and `GET /v1/stats`.

pub mod drift;
pub mod fault;
pub mod hist;
pub mod journal;
pub mod profiler;
pub mod quant;
pub mod span;
pub mod trace;

pub use drift::DriftSnapshot;
pub use hist::{AtomicHistogram, HistSnapshot};
pub use journal::{Event, EventKind};
pub use profiler::{Phase, ProfileSnapshot};
pub use quant::{LayerQuantStats, QuantReport};
pub use span::{RequestSpan, Usage};
pub use trace::SeqSummary;
