//! The streaming generation engine: a dedicated thread drives the
//! continuous-batching [`BatchDecoder`] incrementally and forwards each
//! decoded token into a channel per live request, so SSE bytes can flush
//! mid-decode instead of waiting for run-to-completion.
//!
//! ```text
//! EngineClient::submit ──channel──▶ supervisor thread
//!      │ (validates KV fit,            │ catch_unwind(engine_loop)
//!      │  enforces --max-queue)        │ admit into BatchDecoder slots
//!      ▼                               ▼ step() → per-token events
//!  StreamHandle ◀──Token/Done/Failed── per-request mpsc channels
//! ```
//!
//! Admission control happens on the *caller's* thread in
//! [`EngineClient::submit`]: requests that cannot fit a KV slot fail
//! immediately with the decoder's own capacity text
//! ([`crate::backend::ensure_fits`]), and requests beyond the `max_queue`
//! backlog bound are refused so the HTTP layer can answer `503` +
//! `Retry-After` without ever touching the decode loop. Token channels are
//! unbounded: a slow SSE reader can never stall the fused decode step (the
//! buffered cost is bounded by the request's own `max_new`).
//!
//! Fault tolerance: [`GenEngine::start`] runs [`engine_loop`] under the
//! supervisor in [`crate::serve::supervisor`], which catches panics,
//! delivers a terminal [`StreamEvent::Failed`] to every in-flight channel,
//! rebuilds the decoder, and restarts with capped exponential backoff.
//! Exactly-once terminal delivery is enforced by the [`Shared`] roster:
//! every submission registers its channel before it can reach the engine,
//! and every terminal send (`Done`, `Failed`) goes through a
//! remove-then-send on that roster — a request can be completed, timed
//! out, cancelled, or crash-failed, but never two of those and never zero.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::backend::batch::{ensure_fits, BatchDecoder, CancelOutcome};
use crate::backend::{EngineConfig, NativeBackend, SampleCfg};
use crate::obs::fault::{self, Site};
use crate::obs::journal::{self, EventKind};
use crate::obs::span::{request_log_line, RequestSpan, Usage};
use crate::serve::metrics::ServeMetrics;
use crate::serve::supervisor::{supervise, SupervisorCfg};

/// One event on a generation stream.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    /// One greedily decoded token, emitted as soon as its step finishes.
    Token(u8),
    /// Terminal event: the request completed, with its closed span's
    /// `usage` accounting (token counts, queue wait, TTFT, totals).
    /// `finish_reason` is `"length"`, `"timeout"`, or `"cancelled"`.
    Done {
        finish_reason: &'static str,
        usage: Usage,
    },
    /// Terminal event: the request failed (engine crash, admission error,
    /// shutdown before decode). The HTTP layer renders it as an
    /// `engine_error` envelope carrying the request id.
    Failed { request_id: usize, message: String },
}

/// Receiving side of one request's event stream.
#[derive(Debug)]
pub struct StreamHandle {
    /// Engine-assigned request id (monotonic).
    pub id: usize,
    pub rx: Receiver<StreamEvent>,
}

/// Why [`EngineClient::submit`] refused a request — mapped by the HTTP
/// layer onto status codes. Carries the request span id minted for the
/// attempt so the error envelope, the `X-Request-Id` header, logs, and the
/// flight-recorder journal all join on one key even for refused requests.
#[derive(Debug)]
pub struct SubmitError {
    /// Span id minted for this submission attempt.
    pub id: usize,
    pub kind: SubmitErrorKind,
}

#[derive(Debug)]
pub enum SubmitErrorKind {
    /// `400`: the request can never run (empty prompt / beyond KV capacity).
    Invalid(String),
    /// `503` + `Retry-After`: the backlog is at the `--max-queue` bound.
    Busy { queued: usize, max_queue: usize },
    /// `503`: the engine is shutting down (or died on an engine error).
    Unavailable(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.kind {
            SubmitErrorKind::Invalid(msg) => write!(f, "{msg}"),
            SubmitErrorKind::Busy { queued, max_queue } => write!(
                f,
                "generation queue full ({queued} queued, --max-queue {max_queue}); retry later"
            ),
            SubmitErrorKind::Unavailable(msg) => write!(f, "{msg}"),
        }
    }
}

/// One admitted request travelling from a handler thread to the engine.
pub(crate) struct Submission {
    id: usize,
    prompt: Vec<u8>,
    max_new: usize,
    /// Seeded sampling parameters; `None` decodes greedily.
    sample: Option<SampleCfg>,
    tx: Sender<StreamEvent>,
    enqueued: Instant,
    /// Absolute wall-clock deadline (per-request `deadline_ms` clamped by
    /// `--request-timeout-ms`); queue wait counts against it.
    deadline: Option<Instant>,
}

/// What travels from handler threads to the engine thread.
pub(crate) enum EngineMsg {
    Submit(Submission),
    /// Client went away: evict the request's slot at the next step boundary.
    Cancel(usize),
}

/// One live entry in the exactly-once terminal roster.
struct RosterEntry {
    tx: Sender<StreamEvent>,
    /// Still counted in the `queued` backlog gauge: flipped false when the
    /// decoder admits the request into a KV slot. Crash/shutdown drains use
    /// it to release exactly the gauge reservations still outstanding.
    queued: bool,
}

/// State shared between the engine thread and every [`EngineClient`].
pub(crate) struct Shared {
    capacity: usize,
    /// KV page granularity (positions) — admission checks charge requests
    /// by the pages they will claim, not a contiguous per-slot reservation.
    page_size: usize,
    /// Page-pool size the decoder was built with.
    pages_total: usize,
    max_queue: usize,
    pub(crate) metrics: Arc<ServeMetrics>,
    /// `--log-json`: print one structured line per completed request.
    log_json: bool,
    /// Server-wide deadline ceiling (ms) applied to every submission.
    request_timeout_ms: u64,
    next_id: AtomicUsize,
    shutting_down: AtomicBool,
    /// Set when the supervisor has exited (drain finished or degraded).
    dead: AtomicBool,
    /// Every submission that can still receive a terminal event, keyed by
    /// request id. Terminal delivery is remove-then-send on this map, so a
    /// second terminal for the same id is structurally impossible.
    roster: Mutex<HashMap<usize, RosterEntry>>,
}

impl Shared {
    pub(crate) fn is_shutting_down(&self) -> bool {
        self.shutting_down.load(Ordering::SeqCst)
    }

    pub(crate) fn set_dead(&self) {
        self.dead.store(true, Ordering::SeqCst);
    }

    /// Register a submission's channel before it can reach the engine.
    fn register(&self, id: usize, tx: Sender<StreamEvent>) {
        self.roster.lock().expect("roster").insert(id, RosterEntry { tx, queued: true });
    }

    /// The decoder admitted `id` into a KV slot: its backlog-gauge
    /// reservation was released by the engine's count-based decrement.
    fn mark_admitted(&self, id: usize) {
        if let Some(e) = self.roster.lock().expect("roster").get_mut(&id) {
            e.queued = false;
        }
    }

    /// Deliver a terminal event exactly once: whoever removes the roster
    /// entry sends; later callers for the same id are no-ops.
    fn finish(&self, id: usize, ev: StreamEvent) {
        let entry = self.roster.lock().expect("roster").remove(&id);
        if let Some(e) = entry {
            let _ = e.tx.send(ev);
        }
    }

    /// Terminal `Failed` for a request that never completed, releasing its
    /// backlog-gauge reservation if it was still queued.
    pub(crate) fn fail(&self, id: usize, message: &str) {
        let entry = self.roster.lock().expect("roster").remove(&id);
        if let Some(e) = entry {
            if e.queued {
                self.metrics.queued.fetch_sub(1, Ordering::SeqCst);
            }
            let _ = e.tx.send(StreamEvent::Failed { request_id: id, message: message.into() });
        }
    }

    /// Crash/shutdown drain: terminal `Failed` for every in-flight request.
    /// Returns how many were failed.
    pub(crate) fn fail_all(&self, message: &str) -> usize {
        let drained: Vec<(usize, RosterEntry)> =
            self.roster.lock().expect("roster").drain().collect();
        let n = drained.len();
        for (id, e) in drained {
            if e.queued {
                self.metrics.queued.fetch_sub(1, Ordering::SeqCst);
            }
            let _ = e.tx.send(StreamEvent::Failed { request_id: id, message: message.into() });
        }
        n
    }
}

/// Cloneable submission handle used by connection handler threads.
#[derive(Clone)]
pub struct EngineClient {
    tx: Sender<EngineMsg>,
    shared: Arc<Shared>,
}

impl EngineClient {
    /// Validate and enqueue one generation request; returns the stream of
    /// per-token events. `max_new == 0` completes immediately without
    /// touching the engine. `sample` enables seeded temperature/top-k
    /// sampling; `None` keeps the bit-identical greedy default.
    /// `deadline_ms` bounds the request's total wall-clock time (queue wait
    /// included), clamped by the server-wide `--request-timeout-ms`; expired
    /// requests finish with `finish_reason: "timeout"`.
    pub fn submit(
        &self,
        prompt: Vec<u8>,
        max_new: usize,
        sample: Option<SampleCfg>,
        deadline_ms: Option<u64>,
    ) -> Result<StreamHandle, SubmitError> {
        let id = self.shared.next_id.fetch_add(1, Ordering::SeqCst);
        if self.shared.shutting_down.load(Ordering::SeqCst)
            || self.shared.dead.load(Ordering::SeqCst)
        {
            let msg = if self.shared.metrics.engine_degraded.load(Ordering::Relaxed) != 0 {
                "generation engine degraded: restart budget exhausted"
            } else {
                "server is shutting down"
            };
            return Err(SubmitError { id, kind: SubmitErrorKind::Unavailable(msg.into()) });
        }
        if let Err(e) = fault::check(Site::Submit) {
            return Err(SubmitError { id, kind: SubmitErrorKind::Unavailable(e.to_string()) });
        }
        ensure_fits(
            self.shared.capacity,
            self.shared.page_size,
            self.shared.pages_total,
            id,
            prompt.len(),
            max_new,
        )
        .map_err(|e| SubmitError { id, kind: SubmitErrorKind::Invalid(e.to_string()) })?;
        let metrics = &self.shared.metrics;
        if max_new == 0 {
            let (tx, rx) = channel();
            let usage = RequestSpan::new(id, prompt.len(), Instant::now()).finish(0);
            if self.shared.log_json {
                println!("{}", request_log_line(id, "length", &usage));
            }
            journal::record(EventKind::Enqueue, id, 0);
            journal::record(EventKind::Complete, id, 0);
            let _ = tx.send(StreamEvent::Done { finish_reason: "length", usage });
            metrics.requests_total.fetch_add(1, Ordering::Relaxed);
            metrics.completed_total.fetch_add(1, Ordering::Relaxed);
            return Ok(StreamHandle { id, rx });
        }
        // Reserve a backlog slot atomically: `queued` counts requests
        // accepted but not yet admitted into a KV slot.
        let queued = metrics.queued.fetch_add(1, Ordering::SeqCst);
        if queued >= self.shared.max_queue {
            metrics.queued.fetch_sub(1, Ordering::SeqCst);
            metrics.rejected_total.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError {
                id,
                kind: SubmitErrorKind::Busy { queued, max_queue: self.shared.max_queue },
            });
        }
        let (tx, rx) = channel();
        let enqueued = Instant::now();
        // Queue wait counts against the deadline: the budget starts at the
        // accept-side enqueue stamp, not at slot admission.
        let budget = EngineConfig::new()
            .with_request_timeout_ms(self.shared.request_timeout_ms)
            .effective_deadline_ms(deadline_ms);
        let deadline = budget.map(|ms| enqueued + Duration::from_millis(ms));
        self.shared.register(id, tx.clone());
        let sub = Submission { id, prompt, max_new, sample, tx, enqueued, deadline };
        if self.tx.send(EngineMsg::Submit(sub)).is_err() {
            self.shared.roster.lock().expect("roster").remove(&id);
            metrics.queued.fetch_sub(1, Ordering::SeqCst);
            return Err(SubmitError {
                id,
                kind: SubmitErrorKind::Unavailable("generation engine stopped".into()),
            });
        }
        // Close the race with a concurrently-exiting supervisor: if it went
        // dead after the check at the top, its final drain may have run
        // before our roster entry existed — self-deliver the terminal
        // `Failed` (idempotent: whoever removes the entry sends).
        if self.shared.dead.load(Ordering::SeqCst) {
            self.shared.fail(id, "generation engine stopped");
        }
        // The accept-side enqueue stamp: the decoder stamps its own when
        // the engine thread hands the request over, and the trace exporter
        // keeps the earliest — so queue wait includes the channel hop.
        journal::record(EventKind::Enqueue, id, 0);
        metrics.requests_total.fetch_add(1, Ordering::Relaxed);
        Ok(StreamHandle { id, rx })
    }

    /// Per-slot KV capacity (positions) of the engine's decoder.
    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }

    /// Tell the engine the client of request `id` disconnected: its KV slot
    /// is evicted at the next step boundary instead of decoding to
    /// `max_new` (counted in the `evicted` metric). Unknown or finished ids
    /// are ignored, so callers may cancel unconditionally on write errors.
    pub fn cancel(&self, id: usize) {
        let _ = self.tx.send(EngineMsg::Cancel(id));
    }
}

/// The streaming engine: owns the decode thread. Constructed by
/// [`GenEngine::start`]; [`GenEngine::client`] hands out submission handles.
pub struct GenEngine {
    client: EngineClient,
    thread: Option<thread::JoinHandle<()>>,
}

impl GenEngine {
    /// Spawn the engine thread over a shared backend, sized by `cfg`
    /// (generation slots, per-sequence context cap, KV precision, page-pool
    /// geometry), refusing submissions once `max_queue` requests are
    /// waiting for a slot.
    pub fn start(
        be: Arc<NativeBackend>,
        cfg: EngineConfig,
        max_queue: usize,
        metrics: Arc<ServeMetrics>,
    ) -> anyhow::Result<GenEngine> {
        GenEngine::start_with_logging(be, cfg, max_queue, metrics, false)
    }

    /// [`GenEngine::start`] with `--log-json` request logging: one compact
    /// JSON line per completed request on stdout.
    pub fn start_with_logging(
        be: Arc<NativeBackend>,
        cfg: EngineConfig,
        max_queue: usize,
        metrics: Arc<ServeMetrics>,
        log_json: bool,
    ) -> anyhow::Result<GenEngine> {
        GenEngine::start_supervised(be, cfg, max_queue, metrics, log_json, SupervisorCfg::default())
    }

    /// Full-control constructor: the supervisor policy (restart budget,
    /// backoff curve) is explicit. [`GenEngine::start`] uses
    /// [`SupervisorCfg::default`]; tests use a fast-backoff variant.
    pub fn start_supervised(
        be: Arc<NativeBackend>,
        cfg: EngineConfig,
        max_queue: usize,
        metrics: Arc<ServeMetrics>,
        log_json: bool,
        sup: SupervisorCfg,
    ) -> anyhow::Result<GenEngine> {
        // Probe construction on the caller's thread so bad weight sets fail
        // at startup, not on the first request — and publish the KV shape
        // (`/healthz` + `/metrics` report it) while the decoder exists.
        {
            let probe = BatchDecoder::with_config(&be, &cfg)?;
            metrics.slots.store(cfg.max_batch, Ordering::Relaxed);
            metrics.kv_bytes_per_page.store(probe.kv_bytes_per_page(), Ordering::Relaxed);
            metrics.kv_bits.store(probe.kv_bits().bits() as usize, Ordering::Relaxed);
            metrics.kv_page_size.store(probe.page_size(), Ordering::Relaxed);
            metrics.kv_pages_total.store(probe.pages_total(), Ordering::Relaxed);
            metrics.kv_pages_free.store(probe.pages_free(), Ordering::Relaxed);
        }
        let shared = Arc::new(Shared {
            capacity: cfg.max_context.max(1),
            page_size: cfg.page_positions(),
            pages_total: cfg.pages_total(),
            max_queue,
            metrics,
            log_json,
            request_timeout_ms: cfg.request_timeout_ms,
            next_id: AtomicUsize::new(0),
            shutting_down: AtomicBool::new(false),
            dead: AtomicBool::new(false),
            roster: Mutex::new(HashMap::new()),
        });
        let (tx, rx) = channel::<EngineMsg>();
        let thread_shared = shared.clone();
        let thread = thread::Builder::new()
            .name("sinq-gen-engine".into())
            .spawn(move || supervise(&be, &cfg, &sup, &rx, &thread_shared))
            .expect("spawn generation engine");
        Ok(GenEngine { client: EngineClient { tx, shared }, thread: Some(thread) })
    }

    /// Cloneable submission handle.
    pub fn client(&self) -> EngineClient {
        self.client.clone()
    }

    /// Graceful shutdown: refuse new submissions, let the engine drain
    /// every live slot (and already-queued request), then join the thread.
    pub fn shutdown(mut self) {
        self.client.shared.shutting_down.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for GenEngine {
    fn drop(&mut self) {
        self.client.shared.shutting_down.store(true, Ordering::SeqCst);
        // No join: dropping without `shutdown()` (error paths) must not
        // block; the thread notices the flag within its idle timeout.
    }
}

/// Decode progress the engine tracks per admitted request.
struct Session {
    tx: Sender<StreamEvent>,
    span: RequestSpan,
    /// Tokens streamed so far — the completion count for cancelled requests.
    emitted: usize,
}

/// How one run of [`engine_loop`] ended, as seen by the supervisor.
pub(crate) enum ExitKind {
    /// Graceful drain after the shutdown flag: do not restart.
    Shutdown,
    /// The decoder failed (init or step error): restart-eligible, like a
    /// panic but without unwinding.
    Failed(String),
}

/// One incarnation of the decode loop. The supervisor owns the channel
/// receiver and the restart policy; this function owns exactly one
/// [`BatchDecoder`] built fresh per incarnation, so a crashed decoder's
/// state is discarded wholesale rather than repaired.
pub(crate) fn engine_loop(
    be: &NativeBackend,
    cfg: &EngineConfig,
    rx: &Receiver<EngineMsg>,
    shared: &Arc<Shared>,
) -> ExitKind {
    let metrics = shared.metrics.clone();
    let mut sessions: HashMap<usize, Session> = HashMap::new();
    let mut dec = match BatchDecoder::with_config(be, cfg) {
        Ok(d) => d,
        Err(e) => return ExitKind::Failed(format!("engine init failed: {e}")),
    };

    let admit = |dec: &mut BatchDecoder,
                 sessions: &mut HashMap<usize, Session>,
                 sub: Submission| {
        if let Err(e) = fault::check(Site::Admit) {
            shared.fail(sub.id, &format!("admission failed: {e}"));
            return;
        }
        match dec.submit_deadline(sub.id, &sub.prompt, sub.max_new, sub.sample, sub.deadline) {
            Ok(()) => {
                let span = RequestSpan::new(sub.id, sub.prompt.len(), sub.enqueued);
                sessions.insert(sub.id, Session { tx: sub.tx, span, emitted: 0 });
            }
            Err(e) => {
                // Pre-validated in submit(); defensive only.
                shared.fail(sub.id, &format!("admission failed: {e}"));
            }
        }
    };
    // Client-disconnect eviction: free the request's KV slot (or backlog
    // entry) at this step boundary; finished ids fall through harmlessly.
    // The cancelled stream still gets its terminal event (`Done` with
    // `finish_reason: "cancelled"`) so no channel ever closes silently.
    let cancel = |dec: &mut BatchDecoder, sessions: &mut HashMap<usize, Session>, id: usize| {
        let s = match sessions.remove(&id) {
            Some(s) => s,
            None => return,
        };
        match dec.cancel(id) {
            CancelOutcome::Pending => {
                // Never decoded: release its --max-queue backlog entry but
                // do not count a slot eviction.
                metrics.queued.fetch_sub(1, Ordering::SeqCst);
            }
            CancelOutcome::Evicted => {
                metrics.evicted_total.fetch_add(1, Ordering::Relaxed);
            }
            CancelOutcome::NotFound => {}
        }
        let usage = s.span.finish(s.emitted);
        if shared.log_json {
            println!("{}", request_log_line(id, "cancelled", &usage));
        }
        shared.finish(id, StreamEvent::Done { finish_reason: "cancelled", usage });
    };

    loop {
        if sessions.is_empty() {
            // Idle: block briefly so shutdown is noticed without spinning.
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(EngineMsg::Submit(sub)) => admit(&mut dec, &mut sessions, sub),
                Ok(EngineMsg::Cancel(id)) => cancel(&mut dec, &mut sessions, id),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                    if shared.is_shutting_down() {
                        break;
                    }
                    continue;
                }
            }
        }
        // Live: drain whatever queued up without blocking the decode step.
        while let Ok(msg) = rx.try_recv() {
            match msg {
                EngineMsg::Submit(sub) => admit(&mut dec, &mut sessions, sub),
                EngineMsg::Cancel(id) => cancel(&mut dec, &mut sessions, id),
            }
        }

        let pending_before = dec.pending();
        // Captured just before step(): admission happens at the very top of
        // the step, so this is the queue-wait stamp for drained admissions,
        // and its elapsed time is the step latency.
        let t_step = Instant::now();
        let stepped = match dec.step() {
            Ok(n) => n,
            // In-flight channels get their terminal `Failed` from the
            // supervisor's roster drain; local sessions just drop.
            Err(e) => return ExitKind::Failed(format!("decode step failed: {e}")),
        };
        // step() admitted pending requests into freed slots (or expired
        // them off the pending queue): those left the --max-queue backlog.
        let admitted = pending_before.saturating_sub(dec.pending());
        if admitted > 0 {
            metrics.queued.fetch_sub(admitted, Ordering::SeqCst);
        }
        for id in dec.drain_admitted() {
            shared.mark_admitted(id);
            if let Some(s) = sessions.get_mut(&id) {
                s.span.admitted = Some(t_step);
                metrics.record_queue_wait(t_step.duration_since(s.span.enqueued));
            }
        }
        if stepped > 0 {
            metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
            metrics.tokens_generated.fetch_add(dec.emitted().len(), Ordering::Relaxed);
            metrics.record_step(t_step.elapsed(), dec.emitted().len());
        }
        for &(id, tok) in dec.emitted() {
            if let Some(s) = sessions.get_mut(&id) {
                if s.span.first_token.is_none() {
                    let now = Instant::now();
                    s.span.first_token = Some(now);
                    metrics.record_ttft(now.duration_since(s.span.enqueued));
                }
                s.emitted += 1;
                let _ = s.tx.send(StreamEvent::Token(tok));
            }
        }
        for out in dec.take_finished() {
            if let Some(s) = sessions.remove(&out.id) {
                // A pending-queue expiry never counted as admitted above
                // (it left `pending` in the same step-delta), so the gauge
                // is already consistent; only the outcome counter differs.
                if out.finish_reason == "timeout" {
                    metrics.timeout_total.fetch_add(1, Ordering::Relaxed);
                } else {
                    metrics.completed_total.fetch_add(1, Ordering::Relaxed);
                }
                let usage = s.span.finish(out.tokens.len());
                if shared.log_json {
                    println!("{}", request_log_line(out.id, out.finish_reason, &usage));
                }
                shared.finish(out.id, StreamEvent::Done { finish_reason: out.finish_reason, usage });
            }
        }
        metrics.live_slots.store(dec.live(), Ordering::Relaxed);
        // Page-pool + prefix-cache health after this step. The decoder's
        // counters are cumulative, so `store` (not `fetch_add`) keeps the
        // gauges exact across steps.
        metrics.kv_pages_free.store(dec.pages_free(), Ordering::Relaxed);
        metrics.prefix_cached_pages.store(dec.prefix_cached_pages(), Ordering::Relaxed);
        let stats = dec.stats();
        metrics.prefix_hits_total.store(stats.prefix_hits, Ordering::Relaxed);
        metrics.prefix_tokens_reused_total.store(stats.prefix_tokens_reused, Ordering::Relaxed);
        metrics.preempted_total.store(stats.preempted, Ordering::Relaxed);
    }

    metrics.live_slots.store(0, Ordering::Relaxed);
    ExitKind::Shutdown
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, ModelWeights};

    fn pico_arc() -> Arc<NativeBackend> {
        let cfg = ModelConfig::family("pico").unwrap();
        Arc::new(NativeBackend::from_weights(&ModelWeights::synthetic(&cfg, 31)))
    }

    fn engine_cfg(slots: usize, capacity: usize) -> EngineConfig {
        EngineConfig::new().with_max_batch(slots).with_max_context(capacity)
    }

    fn collect(handle: StreamHandle) -> (Vec<u8>, Option<StreamEvent>) {
        let mut tokens = Vec::new();
        for ev in handle.rx.iter() {
            match ev {
                StreamEvent::Token(t) => tokens.push(t),
                terminal => return (tokens, Some(terminal)),
            }
        }
        (tokens, None)
    }

    #[test]
    fn streamed_tokens_match_backend_generate() {
        let be = pico_arc();
        let expected = be.generate(b"hello engine", 7).unwrap();
        let metrics = Arc::new(ServeMetrics::new());
        let eng = GenEngine::start(be, engine_cfg(2, 64), 16, metrics.clone()).unwrap();
        let handle = eng.client().submit(b"hello engine".to_vec(), 7, None, None).unwrap();
        let (tokens, terminal) = collect(handle);
        assert_eq!(tokens, expected);
        match terminal {
            Some(StreamEvent::Done { finish_reason: "length", usage }) => {
                assert_eq!(usage.prompt_tokens, 12);
                assert_eq!(usage.completion_tokens, 7);
                assert!(usage.ttft_ms > 0.0, "TTFT must be stamped");
                assert!(usage.total_ms >= usage.ttft_ms);
                assert!(usage.queue_wait_ms >= 0.0);
                assert!(usage.tokens_per_sec() > 0.0);
            }
            other => panic!("expected Done, got {other:?}"),
        }
        eng.shutdown();
        assert_eq!(metrics.completed_total.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.tokens_generated.load(Ordering::Relaxed), 7);
        assert_eq!(metrics.queued.load(Ordering::Relaxed), 0);
        // The span plumbing feeds every latency histogram exactly once per
        // request / once per step.
        assert_eq!(metrics.ttft.count(), 1);
        assert_eq!(metrics.queue_wait.count(), 1);
        assert!(metrics.step_latency.count() > 0);
    }

    #[test]
    fn oversized_request_is_invalid_and_zero_max_new_completes() {
        let be = pico_arc();
        let eng =
            GenEngine::start(be, engine_cfg(1, 8), 4, Arc::new(ServeMetrics::new())).unwrap();
        let client = eng.client();
        match client.submit(vec![b'x'; 32], 4, None, None) {
            Err(SubmitError { kind: SubmitErrorKind::Invalid(msg), .. }) => {
                assert!(msg.contains("KV"), "unclear capacity error: {msg}")
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
        let (tokens, terminal) = collect(client.submit(b"ok".to_vec(), 0, None, None).unwrap());
        assert!(tokens.is_empty());
        assert!(matches!(
            terminal,
            Some(StreamEvent::Done { ref usage, .. }) if usage.completion_tokens == 0
        ));
        eng.shutdown();
    }

    #[test]
    fn max_queue_zero_refuses_everything() {
        let be = pico_arc();
        let metrics = Arc::new(ServeMetrics::new());
        let eng = GenEngine::start(be, engine_cfg(1, 16), 0, metrics.clone()).unwrap();
        match eng.client().submit(b"hi".to_vec(), 2, None, None) {
            Err(SubmitError { kind: SubmitErrorKind::Busy { max_queue: 0, .. }, .. }) => {}
            other => panic!("expected Busy, got {other:?}"),
        }
        assert_eq!(metrics.rejected_total.load(Ordering::Relaxed), 1);
        eng.shutdown();
    }

    #[test]
    fn cancel_evicts_live_request_and_counts_eviction() {
        let be = pico_arc();
        let metrics = Arc::new(ServeMetrics::new());
        let eng = GenEngine::start(be, engine_cfg(1, 4096), 8, metrics.clone()).unwrap();
        assert_eq!(metrics.slots.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.kv_bits.load(Ordering::Relaxed), 32);
        assert!(metrics.kv_bytes_per_page.load(Ordering::Relaxed) > 0);
        // Page-pool shape published at startup: 4096 positions / 16-position
        // pages × 1 slot, all free before the first request.
        assert_eq!(metrics.kv_page_size.load(Ordering::Relaxed), 16);
        assert_eq!(metrics.kv_pages_total.load(Ordering::Relaxed), 256);
        assert_eq!(metrics.kv_pages_free.load(Ordering::Relaxed), 256);
        let client = eng.client();
        let handle = client.submit(b"evict me".to_vec(), 4000, None, None).unwrap();
        // Wait until the request is actually decoding before cancelling.
        let first = handle.rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(matches!(first, StreamEvent::Token(_)));
        client.cancel(handle.id);
        // The engine evicts the slot at the next step boundary and still
        // delivers a terminal event, far short of max_new.
        let (tokens, terminal) = collect(handle);
        match terminal {
            Some(StreamEvent::Done { finish_reason: "cancelled", usage }) => {
                // One token was consumed by recv_timeout above.
                assert_eq!(usage.completion_tokens, tokens.len() + 1);
            }
            other => panic!("expected Done(cancelled), got {other:?}"),
        }
        assert!(tokens.len() < 4000 - 1, "slot kept decoding after cancel");
        eng.shutdown();
        assert_eq!(metrics.evicted_total.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.completed_total.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.queued.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn shutdown_drains_queued_work_and_refuses_new() {
        let be = pico_arc();
        let metrics = Arc::new(ServeMetrics::new());
        let eng = GenEngine::start(be, engine_cfg(1, 32), 8, metrics.clone()).unwrap();
        let client = eng.client();
        let handles: Vec<StreamHandle> = (0..3)
            .map(|i| client.submit(vec![b'a' + i as u8, b'b'], 4, None, None).unwrap())
            .collect();
        eng.shutdown();
        for h in handles {
            let (tokens, terminal) = collect(h);
            assert_eq!(tokens.len(), 4);
            assert!(matches!(
                terminal,
                Some(StreamEvent::Done { ref usage, .. }) if usage.completion_tokens == 4
            ));
        }
        assert!(matches!(
            client.submit(b"late".to_vec(), 1, None, None),
            Err(SubmitError { kind: SubmitErrorKind::Unavailable(_), .. })
        ));
        assert_eq!(metrics.completed_total.load(Ordering::Relaxed), 3);
    }

    /// Consume a stream to the end: every event, in order, until the
    /// channel closes. Exactly-once terminal delivery means the terminal
    /// list must always have length 1 for an accepted request.
    fn drain_all(h: StreamHandle) -> (Vec<u8>, Vec<StreamEvent>) {
        let mut tokens = Vec::new();
        let mut terminals = Vec::new();
        for ev in h.rx.iter() {
            match ev {
                StreamEvent::Token(t) => tokens.push(t),
                terminal => terminals.push(terminal),
            }
        }
        (tokens, terminals)
    }

    #[test]
    fn expired_deadline_times_out_with_terminal_done() {
        let be = pico_arc();
        let metrics = Arc::new(ServeMetrics::new());
        let eng = GenEngine::start(be, engine_cfg(1, 4096), 8, metrics.clone()).unwrap();
        // 4000 greedy decode steps cannot finish inside 1ms, so the
        // deadline trips mid-decode and the request ends early.
        let handle = eng.client().submit(b"deadline".to_vec(), 4000, None, Some(1)).unwrap();
        let (tokens, terminals) = drain_all(handle);
        assert!(tokens.len() < 4000, "deadline never enforced");
        match &terminals[..] {
            [StreamEvent::Done { finish_reason: "timeout", usage }] => {
                assert_eq!(usage.completion_tokens, tokens.len());
            }
            other => panic!("expected one Done(timeout), got {other:?}"),
        }
        eng.shutdown();
        assert_eq!(metrics.timeout_total.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.completed_total.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.queued.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn queue_wait_counts_against_deadline() {
        let be = pico_arc();
        let metrics = Arc::new(ServeMetrics::new());
        // One slot: the first request occupies it, the second expires while
        // still waiting in the pending queue.
        let eng = GenEngine::start(be, engine_cfg(1, 4096), 8, metrics.clone()).unwrap();
        let client = eng.client();
        let hog = client.submit(b"occupy the only slot".to_vec(), 4000, None, None).unwrap();
        // Ensure the hog is actually decoding before queueing behind it.
        let first = hog.rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(matches!(first, StreamEvent::Token(_)));
        let queued = client.submit(b"never admitted".to_vec(), 5, None, Some(1)).unwrap();
        let (tokens, terminals) = drain_all(queued);
        assert!(tokens.is_empty(), "expired in queue, before any decode");
        assert!(matches!(
            &terminals[..],
            [StreamEvent::Done { finish_reason: "timeout", usage }] if usage.completion_tokens == 0
        ));
        client.cancel(hog.id);
        let (_, hog_terminals) = drain_all(hog);
        assert_eq!(hog_terminals.len(), 1, "exactly one terminal for the hog");
        eng.shutdown();
        assert_eq!(metrics.timeout_total.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.queued.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn cancel_paths_deliver_exactly_one_terminal_event() {
        let be = pico_arc();
        let metrics = Arc::new(ServeMetrics::new());
        let eng = GenEngine::start(be, engine_cfg(1, 4096), 8, metrics.clone()).unwrap();
        let client = eng.client();
        // A: live in the only slot. B: stuck pending behind it.
        let a = client.submit(b"live request".to_vec(), 4000, None, None).unwrap();
        let first = a.rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(matches!(first, StreamEvent::Token(_)));
        let b = client.submit(b"pending request".to_vec(), 5, None, None).unwrap();
        // Cancel the pending one first (backlog path), then the live one
        // (eviction path), then the live one again (stale-id path).
        client.cancel(b.id);
        client.cancel(a.id);
        client.cancel(a.id);
        let (b_tokens, b_terminals) = drain_all(b);
        assert!(b_tokens.is_empty(), "pending request never decoded");
        assert!(matches!(
            &b_terminals[..],
            [StreamEvent::Done { finish_reason: "cancelled", usage }] if usage.completion_tokens == 0
        ));
        let (_, a_terminals) = drain_all(a);
        assert!(
            matches!(&a_terminals[..], [StreamEvent::Done { finish_reason: "cancelled", .. }]),
            "double-cancel must still deliver exactly one terminal: {a_terminals:?}"
        );
        eng.shutdown();
        assert_eq!(metrics.evicted_total.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.queued.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.completed_total.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn supervised_engine_streams_identical_tokens() {
        // Supervision on, faults disarmed: bit-identical to the direct
        // backend decode (the tentpole's parity requirement).
        let be = pico_arc();
        let expected = be.generate(b"supervised parity", 24).unwrap();
        let metrics = Arc::new(ServeMetrics::new());
        let eng = GenEngine::start_supervised(
            be,
            engine_cfg(2, 128),
            16,
            metrics.clone(),
            false,
            SupervisorCfg { max_restarts: 3, backoff_base_ms: 1, backoff_cap_ms: 4 },
        )
        .unwrap();
        let handle = eng.client().submit(b"supervised parity".to_vec(), 24, None, None).unwrap();
        let (tokens, terminals) = drain_all(handle);
        assert_eq!(tokens, expected);
        assert_eq!(terminals.len(), 1);
        eng.shutdown();
        assert_eq!(metrics.engine_panics_total.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.engine_restarts_total.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.engine_degraded.load(Ordering::Relaxed), 0);
    }
}
