//! Serving metrics: lock-free counters/gauges plus latency histograms
//! (time-to-first-token, queue-wait, per-step decode latency), rendered as
//! Prometheus text exposition for `GET /metrics` and as JSON snapshots for
//! `GET /v1/stats`.
//!
//! The streaming engine and the connection handlers update these through a
//! shared `Arc<ServeMetrics>`. Every record path is lock-free: histograms
//! are [`crate::obs::AtomicHistogram`]s and throughput feeds a fixed ring
//! of packed atomics, so a slow scrape never stalls the decode loop.
//!
//! `sinq_serve_tokens_per_sec` is generated-token throughput over a rolling
//! window of recent decode steps (the number a dashboard wants: what the
//! engine is doing *now*). The old process-lifetime average — which decays
//! toward zero whenever the server idles — is kept as
//! `sinq_serve_tokens_per_sec_lifetime`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::obs::hist::{AtomicHistogram, REQUEST_BUCKETS, STEP_BUCKETS};

/// Rolling throughput window length.
const RATE_WINDOW_SECS: f64 = 10.0;

/// Ring capacity for recent decode steps. At one entry per fused batch step
/// this covers the full window even at thousands of steps per second for
/// short windows; overwritten entries simply age out of the estimate.
const RATE_RING: usize = 2048;

/// Lock-free rolling-window token-rate estimator: a ring of packed
/// `(micros_since_start << 16) | tokens` entries, one per decode step.
/// Readers scan the whole (fixed, small) ring and sum tokens whose
/// timestamp falls inside the window.
struct RateRing {
    started: Instant,
    slots: Vec<AtomicU64>,
    next: AtomicUsize,
}

impl RateRing {
    fn new(started: Instant) -> RateRing {
        RateRing {
            started,
            slots: (0..RATE_RING).map(|_| AtomicU64::new(0)).collect(),
            next: AtomicUsize::new(0),
        }
    }

    fn record(&self, tokens: usize) {
        if tokens == 0 {
            return;
        }
        let micros = self.started.elapsed().as_micros() as u64;
        // 48 bits of microseconds (~8.9 years) + 16 bits of tokens.
        let packed = (micros << 16) | (tokens as u64).min(0xFFFF);
        let i = self.next.fetch_add(1, Ordering::Relaxed) % RATE_RING;
        self.slots[i].store(packed, Ordering::Relaxed);
    }

    /// Tokens/sec over the most recent window (clamped to process uptime so
    /// a freshly started server reports its true rate, not a diluted one).
    fn rate(&self) -> f64 {
        let now = self.started.elapsed().as_micros() as u64;
        let horizon = now.saturating_sub((RATE_WINDOW_SECS * 1e6) as u64);
        let mut tokens = 0u64;
        for slot in &self.slots {
            let packed = slot.load(Ordering::Relaxed);
            if packed != 0 && (packed >> 16) >= horizon {
                tokens += packed & 0xFFFF;
            }
        }
        let window = (now as f64 / 1e6).min(RATE_WINDOW_SECS).max(1e-9);
        tokens as f64 / window
    }
}

/// Counters and gauges for the serving front-end.
pub struct ServeMetrics {
    started: Instant,
    /// Generation requests accepted (admitted past the queue bound).
    pub requests_total: AtomicUsize,
    /// Generation requests rejected with `503` at the `--max-queue` bound.
    pub rejected_total: AtomicUsize,
    /// Generation requests completed (terminal `done` event sent).
    pub completed_total: AtomicUsize,
    /// Tokens generated across all requests.
    pub tokens_generated: AtomicUsize,
    /// Fused continuous-batching decode steps executed.
    pub decode_steps: AtomicUsize,
    /// Scoring requests served through the batcher queue.
    pub score_requests: AtomicUsize,
    /// Gauge: sequences currently occupying KV slots.
    pub live_slots: AtomicUsize,
    /// Gauge: generation requests accepted but not yet in a KV slot — the
    /// backlog the `--max-queue` admission bound applies to.
    pub queued: AtomicUsize,
    /// Live sequences evicted because their client disconnected mid-stream
    /// (slot freed at the next step boundary instead of decoding to
    /// `max_new`).
    pub evicted_total: AtomicUsize,
    /// Gauge: total KV slots the engine preallocated (`--max-batch`);
    /// occupancy = `live_slots / slots`.
    pub slots: AtomicUsize,
    /// Gauge: resident bytes of one KV page at the configured `--kv-bits`
    /// (one page spans `kv_page_size` positions across every layer).
    pub kv_bytes_per_page: AtomicUsize,
    /// Gauge: KV-cache element precision in bits (32 or 8).
    pub kv_bits: AtomicUsize,
    /// Gauge: KV page granularity in positions (`--page-size`).
    pub kv_page_size: AtomicUsize,
    /// Gauge: page-pool size the decoder was built with (`--kv-pages`).
    pub kv_pages_total: AtomicUsize,
    /// Gauge: pages currently unclaimed (free-list depth after the latest
    /// decode step).
    pub kv_pages_free: AtomicUsize,
    /// Gauge: full pages currently held by the prefix cache for copy-free
    /// shared-prompt reuse.
    pub prefix_cached_pages: AtomicUsize,
    /// Admissions that mapped at least one prefix-cached page (skipping
    /// prefill for the shared span).
    pub prefix_hits_total: AtomicUsize,
    /// Prompt positions skipped through prefix-cache page reuse.
    pub prefix_tokens_reused_total: AtomicUsize,
    /// Live sequences preempted back to the queue when the page pool ran
    /// dry (they resume later; nothing is lost).
    pub preempted_total: AtomicUsize,
    /// Requests evicted because their deadline (`deadline_ms` /
    /// `--request-timeout-ms`) expired before completion.
    pub timeout_total: AtomicUsize,
    /// Engine-loop panics the supervisor caught.
    pub engine_panics_total: AtomicUsize,
    /// Supervisor restarts of the engine loop after a crash.
    pub engine_restarts_total: AtomicUsize,
    /// Gauge: 1 once the restart budget (`--max-engine-restarts`) is
    /// exhausted — `/healthz` reports `degraded` and submits answer 503.
    pub engine_degraded: AtomicUsize,
    /// Request time-to-first-token (accept → first streamed token).
    pub ttft: AtomicHistogram,
    /// Request queue wait (accept → KV-slot admission).
    pub queue_wait: AtomicHistogram,
    /// Fused decode step latency (one `BatchDecoder::step`).
    pub step_latency: AtomicHistogram,
    rate: RateRing,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        let started = Instant::now();
        ServeMetrics {
            started,
            requests_total: AtomicUsize::new(0),
            rejected_total: AtomicUsize::new(0),
            completed_total: AtomicUsize::new(0),
            tokens_generated: AtomicUsize::new(0),
            decode_steps: AtomicUsize::new(0),
            score_requests: AtomicUsize::new(0),
            live_slots: AtomicUsize::new(0),
            queued: AtomicUsize::new(0),
            evicted_total: AtomicUsize::new(0),
            slots: AtomicUsize::new(0),
            kv_bytes_per_page: AtomicUsize::new(0),
            kv_bits: AtomicUsize::new(32),
            kv_page_size: AtomicUsize::new(0),
            kv_pages_total: AtomicUsize::new(0),
            kv_pages_free: AtomicUsize::new(0),
            prefix_cached_pages: AtomicUsize::new(0),
            prefix_hits_total: AtomicUsize::new(0),
            prefix_tokens_reused_total: AtomicUsize::new(0),
            preempted_total: AtomicUsize::new(0),
            timeout_total: AtomicUsize::new(0),
            engine_panics_total: AtomicUsize::new(0),
            engine_restarts_total: AtomicUsize::new(0),
            engine_degraded: AtomicUsize::new(0),
            ttft: AtomicHistogram::new(&REQUEST_BUCKETS),
            queue_wait: AtomicHistogram::new(&REQUEST_BUCKETS),
            step_latency: AtomicHistogram::new(&STEP_BUCKETS),
            rate: RateRing::new(started),
        }
    }

    /// Record one request's time-to-first-token.
    pub fn record_ttft(&self, ttft: Duration) {
        self.ttft.record(ttft);
    }

    /// Record one request's queue wait (accept → admission).
    pub fn record_queue_wait(&self, wait: Duration) {
        self.queue_wait.record(wait);
    }

    /// Record one fused decode step: its latency and how many tokens it
    /// emitted (feeds the rolling throughput window).
    pub fn record_step(&self, latency: Duration, tokens: usize) {
        self.step_latency.record(latency);
        self.rate.record(tokens);
    }

    /// Seconds since the metrics (and so the server) came up.
    pub fn uptime_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Generated-token throughput over the rolling window of recent decode
    /// steps — what the engine is doing *now*.
    pub fn tokens_per_sec(&self) -> f64 {
        self.rate.rate()
    }

    /// Aggregate generated-token throughput since the server started.
    pub fn tokens_per_sec_lifetime(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64().max(1e-9);
        self.tokens_generated.load(Ordering::Relaxed) as f64 / secs
    }

    /// Fraction of accepted generation requests whose admission mapped at
    /// least one prefix-cached page (0.0 before the first request).
    pub fn prefix_hit_rate(&self) -> f64 {
        let hits = self.prefix_hits_total.load(Ordering::Relaxed) as f64;
        hits / (self.requests_total.load(Ordering::Relaxed).max(1) as f64)
    }

    /// Seconds a 503-rejected client should wait before retrying: the
    /// current backlog (`queued` gauge) times the mean tokens per completed
    /// request, divided by the rolling-window throughput. An idle or
    /// freshly-started server (empty queue, or no rate signal yet) hints
    /// the 1-second floor; a saturated one scales with its real drain time,
    /// capped at 60s so a transient spike cannot park clients for minutes.
    pub fn retry_after_secs(&self) -> u64 {
        let queued = self.queued.load(Ordering::Relaxed);
        if queued == 0 {
            return 1;
        }
        let completed = self.completed_total.load(Ordering::Relaxed);
        let mean_tokens = if completed > 0 {
            (self.tokens_generated.load(Ordering::Relaxed) as f64 / completed as f64).max(1.0)
        } else {
            32.0
        };
        let rate = self.tokens_per_sec();
        if rate <= 0.0 {
            return 1;
        }
        let secs = (queued as f64 * mean_tokens / rate).ceil();
        (secs as u64).clamp(1, 60)
    }

    /// Render the Prometheus text exposition for `GET /metrics`.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::with_capacity(4096);
        let counters: [(&str, &str, usize); 23] = [
            ("sinq_serve_live_slots", "gauge", self.live_slots.load(Ordering::Relaxed)),
            ("sinq_serve_slots", "gauge", self.slots.load(Ordering::Relaxed)),
            ("sinq_serve_queued_requests", "gauge", self.queued.load(Ordering::Relaxed)),
            (
                "sinq_serve_kv_bytes_per_page",
                "gauge",
                self.kv_bytes_per_page.load(Ordering::Relaxed),
            ),
            ("sinq_serve_kv_bits", "gauge", self.kv_bits.load(Ordering::Relaxed)),
            ("sinq_serve_kv_page_size", "gauge", self.kv_page_size.load(Ordering::Relaxed)),
            ("sinq_serve_kv_pages_total", "gauge", self.kv_pages_total.load(Ordering::Relaxed)),
            ("sinq_serve_kv_pages_free", "gauge", self.kv_pages_free.load(Ordering::Relaxed)),
            (
                "sinq_serve_prefix_cached_pages",
                "gauge",
                self.prefix_cached_pages.load(Ordering::Relaxed),
            ),
            (
                "sinq_serve_prefix_hits_total",
                "counter",
                self.prefix_hits_total.load(Ordering::Relaxed),
            ),
            (
                "sinq_serve_prefix_tokens_reused_total",
                "counter",
                self.prefix_tokens_reused_total.load(Ordering::Relaxed),
            ),
            (
                "sinq_serve_preempted_total",
                "counter",
                self.preempted_total.load(Ordering::Relaxed),
            ),
            ("sinq_serve_evicted_total", "counter", self.evicted_total.load(Ordering::Relaxed)),
            ("sinq_serve_requests_total", "counter", self.requests_total.load(Ordering::Relaxed)),
            ("sinq_serve_rejected_total", "counter", self.rejected_total.load(Ordering::Relaxed)),
            (
                "sinq_serve_completed_total",
                "counter",
                self.completed_total.load(Ordering::Relaxed),
            ),
            (
                "sinq_serve_score_requests_total",
                "counter",
                self.score_requests.load(Ordering::Relaxed),
            ),
            (
                "sinq_serve_tokens_generated_total",
                "counter",
                self.tokens_generated.load(Ordering::Relaxed),
            ),
            ("sinq_serve_decode_steps_total", "counter", self.decode_steps.load(Ordering::Relaxed)),
            ("sinq_serve_timeout_total", "counter", self.timeout_total.load(Ordering::Relaxed)),
            (
                "sinq_engine_panics_total",
                "counter",
                self.engine_panics_total.load(Ordering::Relaxed),
            ),
            (
                "sinq_engine_restarts_total",
                "counter",
                self.engine_restarts_total.load(Ordering::Relaxed),
            ),
            ("sinq_engine_degraded", "gauge", self.engine_degraded.load(Ordering::Relaxed)),
        ];
        for (name, kind, value) in counters {
            let _ = writeln!(s, "# TYPE {name} {kind}");
            let _ = writeln!(s, "{name} {value}");
        }
        let _ = writeln!(s, "# TYPE sinq_serve_uptime_seconds gauge");
        let _ = writeln!(s, "sinq_serve_uptime_seconds {:.3}", self.uptime_secs());
        let _ = writeln!(s, "# TYPE sinq_serve_tokens_per_sec gauge");
        let _ = writeln!(s, "sinq_serve_tokens_per_sec {:.3}", self.tokens_per_sec());
        let _ = writeln!(s, "# TYPE sinq_serve_tokens_per_sec_lifetime gauge");
        let _ = writeln!(
            s,
            "sinq_serve_tokens_per_sec_lifetime {:.3}",
            self.tokens_per_sec_lifetime()
        );
        let _ = writeln!(s, "# TYPE sinq_serve_prefix_hit_rate gauge");
        let _ = writeln!(s, "sinq_serve_prefix_hit_rate {:.3}", self.prefix_hit_rate());
        // Drift-sentinel families (all zero while `--drift-sample` is off):
        // sampled fast-vs-scalar logit comparisons from the decode loop.
        let drift = crate::obs::drift::snapshot();
        let _ = writeln!(s, "# TYPE sinq_drift_samples_total counter");
        let _ = writeln!(s, "sinq_drift_samples_total {}", drift.samples);
        let _ = writeln!(s, "# TYPE sinq_drift_argmax_flips_total counter");
        let _ = writeln!(s, "sinq_drift_argmax_flips_total {}", drift.argmax_flips);
        let _ = writeln!(s, "# TYPE sinq_drift_max_abs_diff gauge");
        let _ = writeln!(s, "sinq_drift_max_abs_diff {:e}", drift.max_abs_diff);
        let _ = writeln!(s, "# TYPE sinq_drift_max_rel_err gauge");
        let _ = writeln!(s, "sinq_drift_max_rel_err {:e}", drift.max_rel_err);
        self.ttft.render_prometheus("sinq_serve_ttft_seconds", &mut s);
        self.queue_wait.render_prometheus("sinq_serve_queue_wait_seconds", &mut s);
        self.step_latency.render_prometheus("sinq_serve_step_latency_seconds", &mut s);
        s
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_cumulative_and_count_matches() {
        let m = ServeMetrics::new();
        m.record_ttft(Duration::from_micros(500)); // ≤ 0.001
        m.record_ttft(Duration::from_millis(30)); // ≤ 0.05
        m.record_ttft(Duration::from_secs(60)); // +Inf overflow
        let text = m.render();
        assert!(text.contains("sinq_serve_ttft_seconds_bucket{le=\"0.001\"} 1"), "{text}");
        assert!(text.contains("sinq_serve_ttft_seconds_bucket{le=\"0.05\"} 2"), "{text}");
        assert!(text.contains("sinq_serve_ttft_seconds_bucket{le=\"5\"} 2"), "{text}");
        assert!(text.contains("sinq_serve_ttft_seconds_bucket{le=\"+Inf\"} 3"), "{text}");
        assert!(text.contains("sinq_serve_ttft_seconds_count 3"), "{text}");
    }

    #[test]
    fn queue_wait_and_step_latency_histograms_render() {
        let m = ServeMetrics::new();
        m.record_queue_wait(Duration::from_millis(2));
        m.record_step(Duration::from_micros(300), 4);
        let text = m.render();
        assert!(text.contains("# TYPE sinq_serve_queue_wait_seconds histogram"), "{text}");
        assert!(text.contains("sinq_serve_queue_wait_seconds_count 1"), "{text}");
        assert!(text.contains("# TYPE sinq_serve_step_latency_seconds histogram"), "{text}");
        assert!(text.contains("sinq_serve_step_latency_seconds_count 1"), "{text}");
        assert!(text.contains("sinq_serve_step_latency_seconds_bucket{le=\"0.0005\"} 1"), "{text}");
        assert!(text.contains("# TYPE sinq_serve_uptime_seconds gauge"), "{text}");
    }

    #[test]
    fn counters_render_and_throughput_gauges_move() {
        let m = ServeMetrics::new();
        assert_eq!(m.tokens_per_sec(), 0.0);
        assert_eq!(m.tokens_per_sec_lifetime(), 0.0);
        // The windowed rate follows recorded steps; the lifetime rate
        // follows the raw token counter.
        m.record_step(Duration::from_micros(200), 50);
        m.record_step(Duration::from_micros(200), 50);
        m.tokens_generated.fetch_add(100, Ordering::Relaxed);
        assert!(m.tokens_per_sec() > 0.0);
        assert!(m.tokens_per_sec_lifetime() > 0.0);
        m.live_slots.store(3, Ordering::Relaxed);
        m.kv_bytes_per_page.store(4096, Ordering::Relaxed);
        m.kv_bits.store(8, Ordering::Relaxed);
        m.evicted_total.fetch_add(2, Ordering::Relaxed);
        m.kv_page_size.store(16, Ordering::Relaxed);
        m.kv_pages_total.store(64, Ordering::Relaxed);
        m.kv_pages_free.store(60, Ordering::Relaxed);
        m.preempted_total.fetch_add(1, Ordering::Relaxed);
        let text = m.render();
        assert!(text.contains("sinq_serve_tokens_generated_total 100"), "{text}");
        assert!(text.contains("sinq_serve_live_slots 3"), "{text}");
        assert!(text.contains("# TYPE sinq_serve_requests_total counter"), "{text}");
        assert!(text.contains("sinq_serve_kv_bytes_per_page 4096"), "{text}");
        assert!(text.contains("sinq_serve_kv_bits 8"), "{text}");
        assert!(text.contains("sinq_serve_kv_page_size 16"), "{text}");
        assert!(text.contains("sinq_serve_kv_pages_total 64"), "{text}");
        assert!(text.contains("sinq_serve_kv_pages_free 60"), "{text}");
        assert!(text.contains("# TYPE sinq_serve_prefix_hits_total counter"), "{text}");
        assert!(text.contains("sinq_serve_preempted_total 1"), "{text}");
        assert!(text.contains("sinq_serve_evicted_total 2"), "{text}");
        assert!(text.contains("# TYPE sinq_serve_tokens_per_sec_lifetime gauge"), "{text}");
    }

    #[test]
    fn prefix_hit_rate_divides_hits_by_requests() {
        let m = ServeMetrics::new();
        // No requests yet: rate must be 0, not NaN.
        assert_eq!(m.prefix_hit_rate(), 0.0);
        m.requests_total.store(4, Ordering::Relaxed);
        m.prefix_hits_total.store(3, Ordering::Relaxed);
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        let text = m.render();
        assert!(text.contains("sinq_serve_prefix_hit_rate 0.750"), "{text}");
    }

    #[test]
    fn drift_families_always_render() {
        // Values are global (other tests may be recording concurrently), so
        // assert the families exist rather than their exact readings.
        let text = ServeMetrics::new().render();
        assert!(text.contains("# TYPE sinq_drift_samples_total counter"), "{text}");
        assert!(text.contains("\nsinq_drift_samples_total "), "{text}");
        assert!(text.contains("# TYPE sinq_drift_argmax_flips_total counter"), "{text}");
        assert!(text.contains("# TYPE sinq_drift_max_abs_diff gauge"), "{text}");
        assert!(text.contains("# TYPE sinq_drift_max_rel_err gauge"), "{text}");
    }

    #[test]
    fn rate_ring_wraps_past_capacity_without_double_counting() {
        let ring = RateRing::new(Instant::now());
        for _ in 0..RATE_RING + 100 {
            ring.record(1);
        }
        // The write cursor keeps counting, but the ring holds exactly
        // RATE_RING live entries: wrapped writes overwrite the oldest slot
        // instead of double-counting.
        assert_eq!(ring.next.load(Ordering::Relaxed), RATE_RING + 100);
        let mut tokens = 0u64;
        for slot in &ring.slots {
            let packed = slot.load(Ordering::Relaxed);
            assert_ne!(packed, 0, "every slot is written after wraparound");
            tokens += packed & 0xFFFF;
        }
        assert_eq!(tokens as usize, RATE_RING);
        assert!(ring.rate() > 0.0);
        // Oversized per-step token counts saturate the 16-bit field rather
        // than bleeding into the timestamp bits.
        let big = RateRing::new(Instant::now());
        big.record(usize::MAX);
        assert_eq!(big.slots[0].load(Ordering::Relaxed) & 0xFFFF, 0xFFFF);
    }

    #[test]
    fn supervisor_and_timeout_families_render() {
        let m = ServeMetrics::new();
        m.engine_panics_total.fetch_add(1, Ordering::Relaxed);
        m.engine_restarts_total.fetch_add(1, Ordering::Relaxed);
        m.timeout_total.fetch_add(2, Ordering::Relaxed);
        m.engine_degraded.store(1, Ordering::Relaxed);
        let text = m.render();
        assert!(text.contains("# TYPE sinq_engine_panics_total counter"), "{text}");
        assert!(text.contains("sinq_engine_panics_total 1"), "{text}");
        assert!(text.contains("# TYPE sinq_engine_restarts_total counter"), "{text}");
        assert!(text.contains("sinq_engine_restarts_total 1"), "{text}");
        assert!(text.contains("sinq_serve_timeout_total 2"), "{text}");
        assert!(text.contains("sinq_engine_degraded 1"), "{text}");
    }

    #[test]
    fn retry_after_floors_on_empty_queue_and_scales_with_backlog() {
        let m = ServeMetrics::new();
        // Empty queue: immediate retry hint regardless of rate history.
        assert_eq!(m.retry_after_secs(), 1);
        // Backlog but no throughput signal yet (cold server): stay at the
        // floor instead of dividing by zero.
        m.queued.store(8, Ordering::Relaxed);
        assert_eq!(m.retry_after_secs(), 1);
        // 4 queued × (64 tokens/req) at ≥100 tok/s → a small finite hint.
        m.queued.store(4, Ordering::Relaxed);
        m.completed_total.store(10, Ordering::Relaxed);
        m.tokens_generated.store(640, Ordering::Relaxed);
        m.record_step(Duration::from_micros(100), 1000);
        let hint = m.retry_after_secs();
        assert!((1..=60).contains(&hint), "hint {hint}");
        // Saturated: a deep queue against a trickle of throughput clamps
        // at the 60s ceiling rather than quoting minutes.
        m.queued.store(10_000, Ordering::Relaxed);
        assert_eq!(m.retry_after_secs(), 60);
    }

    #[test]
    fn rate_ring_ignores_ancient_and_empty_slots() {
        let m = ServeMetrics::new();
        // Steps that emitted nothing do not pollute the window.
        m.record_step(Duration::from_micros(100), 0);
        assert_eq!(m.tokens_per_sec(), 0.0);
        m.record_step(Duration::from_micros(100), 7);
        let r = m.tokens_per_sec();
        assert!(r > 0.0, "windowed rate {r}");
    }
}
