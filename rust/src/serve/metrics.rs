//! Serving metrics: lock-free counters/gauges plus a time-to-first-token
//! histogram, rendered as Prometheus text exposition for `GET /metrics`.
//!
//! The streaming engine and the connection handlers update these through a
//! shared `Arc<ServeMetrics>`; `/metrics` renders a point-in-time snapshot.
//! `tokens_per_sec` is generated tokens over process-lifetime wall clock —
//! coarse, but zero-state and enough to see whether the engine is moving.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// TTFT histogram bucket upper bounds, in seconds (Prometheus `le` labels);
/// observations above the last bound land in `+Inf`.
pub const TTFT_BUCKETS: [f64; 10] =
    [0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 1.0, 5.0];

/// Cumulative-histogram state for request time-to-first-token.
struct TtftHistogram {
    /// Per-bucket counts (non-cumulative; the renderer accumulates), plus
    /// one overflow slot for `+Inf`.
    counts: [u64; TTFT_BUCKETS.len() + 1],
    sum_secs: f64,
    count: u64,
}

/// Counters and gauges for the serving front-end.
pub struct ServeMetrics {
    started: Instant,
    /// Generation requests accepted (admitted past the queue bound).
    pub requests_total: AtomicUsize,
    /// Generation requests rejected with `503` at the `--max-queue` bound.
    pub rejected_total: AtomicUsize,
    /// Generation requests completed (terminal `done` event sent).
    pub completed_total: AtomicUsize,
    /// Tokens generated across all requests.
    pub tokens_generated: AtomicUsize,
    /// Fused continuous-batching decode steps executed.
    pub decode_steps: AtomicUsize,
    /// Scoring requests served through the batcher queue.
    pub score_requests: AtomicUsize,
    /// Gauge: sequences currently occupying KV slots.
    pub live_slots: AtomicUsize,
    /// Gauge: generation requests accepted but not yet in a KV slot — the
    /// backlog the `--max-queue` admission bound applies to.
    pub queued: AtomicUsize,
    /// Live sequences evicted because their client disconnected mid-stream
    /// (slot freed at the next step boundary instead of decoding to
    /// `max_new`).
    pub evicted_total: AtomicUsize,
    /// Gauge: total KV slots the engine preallocated (`--max-batch`);
    /// occupancy = `live_slots / slots`.
    pub slots: AtomicUsize,
    /// Gauge: resident bytes of one KV slot at the configured `--kv-bits`.
    pub kv_bytes_per_slot: AtomicUsize,
    /// Gauge: KV-cache element precision in bits (32 or 8).
    pub kv_bits: AtomicUsize,
    ttft: Mutex<TtftHistogram>,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            started: Instant::now(),
            requests_total: AtomicUsize::new(0),
            rejected_total: AtomicUsize::new(0),
            completed_total: AtomicUsize::new(0),
            tokens_generated: AtomicUsize::new(0),
            decode_steps: AtomicUsize::new(0),
            score_requests: AtomicUsize::new(0),
            live_slots: AtomicUsize::new(0),
            queued: AtomicUsize::new(0),
            evicted_total: AtomicUsize::new(0),
            slots: AtomicUsize::new(0),
            kv_bytes_per_slot: AtomicUsize::new(0),
            kv_bits: AtomicUsize::new(32),
            ttft: Mutex::new(TtftHistogram {
                counts: [0; TTFT_BUCKETS.len() + 1],
                sum_secs: 0.0,
                count: 0,
            }),
        }
    }

    /// Record one request's time-to-first-token.
    pub fn record_ttft(&self, ttft: Duration) {
        let secs = ttft.as_secs_f64();
        let slot = TTFT_BUCKETS
            .iter()
            .position(|&ub| secs <= ub)
            .unwrap_or(TTFT_BUCKETS.len());
        let mut h = self.ttft.lock().expect("ttft histogram lock");
        h.counts[slot] += 1;
        h.sum_secs += secs;
        h.count += 1;
    }

    /// Aggregate generated-token throughput since the server started.
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64().max(1e-9);
        self.tokens_generated.load(Ordering::Relaxed) as f64 / secs
    }

    /// Render the Prometheus text exposition for `GET /metrics`.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::with_capacity(2048);
        let counters: [(&str, &str, usize); 12] = [
            ("sinq_serve_live_slots", "gauge", self.live_slots.load(Ordering::Relaxed)),
            ("sinq_serve_slots", "gauge", self.slots.load(Ordering::Relaxed)),
            ("sinq_serve_queued_requests", "gauge", self.queued.load(Ordering::Relaxed)),
            (
                "sinq_serve_kv_bytes_per_slot",
                "gauge",
                self.kv_bytes_per_slot.load(Ordering::Relaxed),
            ),
            ("sinq_serve_kv_bits", "gauge", self.kv_bits.load(Ordering::Relaxed)),
            ("sinq_serve_evicted_total", "counter", self.evicted_total.load(Ordering::Relaxed)),
            ("sinq_serve_requests_total", "counter", self.requests_total.load(Ordering::Relaxed)),
            ("sinq_serve_rejected_total", "counter", self.rejected_total.load(Ordering::Relaxed)),
            (
                "sinq_serve_completed_total",
                "counter",
                self.completed_total.load(Ordering::Relaxed),
            ),
            (
                "sinq_serve_score_requests_total",
                "counter",
                self.score_requests.load(Ordering::Relaxed),
            ),
            (
                "sinq_serve_tokens_generated_total",
                "counter",
                self.tokens_generated.load(Ordering::Relaxed),
            ),
            ("sinq_serve_decode_steps_total", "counter", self.decode_steps.load(Ordering::Relaxed)),
        ];
        for (name, kind, value) in counters {
            let _ = writeln!(s, "# TYPE {name} {kind}");
            let _ = writeln!(s, "{name} {value}");
        }
        let _ = writeln!(s, "# TYPE sinq_serve_tokens_per_sec gauge");
        let _ = writeln!(s, "sinq_serve_tokens_per_sec {:.3}", self.tokens_per_sec());

        let h = self.ttft.lock().expect("ttft histogram lock");
        let _ = writeln!(s, "# TYPE sinq_serve_ttft_seconds histogram");
        let mut cumulative = 0u64;
        for (i, &ub) in TTFT_BUCKETS.iter().enumerate() {
            cumulative += h.counts[i];
            let _ = writeln!(s, "sinq_serve_ttft_seconds_bucket{{le=\"{ub}\"}} {cumulative}");
        }
        let _ = writeln!(s, "sinq_serve_ttft_seconds_bucket{{le=\"+Inf\"}} {}", h.count);
        let _ = writeln!(s, "sinq_serve_ttft_seconds_sum {:.6}", h.sum_secs);
        let _ = writeln!(s, "sinq_serve_ttft_seconds_count {}", h.count);
        s
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_cumulative_and_count_matches() {
        let m = ServeMetrics::new();
        m.record_ttft(Duration::from_micros(500)); // ≤ 0.001
        m.record_ttft(Duration::from_millis(30)); // ≤ 0.05
        m.record_ttft(Duration::from_secs(60)); // +Inf overflow
        let text = m.render();
        assert!(text.contains("sinq_serve_ttft_seconds_bucket{le=\"0.001\"} 1"), "{text}");
        assert!(text.contains("sinq_serve_ttft_seconds_bucket{le=\"0.05\"} 2"), "{text}");
        assert!(text.contains("sinq_serve_ttft_seconds_bucket{le=\"5\"} 2"), "{text}");
        assert!(text.contains("sinq_serve_ttft_seconds_bucket{le=\"+Inf\"} 3"), "{text}");
        assert!(text.contains("sinq_serve_ttft_seconds_count 3"), "{text}");
    }

    #[test]
    fn counters_render_and_tokens_per_sec_moves() {
        let m = ServeMetrics::new();
        assert_eq!(m.tokens_per_sec(), 0.0);
        m.tokens_generated.fetch_add(100, Ordering::Relaxed);
        m.live_slots.store(3, Ordering::Relaxed);
        assert!(m.tokens_per_sec() > 0.0);
        m.kv_bytes_per_slot.store(4096, Ordering::Relaxed);
        m.kv_bits.store(8, Ordering::Relaxed);
        m.evicted_total.fetch_add(2, Ordering::Relaxed);
        let text = m.render();
        assert!(text.contains("sinq_serve_tokens_generated_total 100"), "{text}");
        assert!(text.contains("sinq_serve_live_slots 3"), "{text}");
        assert!(text.contains("# TYPE sinq_serve_requests_total counter"), "{text}");
        assert!(text.contains("sinq_serve_kv_bytes_per_slot 4096"), "{text}");
        assert!(text.contains("sinq_serve_kv_bits 8"), "{text}");
        assert!(text.contains("sinq_serve_evicted_total 2"), "{text}");
    }
}
