//! Minimal HTTP/1.1 parsing and response writing over raw streams.
//!
//! Just enough protocol for the serving endpoints — `GET`/`POST` request
//! lines, header fields, `Content-Length` bodies, fixed-length JSON
//! responses, and close-delimited `text/event-stream` (SSE) responses —
//! with no external dependencies, consistent with the offline vendored-deps
//! build.
//!
//! Connection reuse is **opt-in**: a client that sends
//! `Connection: keep-alive` gets `Connection: keep-alive` back on
//! fixed-length responses and may pipeline further requests on the same
//! socket (see [`poll_request_start`] for the between-requests peek that
//! distinguishes "peer finished" from "next request arriving"). Everything
//! else — including every SSE stream, whose body is delimited by the
//! server closing — answers `Connection: close`, which keeps EOF-framed
//! clients working unchanged.

use std::io::{BufRead, Read, Write};

/// Cap on the request line + headers; larger requests are rejected.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Cap on a request body (`Content-Length`); larger requests are rejected.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Whether the client explicitly asked to reuse this connection
    /// (`Connection: keep-alive`). Reuse is opt-in — an absent header means
    /// close-after-response — so close-delimited clients keep working.
    pub fn wants_keep_alive(&self) -> bool {
        self.header("connection")
            .map(|v| v.trim().eq_ignore_ascii_case("keep-alive"))
            .unwrap_or(false)
    }
}

/// Read one `\n`-terminated line of at most `limit` bytes. Bounded *while
/// reading*, not after: a peer streaming an endless line cannot grow server
/// memory past the cap (this faces the network).
fn read_line_limited<R: BufRead>(r: &mut R, limit: usize, what: &str) -> anyhow::Result<String> {
    let mut buf = Vec::new();
    let n = r.by_ref().take(limit as u64 + 1).read_until(b'\n', &mut buf)?;
    anyhow::ensure!(n > 0, "connection closed before {what}");
    anyhow::ensure!(buf.ends_with(b"\n"), "{what} exceeds {limit} bytes or is truncated");
    String::from_utf8(buf).map_err(|_| anyhow::anyhow!("{what} is not valid UTF-8"))
}

/// Wait for the first byte of the next request on a (possibly kept-alive)
/// connection: `Ok(true)` when request bytes are buffered and ready to
/// parse, `Ok(false)` when the peer closed cleanly or the socket's read
/// timeout (the keep-alive idle timeout) expired first, `Err` on a hard
/// socket error. Separating this peek from [`read_request`] lets the
/// connection loop apply the short idle timeout only *between* requests
/// and restore the full per-request timeout before parsing begins.
pub fn poll_request_start<R: BufRead>(r: &mut R) -> std::io::Result<bool> {
    match r.fill_buf() {
        Ok(buf) => Ok(!buf.is_empty()),
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::UnexpectedEof
                    | std::io::ErrorKind::ConnectionReset
            ) =>
        {
            Ok(false)
        }
        Err(e) => Err(e),
    }
}

/// Read and parse one request (request line, headers, `Content-Length`
/// body) from a buffered stream.
pub fn read_request<R: BufRead>(r: &mut R) -> anyhow::Result<Request> {
    let line = read_line_limited(r, MAX_HEADER_BYTES, "request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    anyhow::ensure!(
        !method.is_empty() && path.starts_with('/') && version.starts_with("HTTP/1."),
        "malformed request line {:?}",
        line.trim_end()
    );

    let mut headers = Vec::new();
    let mut total = line.len();
    loop {
        anyhow::ensure!(total <= MAX_HEADER_BYTES, "headers exceed {MAX_HEADER_BYTES} bytes");
        let h = read_line_limited(r, MAX_HEADER_BYTES - total + 1, "header line")?;
        total += h.len();
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }

    let req = Request { method, path, headers, body: Vec::new() };
    let len: usize = match req.header("content-length") {
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad Content-Length {v:?}"))?,
        None => 0,
    };
    anyhow::ensure!(len <= MAX_BODY_BYTES, "body of {len} bytes exceeds {MAX_BODY_BYTES}");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Request { body, ..req })
}

/// Canonical reason phrase for the status codes the server emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response. `keep_alive` selects the
/// `Connection` header: `keep-alive` tells the client the socket stays
/// open for its next request, `close` that the server hangs up after the
/// body.
pub fn write_response(
    w: &mut impl Write,
    code: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {code} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\
         Connection: {}\r\n",
        status_text(code),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// OpenAI-style machine-readable error kind for a status code.
fn error_kind(code: u16) -> &'static str {
    match code {
        400 => "invalid_request_error",
        404 => "not_found_error",
        405 => "method_not_allowed",
        503 => "overloaded_error",
        _ => "internal_error",
    }
}

/// Build the unified JSON error envelope every endpoint answers with:
/// `{"error": {"message": msg, "type": kind}}`, the OpenAI-compatible shape
/// clients already know how to unwrap.
pub fn error_body(code: u16, msg: &str) -> String {
    use crate::util::json::Json;
    Json::obj(vec![(
        "error",
        Json::obj(vec![
            ("message", Json::Str(msg.to_string())),
            ("type", Json::Str(error_kind(code).to_string())),
        ]),
    )])
    .to_string_compact()
}

/// [`error_body`] plus a `request_id` field, for errors that occur after a
/// request id has been minted (engine submission refusals): clients can
/// correlate the envelope with the `X-Request-Id` header and the
/// `--log-json` line carrying the same id.
pub fn error_body_with_id(code: u16, msg: &str, request_id: usize) -> String {
    use crate::util::json::Json;
    Json::obj(vec![(
        "error",
        Json::obj(vec![
            ("message", Json::Str(msg.to_string())),
            ("request_id", Json::Num(request_id as f64)),
            ("type", Json::Str(error_kind(code).to_string())),
        ]),
    )])
    .to_string_compact()
}

/// The envelope for a request the supervised engine failed (crash,
/// admission fault, shutdown mid-queue): same shape as
/// [`error_body_with_id`] but with the distinguished type `engine_error`,
/// so clients and the chaos harness can tell "the engine died under you"
/// from an ordinary 500.
pub fn engine_error_body(msg: &str, request_id: usize) -> String {
    use crate::util::json::Json;
    Json::obj(vec![(
        "error",
        Json::obj(vec![
            ("message", Json::Str(msg.to_string())),
            ("request_id", Json::Num(request_id as f64)),
            ("type", Json::Str("engine_error".to_string())),
        ]),
    )])
    .to_string_compact()
}

/// Write the unified error envelope ([`error_body`]) with `code`.
pub fn write_error(
    w: &mut impl Write,
    code: u16,
    msg: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    write_response(
        w,
        code,
        "application/json",
        &[],
        error_body(code, msg).as_bytes(),
        keep_alive,
    )
}

/// Start a `text/event-stream` response. The body is close-delimited:
/// events follow via [`write_sse_event`] until the server closes the
/// connection after the terminal event.
pub fn write_sse_header(w: &mut impl Write) -> std::io::Result<()> {
    write_sse_header_with(w, &[])
}

/// [`write_sse_header`] with extra response headers (e.g. `X-Request-Id`),
/// written before the blank line that opens the event stream.
pub fn write_sse_header_with(
    w: &mut impl Write,
    extra_headers: &[(&str, &str)],
) -> std::io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n\
          Connection: close\r\n",
    )?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.flush()
}

/// The `sse_write` fault point: an injected error renders as a socket
/// error, which the handlers treat exactly like a client disconnect
/// (cancel + evict). Disarmed cost: one relaxed atomic load.
fn sse_fault() -> std::io::Result<()> {
    crate::obs::fault::check(crate::obs::fault::Site::SseWrite)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::Other, e.to_string()))
}

/// Write one SSE event and flush, so tokens reach the client mid-decode.
pub fn write_sse_event(w: &mut impl Write, event: &str, data: &str) -> std::io::Result<()> {
    sse_fault()?;
    write!(w, "event: {event}\ndata: {data}\n\n")?;
    w.flush()
}

/// Write one bare `data:` SSE frame (no `event:` line) and flush — the
/// OpenAI streaming wire format `/v1/completions` uses, where the terminal
/// frame is the literal `data: [DONE]`.
pub fn write_sse_data(w: &mut impl Write, data: &str) -> std::io::Result<()> {
    sse_fault()?;
    write!(w, "data: {data}\n\n")?;
    w.flush()
}

/// Write one SSE comment line (`: text`) and flush. Comment lines are the
/// spec's keep-alive mechanism: clients ignore them, proxies see bytes.
/// Written only between events, never inside one, so heartbeats can never
/// corrupt a token frame.
pub fn write_sse_comment(w: &mut impl Write, text: &str) -> std::io::Result<()> {
    sse_fault()?;
    write!(w, ": {text}\n\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str) -> anyhow::Result<Request> {
        read_request(&mut BufReader::new(raw.as_bytes()))
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            "POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\nhello world",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert_eq!(req.body, b"hello world");
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse("GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_garbage_request_line() {
        assert!(parse("NONSENSE\r\n\r\n").is_err());
        assert!(parse("GET nopath HTTP/1.1\r\n\r\n").is_err());
        assert!(parse("GET / SPDY/9\r\n\r\n").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn bounds_runaway_header_lines_while_reading() {
        // A request line longer than the cap is refused without buffering it.
        let raw = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_HEADER_BYTES * 2));
        assert!(parse(&raw).is_err());
        // So is a header section that dribbles past the cap line by line.
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..40 {
            raw.push_str(&format!("X-Pad-{i}: {}\r\n", "b".repeat(512)));
        }
        raw.push_str("\r\n");
        assert!(raw.len() > MAX_HEADER_BYTES);
        assert!(parse(&raw).is_err());
        // EOF in the middle of a line is a clean error, not a hang.
        assert!(parse("GET / HTTP").is_err());
    }

    #[test]
    fn rejects_bad_or_oversized_content_length() {
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: soup\r\n\r\n").is_err());
        let too_big = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(parse(&too_big).is_err());
        // Declared longer than the bytes actually sent.
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").is_err());
    }

    #[test]
    fn response_shape_and_error_body() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", &[("X-A", "1")], b"{}", false).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.contains("X-A: 1\r\n"));
        assert!(s.ends_with("\r\n\r\n{}"));

        let mut out = Vec::new();
        write_error(&mut out, 503, "busy", false).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(s.ends_with("{\"error\":{\"message\":\"busy\",\"type\":\"overloaded_error\"}}"), "{s}");
    }

    #[test]
    fn error_envelope_maps_status_to_type() {
        assert_eq!(
            error_body(400, "bad"),
            "{\"error\":{\"message\":\"bad\",\"type\":\"invalid_request_error\"}}"
        );
        assert!(error_body(404, "x").contains("\"type\":\"not_found_error\""));
        assert!(error_body(405, "x").contains("\"type\":\"method_not_allowed\""));
        assert!(error_body(500, "x").contains("\"type\":\"internal_error\""));
    }

    #[test]
    fn keep_alive_flag_selects_connection_header() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", &[], b"{}", true).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains("Connection: keep-alive\r\n"), "{s}");

        let mut out = Vec::new();
        write_error(&mut out, 400, "nope", true).unwrap();
        assert!(String::from_utf8(out).unwrap().contains("Connection: keep-alive\r\n"));
    }

    #[test]
    fn keep_alive_request_detection_is_opt_in() {
        let req = parse("GET / HTTP/1.1\r\nConnection: keep-alive\r\n\r\n").unwrap();
        assert!(req.wants_keep_alive());
        let req = parse("GET / HTTP/1.1\r\nConnection: Keep-Alive\r\n\r\n").unwrap();
        assert!(req.wants_keep_alive(), "header value is case-insensitive");
        let req = parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!req.wants_keep_alive());
        // No header at all → close (reuse is opt-in).
        let req = parse("GET / HTTP/1.1\r\n\r\n").unwrap();
        assert!(!req.wants_keep_alive());
    }

    #[test]
    fn poll_request_start_separates_close_from_pending_bytes() {
        // Clean EOF before any bytes → not ready (normal keep-alive end).
        let mut empty = BufReader::new(&b""[..]);
        assert!(!poll_request_start(&mut empty).unwrap());
        // Buffered request bytes → ready, and the subsequent parse sees
        // the complete request (the peek consumes nothing).
        let mut ok = BufReader::new(&b"GET /healthz HTTP/1.1\r\n\r\n"[..]);
        assert!(poll_request_start(&mut ok).unwrap());
        assert_eq!(read_request(&mut ok).unwrap().path, "/healthz");
    }

    #[test]
    fn error_body_with_id_carries_request_id() {
        assert_eq!(
            error_body_with_id(503, "busy", 7),
            "{\"error\":{\"message\":\"busy\",\"request_id\":7,\"type\":\"overloaded_error\"}}"
        );
        // The id-less envelope is unchanged by the new variant.
        assert_eq!(
            error_body(503, "busy"),
            "{\"error\":{\"message\":\"busy\",\"type\":\"overloaded_error\"}}"
        );
    }

    #[test]
    fn sse_header_with_extra_headers() {
        let mut out = Vec::new();
        write_sse_header_with(&mut out, &[("X-Request-Id", "42")]).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
        assert!(s.contains("Content-Type: text/event-stream\r\n"), "{s}");
        assert!(s.contains("X-Request-Id: 42\r\n"), "{s}");
        assert!(s.ends_with("\r\n\r\n"), "{s}");
        // The plain variant stays byte-compatible with the old header.
        let mut plain = Vec::new();
        write_sse_header(&mut plain).unwrap();
        assert!(!String::from_utf8(plain).unwrap().contains("X-Request-Id"));
    }

    #[test]
    fn sse_event_format() {
        let mut out = Vec::new();
        write_sse_event(&mut out, "token", "{\"token\":65}").unwrap();
        assert_eq!(out, b"event: token\ndata: {\"token\":65}\n\n");
    }

    #[test]
    fn sse_data_frame_has_no_event_line() {
        let mut out = Vec::new();
        write_sse_data(&mut out, "{\"text\":\"a\"}").unwrap();
        write_sse_data(&mut out, "[DONE]").unwrap();
        assert_eq!(out, b"data: {\"text\":\"a\"}\n\ndata: [DONE]\n\n");
    }

    #[test]
    fn sse_comment_is_a_standalone_ping_frame() {
        let mut out = Vec::new();
        write_sse_comment(&mut out, "ping").unwrap();
        write_sse_event(&mut out, "token", "{\"token\":65}").unwrap();
        // The heartbeat is its own frame: it ends with a blank line before
        // the next event begins, so it can never interleave mid-event.
        assert_eq!(out, b": ping\n\nevent: token\ndata: {\"token\":65}\n\n");
    }

    #[test]
    fn engine_error_body_is_typed_and_carries_the_id() {
        assert_eq!(
            engine_error_body("engine crashed: boom", 9),
            "{\"error\":{\"message\":\"engine crashed: boom\",\"request_id\":9,\
             \"type\":\"engine_error\"}}"
        );
    }
}
