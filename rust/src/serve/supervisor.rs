//! Panic isolation for the generation engine: run [`engine_loop`] under
//! `catch_unwind`, fail every in-flight request with a terminal
//! [`StreamEvent::Failed`], rebuild the decoder, and restart with capped
//! exponential backoff — so one poisoned request (or an injected fault from
//! [`crate::obs::fault`]) cannot take the whole server down.
//!
//! ```text
//!            ┌────────────────────────────────────────────┐
//!            │ supervise (owns Receiver<EngineMsg>)       │
//!            │   loop {                                   │
//!            │     catch_unwind(engine_loop)  ──ok──▶ drain + exit
//!            │        │ panic / step error               │
//!            │        ▼                                   │
//!            │     fail_all roster (Failed events)        │
//!            │     journal Crash → backoff → Restart      │
//!            │   } until restart budget exhausted         │
//!            │        ▼                                   │
//!            │   degraded: /healthz flips, submits → 503  │
//!            └────────────────────────────────────────────┘
//! ```
//!
//! The supervisor — not the engine — owns the `EngineMsg` receiver, so the
//! submission channel survives a crash: requests accepted during the
//! backoff window queue up and are admitted by the next incarnation.
//! Restart state (the roster of in-flight channels, the backlog gauge) lives
//! in [`Shared`], outside the unwind boundary; the [`BatchDecoder`] is
//! rebuilt from the shared backend each incarnation, never repaired.
//!
//! [`BatchDecoder`]: crate::backend::batch::BatchDecoder
//! [`StreamEvent::Failed`]: crate::serve::engine::StreamEvent

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::backend::{EngineConfig, NativeBackend};
use crate::obs::journal::{self, EventKind};
use crate::serve::engine::{engine_loop, EngineMsg, ExitKind, Shared};

/// Restart policy for the supervised engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SupervisorCfg {
    /// Crashes tolerated before the engine goes degraded
    /// (`--max-engine-restarts`); the N+1th crash is terminal.
    pub max_restarts: usize,
    /// First backoff delay; doubles per consecutive restart.
    pub backoff_base_ms: u64,
    /// Backoff ceiling.
    pub backoff_cap_ms: u64,
}

impl Default for SupervisorCfg {
    fn default() -> SupervisorCfg {
        SupervisorCfg { max_restarts: 3, backoff_base_ms: 100, backoff_cap_ms: 5_000 }
    }
}

impl SupervisorCfg {
    /// `--max-engine-restarts N` with the default backoff curve.
    pub fn with_max_restarts(max_restarts: usize) -> SupervisorCfg {
        SupervisorCfg { max_restarts, ..SupervisorCfg::default() }
    }
}

/// Backoff before restart `attempt` (1-based): `base × 2^(attempt-1)`,
/// capped. Deterministic — no jitter — so tests and the chaos harness can
/// reason about exact recovery timing.
pub fn backoff_delay(cfg: &SupervisorCfg, attempt: usize) -> Duration {
    let shift = (attempt.max(1) - 1).min(20) as u32;
    let ms = cfg.backoff_base_ms.saturating_mul(1u64 << shift);
    Duration::from_millis(ms.min(cfg.backoff_cap_ms))
}

/// Best-effort text out of a panic payload (`panic!("...")` carries `&str`
/// or `String`; anything else is opaque).
pub fn panic_message(payload: &(dyn Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "opaque panic payload"
    }
}

/// Run the engine until graceful shutdown or an exhausted restart budget.
/// Every incarnation of [`engine_loop`] runs under `catch_unwind`; a panic
/// or decoder error fails all in-flight requests (terminal `Failed` on each
/// channel, exactly once via the roster) and, budget permitting, restarts a
/// fresh decoder after backoff.
pub(crate) fn supervise(
    be: &NativeBackend,
    cfg: &EngineConfig,
    sup: &SupervisorCfg,
    rx: &Receiver<EngineMsg>,
    shared: &Arc<Shared>,
) {
    let metrics = shared.metrics.clone();
    let mut restarts = 0usize;
    let mut degraded = false;
    loop {
        let failure = match catch_unwind(AssertUnwindSafe(|| engine_loop(be, cfg, rx, shared))) {
            Ok(ExitKind::Shutdown) => break,
            Ok(ExitKind::Failed(msg)) => msg,
            Err(payload) => {
                metrics.engine_panics_total.fetch_add(1, Ordering::Relaxed);
                format!("engine panicked: {}", panic_message(payload.as_ref()))
            }
        };
        // Crash path. Discard queued messages first so the roster drain
        // below is the single source of truth for in-flight channels (a
        // queued Submission's roster entry was registered before the send).
        discard_queued(rx);
        let failed =
            shared.fail_all(&format!("engine crashed: {failure}; request aborted"));
        metrics.live_slots.store(0, Ordering::Relaxed);
        journal::record(EventKind::Crash, 0, failed as u64);
        eprintln!("engine crashed: {failure} ({failed} in-flight requests failed)");
        if shared.is_shutting_down() {
            break;
        }
        if restarts >= sup.max_restarts {
            degraded = true;
            metrics.engine_degraded.store(1, Ordering::Relaxed);
            eprintln!(
                "engine degraded: restart budget exhausted ({} restarts); serving 503",
                sup.max_restarts
            );
            break;
        }
        restarts += 1;
        metrics.engine_restarts_total.fetch_add(1, Ordering::Relaxed);
        journal::record(EventKind::Restart, 0, restarts as u64);
        let delay = backoff_delay(sup, restarts);
        eprintln!(
            "engine restarting (attempt {restarts}/{}) after {}ms backoff",
            sup.max_restarts,
            delay.as_millis()
        );
        thread::sleep(delay);
    }
    // Terminal: no further incarnation will run. Refuse new submissions,
    // then fail anything that raced past the flags (the submit path
    // re-checks `dead` after registering, so this drain cannot strand a
    // channel).
    shared.set_dead();
    metrics.live_slots.store(0, Ordering::Relaxed);
    discard_queued(rx);
    let msg = if degraded {
        "generation engine degraded: restart budget exhausted"
    } else {
        "server shut down before this request was decoded"
    };
    shared.fail_all(msg);
}

/// Drop every queued message. Submissions are NOT failed here — their
/// roster entries are, by the caller, via [`Shared::fail_all`]; cancels for
/// them are moot.
fn discard_queued(rx: &Receiver<EngineMsg>) {
    while rx.try_recv().is_ok() {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps() {
        let cfg = SupervisorCfg { max_restarts: 5, backoff_base_ms: 100, backoff_cap_ms: 900 };
        assert_eq!(backoff_delay(&cfg, 1), Duration::from_millis(100));
        assert_eq!(backoff_delay(&cfg, 2), Duration::from_millis(200));
        assert_eq!(backoff_delay(&cfg, 3), Duration::from_millis(400));
        assert_eq!(backoff_delay(&cfg, 4), Duration::from_millis(800));
        assert_eq!(backoff_delay(&cfg, 5), Duration::from_millis(900), "cap binds");
        assert_eq!(backoff_delay(&cfg, 0), Duration::from_millis(100), "attempt clamps to 1");
        // Huge attempts must not overflow the shift.
        assert_eq!(backoff_delay(&cfg, 500), Duration::from_millis(900));
    }

    #[test]
    fn panic_payloads_render_as_text() {
        let p: Box<dyn Any + Send> = Box::new("static str payload");
        assert_eq!(panic_message(p.as_ref()), "static str payload");
        let p: Box<dyn Any + Send> = Box::new(String::from("owned payload"));
        assert_eq!(panic_message(p.as_ref()), "owned payload");
        let p: Box<dyn Any + Send> = Box::new(42usize);
        assert_eq!(panic_message(p.as_ref()), "opaque panic payload");
    }

    #[test]
    fn default_policy_matches_cli_defaults() {
        let cfg = SupervisorCfg::default();
        assert_eq!(cfg.max_restarts, 3);
        assert_eq!(SupervisorCfg::with_max_restarts(0).max_restarts, 0);
        assert_eq!(
            SupervisorCfg::with_max_restarts(7).backoff_base_ms,
            cfg.backoff_base_ms,
            "custom budget keeps the default backoff curve"
        );
    }
}
