//! Streaming HTTP/SSE serving front-end over the continuous batcher.
//!
//! This module turns `sinq serve --listen ADDR:PORT` into a long-running
//! network endpoint on `std::net::TcpListener` — no external crates,
//! consistent with the offline vendored-deps build. It is the layer the
//! ROADMAP calls the "streaming generation front-end": a thin protocol
//! front-end that admits requests into the continuous-batching
//! [`BatchDecoder`](crate::backend::BatchDecoder) and streams tokens back
//! as they are produced.
//!
//! ```text
//!                        ┌────────────────────────────────────────────┐
//!  TCP conn ─ handler ───┤ POST /v1/generate ─▶ EngineClient::submit  │
//!  (thread per conn)     │     "stream":true ◀─ SSE token events ──── │──▶ GenEngine thread
//!                        │ POST /v1/score ───▶ BatchServer queue      │    (BatchDecoder:
//!                        │ GET  /healthz      (dynamic batcher)       │     admit/step/retire,
//!                        │ GET  /metrics ───▶ ServeMetrics::render    │     per-step emission)
//!                        └────────────────────────────────────────────┘
//! ```
//!
//! ## Endpoints
//!
//! | endpoint | body | behaviour |
//! |---|---|---|
//! | `POST /v1/generate` | `{"prompt": str, "max_new_tokens": n, "stream": bool, "temperature": t, "top_k": k, "seed": s, "deadline_ms": ms}` | greedy continuation by default (bit-identical to the decoder); `temperature > 0` switches to seeded top-k sampling, reproducible across runs and batch placements; `"stream": true` answers `text/event-stream` with one `token` event per decoded token and a terminal `done` event (finish reason + token counts); otherwise one JSON document. `deadline_ms` bounds the request's total wall-clock time (queue wait included, clamped by `--request-timeout-ms`); expired requests finish with `finish_reason: "timeout"` |
//! | `POST /v1/completions` | `{"prompt": str, "max_tokens": n, "stream": bool, "temperature": t, "top_k": k, "seed": s}` | OpenAI-compatible completion over the same engine: a `text_completion` document with `choices` and `usage` (including `total_tokens`); `"stream": true` answers bare `data:` SSE chunks terminated by `data: [DONE]` |
//! | `POST /v1/score` | `{"text": str}` or `{"tokens": [u8…]}` | teacher-forced scoring through the existing `BatchServer` dynamic batcher; returns per-position log-probs, mean NLL, and perplexity |
//! | `GET /healthz` | — | liveness + engine identity/capacity + page-pool shape + model shape + build info + uptime |
//! | `GET /metrics` | — | Prometheus text: live slots, queued requests, page-pool and prefix-cache gauges (`kv_pages_*`, `prefix_hit_rate`), tokens/sec (windowed + lifetime), TTFT/queue-wait/step-latency histograms |
//! | `GET /v1/stats` | — | one JSON document: request/latency aggregates, throughput, page-pool + prefix-cache health, per-phase decode profile (`SINQ_PROFILE=1`), drift-sentinel summary (`--drift-sample`), per-layer quantization-quality report |
//! | `GET /debug/trace?last=N` | — | the flight recorder's newest `N` events (default 512) rendered as Chrome-trace JSON — load it in Perfetto / `chrome://tracing` to see per-request queued/running/preempted lanes over the engine's step + phase timeline |
//!
//! Every generation response — the JSON body and the SSE `done` event —
//! carries a `usage` object (prompt/completion token counts, queue-wait,
//! TTFT, total latency, request-level tokens/sec) derived from the
//! request's span ([`crate::obs::RequestSpan`]).
//!
//! ## Error and backpressure contract
//!
//! * Every error answers one JSON envelope —
//!   `{"error": {"message": …, "type": …}}` ([`http::error_body`]) — so
//!   clients unwrap `400`/`404`/`405`/`503` identically. Malformed JSON
//!   bodies and requests that cannot fit the page pool answer `400`
//!   carrying the decoder's own page-accounting text; they never tear down
//!   the engine.
//! * When more than `--max-queue` generation requests are waiting for a KV
//!   slot, new requests answer `503` with a `Retry-After` header instead of
//!   queueing unboundedly.
//! * Clients that send `Connection: keep-alive` get the socket back for
//!   their next request (bounded by an idle timeout and a per-connection
//!   request cap), cutting TCP setup out of steady-state TTFT; everything
//!   else — including every SSE stream, which is close-delimited by
//!   design — stays one-request-per-connection.
//! * `Ctrl-C` (SIGINT/SIGTERM) stops accepting connections, drains every
//!   live slot and already-queued request, then exits cleanly.
//!
//! Scoring and generation share **one** weight set: the [`NativeBackend`]
//! is built once and shared (`Arc`) between the scoring router and the
//! streaming engine. A client that disconnects mid-SSE-stream is detected
//! by the failed socket write: the handler cancels the request and the
//! engine evicts its KV slot at the next step boundary instead of decoding
//! to `max_new_tokens` (`sinq_serve_evicted_total` counts these). The
//! KV-cache precision follows the backend's `--kv-bits 32|8` flag; KV
//! memory is a shared pool of fixed-size pages (`--page-size`,
//! `--kv-pages`) with prefix caching across shared prompt prefixes, and
//! `/healthz` + `/metrics` report `kv_bits`, `kv_bytes_per_page`, and the
//! pool/prefix gauges.

pub mod engine;
pub mod http;
pub mod metrics;
pub mod supervisor;

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::backend::{self, simd, BackendSpec, InferenceBackend, NativeBackend, SampleCfg};
use crate::coordinator::server::{BatchServer, ScoreClient, ServerStats};
use crate::eval::{log_prob, LogitsEngine};
use crate::obs::span::Usage;
use crate::obs::{drift, journal, trace};
use crate::tensor::Matrix;
use crate::util::json::Json;

use engine::{EngineClient, GenEngine, StreamEvent, StreamHandle, SubmitError, SubmitErrorKind};
use metrics::ServeMetrics;
use supervisor::SupervisorCfg;

/// Longest token sequence `/v1/score` accepts (the full forward is
/// quadratic in sequence length; unbounded request bodies must not be able
/// to pin the batcher).
pub const MAX_SCORE_TOKENS: usize = 4096;

/// Requests served on one kept-alive connection before the server closes
/// it anyway — bounds how long a single socket can monopolize a handler
/// thread.
pub const MAX_KEEPALIVE_REQUESTS: usize = 256;

/// Flight-recorder events `GET /debug/trace` returns when the request does
/// not pass `?last=N`.
pub const DEFAULT_TRACE_EVENTS: usize = 512;

/// Front-end configuration (the CLI flags of `sinq serve --listen`).
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Bind address, e.g. `127.0.0.1:8080` (port 0 picks a free port).
    pub listen: String,
    /// Concurrent KV slots in the streaming engine (`--max-batch`).
    pub max_batch: usize,
    /// Per-sequence KV capacity in positions (`--max-context`): bounds
    /// `prompt + generated` per request.
    pub max_context: usize,
    /// KV page granularity in positions (`--page-size`); requests claim
    /// pages from a shared pool as they decode instead of reserving
    /// `max_context` positions up front.
    pub page_size: usize,
    /// Page-pool size override (`--kv-pages`); `None` sizes the pool to
    /// `max_batch × ceil(max_context / page_size)` pages.
    pub kv_pages: Option<usize>,
    /// Generation requests allowed to wait for a slot before new ones get
    /// `503` (`--max-queue`).
    pub max_queue: usize,
    /// `max_new_tokens` applied when a request omits it.
    pub default_max_new: usize,
    /// Bounded queue depth of the scoring batcher.
    pub score_queue: usize,
    /// Concurrent connections (one handler thread each) before new ones
    /// are answered `503` at the TCP layer — keeps connection floods from
    /// bypassing the `--max-queue` admission bound.
    pub max_connections: usize,
    /// How long a kept-alive connection may sit idle between requests
    /// before the server closes it (`--keepalive-idle-ms`). Also bounds how
    /// long an idle keep-alive socket pins one handler thread.
    pub keepalive_idle_ms: u64,
    /// `--log-json`: print one structured JSON line per completed request.
    pub log_json: bool,
    /// `--drift-sample N`: every `N`th decode step recomputes one live
    /// row's logits through the forced-scalar kernel path and feeds the
    /// comparison into the drift sentinel (`/metrics`, `/v1/stats`). `0`
    /// (the default) disables the sentinel.
    pub drift_sample: usize,
    /// `--request-timeout-ms`: server-wide deadline ceiling applied to
    /// every generation request (clamps any per-request `deadline_ms`).
    /// `0` (the default) imposes none.
    pub request_timeout_ms: u64,
    /// `--max-engine-restarts`: engine crashes tolerated before `/healthz`
    /// flips to `degraded` and submissions answer `503`.
    pub max_engine_restarts: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            listen: "127.0.0.1:0".into(),
            max_batch: 8,
            max_context: 512,
            page_size: backend::config::DEFAULT_PAGE_SIZE,
            kv_pages: None,
            max_queue: 64,
            default_max_new: 32,
            score_queue: 64,
            max_connections: 256,
            keepalive_idle_ms: 5_000,
            log_json: false,
            drift_sample: 0,
            request_timeout_ms: 0,
            max_engine_restarts: 3,
        }
    }
}

/// Final counters reported by [`Server::shutdown`].
#[derive(Debug, Default, Clone)]
pub struct ShutdownStats {
    /// Generation requests accepted.
    pub gen_requests: usize,
    /// Generation requests completed.
    pub gen_completed: usize,
    /// Tokens generated.
    pub gen_tokens: usize,
    /// Scoring-router counters.
    pub score: ServerStats,
}

/// [`InferenceBackend`] adapter over a shared [`NativeBackend`], so the
/// scoring router batches against the same weight set the streaming engine
/// decodes from (every native entry point takes `&self`; the `&mut` trait
/// surface just delegates through the `Arc`).
struct SharedNative(Arc<NativeBackend>);

impl LogitsEngine for SharedNative {
    fn logits(&mut self, tokens: &[u8]) -> anyhow::Result<Matrix> {
        self.0.forward(tokens)
    }

    fn vocab(&self) -> usize {
        self.0.cfg.vocab
    }
}

impl InferenceBackend for SharedNative {
    fn name(&self) -> &'static str {
        "native"
    }

    fn max_batch(&self) -> usize {
        InferenceBackend::max_batch(&*self.0)
    }

    fn forward_batch(&mut self, seqs: &[&[u8]]) -> anyhow::Result<Vec<Matrix>> {
        self.0.forward_batch(seqs)
    }

    fn generate(&mut self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
        self.0.generate(prompt, n)
    }

    fn generate_batch(
        &mut self,
        prompts: &[&[u8]],
        max_new: &[usize],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        self.0.generate_batch(prompts, max_new)
    }
}

/// Per-connection handler context.
struct ConnState {
    engine: EngineClient,
    score: ScoreClient,
    metrics: Arc<ServeMetrics>,
    /// The shared backend, so `/healthz` and `/v1/stats` can report the
    /// model shape and the build-time quantization-quality report.
    be: Arc<NativeBackend>,
    model: String,
    slots: usize,
    capacity: usize,
    default_max_new: usize,
    /// Drift-sentinel sampling rate the engine runs with (`0` = off), so
    /// `/v1/stats` can report the rate next to the counters.
    drift_sample: usize,
    /// Keep-alive idle timeout between requests on one connection.
    idle: Duration,
    /// Server shutdown flag (shared with the accept loop): once set,
    /// responses advertise `Connection: close` so kept-alive sockets stop
    /// extending the graceful drain.
    stop: Arc<AtomicBool>,
}

/// A running serving endpoint: listener thread + streaming engine +
/// scoring router. Bind with [`Server::start`] (or
/// [`Server::start_with_backend`] to reuse an already-built engine), stop
/// with [`Server::shutdown`].
pub struct Server {
    /// The bound address — with port 0 this is where the OS actually put us.
    pub addr: SocketAddr,
    /// Live counters (shared with the engine and handlers).
    pub metrics: Arc<ServeMetrics>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
    engine: Option<GenEngine>,
    score: Option<BatchServer>,
}

impl Server {
    /// Build the native engine from `spec` and start serving.
    pub fn start(spec: &BackendSpec, opts: &ServeOpts) -> anyhow::Result<Server> {
        Server::start_with_backend(Arc::new(backend::build_native(spec)?), opts)
    }

    /// Start serving over an already-built backend.
    pub fn start_with_backend(
        be: Arc<NativeBackend>,
        opts: &ServeOpts,
    ) -> anyhow::Result<Server> {
        let metrics = Arc::new(ServeMetrics::new());
        // The flight recorder runs whenever the server does: its record
        // path is a handful of relaxed atomics per lifecycle event, and
        // `/debug/trace` is only useful if history already exists when an
        // incident is noticed.
        journal::set_enabled(true);
        // One engine configuration for the whole front-end: the backend's
        // spec-level defaults (KV precision, sampling) plus the serve
        // flags' concurrency/context/page geometry.
        let cfg = be
            .engine()
            .with_max_batch(opts.max_batch)
            .with_max_context(opts.max_context)
            .with_page_size(opts.page_size)
            .with_pages(opts.kv_pages)
            .with_drift_sample(opts.drift_sample)
            .with_request_timeout_ms(opts.request_timeout_ms);
        let slots = cfg.max_batch;
        let capacity = cfg.max_context;
        let gen_engine = GenEngine::start_supervised(
            be.clone(),
            cfg,
            opts.max_queue,
            metrics.clone(),
            opts.log_json,
            SupervisorCfg::with_max_restarts(opts.max_engine_restarts),
        )?;
        let score = BatchServer::spawn(
            {
                let be = be.clone();
                move || Ok(SharedNative(be))
            },
            opts.score_queue.max(1),
            Duration::from_millis(4),
        );
        let listener = TcpListener::bind(&opts.listen)
            .map_err(|e| anyhow::anyhow!("cannot bind {}: {e}", opts.listen))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;

        let stop = Arc::new(AtomicBool::new(false));
        let state = Arc::new(ConnState {
            engine: gen_engine.client(),
            score: score.client(),
            metrics: metrics.clone(),
            model: be.cfg.name.clone(),
            be: be.clone(),
            slots,
            capacity,
            default_max_new: opts.default_max_new,
            drift_sample: opts.drift_sample,
            idle: Duration::from_millis(opts.keepalive_idle_ms.max(1)),
            stop: stop.clone(),
        });
        let accept_stop = stop.clone();
        let max_connections = opts.max_connections.max(1);
        let accept_thread = thread::Builder::new()
            .name("sinq-serve-accept".into())
            .spawn(move || accept_loop(listener, &accept_stop, &state, max_connections))
            .expect("spawn accept loop");

        Ok(Server {
            addr,
            metrics,
            stop,
            accept_thread: Some(accept_thread),
            engine: Some(gen_engine),
            score: Some(score),
        })
    }

    /// Graceful shutdown: stop accepting, wait for in-flight connections,
    /// drain every live KV slot, stop the scoring router; returns final
    /// counters.
    pub fn shutdown(mut self) -> ShutdownStats {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(e) = self.engine.take() {
            e.shutdown();
        }
        let score = self.score.take().map(BatchServer::shutdown).unwrap_or_default();
        ShutdownStats {
            gen_requests: self.metrics.requests_total.load(Ordering::Relaxed),
            gen_completed: self.metrics.completed_total.load(Ordering::Relaxed),
            gen_tokens: self.metrics.tokens_generated.load(Ordering::Relaxed),
            score,
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Signal everything without joining, so error paths never block;
        // `shutdown()` is the orderly exit.
        self.stop.store(true, Ordering::SeqCst);
    }
}

fn accept_loop(
    listener: TcpListener,
    stop: &AtomicBool,
    state: &Arc<ConnState>,
    max_connections: usize,
) {
    let mut handlers: Vec<thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                handlers.retain(|h| !h.is_finished());
                if handlers.len() >= max_connections {
                    // Thread-per-connection: cap live handlers so a
                    // connection flood cannot bypass the request-level
                    // `--max-queue` bound by exhausting threads first.
                    let _ = stream.set_nonblocking(false);
                    let _ = http::write_error(&mut stream, 503, "too many open connections", false);
                    continue;
                }
                let state = state.clone();
                let h = thread::Builder::new()
                    .name("sinq-serve-conn".into())
                    .spawn(move || handle_connection(stream, &state))
                    .expect("spawn connection handler");
                handlers.push(h);
            }
            // Nonblocking listener: sleep briefly between polls so the stop
            // flag is honored without a dedicated wakeup pipe.
            Err(_) => thread::sleep(Duration::from_millis(5)),
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

fn handle_connection(stream: TcpStream, state: &ConnState) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut w = stream;
    // Per-connection request loop: runs once for close-delimited clients,
    // and until idle timeout / request cap / shutdown / protocol error for
    // clients that opt into `Connection: keep-alive`.
    for served in 0..MAX_KEEPALIVE_REQUESTS {
        if served > 0 {
            // Between kept-alive requests only the (shorter) idle timeout
            // applies, so a silent client costs one handler thread for at
            // most `idle` (the clones share one socket, so setting the
            // timeout on the writer also governs the reader). The peek
            // below restores the full per-request timeout as soon as the
            // next request's first bytes arrive, so a slow-but-active
            // request is never cut short by the idle bound.
            let _ = w.set_read_timeout(Some(state.idle));
            match http::poll_request_start(&mut reader) {
                Ok(true) => {}
                // Peer finished, idled out, or hard socket error: nothing
                // left to answer on this connection.
                Ok(false) | Err(_) => return,
            }
            let _ = w.set_read_timeout(Some(Duration::from_secs(30)));
        }
        let req = match http::read_request(&mut reader) {
            Ok(r) => r,
            Err(e) => {
                // Bytes arrived but did not parse as a request (first
                // request, or garbage after a kept-alive one): answer 400
                // and hang up. A peer that already died just loses the
                // write, which `let _` absorbs.
                let _ = http::write_error(&mut w, 400, &format!("bad request: {e}"), false);
                return;
            }
        };
        // Stop extending the session once shutdown begins: the response
        // advertises `Connection: close` and the loop exits, so graceful
        // drain stays bounded by in-flight work instead of up to
        // MAX_KEEPALIVE_REQUESTS further requests per open socket.
        let keep = req.wants_keep_alive()
            && served + 1 < MAX_KEEPALIVE_REQUESTS
            && !state.stop.load(Ordering::SeqCst);
        // Split an optional query string off the path so parameterized GET
        // routes (`/debug/trace?last=N`) match on the bare path.
        let (path, query) = match req.path.split_once('?') {
            Some((p, q)) => (p, Some(q)),
            None => (req.path.as_str(), None),
        };
        // Write failures (client hung up mid-stream) are not server errors;
        // they end the connection like any non-reusable response.
        let reusable = match (req.method.as_str(), path) {
            ("GET", "/healthz") => handle_health(&mut w, state, keep).map(|_| keep),
            ("GET", "/metrics") => http::write_response(
                &mut w,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                &[],
                state.metrics.render().as_bytes(),
                keep,
            )
            .map(|_| keep),
            ("GET", "/v1/stats") => handle_stats(&mut w, state, keep).map(|_| keep),
            ("GET", "/debug/trace") => handle_trace(&mut w, query, keep).map(|_| keep),
            ("POST", "/v1/generate") => handle_generate(&mut w, state, &req.body, keep),
            ("POST", "/v1/completions") => handle_completions(&mut w, state, &req.body, keep),
            ("POST", "/v1/score") => handle_score(&mut w, state, &req.body, keep).map(|_| keep),
            (
                _,
                "/healthz" | "/metrics" | "/v1/stats" | "/debug/trace" | "/v1/generate"
                | "/v1/completions" | "/v1/score",
            ) => {
                http::write_error(
                    &mut w,
                    405,
                    &format!("method {} not allowed on {}", req.method, req.path),
                    keep,
                )
                .map(|_| keep)
            }
            _ => http::write_error(&mut w, 404, &format!("unknown path {}", req.path), keep)
                .map(|_| keep),
        };
        if !reusable.unwrap_or(false) {
            return;
        }
    }
}

/// Build identity baked in at compile time: the CI/build scripts export
/// `SINQ_GIT_SHA`; local builds without it report `"unknown"`.
fn build_info() -> Json {
    Json::obj(vec![
        ("git_sha", Json::Str(option_env!("SINQ_GIT_SHA").unwrap_or("unknown").into())),
        (
            "profile",
            Json::Str(if cfg!(debug_assertions) { "debug" } else { "release" }.into()),
        ),
    ])
}

/// Model shape summary shared by `/healthz` and `/v1/stats`.
fn model_shape(state: &ConnState) -> Json {
    let cfg = &state.be.cfg;
    Json::obj(vec![
        ("name", Json::Str(cfg.name.clone())),
        ("layers", Json::Num(cfg.layers as f64)),
        ("dim", Json::Num(cfg.d as f64)),
        ("heads", Json::Num(cfg.heads as f64)),
        ("vocab", Json::Num(cfg.vocab as f64)),
    ])
}

fn handle_health(w: &mut TcpStream, state: &ConnState, keep_alive: bool) -> std::io::Result<()> {
    let m = &state.metrics;
    // `degraded`: the supervised engine exhausted its restart budget; the
    // process is alive (scoring, metrics, traces still work) but every
    // generation submit answers 503.
    let status = if m.engine_degraded.load(Ordering::Relaxed) != 0 { "degraded" } else { "ok" };
    let body = Json::obj(vec![
        ("status", Json::Str(status.into())),
        ("engine_restarts", Json::Num(m.engine_restarts_total.load(Ordering::Relaxed) as f64)),
        ("engine_panics", Json::Num(m.engine_panics_total.load(Ordering::Relaxed) as f64)),
        ("backend", Json::Str("native".into())),
        ("simd", Json::Str(simd::kernel_name().into())),
        ("threads", Json::Num(state.be.threads as f64)),
        ("model", Json::Str(state.model.clone())),
        ("model_shape", model_shape(state)),
        ("build", build_info()),
        ("uptime_secs", Json::Num(m.uptime_secs())),
        ("slots", Json::Num(state.slots as f64)),
        ("kv_capacity", Json::Num(state.capacity as f64)),
        ("kv_bits", Json::Num(m.kv_bits.load(Ordering::Relaxed) as f64)),
        ("kv_bytes_per_page", Json::Num(m.kv_bytes_per_page.load(Ordering::Relaxed) as f64)),
        ("kv_page_size", Json::Num(m.kv_page_size.load(Ordering::Relaxed) as f64)),
        ("kv_pages_total", Json::Num(m.kv_pages_total.load(Ordering::Relaxed) as f64)),
        ("kv_pages_free", Json::Num(m.kv_pages_free.load(Ordering::Relaxed) as f64)),
        (
            "prefix_cached_pages",
            Json::Num(m.prefix_cached_pages.load(Ordering::Relaxed) as f64),
        ),
        ("live_slots", Json::Num(m.live_slots.load(Ordering::Relaxed) as f64)),
        ("queued_requests", Json::Num(m.queued.load(Ordering::Relaxed) as f64)),
    ]);
    http::write_response(
        w,
        200,
        "application/json",
        &[],
        body.to_string_compact().as_bytes(),
        keep_alive,
    )
}

/// `GET /v1/stats`: one JSON document aggregating everything the
/// observability layer collects — request/latency aggregates, windowed and
/// lifetime throughput, the per-phase decode profile (when `SINQ_PROFILE`
/// is on), and the build-time quantization-quality report.
fn handle_stats(w: &mut TcpStream, state: &ConnState, keep_alive: bool) -> std::io::Result<()> {
    let m = &state.metrics;
    let requests = Json::obj(vec![
        ("total", Json::Num(m.requests_total.load(Ordering::Relaxed) as f64)),
        ("completed", Json::Num(m.completed_total.load(Ordering::Relaxed) as f64)),
        ("rejected", Json::Num(m.rejected_total.load(Ordering::Relaxed) as f64)),
        ("evicted", Json::Num(m.evicted_total.load(Ordering::Relaxed) as f64)),
        ("preempted", Json::Num(m.preempted_total.load(Ordering::Relaxed) as f64)),
        ("queued", Json::Num(m.queued.load(Ordering::Relaxed) as f64)),
        ("live_slots", Json::Num(m.live_slots.load(Ordering::Relaxed) as f64)),
        ("score", Json::Num(m.score_requests.load(Ordering::Relaxed) as f64)),
    ]);
    let throughput = Json::obj(vec![
        ("tokens_generated", Json::Num(m.tokens_generated.load(Ordering::Relaxed) as f64)),
        ("decode_steps", Json::Num(m.decode_steps.load(Ordering::Relaxed) as f64)),
        ("tokens_per_sec", Json::Num(m.tokens_per_sec())),
        ("tokens_per_sec_lifetime", Json::Num(m.tokens_per_sec_lifetime())),
    ]);
    let latency = Json::obj(vec![
        ("ttft", m.ttft.snapshot().to_json()),
        ("queue_wait", m.queue_wait.snapshot().to_json()),
        ("step", m.step_latency.snapshot().to_json()),
    ]);
    let kv_pages = Json::obj(vec![
        ("page_size", Json::Num(m.kv_page_size.load(Ordering::Relaxed) as f64)),
        ("total", Json::Num(m.kv_pages_total.load(Ordering::Relaxed) as f64)),
        ("free", Json::Num(m.kv_pages_free.load(Ordering::Relaxed) as f64)),
        ("bytes_per_page", Json::Num(m.kv_bytes_per_page.load(Ordering::Relaxed) as f64)),
    ]);
    let prefix_cache = Json::obj(vec![
        ("cached_pages", Json::Num(m.prefix_cached_pages.load(Ordering::Relaxed) as f64)),
        ("hits", Json::Num(m.prefix_hits_total.load(Ordering::Relaxed) as f64)),
        (
            "tokens_reused",
            Json::Num(m.prefix_tokens_reused_total.load(Ordering::Relaxed) as f64),
        ),
        ("hit_rate", Json::Num(m.prefix_hit_rate())),
    ]);
    let quant = match state.be.quant_report() {
        Some(r) => r.to_json(),
        None => Json::Null,
    };
    let body = Json::obj(vec![
        ("uptime_secs", Json::Num(m.uptime_secs())),
        ("kernel", Json::Str(simd::kernel_name().into())),
        ("threads", Json::Num(state.be.threads as f64)),
        ("model", model_shape(state)),
        ("build", build_info()),
        ("requests", requests),
        ("throughput", throughput),
        ("latency", latency),
        ("kv_pages", kv_pages),
        ("prefix_cache", prefix_cache),
        ("profile", crate::obs::profiler::snapshot().to_json()),
        ("drift", drift::snapshot().to_json(state.drift_sample)),
        ("quant", quant),
    ]);
    http::write_response(
        w,
        200,
        "application/json",
        &[],
        body.to_string_compact().as_bytes(),
        keep_alive,
    )
}

/// `GET /debug/trace?last=N`: the flight recorder's newest `N` events
/// (default [`DEFAULT_TRACE_EVENTS`]) rendered as Chrome-trace JSON —
/// loadable directly in Perfetto or `chrome://tracing`.
fn handle_trace(w: &mut TcpStream, query: Option<&str>, keep_alive: bool) -> std::io::Result<()> {
    let last = query
        .and_then(|q| q.split('&').find_map(|kv| kv.strip_prefix("last=")))
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(DEFAULT_TRACE_EVENTS);
    let body = trace::chrome_trace(&journal::snapshot(last));
    http::write_response(
        w,
        200,
        "application/json",
        &[],
        body.to_string_compact().as_bytes(),
        keep_alive,
    )
}

/// Parsed `POST /v1/generate` body.
struct GenerateBody {
    prompt: Vec<u8>,
    max_new: usize,
    stream: bool,
    /// Seeded sampling parameters; `None` decodes greedily.
    sample: Option<SampleCfg>,
    /// Per-request wall-clock budget in milliseconds (queue wait counts);
    /// clamped server-side by `--request-timeout-ms`.
    deadline_ms: Option<u64>,
}

fn parse_generate(body: &[u8], default_max_new: usize) -> Result<GenerateBody, String> {
    parse_gen_fields(body, default_max_new, "max_new_tokens")
}

/// `POST /v1/completions` parses identically except the token budget field
/// follows the OpenAI name `max_tokens`.
fn parse_completions(body: &[u8], default_max_new: usize) -> Result<GenerateBody, String> {
    parse_gen_fields(body, default_max_new, "max_tokens")
}

fn parse_gen_fields(
    body: &[u8],
    default_max_new: usize,
    max_field: &str,
) -> Result<GenerateBody, String> {
    let text =
        std::str::from_utf8(body).map_err(|_| "request body is not valid UTF-8".to_string())?;
    let json = Json::parse(text).map_err(|e| format!("malformed JSON body: {e}"))?;
    let prompt = match json.get("prompt") {
        Some(Json::Str(p)) if !p.is_empty() => p.as_bytes().to_vec(),
        Some(Json::Str(_)) => return Err("'prompt' must be a non-empty string".into()),
        Some(_) => return Err("'prompt' must be a string".into()),
        None => return Err("missing field 'prompt'".into()),
    };
    let max_new = match json.get(max_field) {
        Some(v) => v
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .ok_or_else(|| format!("'{max_field}' must be a non-negative integer"))?
            as usize,
        None => default_max_new,
    };
    let stream = match json.get("stream") {
        Some(Json::Bool(b)) => *b,
        Some(_) => return Err("'stream' must be a boolean".into()),
        None => false,
    };
    let temperature = match json.get("temperature") {
        Some(v) => v
            .as_f64()
            .filter(|t| t.is_finite() && *t >= 0.0)
            .ok_or("'temperature' must be a non-negative number")? as f32,
        None => 0.0,
    };
    let top_k = match json.get("top_k") {
        Some(v) => v
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .ok_or("'top_k' must be a non-negative integer")? as usize,
        None => 0,
    };
    let seed = match json.get("seed") {
        Some(v) => v
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .ok_or("'seed' must be a non-negative integer")? as u64,
        None => 0,
    };
    let deadline_ms = match json.get("deadline_ms") {
        Some(v) => {
            let ms = v
                .as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .ok_or("'deadline_ms' must be a non-negative integer")? as u64;
            // 0 means "no per-request deadline" (the server ceiling, if
            // any, still applies).
            (ms > 0).then_some(ms)
        }
        None => None,
    };
    // Greedy unless a positive temperature opts into sampling (top_k/seed
    // without one are inert), so the default stays bit-identical.
    let sample = if temperature > 0.0 {
        Some(SampleCfg { temperature, top_k, seed })
    } else {
        None
    };
    Ok(GenerateBody { prompt, max_new, stream, sample, deadline_ms })
}

/// Returns whether the connection is still reusable afterwards: every
/// fixed-length response (success or structured error) preserves the
/// request's keep-alive choice; an SSE stream is close-delimited, so
/// streaming always ends the connection.
fn handle_generate(
    w: &mut TcpStream,
    state: &ConnState,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<bool> {
    let parsed = match parse_generate(body, state.default_max_new) {
        Ok(p) => p,
        Err(msg) => return http::write_error(w, 400, &msg, keep_alive).map(|_| keep_alive),
    };
    match state.engine.submit(parsed.prompt, parsed.max_new, parsed.sample, parsed.deadline_ms) {
        Err(e) => write_submit_error(w, state, &e, keep_alive).map(|_| keep_alive),
        Ok(handle) => {
            if parsed.stream {
                let id = handle.id;
                let streamed = stream_generate(w, state, handle);
                if streamed.is_err() {
                    // The SSE write failed: the client disconnected
                    // mid-stream. Evict the slot at the next step boundary
                    // instead of decoding to max_new.
                    state.engine.cancel(id);
                }
                streamed.map(|_| false)
            } else {
                respond_generate(w, handle, keep_alive).map(|_| keep_alive)
            }
        }
    }
}

/// Map a refused submission onto the wire: over-capacity prompts answer
/// `400` with the decoder's own page-accounting text, saturation answers
/// `503` + `Retry-After` — all in the unified error envelope, which (like
/// the `X-Request-Id` header) carries the request id the engine minted
/// before refusing, so rejected requests correlate with `--log-json` lines
/// and flight-recorder events too. The `Retry-After` hint is computed from
/// the live backlog and recent throughput ([`ServeMetrics::retry_after_secs`])
/// rather than a constant, so a saturated server sheds load for as long as
/// its queue actually needs.
fn write_submit_error(
    w: &mut TcpStream,
    state: &ConnState,
    e: &SubmitError,
    keep_alive: bool,
) -> std::io::Result<()> {
    let code: u16 = match &e.kind {
        SubmitErrorKind::Invalid(_) => 400,
        SubmitErrorKind::Busy { .. } | SubmitErrorKind::Unavailable(_) => 503,
    };
    let rid = e.id.to_string();
    let retry_after = state.metrics.retry_after_secs().to_string();
    let mut headers: Vec<(&str, &str)> = vec![("X-Request-Id", &rid)];
    if matches!(e.kind, SubmitErrorKind::Busy { .. }) {
        headers.push(("Retry-After", &retry_after));
    }
    http::write_response(
        w,
        code,
        "application/json",
        &headers,
        http::error_body_with_id(code, &e.to_string(), e.id).as_bytes(),
        keep_alive,
    )
}

/// `POST /v1/completions`: the OpenAI completion shape over the same
/// engine path as `/v1/generate`. Returns whether the connection is still
/// reusable afterwards (streaming is close-delimited, like SSE above).
fn handle_completions(
    w: &mut TcpStream,
    state: &ConnState,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<bool> {
    let parsed = match parse_completions(body, state.default_max_new) {
        Ok(p) => p,
        Err(msg) => return http::write_error(w, 400, &msg, keep_alive).map(|_| keep_alive),
    };
    match state.engine.submit(parsed.prompt, parsed.max_new, parsed.sample, parsed.deadline_ms) {
        Err(e) => write_submit_error(w, state, &e, keep_alive).map(|_| keep_alive),
        Ok(handle) => {
            if parsed.stream {
                let id = handle.id;
                let streamed = stream_completions(w, state, handle);
                if streamed.is_err() {
                    state.engine.cancel(id);
                }
                streamed.map(|_| false)
            } else {
                respond_completions(w, state, handle, keep_alive).map(|_| keep_alive)
            }
        }
    }
}

/// Unix seconds for the OpenAI `created` stamp.
fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// The OpenAI `usage` object: the request span's accounting plus the
/// `total_tokens` sum OpenAI clients expect.
fn openai_usage(u: &Usage) -> Json {
    let mut j = u.to_json();
    if let Json::Obj(m) = &mut j {
        m.insert(
            "total_tokens".into(),
            Json::Num((u.prompt_tokens + u.completion_tokens) as f64),
        );
    }
    j
}

/// One OpenAI `text_completion` document — shared by the non-streamed
/// response and every streamed chunk (chunks carry `finish_reason: null`
/// and no `usage` until the final one).
fn completion_json(
    id: usize,
    model: &str,
    created: u64,
    text: &str,
    finish_reason: Option<&str>,
    usage: Option<&Usage>,
) -> Json {
    let choice = Json::obj(vec![
        ("text", Json::Str(text.to_string())),
        ("index", Json::Num(0.0)),
        ("logprobs", Json::Null),
        (
            "finish_reason",
            match finish_reason {
                Some(r) => Json::Str(r.to_string()),
                None => Json::Null,
            },
        ),
    ]);
    let mut fields = vec![
        ("id", Json::Str(format!("cmpl-{id}"))),
        ("object", Json::Str("text_completion".into())),
        ("created", Json::Num(created as f64)),
        ("model", Json::Str(model.to_string())),
        ("choices", Json::Arr(vec![choice])),
    ];
    if let Some(u) = usage {
        fields.push(("usage", openai_usage(u)));
    }
    Json::obj(fields)
}

/// Streamed `/v1/completions`: bare `data:` chunks in the OpenAI wire
/// format, one per decoded token, then a final chunk with `finish_reason`
/// + `usage` and the literal `data: [DONE]` terminator. While the request
/// sits queued (or decode stalls) past the keep-alive idle window, an SSE
/// comment line (`: ping`) keeps intermediaries from timing the stream out
/// — comments are written only between events, never inside one.
fn stream_completions(
    w: &mut TcpStream,
    state: &ConnState,
    handle: StreamHandle,
) -> std::io::Result<()> {
    let id = handle.id;
    http::write_sse_header_with(w, &[("X-Request-Id", &id.to_string())])?;
    let created = unix_now();
    loop {
        match handle.rx.recv_timeout(state.idle) {
            Ok(StreamEvent::Token(tok)) => {
                let piece = String::from_utf8_lossy(&[tok]).into_owned();
                let chunk = completion_json(id, &state.model, created, &piece, None, None);
                http::write_sse_data(w, &chunk.to_string_compact())?;
            }
            Ok(StreamEvent::Done { finish_reason, usage }) => {
                let last =
                    completion_json(id, &state.model, created, "", Some(finish_reason), Some(&usage));
                http::write_sse_data(w, &last.to_string_compact())?;
                return http::write_sse_data(w, "[DONE]");
            }
            Ok(StreamEvent::Failed { request_id, message }) => {
                http::write_sse_data(w, &http::engine_error_body(&message, request_id))?;
                return http::write_sse_data(w, "[DONE]");
            }
            Err(RecvTimeoutError::Timeout) => http::write_sse_comment(w, "ping")?,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    http::write_sse_data(w, &http::error_body(500, "stream interrupted"))?;
    http::write_sse_data(w, "[DONE]")
}

/// Non-streamed `/v1/completions`: one `text_completion` document.
fn respond_completions(
    w: &mut TcpStream,
    state: &ConnState,
    handle: StreamHandle,
    keep_alive: bool,
) -> std::io::Result<()> {
    let id = handle.id;
    let rid = id.to_string();
    let mut text = Vec::new();
    for ev in handle.rx.iter() {
        match ev {
            StreamEvent::Token(tok) => text.push(tok),
            StreamEvent::Done { finish_reason, usage } => {
                let body = completion_json(
                    id,
                    &state.model,
                    unix_now(),
                    &String::from_utf8_lossy(&text),
                    Some(finish_reason),
                    Some(&usage),
                );
                return http::write_response(
                    w,
                    200,
                    "application/json",
                    &[("X-Request-Id", &rid)],
                    body.to_string_compact().as_bytes(),
                    keep_alive,
                );
            }
            StreamEvent::Failed { request_id, message } => {
                return write_engine_error(w, request_id, &message, keep_alive)
            }
        }
    }
    http::write_error(w, 500, "stream interrupted", keep_alive)
}

/// One `500` with the typed `engine_error` envelope and the request id in
/// both the body and the `X-Request-Id` header — the non-stream rendering
/// of a terminal [`StreamEvent::Failed`].
fn write_engine_error(
    w: &mut TcpStream,
    request_id: usize,
    message: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let rid = request_id.to_string();
    http::write_response(
        w,
        500,
        "application/json",
        &[("X-Request-Id", &rid)],
        http::engine_error_body(message, request_id).as_bytes(),
        keep_alive,
    )
}

/// Streamed generation: one SSE `token` event per decoded token as the
/// engine emits it, then a terminal `done` (or `error`) event. Idle gaps
/// longer than the keep-alive window emit `: ping` comment lines between
/// events (never inside one), so proxies keep queued streams open.
fn stream_generate(
    w: &mut TcpStream,
    state: &ConnState,
    handle: StreamHandle,
) -> std::io::Result<()> {
    http::write_sse_header_with(w, &[("X-Request-Id", &handle.id.to_string())])?;
    let mut text = Vec::new();
    loop {
        match handle.rx.recv_timeout(state.idle) {
            Ok(StreamEvent::Token(tok)) => {
                text.push(tok);
                let data = Json::obj(vec![
                    ("index", Json::Num((text.len() - 1) as f64)),
                    ("token", Json::Num(tok as f64)),
                ]);
                http::write_sse_event(w, "token", &data.to_string_compact())?;
            }
            Ok(StreamEvent::Done { finish_reason, usage }) => {
                let data = Json::obj(vec![
                    ("finish_reason", Json::Str(finish_reason.into())),
                    ("prompt_tokens", Json::Num(usage.prompt_tokens as f64)),
                    ("generated_tokens", Json::Num(usage.completion_tokens as f64)),
                    ("usage", usage.to_json()),
                    ("text", Json::Str(String::from_utf8_lossy(&text).into_owned())),
                ]);
                return http::write_sse_event(w, "done", &data.to_string_compact());
            }
            Ok(StreamEvent::Failed { request_id, message }) => {
                let data = Json::obj(vec![
                    ("error", Json::Str(message)),
                    ("type", Json::Str("engine_error".into())),
                    ("request_id", Json::Num(request_id as f64)),
                ]);
                return http::write_sse_event(w, "error", &data.to_string_compact());
            }
            Err(RecvTimeoutError::Timeout) => http::write_sse_comment(w, "ping")?,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    let data = Json::obj(vec![("error", Json::Str("stream interrupted".into()))]);
    http::write_sse_event(w, "error", &data.to_string_compact())
}

/// Non-streamed generation: collect the same event stream into one JSON
/// response (token-identical to streaming — both read the same channel).
fn respond_generate(
    w: &mut TcpStream,
    handle: StreamHandle,
    keep_alive: bool,
) -> std::io::Result<()> {
    let rid = handle.id.to_string();
    let mut tokens: Vec<u8> = Vec::new();
    for ev in handle.rx.iter() {
        match ev {
            StreamEvent::Token(tok) => tokens.push(tok),
            StreamEvent::Failed { request_id, message } => {
                return write_engine_error(w, request_id, &message, keep_alive)
            }
            StreamEvent::Done { finish_reason, usage } => {
                let body = Json::obj(vec![
                    ("text", Json::Str(String::from_utf8_lossy(&tokens).into_owned())),
                    (
                        "tokens",
                        Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                    ),
                    ("finish_reason", Json::Str(finish_reason.into())),
                    ("prompt_tokens", Json::Num(usage.prompt_tokens as f64)),
                    ("generated_tokens", Json::Num(usage.completion_tokens as f64)),
                    ("usage", usage.to_json()),
                ]);
                return http::write_response(
                    w,
                    200,
                    "application/json",
                    &[("X-Request-Id", &rid)],
                    body.to_string_compact().as_bytes(),
                    keep_alive,
                );
            }
        }
    }
    http::write_error(w, 500, "stream interrupted", keep_alive)
}

fn parse_score(body: &[u8]) -> Result<Vec<u8>, String> {
    let text =
        std::str::from_utf8(body).map_err(|_| "request body is not valid UTF-8".to_string())?;
    let json = Json::parse(text).map_err(|e| format!("malformed JSON body: {e}"))?;
    let tokens: Vec<u8> = if let Some(Json::Str(t)) = json.get("text") {
        t.as_bytes().to_vec()
    } else if let Some(arr) = json.get("tokens").and_then(Json::as_arr) {
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            let n = v
                .as_f64()
                .filter(|n| (0.0..=255.0).contains(n) && n.fract() == 0.0)
                .ok_or("'tokens' entries must be integers in 0..=255")?;
            out.push(n as u8);
        }
        out
    } else {
        return Err("provide a string field 'text' or a byte array 'tokens'".into());
    };
    if tokens.len() < 2 {
        return Err("need at least 2 tokens to score next-token log-probs".into());
    }
    if tokens.len() > MAX_SCORE_TOKENS {
        return Err(format!(
            "sequence of {} tokens exceeds the scoring cap of {MAX_SCORE_TOKENS}",
            tokens.len()
        ));
    }
    Ok(tokens)
}

/// `/v1/score`: teacher-forced next-token log-probs through the scoring
/// batcher (concurrent requests share fused batched dispatches).
fn handle_score(
    w: &mut TcpStream,
    state: &ConnState,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    let tokens = match parse_score(body) {
        Ok(t) => t,
        Err(msg) => return http::write_error(w, 400, &msg, keep_alive),
    };
    let logits = match state.score.score(tokens.clone()) {
        Ok(m) => m,
        Err(e) => return http::write_error(w, 500, &format!("scoring failed: {e}"), keep_alive),
    };
    state.metrics.score_requests.fetch_add(1, Ordering::Relaxed);
    let mut logprobs = Vec::with_capacity(tokens.len() - 1);
    let mut nll = 0.0f64;
    for p in 0..tokens.len() - 1 {
        let lp = log_prob(logits.row(p), tokens[p + 1]);
        nll -= lp;
        logprobs.push(lp);
    }
    let mean_nll = nll / logprobs.len() as f64;
    let body = Json::obj(vec![
        ("tokens", Json::Num(tokens.len() as f64)),
        ("logprobs", Json::Arr(logprobs.into_iter().map(Json::Num).collect())),
        ("mean_nll", Json::Num(mean_nll)),
        ("ppl", Json::Num(mean_nll.exp())),
    ]);
    http::write_response(
        w,
        200,
        "application/json",
        &[],
        body.to_string_compact().as_bytes(),
        keep_alive,
    )
}

static INTERRUPTED: AtomicBool = AtomicBool::new(false);

/// Route SIGINT/SIGTERM to a flag the serve loop polls, so Ctrl-C drains
/// live slots instead of killing mid-decode. Raw `signal(2)` through the
/// platform libc that is already linked by std — no crate needed.
#[cfg(unix)]
fn install_interrupt_handler() {
    extern "C" fn on_signal(_sig: i32) {
        INTERRUPTED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

#[cfg(not(unix))]
fn install_interrupt_handler() {
    // No signal routing off unix; the process runs until killed.
}

/// Blocking CLI entry point for `sinq serve --listen`: build the engine,
/// serve until SIGINT/SIGTERM, drain, report.
pub fn run(spec: &BackendSpec, opts: &ServeOpts) -> anyhow::Result<()> {
    let be = Arc::new(backend::build_native(spec)?);
    println!(
        "native engine ready: model '{}', {} quantized linears, simd kernel '{}', \
         {} worker threads, kv-bits {}",
        be.cfg.name,
        be.quantized_layer_count(),
        simd::kernel_name(),
        be.threads,
        be.kv_bits().bits()
    );
    if let Some(report) = be.quant_report() {
        println!("{}", report.summary_line());
    }
    if crate::obs::profiler::enabled() {
        println!("per-phase decode profiling enabled (SINQ_PROFILE=1): see /v1/stats");
    }
    if opts.drift_sample > 0 {
        println!(
            "drift sentinel enabled: recomputing 1 in {} decode steps on the scalar path \
             (see /metrics and /v1/stats)",
            opts.drift_sample
        );
    }
    if crate::obs::fault::armed() {
        println!(
            "fault injection armed (SINQ_FAULTS): {}",
            crate::obs::fault::list_armed().join(",")
        );
    }
    let server = Server::start_with_backend(be, opts)?;
    println!(
        "listening on http://{} ({} slots x {} KV positions, page pool {} x {}-position pages, \
         max queue {})",
        server.addr,
        opts.max_batch.max(1),
        opts.max_context.max(1),
        server.metrics.kv_pages_total.load(Ordering::Relaxed),
        server.metrics.kv_page_size.load(Ordering::Relaxed),
        opts.max_queue
    );
    println!(
        "endpoints: POST /v1/generate  POST /v1/completions  POST /v1/score  GET /healthz  \
         GET /metrics  GET /v1/stats  GET /debug/trace"
    );

    install_interrupt_handler();
    while !INTERRUPTED.load(Ordering::SeqCst) {
        thread::sleep(Duration::from_millis(100));
    }
    println!("\ninterrupt received: draining live slots ...");
    let stats = server.shutdown();
    println!(
        "served {} generation requests ({} completed, {} tokens) and {} scoring requests",
        stats.gen_requests, stats.gen_completed, stats.gen_tokens, stats.score.requests
    );
    Ok(())
}
