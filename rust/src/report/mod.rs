//! Table/figure emitters: pretty text tables shaped like the paper's, plus
//! machine-readable JSON-lines sidecars for EXPERIMENTS.md regeneration.

pub mod tables;

use crate::util::json::Json;
use std::fmt::Write as _;

/// A simple column-aligned table printer.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n## {}", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, "{c:w$} | ");
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "{}", line(&sep, &widths));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// JSON representation for the results sidecar.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            ("headers", Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect())),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Append to `results.jsonl` next to the artifacts.
    pub fn dump(&self, art_dir: &str) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(format!("{art_dir}/results.jsonl"))?;
        writeln!(f, "{}", self.to_json().to_string_compact())
    }
}

/// Format a float to a fixed number of decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("Demo", &["Method", "Wiki2"]);
        t.row(vec!["rtn".into(), "32.43".into()]);
        t.row(vec!["sinq (ours)".into(), "22.39".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "aligned widths");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
