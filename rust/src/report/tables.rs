//! Regeneration of every table and figure in the paper's evaluation
//! (experiment index in DESIGN.md §5). Each `table_*` function loads the
//! trained family from `artifacts/` (or a deterministic synthetic stand-in
//! on the native backend), runs the quantizer zoo, evaluates through the
//! [`crate::backend::InferenceBackend`] trait, and prints a paper-shaped
//! table (also appended to `artifacts/results.jsonl`).
//!
//! The [`Ctx`] carries the resolved backend: on `native` the whole sweep is
//! artifact-free (fused-kernel engine, synthetic model/corpus fallbacks);
//! on `pjrt` the evaluations execute the AOT artifacts as before. Tables 5
//! and 6 time PJRT-compiled Pallas kernels and therefore still require
//! `--backend pjrt` + `make artifacts`; [`Ctx::rt`] reports that clearly.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::backend::{self, BackendKind, InferenceBackend, NativeBackend};
use crate::coordinator::pipeline::{self, PipelineOpts};
use crate::coordinator::scheduler::{self, ScheduleOpts};
use crate::data::{qa, Corpus};
use crate::eval::{flips, pareto::ParetoPoint, ppl, r2, recon};
use crate::fmt::gguf;
use crate::fmt::grids::Grid;
use crate::model::{memory, ModelConfig, ModelWeights, QuantizedModel};
use crate::quant::{AuxPrecision, Method, QuantConfig};
use crate::report::{f, Table};
use crate::runtime::{PjrtForward, PjrtRuntime};
use crate::tensor::Matrix;

/// Shared context for all tables.
pub struct Ctx {
    pub art_dir: String,
    /// Resolved engine the evaluations dispatch through.
    pub backend: BackendKind,
    /// Present only on the PJRT backend (tables 5/6 + pjrt evaluations).
    rt: Option<PjrtRuntime>,
    pub eval_windows: usize,
    pub qa_tasks: usize,
    pub seq: usize,
    pub fast: bool,
}

impl Ctx {
    /// Auto-probing constructor: PJRT when artifacts + a usable client
    /// exist, otherwise the artifact-free native engine.
    pub fn new(art_dir: &str, fast: bool) -> anyhow::Result<Ctx> {
        Ctx::with_backend(art_dir, fast, BackendKind::Auto)
    }

    /// Construct for an explicit backend (`Auto` probes, see
    /// [`backend::resolve`]).
    pub fn with_backend(art_dir: &str, fast: bool, kind: BackendKind) -> anyhow::Result<Ctx> {
        let resolved = backend::resolve(kind, art_dir);
        let rt = match resolved {
            BackendKind::Pjrt => Some(PjrtRuntime::cpu(art_dir)?),
            _ => None,
        };
        Ok(Ctx {
            art_dir: art_dir.to_string(),
            backend: resolved,
            rt,
            eval_windows: if fast { 8 } else { 32 },
            qa_tasks: if fast { 24 } else { 60 },
            seq: 128,
            fast,
        })
    }

    /// The PJRT runtime, for experiments that execute AOT-compiled Pallas
    /// kernels directly (tables 5/6); errors with a pointer to `--backend
    /// pjrt` when the context runs the native engine.
    pub fn rt(&self) -> anyhow::Result<&PjrtRuntime> {
        self.rt.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "this experiment times AOT PJRT artifacts and cannot run on the '{}' \
                 backend; run `make artifacts` and pass --backend pjrt",
                self.backend.name()
            )
        })
    }

    pub fn load_model(&self, name: &str) -> anyhow::Result<ModelWeights> {
        match self.backend {
            // Artifact-free sweep: fall back to a deterministic synthetic
            // model (with a notice) when no checkpoint exists.
            BackendKind::Native => scheduler::load_or_synthetic_checked(&self.art_dir, name, 42),
            _ => scheduler::load_family_member(&self.art_dir, name),
        }
    }

    pub fn corpus(&self, kind: &str) -> anyhow::Result<Corpus> {
        match self.backend {
            BackendKind::Native => Ok(Corpus::load_or_synthetic(&self.art_dir, kind, "eval")),
            _ => Corpus::load(&self.art_dir, kind, "eval"),
        }
    }

    pub fn calib_sample(&self) -> anyhow::Result<Vec<u8>> {
        // Calibration data comes from the *training* distribution.
        let c = match self.backend {
            BackendKind::Native => Corpus::load_or_synthetic(&self.art_dir, "wiki", "train"),
            _ => Corpus::load(&self.art_dir, "wiki", "train")?,
        };
        Ok(c.data[..(6 * self.seq).min(c.data.len())].to_vec())
    }

    /// Scoring engine over a set of effective weights, on whichever backend
    /// the context resolved — the one dispatch point every perplexity and
    /// flip evaluation goes through.
    pub fn forward_engine(
        &self,
        cfg: &ModelConfig,
        weights: &BTreeMap<String, Matrix>,
        vectors: &BTreeMap<String, Vec<f32>>,
    ) -> anyhow::Result<Box<dyn InferenceBackend>> {
        match self.backend {
            BackendKind::Native => Ok(Box::new(NativeBackend::from_parts(cfg, weights, vectors))),
            BackendKind::Pjrt => Ok(Box::new(PjrtForward::new(self.rt()?, cfg, weights, vectors)?)),
            BackendKind::Auto => unreachable!("Ctx::with_backend resolves auto"),
        }
    }

    /// Perplexity of effective weights through the selected backend.
    /// Dispatches via the [`crate::backend::InferenceBackend`] trait, which
    /// batches windows `max_batch` at a time.
    pub fn ppl_eff(
        &self,
        mw: &ModelWeights,
        eff: &BTreeMap<String, Matrix>,
        vectors: &BTreeMap<String, Vec<f32>>,
        kind: &str,
    ) -> anyhow::Result<f64> {
        let mut fwd = self.forward_engine(&mw.cfg, eff, vectors)?;
        let corpus = self.corpus(kind)?;
        ppl::perplexity_backend(&mut *fwd, &corpus, self.seq, self.eval_windows)
    }

    /// FP baseline perplexity.
    pub fn ppl_fp(&self, mw: &ModelWeights, kind: &str) -> anyhow::Result<f64> {
        self.ppl_eff(mw, &mw.tensors, &mw.vectors, kind)
    }

    /// Quantize + both-corpora perplexity + memory.
    pub fn eval_config(
        &self,
        mw: &ModelWeights,
        cfg: &QuantConfig,
        no_overhead: bool,
    ) -> anyhow::Result<EvalRow> {
        let calib = if cfg.method.needs_calibration() {
            Some(self.calib_sample()?)
        } else {
            None
        };
        let opts = PipelineOpts {
            schedule: ScheduleOpts { threads: 2, calib_sample: calib, verbose: false },
            no_overhead,
        };
        let (qm, secs) = pipeline::run(mw, cfg, &opts)?;
        let eff = qm.effective_weights();
        let wiki = self.ppl_eff(mw, &eff, &qm.fvectors, "wiki")?;
        let c4 = self.ppl_eff(mw, &eff, &qm.fvectors, "c4")?;
        Ok(EvalRow {
            mem_gb: memory::gb(memory::quantized_total_bytes(&qm, 4, self.seq)),
            wiki,
            c4,
            quant_secs: secs,
            qm,
        })
    }
}

pub struct EvalRow {
    pub mem_gb: f64,
    pub wiki: f64,
    pub c4: f64,
    pub quant_secs: f64,
    pub qm: QuantizedModel,
}

fn mb(gb: f64) -> String {
    f(gb * 1000.0, 2) // family models are MB-scale; report MB for legibility
}

// ======================================================================
// Table 1 — uncalibrated uniform PTQ (ppl + memory)
// ======================================================================

pub fn table1(ctx: &Ctx, models: &[&str]) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table 1 — Weight-only uncalibrated uniform PTQ (ppl ↓, Mem MB)",
        &["Bits", "Method", "Model", "Mem", "Wiki2", "C4"],
    );
    for name in models {
        let mw = ctx.load_model(name)?;
        let base_mem = memory::gb(
            memory::baseline_bytes(&mw.cfg) + memory::activation_bytes(&mw.cfg, 4, ctx.seq),
        );
        let wiki = ctx.ppl_fp(&mw, "wiki")?;
        let c4 = ctx.ppl_fp(&mw, "c4")?;
        t.row(vec![
            "16".into(), "original (bf16)".into(), name.to_string(),
            mb(base_mem), f(wiki, 2), f(c4, 2),
        ]);
        for bits in [3u32, 4] {
            for method in [Method::Rtn, Method::HadamardRtn, Method::Hqq, Method::Sinq] {
                let cfg = QuantConfig::new(method, bits);
                let row = ctx.eval_config(&mw, &cfg, false)?;
                t.row(vec![
                    bits.to_string(), method.name().to_string(), name.to_string(),
                    mb(row.mem_gb), f(row.wiki, 2), f(row.c4, 2),
                ]);
            }
        }
    }
    Ok(t)
}

// ======================================================================
// Table 2 / Table 14 — flip rates and accuracy on QA suites
// ======================================================================

pub fn table2(ctx: &Ctx, models: &[&str]) -> anyhow::Result<(Table, Table)> {
    let suites = ["continuation", "plausibility", "topic"];
    let mut t_flip = Table::new(
        "Table 2 — Flip rates (%) ↓ (continuation≈HellaSwag, plausibility≈PIQA, topic≈MMLU)",
        &["Setting", "Bits", "Method", "Model", "cont.", "plaus.", "topic", "Avg"],
    );
    let mut t_acc = Table::new(
        "Table 14 — Accuracy (%) ↑ on the same suites",
        &["Setting", "Bits", "Method", "Model", "cont.", "plaus.", "topic", "Avg"],
    );

    for name in models {
        let mw = ctx.load_model(name)?;
        // FP predictions per suite.
        let mut fp_preds = Vec::new();
        let mut tasks_by_suite = Vec::new();
        {
            let mut fwd = ctx.forward_engine(&mw.cfg, &mw.tensors, &mw.vectors)?;
            for (si, s) in suites.iter().enumerate() {
                let tasks = qa::suite(s, ctx.qa_tasks, 1000 + si as u64);
                fp_preds.push(flips::predictions(&mut fwd, &tasks)?);
                tasks_by_suite.push(tasks);
            }
        }
        // FP accuracy row.
        let accs: Vec<f64> = fp_preds
            .iter()
            .zip(&tasks_by_suite)
            .map(|(p, t)| flips::accuracy(p, t))
            .collect();
        t_acc.row(vec![
            "baseline".into(), "16".into(), "original".into(), name.to_string(),
            f(accs[0], 1), f(accs[1], 1), f(accs[2], 1),
            f(accs.iter().sum::<f64>() / 3.0, 1),
        ]);

        let calib_free: Vec<(u32, Method, Option<Grid>)> = vec![
            (3, Method::Rtn, None),
            (3, Method::HadamardRtn, None),
            (3, Method::Hqq, None),
            (3, Method::Sinq, None),
            (4, Method::Rtn, None),
            (4, Method::BnB, Some(Grid::fp4())),
            (4, Method::BnB, Some(Grid::nf4())),
            (4, Method::HadamardRtn, None),
            (4, Method::Hqq, None),
            (4, Method::Sinq, None),
        ];
        let calibrated: Vec<(u32, Method, Option<Grid>)> = vec![
            (3, Method::Gptq, None),
            (3, Method::HadamardGptq, None),
            (3, Method::ASinq, None),
            (4, Method::Gptq, None),
            (4, Method::HadamardGptq, None),
            (4, Method::Awq, None),
            (4, Method::ASinq, None),
        ];
        for (setting, configs) in [("calib-free", calib_free), ("calibrated", calibrated)] {
            for (bits, method, grid) in configs {
                if ctx.fast && bits == 3 && method != Method::Sinq && method != Method::Rtn {
                    continue;
                }
                let mut cfg = QuantConfig::new(method, bits);
                let grid_label = match &grid {
                    Some(g) => {
                        cfg = cfg.with_grid(g.clone());
                        if matches!(g, Grid::Table { name: "fp4", .. }) { " (fp4)" } else { " (nf4)" }
                    }
                    None => "",
                };
                let row = ctx.eval_config(&mw, &cfg, false)?;
                let eff = row.qm.effective_weights();
                let mut fwd = ctx.forward_engine(&mw.cfg, &eff, &row.qm.fvectors)?;
                let mut frates = Vec::new();
                let mut qaccs = Vec::new();
                for (si, tasks) in tasks_by_suite.iter().enumerate() {
                    let preds = flips::predictions(&mut fwd, tasks)?;
                    frates.push(flips::flip_rate(&fp_preds[si], &preds));
                    qaccs.push(flips::accuracy(&preds, tasks));
                }
                let label = format!("{}{grid_label}", method.name());
                t_flip.row(vec![
                    setting.into(), bits.to_string(), label.clone(), name.to_string(),
                    f(frates[0], 2), f(frates[1], 2), f(frates[2], 2),
                    f(frates.iter().sum::<f64>() / 3.0, 2),
                ]);
                t_acc.row(vec![
                    setting.into(), bits.to_string(), label, name.to_string(),
                    f(qaccs[0], 1), f(qaccs[1], 1), f(qaccs[2], 1),
                    f(qaccs.iter().sum::<f64>() / 3.0, 1),
                ]);
            }
        }
    }
    Ok((t_flip, t_acc))
}

// ======================================================================
// Table 3 — non-uniform 4-bit
// ======================================================================

pub fn table3(ctx: &Ctx, models: &[&str]) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table 3 — Uncalibrated non-uniform 4-bit PTQ (ppl ↓)",
        &["Method", "Model", "Mem", "Wiki2", "C4"],
    );
    for name in models {
        let mw = ctx.load_model(name)?;
        let configs: Vec<(&str, QuantConfig)> = vec![
            ("bnb (fp4)", QuantConfig::new(Method::BnB, 4).with_grid(Grid::fp4())),
            ("bnb (nf4)", QuantConfig::new(Method::BnB, 4).with_grid(Grid::nf4())),
            ("higgs (non-uniform)", QuantConfig::new(Method::Higgs, 4)),
            ("sinq (nf4)", QuantConfig::new(Method::Sinq, 4).with_grid(Grid::nf4())),
            ("sinq (uniform)", QuantConfig::new(Method::Sinq, 4)),
        ];
        for (label, cfg) in configs {
            let row = ctx.eval_config(&mw, &cfg, false)?;
            t.row(vec![
                label.into(), name.to_string(), mb(row.mem_gb), f(row.wiki, 2), f(row.c4, 2),
            ]);
        }
    }
    Ok(t)
}

// ======================================================================
// Table 4 — calibrated PTQ
// ======================================================================

pub fn table4(ctx: &Ctx, models: &[&str]) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table 4 — Calibrated PTQ vs calibration-free SINQ (ppl ↓)",
        &["Bits", "Method", "Model", "Mem", "Wiki2", "C4"],
    );
    for name in models {
        let mw = ctx.load_model(name)?;
        for bits in [3u32, 4] {
            let mut configs: Vec<(&str, QuantConfig)> = vec![
                ("gptq", QuantConfig::new(Method::Gptq, bits).with_aux(AuxPrecision::I8)),
                ("hadamard+gptq", QuantConfig::new(Method::HadamardGptq, bits).with_aux(AuxPrecision::I8)),
                ("a-sinq", QuantConfig::new(Method::ASinq, bits).with_aux(AuxPrecision::I8)),
                ("sinq (calibration-free)", QuantConfig::new(Method::Sinq, bits)),
            ];
            if bits == 4 {
                configs.insert(2, ("awq", QuantConfig::new(Method::Awq, 4).with_aux(AuxPrecision::I8)));
            }
            for (label, cfg) in configs {
                let row = ctx.eval_config(&mw, &cfg, false)?;
                t.row(vec![
                    bits.to_string(), label.into(), name.to_string(),
                    mb(row.mem_gb), f(row.wiki, 2), f(row.c4, 2),
                ]);
            }
        }
    }
    Ok(t)
}

// ======================================================================
// Table 5 — second-scale kernel overhead (dqmm artifacts)
// ======================================================================

pub fn table5(ctx: &Ctx) -> anyhow::Result<Table> {
    use crate::runtime::client::{lit_f32, lit_i8};
    let mut t = Table::new(
        "Table 5 — Dual-scale overhead of the fused dequant-matmul kernel",
        &["B", "D", "g(x) [ms]", "g(x·t) [ms]", "Overhead"],
    );
    let rt = ctx.rt()?;
    let mut rng = crate::tensor::Rng::new(5);
    for b in [1usize, 64] {
        for d in [1024usize, 2048] {
            let mut times = [0.0f64; 2];
            for (vi, dual) in [false, true].iter().enumerate() {
                let suffix = if *dual { "_dual" } else { "" };
                let exe = rt.load(&format!("dqmm_b{b}_d{d}{suffix}.hlo.txt"))?;
                let x: Vec<f32> = (0..b * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let codes: Vec<u8> = (0..d * d).map(|_| (rng.next_u64() & 15) as u8).collect();
                let ng = d / 64;
                let scales: Vec<f32> = (0..d * ng).map(|_| 0.01).collect();
                let shifts: Vec<f32> = vec![-7.5; d * ng];
                let tvec: Vec<f32> = (0..d).map(|_| 1.0 + rng.uniform() as f32).collect();
                // jax drops unused parameters at lowering: the single-scale
                // variant's artifact has no `t` argument.
                let mut args = vec![
                    lit_f32(&[b, d], &x)?,
                    lit_i8(&[d, d], &codes)?,
                    lit_f32(&[d, ng], &scales)?,
                    lit_f32(&[d, ng], &shifts)?,
                ];
                if *dual {
                    args.push(lit_f32(&[d], &tvec)?);
                }
                // Warmup + timed runs.
                for _ in 0..3 {
                    let _ = exe.execute(&args).map_err(|e| anyhow::anyhow!("{e}"))?;
                }
                let iters = if ctx.fast { 5 } else { 20 };
                let t0 = Instant::now();
                for _ in 0..iters {
                    let _ = exe.execute(&args).map_err(|e| anyhow::anyhow!("{e}"))?;
                }
                times[vi] = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
            }
            let overhead = 100.0 * (times[1] - times[0]) / times[0];
            t.row(vec![
                b.to_string(), d.to_string(), f(times[0], 3), f(times[1], 3),
                format!("{}%", f(overhead, 1)),
            ]);
        }
    }
    Ok(t)
}

// ======================================================================
// Table 6 — end-to-end decode throughput (serving loop)
// ======================================================================

pub fn table6(ctx: &Ctx, models: &[&str]) -> anyhow::Result<Table> {
    use crate::runtime::PjrtDecoder;
    let mut t = Table::new(
        "Table 6 — Decode throughput, batch 1, ctx 256 → gen 512 (tokens/s ↑)",
        &["Model", "Variant", "Prefill tok/s", "Decode tok/s", "Speedup"],
    );
    let rt = ctx.rt()?;
    let gen = if ctx.fast { 64 } else { 512 };
    let ctx_len = if ctx.fast { 64 } else { 256 };
    for name in models {
        let mw = ctx.load_model(name)?;
        let prompt: Vec<u8> = ctx.corpus("wiki")?.data[..ctx_len].to_vec();

        // FP baseline.
        let mut dec = PjrtDecoder::new_fp(rt, &mw.cfg, &mw.tensors, &mw.vectors)?;
        let t0 = Instant::now();
        for &b in &prompt {
            let _ = dec.step(b)?;
        }
        let prefill_fp = prompt.len() as f64 / t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let _ = dec.generate(&[], 0); // no-op guard
        let mut last = dec.step(prompt[prompt.len() - 1])?;
        for _ in 0..gen - 1 {
            let next = last
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u8;
            last = dec.step(next)?;
        }
        let decode_fp = gen as f64 / t0.elapsed().as_secs_f64();
        t.row(vec![
            name.to_string(), "fp32 (W16A16 analogue)".into(),
            f(prefill_fp, 0), f(decode_fp, 0), "1.0x".into(),
        ]);

        // W4 (SINQ) variant — only lowered for tiny/small.
        let qcfg = QuantConfig::new(Method::Sinq, 4).with_aux(AuxPrecision::F32);
        let qm = scheduler::quantize_simple(&mw, &qcfg, None)?;
        match PjrtDecoder::new_w4(rt, &mw.cfg, &qm.layers, &qm.fweights, &qm.fvectors) {
            Ok(mut dec) => {
                let t0 = Instant::now();
                for &b in &prompt {
                    let _ = dec.step(b)?;
                }
                let prefill_w4 = prompt.len() as f64 / t0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                let mut last = dec.step(prompt[prompt.len() - 1])?;
                for _ in 0..gen - 1 {
                    let next = last
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0 as u8;
                    last = dec.step(next)?;
                }
                let decode_w4 = gen as f64 / t0.elapsed().as_secs_f64();
                t.row(vec![
                    name.to_string(), "sinq W4A16 (Pallas dequant-matmul)".into(),
                    f(prefill_w4, 0), f(decode_w4, 0),
                    format!("{}x", f(decode_w4 / decode_fp, 2)),
                ]);
            }
            Err(e) => {
                t.row(vec![
                    name.to_string(), "sinq W4A16".into(), "-".into(), "-".into(),
                    format!("n/a ({e})"),
                ]);
            }
        }
    }
    Ok(t)
}

// ======================================================================
// Table 7 — reasoning (arith chains): accuracy + trace length
// ======================================================================

pub fn table7(ctx: &Ctx, model: &str) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table 7 — Reasoning (addition chains ≈ AIME): acc ↑, trace tokens",
        &["Method", "Acc (%)", "Flip (%)", "Trace tok"],
    );
    let mw = ctx.load_model(model)?;
    let tasks = qa::suite("arith", ctx.qa_tasks, 77);

    let trace_prompts: Vec<Vec<u8>> = tasks
        .iter()
        .take(if ctx.fast { 4 } else { 12 })
        .map(|task| task.prompt.clone())
        .collect();

    let eval = |eff: &BTreeMap<String, Matrix>,
                    vecs: &BTreeMap<String, Vec<f32>>|
     -> anyhow::Result<(Vec<usize>, f64)> {
        let mut fwd = ctx.forward_engine(&mw.cfg, eff, vecs)?;
        let preds = flips::predictions(&mut fwd, &tasks)?;
        let mut total = 0usize;
        for p in &trace_prompts {
            let out = flips::generate_greedy(&mut fwd, p, 24, Some(b'.'))?;
            total += out.len();
        }
        Ok((preds, total as f64 / trace_prompts.len() as f64))
    };

    let (fp_preds, fp_trace) = eval(&mw.tensors, &mw.vectors)?;
    t.row(vec![
        "original (fp)".into(), f(flips::accuracy(&fp_preds, &tasks), 1), "0.00".into(),
        f(fp_trace, 1),
    ]);
    for (label, cfg) in [
        ("rtn", QuantConfig::new(Method::Rtn, 4)),
        ("bnb (nf4)", QuantConfig::new(Method::BnB, 4).with_grid(Grid::nf4())),
        ("hadamard+rtn", QuantConfig::new(Method::HadamardRtn, 4)),
        ("hqq", QuantConfig::new(Method::Hqq, 4)),
        ("sinq", QuantConfig::new(Method::Sinq, 4)),
    ] {
        let row = ctx.eval_config(&mw, &cfg, false)?;
        let eff = row.qm.effective_weights();
        let (preds, trace) = eval(&eff, &row.qm.fvectors)?;
        t.row(vec![
            label.into(), f(flips::accuracy(&preds, &tasks), 1),
            f(flips::flip_rate(&fp_preds, &preds), 2), f(trace, 1),
        ]);
    }
    Ok(t)
}

// ======================================================================
// Table 8 — no-overhead SINQ
// ======================================================================

pub fn table8(ctx: &Ctx, models: &[&str]) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table 8 — No-overhead SINQ variant (4-bit, ppl ↓)",
        &["Method", "Model", "Mem", "Wiki2", "C4"],
    );
    for name in models {
        let mw = ctx.load_model(name)?;
        for (label, method, noov) in [
            ("hadamard+rtn", Method::HadamardRtn, false),
            ("hqq", Method::Hqq, false),
            ("sinq", Method::Sinq, false),
            ("sinq no-overhead", Method::Sinq, true),
        ] {
            let row = ctx.eval_config(&mw, &QuantConfig::new(method, 4), noov)?;
            t.row(vec![
                label.into(), name.to_string(), mb(row.mem_gb), f(row.wiki, 2), f(row.c4, 2),
            ]);
        }
    }
    Ok(t)
}

// ======================================================================
// Table 9 — GGUF Q4_0 / Q3_K_S ± no-overhead SINQ
// ======================================================================

pub fn table9(ctx: &Ctx, models: &[&str]) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table 9 — GGUF formats ± no-overhead SINQ pre-normalization (ppl ↓)",
        &["Model", "Format", "Wiki2", "bits/weight"],
    );
    for name in models {
        let mw = ctx.load_model(name)?;
        let fp = ctx.ppl_fp(&mw, "wiki")?;
        t.row(vec![name.to_string(), "base (f32)".into(), f(fp, 2), "32".into()]);
        let folded = crate::model::fold::fold_model(&mw, 24, (0.5, 2.0));
        for (fmt_name, bpw) in [("q4_0", gguf::q4_0_bits_per_weight()), ("q3_k_s", gguf::q3_k_bits_per_weight())] {
            for (variant, src) in [("base", &mw), ("no-over. sinq", &folded)] {
                let mut eff = src.tensors.clone();
                for lname in src.cfg.quantizable_names() {
                    let w = &src.tensors[&lname];
                    if w.cols % 256 != 0 && fmt_name == "q3_k_s" {
                        continue; // shape not covered by the super-block format
                    }
                    let deq = if fmt_name == "q4_0" {
                        gguf::q4_0_dequantize(&gguf::q4_0_quantize(w))
                    } else {
                        gguf::q3_k_dequantize(&gguf::q3_k_quantize(w))
                    };
                    eff.insert(lname, deq);
                }
                let ppl = ctx.ppl_eff(&mw, &eff, &src.vectors, "wiki")?;
                t.row(vec![
                    name.to_string(),
                    format!("{variant} + {fmt_name}"),
                    f(ppl, 2),
                    f(bpw, 2),
                ]);
            }
        }
    }
    Ok(t)
}

// ======================================================================
// Table 10 / Fig. 8 — quantization time
// ======================================================================

pub fn table10(ctx: &Ctx, models: &[&str]) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table 10 — Quantization wall time (s, mean ± std over runs)",
        &["Method", "Model", "Mean s", "Std", "vs RTN"],
    );
    let runs = if ctx.fast { 2 } else { 5 };
    for name in models {
        let mw = ctx.load_model(name)?;
        let calib = ctx.calib_sample()?;
        let mut rtn_mean = 0.0f64;
        for (label, method) in [
            ("rtn", Method::Rtn),
            ("hqq", Method::Hqq),
            ("gptq", Method::Gptq),
            ("awq", Method::Awq),
            ("a-sinq", Method::ASinq),
            ("sinq", Method::Sinq),
        ] {
            let cfg = QuantConfig::new(method, 4);
            let opts = PipelineOpts {
                schedule: ScheduleOpts {
                    threads: 1, // timing: single worker for clean numbers
                    calib_sample: method.needs_calibration().then(|| calib.clone()),
                    verbose: false,
                },
                no_overhead: false,
            };
            let mut times = Vec::new();
            for _ in 0..runs {
                let (_, secs) = pipeline::run(&mw, &cfg, &opts)?;
                times.push(secs);
            }
            let mean = times.iter().sum::<f64>() / runs as f64;
            let var =
                times.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / runs as f64;
            if method == Method::Rtn {
                rtn_mean = mean;
            }
            t.row(vec![
                label.into(), name.to_string(), f(mean, 3), f(var.sqrt(), 3),
                format!("{}x", f(mean / rtn_mean, 2)),
            ]);
        }
    }
    Ok(t)
}

// ======================================================================
// Table 16 — CrossQuant comparison (W4A8)
// ======================================================================

pub fn table16(ctx: &Ctx, model: &str) -> anyhow::Result<Table> {
    use crate::eval::RustEngine;
    use crate::model::forward::{Forward, ForwardOpts};
    let mut t = Table::new(
        "Table 16 — CrossQuant vs A-SINQ, W4A8 G128 (ppl ↓)",
        &["Method", "Wiki2"],
    );
    let mw = ctx.load_model(model)?;
    let corpus = ctx.corpus("wiki")?;
    let windows = if ctx.fast { 4 } else { 12 };

    let mut rows: Vec<(String, BTreeMap<String, Matrix>, BTreeMap<String, Vec<f32>>)> = Vec::new();
    rows.push(("original (fp)".into(), mw.tensors.clone(), mw.vectors.clone()));
    for (label, method) in [("crossquant", Method::CrossQuant), ("a-sinq", Method::ASinq)] {
        let cfg = QuantConfig::new(method, 4).with_group(128);
        let qm = scheduler::quantize_simple(&mw, &cfg, Some(&ctx.calib_sample()?))?;
        rows.push((label.into(), qm.effective_weights(), qm.fvectors.clone()));
    }
    for (label, eff, vecs) in &rows {
        // W4A8: the rust forward fake-quantizes activations to 8 bits.
        let mut fwd = Forward::new(&mw.cfg, eff, vecs);
        fwd.opts = ForwardOpts { act_bits: if label.starts_with("original") { None } else { Some(8) } };
        let mut eng = RustEngine { fwd };
        let ppl = ppl::perplexity(&mut eng, &corpus, ctx.seq, windows)?;
        t.row(vec![label.clone(), f(ppl, 2)]);
    }
    Ok(t)
}

// ======================================================================
// Table 17 — codebook methods
// ======================================================================

pub fn table17(ctx: &Ctx, model: &str) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table 17 — Codebook (QuIP#/QTIP-class) vs A-SINQ, 4-bit (ppl ↓)",
        &["Method", "Wiki2", "C4"],
    );
    let mw = ctx.load_model(model)?;
    let fp_w = ctx.ppl_fp(&mw, "wiki")?;
    let fp_c = ctx.ppl_fp(&mw, "c4")?;
    t.row(vec!["baseline (fp)".into(), f(fp_w, 2), f(fp_c, 2)]);
    for (label, cfg) in [
        ("codebook (2D-VQ + incoherence)", QuantConfig::new(Method::Codebook, 4)),
        ("a-sinq", QuantConfig::new(Method::ASinq, 4)),
    ] {
        let row = ctx.eval_config(&mw, &cfg, false)?;
        t.row(vec![label.into(), f(row.wiki, 2), f(row.c4, 2)]);
    }
    Ok(t)
}

// ======================================================================
// Table 18 — HIGGS vs SINQ-NF4 with quantized auxiliaries
// ======================================================================

pub fn table18(ctx: &Ctx, models: &[&str]) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table 18 — HIGGS vs SINQ (NF4), incl. quantized auxiliaries (ppl ↓)",
        &["Method", "Model", "Mem", "Wiki2", "C4"],
    );
    for name in models {
        let mw = ctx.load_model(name)?;
        for (label, cfg) in [
            ("higgs (non-uniform)", QuantConfig::new(Method::Higgs, 4)),
            ("sinq (nf4)", QuantConfig::new(Method::Sinq, 4).with_grid(Grid::nf4())),
            (
                "sinq (nf4, q. aux)",
                QuantConfig::new(Method::Sinq, 4).with_grid(Grid::nf4()).with_aux(AuxPrecision::I8),
            ),
        ] {
            let row = ctx.eval_config(&mw, &cfg, false)?;
            t.row(vec![
                label.into(), name.to_string(), mb(row.mem_gb), f(row.wiki, 2), f(row.c4, 2),
            ]);
        }
    }
    Ok(t)
}

// ======================================================================
// Table 19 — MoE models
// ======================================================================

pub fn table19(ctx: &Ctx) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table 19 — MoE model (switch top-1), 3/4-bit calibration-free (ppl ↓)",
        &["Bits", "Method", "Mem", "Wiki2", "C4"],
    );
    let mw = ctx.load_model("tiny_moe")?;
    let wiki = ctx.ppl_fp(&mw, "wiki")?;
    let c4 = ctx.ppl_fp(&mw, "c4")?;
    let base_mem = memory::gb(
        memory::baseline_bytes(&mw.cfg) + memory::activation_bytes(&mw.cfg, 4, ctx.seq),
    );
    t.row(vec!["16".into(), "original".into(), mb(base_mem), f(wiki, 2), f(c4, 2)]);
    for bits in [3u32, 4] {
        for method in [Method::Rtn, Method::Hqq, Method::Sinq] {
            let row = ctx.eval_config(&mw, &QuantConfig::new(method, bits), false)?;
            t.row(vec![
                bits.to_string(), method.name().into(), mb(row.mem_gb),
                f(row.wiki, 2), f(row.c4, 2),
            ]);
        }
    }
    Ok(t)
}

// ======================================================================
// Fig. 4 / Fig. 5 — Pareto fronts and ablations
// ======================================================================

pub fn pareto_table(ctx: &Ctx, models: &[&str]) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig. 4 — Memory-perplexity points (g ∈ {64,128}; front marked *)",
        &["Model", "Method", "Bits", "g", "Mem", "Wiki2", "Front"],
    );
    let mut points = Vec::new();
    let mut rows_raw = Vec::new();
    for name in models {
        let mw = ctx.load_model(name)?;
        let base_mem = memory::gb(
            memory::baseline_bytes(&mw.cfg) + memory::activation_bytes(&mw.cfg, 4, ctx.seq),
        );
        let fp = ctx.ppl_fp(&mw, "wiki")?;
        points.push(ParetoPoint { label: format!("{name}/bf16"), memory_gb: base_mem, ppl: fp });
        rows_raw.push((name.to_string(), "bf16".to_string(), 16u32, 0usize, base_mem, fp));
        for bits in [3u32, 4, 8] {
            for g in [64usize, 128] {
                for method in [Method::Rtn, Method::Hqq, Method::Sinq] {
                    if ctx.fast && (bits == 8 || g == 128) && method != Method::Sinq {
                        continue;
                    }
                    let cfg = QuantConfig::new(method, bits).with_group(g);
                    let row = ctx.eval_config(&mw, &cfg, false)?;
                    let label = format!("{name}/{}-{bits}b-g{g}", method.name());
                    points.push(ParetoPoint {
                        label: label.clone(), memory_gb: row.mem_gb, ppl: row.wiki,
                    });
                    rows_raw.push((
                        name.to_string(), method.name().to_string(), bits, g, row.mem_gb, row.wiki,
                    ));
                }
            }
        }
    }
    let front = crate::eval::pareto::pareto_front(&points);
    let on_front = |mem: f64, ppl: f64| {
        front.iter().any(|p| (p.memory_gb - mem).abs() < 1e-12 && (p.ppl - ppl).abs() < 1e-12)
    };
    for (model, method, bits, g, mem, ppl) in rows_raw {
        t.row(vec![
            model, method, bits.to_string(), g.to_string(), mb(mem), f(ppl, 2),
            if on_front(mem, ppl) { "*".into() } else { "".into() },
        ]);
    }
    Ok(t)
}

pub fn ablation_table(ctx: &Ctx, models: &[&str]) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig. 5 — Ablations: aux precision (a) and shifts (b), 4-bit SINQ",
        &["Model", "Variant", "Mem", "Wiki2", "C4"],
    );
    for name in models {
        let mw = ctx.load_model(name)?;
        for (label, cfg) in [
            ("aux fp16 + shift", QuantConfig::new(Method::Sinq, 4)),
            ("aux int8 + shift", QuantConfig::new(Method::Sinq, 4).with_aux(AuxPrecision::I8)),
            ("aux fp16, no shift", QuantConfig::new(Method::Sinq, 4).with_shift(false)),
            (
                "aux int8, no shift",
                QuantConfig::new(Method::Sinq, 4).with_aux(AuxPrecision::I8).with_shift(false),
            ),
        ] {
            let row = ctx.eval_config(&mw, &cfg, false)?;
            t.row(vec![
                name.to_string(), label.into(), mb(row.mem_gb), f(row.wiki, 2), f(row.c4, 2),
            ]);
        }
    }
    Ok(t)
}

// ======================================================================
// Figures 2a/2b/2c, 3, 6, 7 — analysis tables
// ======================================================================

pub fn fig2a_table(ctx: &Ctx, models: &[&str]) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig. 2a / Fig. 6 — R² of log(1/σ_col) vs log(μ_x) per layer",
        &["Model", "Layer", "R²(1/σ)", "R²(shuffled)", "R²(t_sinq)"],
    );
    for name in models {
        let mw = ctx.load_model(name)?;
        let sample = ctx.corpus("wiki")?.data[..6 * ctx.seq].to_vec();
        for row in r2::r2_analysis(&mw, &sample, layer_seed(name))? {
            t.row(vec![
                name.to_string(), row.layer, f(row.r2_std, 3), f(row.r2_shuffled, 3),
                f(row.r2_t, 3),
            ]);
        }
    }
    Ok(t)
}

fn layer_seed(name: &str) -> u64 {
    name.bytes().map(|b| b as u64).sum()
}

pub fn fig2b_table(_ctx: &Ctx) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig. 2b — Adam stationarity: σ_col(W) ∝ s_x^slope (paper: −1/2)",
        &["nout", "nin", "steps", "slope", "R²"],
    );
    for (nout, nin, steps) in [(32usize, 64usize, 1200usize), (64, 128, 1500)] {
        let (slope, r2v, _, _) = r2::adam_scaling_experiment(nout, nin, steps, 0xF162);
        t.row(vec![
            nout.to_string(), nin.to_string(), steps.to_string(), f(slope, 3), f(r2v, 3),
        ]);
    }
    Ok(t)
}

pub fn fig2c_fig7_table(ctx: &Ctx, model: &str) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig. 2c / Fig. 7 — Mean row kurtosis of the rounded matrix",
        &["Layer", "original", "naive 1/σ_col", "sinq", "awq", "asinq"],
    );
    let mw = ctx.load_model(model)?;
    let sample = ctx.corpus("wiki")?.data[..6 * ctx.seq].to_vec();
    let layers: Vec<String> = mw
        .cfg
        .quantizable_names()
        .into_iter()
        .filter(|n| n.contains("layers.0") || n.contains("layers.1"))
        .collect();
    for row in recon::kurtosis_analysis(&mw, &sample, &layers)? {
        t.row(vec![
            row.layer, f(row.original, 2), f(row.naive_col, 2), f(row.sinq, 2),
            f(row.awq, 2), f(row.asinq, 2),
        ]);
    }
    Ok(t)
}

pub fn fig3_table(ctx: &Ctx, model: &str) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig. 3 — Matrix vs activation reconstruction error deltas vs RTN (3-bit; − is better)",
        &["Layer", "SINQ Δmatrix", "SINQ Δact", "Hadamard Δmatrix", "Hadamard Δact"],
    );
    let mw = ctx.load_model(model)?;
    let sample = ctx.corpus("wiki")?.data[..6 * ctx.seq].to_vec();
    let layers: Vec<String> = mw
        .cfg
        .quantizable_names()
        .into_iter()
        .filter(|n| n.contains(".wq") || n.contains(".wk") || n.contains(".wv") || n.contains(".wo"))
        .collect();
    let s_rows = recon::recon_analysis(&mw, &sample, &layers, Method::Sinq, 3)?;
    let h_rows = recon::recon_analysis(&mw, &sample, &layers, Method::HadamardRtn, 3)?;
    for (s, h) in s_rows.iter().zip(&h_rows) {
        t.row(vec![
            s.layer.clone(),
            f(s.matrix_delta, 4), f(s.activation_delta, 4),
            f(h.matrix_delta, 4), f(h.activation_delta, 4),
        ]);
    }
    Ok(t)
}

pub fn fig1_table(_ctx: &Ctx) -> anyhow::Result<Table> {
    let (single, dual, _) = recon::dual_scale_demo();
    let mut t = Table::new(
        "Fig. 1 — Dual vs single scaling on a 16×16 structured outlier matrix (3-bit MSE)",
        &["Parameterization", "MSE"],
    );
    t.row(vec!["single scale (RTN)".into(), format!("{single:.5}")]);
    t.row(vec!["dual scale (SINQ)".into(), format!("{dual:.5}")]);
    Ok(t)
}

// ======================================================================
// KV-cache precision study (`sinq analyze kv`)
// ======================================================================

/// Teacher-forced decoder NLL and flips for one KV precision: step every
/// window through a [`NativeDecoder`], scoring each next token from the
/// step logits. Returns (mean NLL, argmax token stream).
fn decoder_nll(
    be: &NativeBackend,
    windows: &[&[u8]],
    kv: crate::backend::KvBits,
) -> anyhow::Result<(f64, Vec<u8>)> {
    use crate::backend::{EngineConfig, NativeDecoder};
    let mut nll = 0.0f64;
    let mut count = 0usize;
    let mut argmaxes = Vec::new();
    for w in windows {
        let cfg = EngineConfig::new().with_max_context(w.len() + 1).with_kv_bits(kv);
        let mut dec = NativeDecoder::with_config(be, &cfg)?;
        for p in 0..w.len() - 1 {
            let logits = dec.step(w[p])?;
            nll -= crate::eval::log_prob(&logits, w[p + 1]);
            count += 1;
            // Same argmax the decoders' greedy picker uses, so the flip
            // column measures exactly what serving would emit.
            argmaxes.push(crate::backend::fwd::argmax(&logits) as u8);
        }
    }
    Ok((nll / count.max(1) as f64, argmaxes))
}

/// `sinq analyze kv` — the serving-side extension of the paper's
/// calibration-free low-precision story: quantize the **decode KV cache**
/// to 8 bits with per-head, per-position scales and measure what it costs.
/// Rows compare `--kv-bits 32` vs `8` per weight format (FP and SINQ
/// 4-bit): teacher-forced decoder perplexity, greedy-argmax flip rate
/// against the f32 cache, and the resident KV bytes per serving slot.
pub fn kv_cache_table(ctx: &Ctx, model: &str) -> anyhow::Result<Table> {
    use crate::backend::{EngineConfig, KvBits, NativeDecoder};
    anyhow::ensure!(
        ctx.backend == BackendKind::Native,
        "the KV-cache study steps the native decoders; rerun with --backend native"
    );
    let mut t = Table::new(
        "KV cache — 8-bit per-head-scaled cache vs f32 (decoder ppl, flips, slot bytes)",
        &["Weights", "KV bits", "Ppl", "Flips vs f32 (%)", "KV KiB/slot", "Shrink"],
    );
    let mw = ctx.load_model(model)?;
    let corpus = ctx.corpus("wiki")?;
    let seq = 48usize.min(ctx.seq);
    let windows = corpus.eval_windows(seq, if ctx.fast { 2 } else { 6 });
    anyhow::ensure!(!windows.is_empty(), "corpus too small for {seq}-token windows");

    let mut backends: Vec<(String, NativeBackend)> = Vec::new();
    backends.push(("fp".into(), NativeBackend::from_weights(&mw)));
    let qm = scheduler::quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None)?;
    backends.push(("sinq-4b".into(), NativeBackend::from_quantized(&qm)));

    for (label, be) in &backends {
        let (nll32, top32) = decoder_nll(be, &windows, KvBits::F32)?;
        let (nll8, top8) = decoder_nll(be, &windows, KvBits::Q8)?;
        let flips = top32.iter().zip(&top8).filter(|(a, b)| a != b).count();
        let flip_pct = 100.0 * flips as f64 / top32.len().max(1) as f64;
        let slot_cfg = EngineConfig::new().with_max_context(seq + 1);
        let bytes32 =
            NativeDecoder::with_config(be, &slot_cfg.with_kv_bits(KvBits::F32))?.kv_bytes();
        let bytes8 =
            NativeDecoder::with_config(be, &slot_cfg.with_kv_bits(KvBits::Q8))?.kv_bytes();
        t.row(vec![
            label.clone(),
            "32".into(),
            f(nll32.exp(), 3),
            "0.0".into(),
            f(bytes32 as f64 / 1024.0, 1),
            "1.0x".into(),
        ]);
        t.row(vec![
            label.clone(),
            "8".into(),
            f(nll8.exp(), 3),
            f(flip_pct, 1),
            f(bytes8 as f64 / 1024.0, 1),
            format!("{:.1}x", bytes32 as f64 / bytes8 as f64),
        ]);
    }
    Ok(t)
}

/// `sinq analyze profile`: the per-layer quantization-quality telemetry the
/// scheduler records while quantizing (the same [`crate::obs::QuantReport`]
/// the serving path exposes at `/v1/stats`) — Sinkhorn iterations to the
/// best iterate, row/col imbalance before/after normalization, per-layer
/// NMSE/MSE, and wall time.
pub fn quant_profile_table(ctx: &Ctx, model: &str) -> anyhow::Result<Table> {
    let mw = ctx.load_model(model)?;
    let cfg = QuantConfig::new(Method::Sinq, 4);
    let (qm, reports) = scheduler::quantize_model(&mw, &cfg, &ScheduleOpts::default())?;
    let report = crate::obs::QuantReport::new(&qm.method, qm.bits, reports);
    let mut t = Table::new(
        &format!(
            "Quantization profile — {model} via {} {}-bit ({})",
            report.method,
            report.bits,
            report.summary_line()
        ),
        &["Layer", "Shape", "BPW", "Sinkhorn iters", "Imbalance init→final", "NMSE", "ms"],
    );
    for l in &report.layers {
        let iters = l.sinkhorn_iters.map(|i| i.to_string()).unwrap_or_else(|| "-".into());
        let imb = match (l.imbalance_initial, l.imbalance_final) {
            (Some(a), Some(b)) => format!("{a:.3} → {b:.3}"),
            _ => "-".into(),
        };
        t.row(vec![
            l.layer.clone(),
            format!("{}x{}", l.rows, l.cols),
            f(l.bits_per_weight, 2),
            iters,
            imb,
            format!("{:.2e}", l.nmse),
            f(l.millis, 1),
        ]);
    }
    Ok(t)
}

/// `sinq analyze trace` — drive a miniature serving scenario through the
/// batch decoder with the flight-recorder journal on, then fold the event
/// stream into per-request timelines: queue wait, prefix reuse, preemption
/// count and stall time, and total latency. The page pool is sized so two
/// concurrent requests cannot share it, guaranteeing the journal captures a
/// preempt → resume cycle and not just the happy path.
pub fn trace_table(ctx: &Ctx, model: &str) -> anyhow::Result<Table> {
    use crate::backend::{BatchDecoder, EngineConfig};
    use crate::obs::{journal, trace};
    anyhow::ensure!(
        ctx.backend == BackendKind::Native,
        "the flight-recorder study steps the native batch decoder; rerun with --backend native"
    );
    let mw = ctx.load_model(model)?;
    let be = NativeBackend::from_weights(&mw);
    // Two 7-page requests through an 8-page pool: the pool runs dry
    // mid-decode and the younger sequence is preempted; the third request
    // queues behind the two slots for a visible queue-wait.
    let cfg = EngineConfig::new()
        .with_max_batch(2)
        .with_max_context(32)
        .with_page_size(4)
        .with_pages(Some(8));
    // Id base far from the serving layer's request counter so the rows are
    // attributable even if the process-global journal has other traffic.
    const ID0: usize = 610_000;
    let reqs: [(&[u8], usize); 3] =
        [(b"first long request" as &[u8], 9), (b"second long one!!", 9), (b"third, queued", 5)];
    let was_on = journal::enabled();
    journal::set_enabled(true);
    let mut dec = BatchDecoder::with_config(&be, &cfg)?;
    for (i, (p, n)) in reqs.iter().enumerate() {
        dec.submit(ID0 + i, p, *n)?;
    }
    let run = dec.run();
    journal::set_enabled(was_on);
    run?;

    let events: Vec<crate::obs::Event> = journal::snapshot(journal::JOURNAL_SLOTS)
        .into_iter()
        .filter(|e| (ID0..ID0 + reqs.len()).contains(&e.id))
        .collect();
    let mut t = Table::new(
        "Flight recorder — per-request timelines from the event journal",
        &[
            "Request",
            "Queue µs",
            "Prefix reuse",
            "Preempts",
            "Preempted µs",
            "Tokens",
            "Total µs",
            "Outcome",
        ],
    );
    for s in trace::summarize(&events) {
        t.row(vec![
            (s.id - ID0).to_string(),
            s.queue_us.to_string(),
            s.prefix_reused.to_string(),
            s.preempts.to_string(),
            s.preempted_us.to_string(),
            s.tokens.to_string(),
            s.total_us.map(|u| u.to_string()).unwrap_or_else(|| "-".into()),
            s.outcome.to_string(),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native_ctx() -> Ctx {
        // `/nonexistent`: no artifacts anywhere, so everything must come
        // from synthetic fallbacks through the native engine.
        Ctx::with_backend("/nonexistent", true, BackendKind::Native).unwrap()
    }

    #[test]
    fn auto_ctx_resolves_native_without_artifacts() {
        let ctx = Ctx::new("/nonexistent", true).unwrap();
        assert_eq!(ctx.backend, BackendKind::Native);
        let err = match ctx.rt() {
            Ok(_) => panic!("native ctx must refuse PJRT-only experiments"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("--backend pjrt"), "unhelpful error: {err}");
    }

    #[test]
    fn native_ctx_scores_perplexity_artifact_free() {
        let ctx = native_ctx();
        let mw = ctx.load_model("pico").unwrap();
        let ppl = ctx.ppl_fp(&mw, "wiki").unwrap();
        assert!(ppl.is_finite() && ppl > 1.0, "nonsense ppl {ppl}");
        // Quantized effective weights score through the same trait path.
        let row = ctx.eval_config(&mw, &QuantConfig::new(Method::Sinq, 4), false).unwrap();
        assert!(row.wiki.is_finite() && row.c4.is_finite());
    }

    #[test]
    fn quant_profile_table_covers_every_layer_with_finite_stats() {
        let ctx = native_ctx();
        let t = quant_profile_table(&ctx, "pico").unwrap();
        let mw = ctx.load_model("pico").unwrap();
        assert_eq!(t.rows.len(), mw.cfg.quantizable_names().len());
        assert!(t.title.contains("mean NMSE"), "summary line missing: {}", t.title);
        for row in &t.rows {
            let nmse: f64 = row[5].parse().unwrap();
            assert!(nmse.is_finite() && nmse > 0.0, "nonsense NMSE row {row:?}");
            let iters: usize = row[3].parse().unwrap();
            assert!(iters < 24, "sinkhorn must report a converged iterate: {row:?}");
        }
    }

    #[test]
    fn kv_cache_table_reports_both_precisions_and_shrink() {
        let ctx = native_ctx();
        let t = kv_cache_table(&ctx, "pico").unwrap();
        assert_eq!(t.rows.len(), 4, "fp + sinq-4b, each at 32 and 8 bits");
        for row in &t.rows {
            let ppl: f64 = row[2].parse().unwrap();
            assert!(ppl.is_finite() && ppl > 1.0, "nonsense ppl row {row:?}");
        }
        // The 8-bit rows must report ≥ 3x smaller slots.
        for row in t.rows.iter().filter(|r| r[1] == "8") {
            let shrink: f64 = row[5].trim_end_matches('x').parse().unwrap();
            assert!(shrink >= 3.0, "kv8 slot only {shrink}x smaller: {row:?}");
        }
    }

    #[test]
    fn native_ctx_runs_flip_predictions() {
        let ctx = native_ctx();
        let mw = ctx.load_model("pico").unwrap();
        let mut fwd = ctx.forward_engine(&mw.cfg, &mw.tensors, &mw.vectors).unwrap();
        let tasks = qa::suite("plausibility", 4, 7);
        let preds = flips::predictions(&mut fwd, &tasks).unwrap();
        assert_eq!(preds.len(), tasks.len());
    }
}
