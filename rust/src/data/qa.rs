//! Synthetic QA and reasoning suites (flip-rate / accuracy substrates for
//! Tables 2, 7, 14 — HellaSwag/PIQA/MMLU/AIME stand-ins, DESIGN.md §3).
//!
//! Each task is a byte prompt plus N candidate continuations scored by total
//! log-likelihood under the model; the *flip* metric (Dutta et al. 2024)
//! compares the argmax option between the full-precision and quantized
//! models and needs no ground truth, while the accuracy metric uses the
//! generator's known correct option. Tasks are built from the same template
//! grammar as the training corpus, so a trained model beats chance.

use crate::tensor::Rng;

// Word lists — mirror python/compile/corpus.py (the training distribution).
pub const NOUNS: &[&str] = &[
    "system", "river", "empire", "theory", "engine", "council", "valley", "method", "garden",
    "signal", "market", "temple", "compiler", "harbor", "museum", "planet", "circuit", "forest",
    "treaty", "sensor", "archive", "bridge", "colony", "dialect", "furnace", "glacier", "habitat",
    "isotope", "journal", "kernel", "lattice", "meadow", "nebula", "orchard", "pigment", "quarry",
    "reactor", "stadium", "tunnel", "vessel", "windmill", "zephyr", "algorithm", "basin",
    "cathedral", "dynamo", "estuary",
];
pub const ADJS: &[&str] = &[
    "ancient", "rapid", "quiet", "northern", "dense", "fragile", "modern", "hollow", "distant",
    "precise", "luminous", "brittle", "coastal", "recursive", "thermal", "nomadic", "austere",
    "vivid", "sturdy", "obscure", "parallel", "fertile", "rugged", "serene", "volatile",
    "compact", "ornate", "humid",
];
pub const VERBS: &[&str] = &[
    "describes", "contains", "governs", "produces", "connects", "absorbs", "predicts",
    "regulates", "transforms", "precedes", "supports", "measures", "encodes", "divides",
    "restores", "observes", "balances", "extends", "records", "compresses",
];
pub const TOPICS: &[&str] = &[
    "history", "geology", "music", "trade", "physics", "language", "agriculture", "navigation",
    "astronomy", "medicine", "weaving", "metallurgy", "cartography", "rhetoric",
];

/// One multiple-choice task.
#[derive(Debug, Clone)]
pub struct QaTask {
    pub prompt: Vec<u8>,
    pub options: Vec<Vec<u8>>,
    pub correct: usize,
}

/// The three QA suites + the reasoning suite.
pub const SUITES: &[&str] = &["continuation", "plausibility", "topic", "arith"];

/// Build a suite of `n` tasks.
pub fn suite(name: &str, n: usize, seed: u64) -> Vec<QaTask> {
    let mut rng = Rng::new(seed ^ 0x5EED_0A11);
    (0..n)
        .map(|_| match name {
            "continuation" => continuation_task(&mut rng),
            "plausibility" => plausibility_task(&mut rng),
            "topic" => topic_task(&mut rng),
            "arith" => arith_task(&mut rng),
            _ => panic!("unknown QA suite '{name}'"),
        })
        .collect()
}

fn pick<'a>(rng: &mut Rng, xs: &'a [&'a str]) -> &'a str {
    xs[rng.below(xs.len())]
}

/// HellaSwag-like: grammatical sentence continuation. The correct option is
/// a noun phrase (matching the training grammar); distractors put a verb /
/// adjective / topic word where a noun belongs.
fn continuation_task(rng: &mut Rng) -> QaTask {
    let (a1, n1, v, t) = (pick(rng, ADJS), pick(rng, NOUNS), pick(rng, VERBS), pick(rng, TOPICS));
    let n2 = pick(rng, NOUNS);
    let prompt = format!("The {a1} {n1} {v} the ");
    let correct_opt = format!("{n2} of {t}.");
    let d1 = format!("{} of {t}.", pick(rng, VERBS));
    let d2 = format!("{} of {t}.", pick(rng, ADJS));
    let d3 = format!("of {} the.", pick(rng, NOUNS));
    shuffle_options(rng, prompt, correct_opt, vec![d1, d2, d3])
}

/// PIQA-like: pick the well-formed sentence over scrambled corruptions.
fn plausibility_task(rng: &mut Rng) -> QaTask {
    let (a1, n1, v, n2, t) =
        (pick(rng, ADJS), pick(rng, NOUNS), pick(rng, VERBS), pick(rng, NOUNS), pick(rng, TOPICS));
    let prompt = "".to_string();
    let correct_opt = format!("The {n1} of {t} is a {a1} {n2} that {v} the {n1}.");
    let d1 = format!("The {v} of {a1} is a {t} {n1} that {n2} the {v}.");
    let d2 = format!("{n2} the a {t} of {v} is {n1} that {a1} the.");
    let d3 = format!("is The {n1} {n1} of a that the {v} {t} {a1}.");
    shuffle_options(rng, prompt, correct_opt, vec![d1, d2, d3])
}

/// MMLU-like: register/topic association — which heading fits the wiki
/// register seen in training ("== Noun topic ==").
fn topic_task(rng: &mut Rng) -> QaTask {
    let n = pick(rng, NOUNS);
    let t = pick(rng, TOPICS);
    let prompt = "== ".to_string();
    // Title-case noun + topic is the trained heading shape.
    let mut title = n.to_string();
    title[..1].make_ascii_uppercase();
    let correct_opt = format!("{title} {t} ==");
    let d1 = format!("{t} {title} ==");
    let d2 = format!("{} {} ==", pick(rng, VERBS), pick(rng, VERBS));
    let d3 = format!("{} {} ==", pick(rng, ADJS), pick(rng, ADJS));
    shuffle_options(rng, prompt, correct_opt, vec![d1, d2, d3])
}

/// AIME stand-in (Table 7): two-step addition chains in the exact format the
/// corpus embeds ("a + b = s1. s1 + c = s2.").
fn arith_task(rng: &mut Rng) -> QaTask {
    let a = 2 + rng.below(40) as i64;
    let b = 2 + rng.below(40) as i64;
    let c = 2 + rng.below(20) as i64;
    let s1 = a + b;
    let s2 = s1 + c;
    let prompt = format!("{a} + {b} = {s1}. {s1} + {c} = ");
    let correct_opt = format!("{s2}.");
    let mut distractors = vec![];
    let mut seen = vec![s2];
    while distractors.len() < 3 {
        let delta = [-10, -2, -1, 1, 2, 10][rng.below(6)];
        let wrong = s2 + delta;
        if wrong > 0 && !seen.contains(&wrong) {
            seen.push(wrong);
            distractors.push(format!("{wrong}."));
        }
    }
    shuffle_options(rng, prompt, correct_opt, distractors)
}

fn shuffle_options(rng: &mut Rng, prompt: String, correct: String, others: Vec<String>) -> QaTask {
    let mut options: Vec<String> = vec![correct.clone()];
    options.extend(others);
    let mut order: Vec<usize> = (0..options.len()).collect();
    rng.shuffle(&mut order);
    let shuffled: Vec<Vec<u8>> =
        order.iter().map(|&i| options[i].clone().into_bytes()).collect();
    let correct_pos = order.iter().position(|&i| i == 0).unwrap();
    QaTask { prompt: prompt.into_bytes(), options: shuffled, correct: correct_pos }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_deterministic_and_well_formed() {
        for name in SUITES {
            let a = suite(name, 20, 7);
            let b = suite(name, 20, 7);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.prompt, y.prompt);
                assert_eq!(x.correct, y.correct);
            }
            for t in &a {
                assert_eq!(t.options.len(), 4, "{name}");
                assert!(t.correct < 4);
                // Options distinct.
                for i in 0..4 {
                    for j in i + 1..4 {
                        assert_ne!(t.options[i], t.options[j], "{name}");
                    }
                }
            }
        }
    }

    #[test]
    fn arith_correct_option_is_the_sum() {
        for t in suite("arith", 50, 3) {
            let p = String::from_utf8(t.prompt.clone()).unwrap();
            // parse "a + b = s1. s1 + c = "
            let seg = p.split(". ").nth(1).unwrap(); // "s1 + c = "
            let s1: i64 = seg.split(" + ").next().unwrap().parse().unwrap();
            let c: i64 =
                seg.split(" + ").nth(1).unwrap().split(" = ").next().unwrap().parse().unwrap();
            let correct = String::from_utf8(t.options[t.correct].clone()).unwrap();
            let s2: i64 = correct.trim_end_matches('.').parse().unwrap();
            assert_eq!(s2, s1 + c, "{p}");
        }
    }

    #[test]
    fn word_lists_match_training_grammar_sizes() {
        // Guard against drift from python/compile/corpus.py.
        assert_eq!(NOUNS.len(), 47);
        assert_eq!(ADJS.len(), 28);
        assert_eq!(VERBS.len(), 20);
        assert_eq!(TOPICS.len(), 14);
    }

    #[test]
    fn different_seeds_differ() {
        let a = suite("continuation", 5, 1);
        let b = suite("continuation", 5, 2);
        assert!(a.iter().zip(&b).any(|(x, y)| x.prompt != y.prompt));
    }
}
