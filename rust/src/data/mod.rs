//! Data layer: corpora (loaded from `artifacts/corpus/`), the byte
//! tokenizer, evaluation batching, and the synthetic QA / reasoning suites.

pub mod corpus;
pub mod qa;

pub use corpus::Corpus;
