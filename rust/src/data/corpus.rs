//! Evaluation corpora: the exact byte streams the models were trained /
//! held out on (written by `python/compile/train.py` into
//! `artifacts/corpus/`), plus windowing into evaluation batches.
//!
//! Tokenization is byte-level (vocab 256) — the tokenizer *is* the identity
//! on bytes, which keeps the Python and Rust pipelines trivially in sync.

use std::path::Path;

/// A byte corpus with sequence-window iteration.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub name: String,
    pub data: Vec<u8>,
}

impl Corpus {
    pub fn load(art_dir: &str, kind: &str, split: &str) -> anyhow::Result<Corpus> {
        let path = Path::new(art_dir).join("corpus").join(format!("{kind}_{split}.bin"));
        let data = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("corpus {}: {e} (run `make artifacts`)", path.display()))?;
        Ok(Corpus { name: format!("{kind}_{split}"), data })
    }

    /// Deterministic non-overlapping evaluation windows of length `seq`,
    /// up to `max_windows`.
    pub fn eval_windows(&self, seq: usize, max_windows: usize) -> Vec<&[u8]> {
        self.data.chunks_exact(seq).take(max_windows).collect()
    }

    /// Total tokens available.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// In-memory corpus for tests.
    pub fn from_bytes(name: &str, data: Vec<u8>) -> Corpus {
        Corpus { name: name.to_string(), data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_windows_partition() {
        let c = Corpus::from_bytes("t", (0..=255u8).cycle().take(1000).collect());
        let w = c.eval_windows(128, 100);
        assert_eq!(w.len(), 7); // 1000 / 128
        assert_eq!(w[0][0], 0);
        assert_eq!(w[1][0], 128u8);
        let w2 = c.eval_windows(128, 3);
        assert_eq!(w2.len(), 3);
    }

    #[test]
    fn loads_artifact_corpora_when_present() {
        // Integration-style: skip silently when artifacts are absent.
        if let Ok(c) = Corpus::load("artifacts", "wiki", "eval") {
            assert!(c.len() > 10_000);
            assert!(c.data.iter().all(|&b| b < 128), "ascii corpus");
        }
    }
}
