//! Evaluation corpora: the exact byte streams the models were trained /
//! held out on (written by `python/compile/train.py` into
//! `artifacts/corpus/`), plus windowing into evaluation batches.
//!
//! Tokenization is byte-level (vocab 256) — the tokenizer *is* the identity
//! on bytes, which keeps the Python and Rust pipelines trivially in sync.

use std::path::Path;

/// A byte corpus with sequence-window iteration.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub name: String,
    pub data: Vec<u8>,
}

impl Corpus {
    pub fn load(art_dir: &str, kind: &str, split: &str) -> anyhow::Result<Corpus> {
        let path = Path::new(art_dir).join("corpus").join(format!("{kind}_{split}.bin"));
        let data = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("corpus {}: {e} (run `make artifacts`)", path.display()))?;
        Ok(Corpus { name: format!("{kind}_{split}"), data })
    }

    /// Deterministic non-overlapping evaluation windows of length `seq`,
    /// up to `max_windows`.
    pub fn eval_windows(&self, seq: usize, max_windows: usize) -> Vec<&[u8]> {
        self.data.chunks_exact(seq).take(max_windows).collect()
    }

    /// Total tokens available.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// In-memory corpus for tests.
    pub fn from_bytes(name: &str, data: Vec<u8>) -> Corpus {
        Corpus { name: name.to_string(), data }
    }

    /// Deterministic synthetic corpus (pseudo-English byte stream) for
    /// artifact-free runs of the native backend.
    pub fn synthetic(name: &str, len: usize, seed: u64) -> Corpus {
        use crate::tensor::Rng;
        const WORDS: [&str; 24] = [
            "the", "quantized", "model", "serves", "tokens", "sinkhorn", "scales", "weight",
            "matrix", "fused", "kernel", "native", "backend", "decode", "cache", "batch",
            "rust", "paper", "low", "bit", "precision", "eval", "fast", "loop",
        ];
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(len + 16);
        while data.len() < len {
            data.extend_from_slice(WORDS[rng.below(WORDS.len())].as_bytes());
            data.push(b' ');
        }
        data.truncate(len);
        Corpus { name: name.to_string(), data }
    }

    /// Load a corpus, falling back to a [`Corpus::synthetic`] stream (with
    /// a notice) when the file is genuinely absent — keeps `serve`/`eval`
    /// on the native backend runnable on a clean machine. A corpus file
    /// that exists but cannot be read is a loud warning, not a silent
    /// substitution, so broken artifacts never masquerade as measurements.
    pub fn load_or_synthetic(art_dir: &str, kind: &str, split: &str) -> Corpus {
        let path = Path::new(art_dir).join("corpus").join(format!("{kind}_{split}.bin"));
        if path.exists() {
            match Corpus::load(art_dir, kind, split) {
                Ok(c) => return c,
                Err(e) => eprintln!(
                    "warning: corpus {} exists but is unreadable ({e}) — \
                     substituting a SYNTHETIC corpus",
                    path.display()
                ),
            }
        } else {
            eprintln!(
                "note: corpus {kind}_{split} not found under {art_dir}/corpus — \
                 using a synthetic corpus"
            );
        }
        Corpus::synthetic(&format!("{kind}_{split}_synthetic"), 64 * 1024, 1234)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_windows_partition() {
        let c = Corpus::from_bytes("t", (0..=255u8).cycle().take(1000).collect());
        let w = c.eval_windows(128, 100);
        assert_eq!(w.len(), 7); // 1000 / 128
        assert_eq!(w[0][0], 0);
        assert_eq!(w[1][0], 128u8);
        let w2 = c.eval_windows(128, 3);
        assert_eq!(w2.len(), 3);
    }

    #[test]
    fn synthetic_corpus_is_deterministic_text() {
        let a = Corpus::synthetic("s", 4096, 9);
        let b = Corpus::synthetic("s", 4096, 9);
        assert_eq!(a.data, b.data);
        assert_eq!(a.len(), 4096);
        assert!(a.data.iter().all(|&c| c.is_ascii_lowercase() || c == b' '));
        assert!(!a.eval_windows(128, 8).is_empty());
    }

    #[test]
    fn loads_artifact_corpora_when_present() {
        // Integration-style: skip silently when artifacts are absent.
        if let Ok(c) = Corpus::load("artifacts", "wiki", "eval") {
            assert!(c.len() > 10_000);
            assert!(c.data.iter().all(|&b| b < 128), "ascii corpus");
        }
    }
}
