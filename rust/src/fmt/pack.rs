//! Sub-byte bit-packing for quantized weights.
//!
//! Quantized codes are unsigned integers in `[0, 2^b)` for `b ∈ {2..8}`.
//! Codes are packed LSB-first into a contiguous byte stream; the paper's
//! memory numbers (Tables 1/3/4 "Mem." columns) are computed from exactly
//! these packed sizes plus auxiliary parameters.

/// Number of bytes needed to pack `n` codes of `bits` width.
pub fn packed_len(n: usize, bits: u32) -> usize {
    (n * bits as usize).div_ceil(8)
}

/// Pack `codes` (each `< 2^bits`) LSB-first into bytes.
pub fn pack(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((2..=8).contains(&bits), "bits must be in 2..=8");
    let mask = ((1u16 << bits) - 1) as u8;
    let mut out = vec![0u8; packed_len(codes.len(), bits)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(c <= mask, "code {c} exceeds {bits}-bit range");
        let c = (c & mask) as u16;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= (c << off) as u8;
        if off + bits as usize > 8 {
            out[byte + 1] |= (c >> (8 - off)) as u8;
        }
        bitpos += bits as usize;
    }
    out
}

/// Unpack `out.len()` codes of `bits` width from `bytes` into `out`
/// without allocating — the single source of truth for the LSB-first
/// layout, shared with the fused kernels' tile unpack.
pub fn unpack_into(bytes: &[u8], bits: u32, out: &mut [u8]) {
    assert!((2..=8).contains(&bits));
    assert!(bytes.len() >= packed_len(out.len(), bits), "unpack: buffer too small");
    let mask = ((1u16 << bits) - 1) as u16;
    let mut bitpos = 0usize;
    for slot in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = (bytes[byte] as u16) >> off;
        if off + bits as usize > 8 {
            v |= (bytes[byte + 1] as u16) << (8 - off);
        }
        *slot = (v & mask) as u8;
        bitpos += bits as usize;
    }
}

/// Unpack `n` codes of `bits` width from `bytes`.
pub fn unpack(bytes: &[u8], bits: u32, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_into(bytes, bits, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn round_trip_all_widths() {
        let mut rng = Rng::new(31);
        for bits in 2..=8u32 {
            let n = 1000 + bits as usize; // odd lengths exercise tail handling
            let codes: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
            let packed = pack(&codes, bits);
            assert_eq!(packed.len(), packed_len(n, bits));
            assert_eq!(unpack(&packed, bits, n), codes, "bits={bits}");
        }
    }

    #[test]
    fn packed_len_values() {
        assert_eq!(packed_len(64, 4), 32);
        assert_eq!(packed_len(64, 3), 24);
        assert_eq!(packed_len(5, 3), 2); // 15 bits -> 2 bytes
        assert_eq!(packed_len(0, 4), 0);
    }

    #[test]
    fn int4_nibble_layout() {
        // Two 4-bit codes per byte, first in the low nibble.
        let packed = pack(&[0x3, 0xA], 4);
        assert_eq!(packed, vec![0xA3]);
    }

    #[test]
    fn int3_crosses_byte_boundaries() {
        // 8 3-bit codes = 3 bytes exactly.
        let codes = [0b111, 0b000, 0b101, 0b010, 0b011, 0b100, 0b110, 0b001];
        let packed = pack(&codes, 3);
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack(&packed, 3, 8), codes);
    }

    #[test]
    fn round_trip_awkward_lengths_all_widths() {
        // Deterministic property sweep: every width × lengths chosen so the
        // final code straddles (or exactly fills) a byte boundary, plus the
        // degenerate n=0 and n=1 cases.
        let mut rng = Rng::new(33);
        for bits in 2..=8u32 {
            for n in [0usize, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 63, 64, 65, 121, 255, 256, 257] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
                let packed = pack(&codes, bits);
                assert_eq!(packed.len(), packed_len(n, bits), "bits={bits} n={n}");
                assert_eq!(unpack(&packed, bits, n), codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn round_trip_max_codes() {
        // All-ones codes exercise every carry bit across byte boundaries.
        for bits in 2..=8u32 {
            let max = ((1u16 << bits) - 1) as u8;
            for n in [1usize, 7, 8, 9, 31] {
                let codes = vec![max; n];
                assert_eq!(unpack(&pack(&codes, bits), bits, n), codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn packed_len_boundaries() {
        // Exact formula at and around every byte boundary for every width.
        for bits in 2..=8u32 {
            assert_eq!(packed_len(0, bits), 0, "bits={bits}");
            for n in 1..=129usize {
                let expect = (n * bits as usize + 7) / 8;
                assert_eq!(packed_len(n, bits), expect, "bits={bits} n={n}");
            }
            // A width-aligned count never wastes a byte...
            assert_eq!(packed_len(8, bits), bits as usize);
            // ...and one more code spills into exactly one extra byte.
            assert_eq!(packed_len(9, bits), bits as usize + 1);
        }
    }

    #[test]
    fn property_random_lengths() {
        // Hand-rolled property test: many random (bits, n, codes) cases.
        let mut rng = Rng::new(32);
        for _ in 0..200 {
            let bits = 2 + (rng.below(7)) as u32;
            let n = rng.below(257);
            let codes: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
            assert_eq!(unpack(&pack(&codes, bits), bits, n), codes);
        }
    }
}
