//! Quantization level grids.
//!
//! SINQ is orthogonal to the choice of levels (§3.2): Algorithm 1 normalizes
//! the matrix, then *any* rounding function maps values to a grid. We provide
//! the uniform integer grid (RTN), NF4 (normal-float quantiles, Dettmers et
//! al. 2023), and FP4 E2M1 (the BnB FP4 format). Non-uniform grids quantize
//! to the nearest level of a normalized table scaled per group.

/// The NF4 levels as defined in QLoRA (Dettmers et al., 2023), normalized to
/// `[-1, 1]` — quantiles of N(0,1) with exact 0 representation.
pub const NF4_LEVELS: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

/// FP4 (E2M1) representable magnitudes scaled so max = 1 (matches
/// bitsandbytes' FP4: {0, ±0.0625, ±0.125, ±0.1875, ±0.25, ±0.375, ±0.5,
/// ±0.75, ±1} picked from sign×exp×mantissa but 16 codes total).
pub const FP4_LEVELS: [f32; 16] = [
    -1.0, -0.75, -0.5, -0.375, -0.25, -0.1875, -0.125, -0.0625, //
    0.0, 0.0625, 0.125, 0.1875, 0.25, 0.375, 0.5, 0.75,
];

/// A quantization grid: either a uniform integer range or an explicit level
/// table in `[-1, 1]`.
#[derive(Debug, Clone, PartialEq)]
pub enum Grid {
    /// Uniform asymmetric integer grid with codes `0..2^bits`.
    Uniform { bits: u32 },
    /// Explicit normalized levels (must be sorted ascending).
    Table { name: &'static str, levels: Vec<f32> },
}

impl Grid {
    pub fn uniform(bits: u32) -> Grid {
        Grid::Uniform { bits }
    }

    pub fn nf4() -> Grid {
        Grid::Table { name: "nf4", levels: NF4_LEVELS.to_vec() }
    }

    pub fn fp4() -> Grid {
        Grid::Table { name: "fp4", levels: FP4_LEVELS.to_vec() }
    }

    /// An NF-style grid for arbitrary bit width: quantiles of N(0,1) with an
    /// exact zero, following the QLoRA construction. Used by the codebook /
    /// HIGGS-like baselines at 3 bits.
    pub fn nf(bits: u32) -> Grid {
        if bits == 4 {
            return Grid::nf4();
        }
        let n = 1usize << bits;
        // Build n levels: (n/2) negative quantiles incl. -1, zero, (n/2 - 1)
        // positive quantiles incl. +1 — mirroring the NF4 construction.
        let half1 = n / 2;
        let half2 = n - half1;
        let mut levels = Vec::with_capacity(n);
        let offset = 0.5 * (1.0 / 32.0 + 1.0 / 30.0); // QLoRA's tail offset
        // half1 non-positive levels: p from `offset` (→ most negative) to 0.5 (→ 0).
        for i in 0..half1 {
            let p = offset + (0.5 - offset) * (i as f64) / (half1 - 1).max(1) as f64;
            levels.push(-(normal_icdf(1.0 - p)) as f32);
        }
        // half2 strictly positive levels: p from 0.5+δ to 1−offset (→ max).
        for i in 1..=half2 {
            let p = 0.5 + (0.5 - offset) * (i as f64) / half2 as f64;
            levels.push(normal_icdf(p) as f32);
        }
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let max = levels.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for l in &mut levels {
            *l /= max;
        }
        Grid::Table { name: "nf", levels }
    }

    /// Number of representable codes.
    pub fn size(&self) -> usize {
        match self {
            Grid::Uniform { bits } => 1usize << bits,
            Grid::Table { levels, .. } => levels.len(),
        }
    }

    /// Effective bits per weight for memory accounting.
    pub fn bits(&self) -> u32 {
        (self.size() as f32).log2().ceil() as u32
    }

    pub fn is_uniform(&self) -> bool {
        matches!(self, Grid::Uniform { .. })
    }

    /// Nearest code for a normalized value (Table grids expect inputs
    /// normalized so the group max-abs maps to ±1).
    pub fn nearest(&self, x: f32) -> u8 {
        match self {
            Grid::Uniform { bits } => {
                let maxq = ((1u32 << bits) - 1) as f32;
                x.round().clamp(0.0, maxq) as u8
            }
            Grid::Table { levels, .. } => {
                // Binary search then pick closer neighbour.
                let mut lo = 0usize;
                let mut hi = levels.len() - 1;
                while hi - lo > 1 {
                    let mid = (lo + hi) / 2;
                    if levels[mid] <= x {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                if (x - levels[lo]).abs() <= (levels[hi] - x).abs() {
                    lo as u8
                } else {
                    hi as u8
                }
            }
        }
    }

    /// Decode a code to its (normalized for Table, integer for Uniform) value.
    pub fn decode(&self, code: u8) -> f32 {
        match self {
            Grid::Uniform { .. } => code as f32,
            Grid::Table { levels, .. } => levels[code as usize],
        }
    }
}

/// Inverse standard-normal CDF (Acklam's rational approximation, |ε|<1.15e-9).
pub fn normal_icdf(p: f64) -> f64 {
    assert!((0.0..1.0).contains(&p) && p > 0.0, "icdf domain");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nf4_levels_sorted_and_span() {
        for w in NF4_LEVELS.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(NF4_LEVELS[0], -1.0);
        assert_eq!(*NF4_LEVELS.last().unwrap(), 1.0);
        assert_eq!(NF4_LEVELS[7], 0.0); // exact zero
    }

    #[test]
    fn uniform_nearest_clamps() {
        let g = Grid::uniform(4);
        assert_eq!(g.nearest(-3.0), 0);
        assert_eq!(g.nearest(7.4), 7);
        assert_eq!(g.nearest(99.0), 15);
        assert_eq!(g.size(), 16);
        assert_eq!(g.bits(), 4);
    }

    #[test]
    fn table_nearest_is_truly_nearest() {
        let g = Grid::nf4();
        for i in 0..=200 {
            let x = -1.2 + 2.4 * i as f32 / 200.0;
            let c = g.nearest(x) as usize;
            let d = (x - NF4_LEVELS[c]).abs();
            for (j, &l) in NF4_LEVELS.iter().enumerate() {
                assert!(d <= (x - l).abs() + 1e-6, "x={x} chose {c} but {j} closer");
            }
        }
    }

    #[test]
    fn icdf_matches_known_values() {
        assert!((normal_icdf(0.5)).abs() < 1e-9);
        assert!((normal_icdf(0.975) - 1.959964).abs() < 1e-4);
        assert!((normal_icdf(0.025) + 1.959964).abs() < 1e-4);
    }

    #[test]
    fn nf_grid_generalizes() {
        let g3 = Grid::nf(3);
        assert_eq!(g3.size(), 8);
        if let Grid::Table { levels, .. } = &g3 {
            for w in levels.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!((levels[0] + 1.0).abs() < 1e-6);
            assert!((levels.last().unwrap() - 1.0).abs() < 1e-6);
        } else {
            panic!("nf(3) should be a table grid");
        }
        // nf(4) must be exactly NF4.
        assert_eq!(Grid::nf(4), Grid::nf4());
    }

    #[test]
    fn fp4_decode_encode_round_trip() {
        let g = Grid::fp4();
        for code in 0..16u8 {
            let v = g.decode(code);
            assert_eq!(g.nearest(v), code, "level {v}");
        }
    }
}
