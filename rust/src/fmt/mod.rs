//! Storage formats: the `.stz` tensor archive (Python ⇄ Rust interchange),
//! sub-byte bit-packing, quantization grids (uniform / NF4 / FP4), and
//! GGUF-style block formats (Q4_0, Q3_K_S) for the Appendix A.7 experiments.

pub mod gguf;
pub mod grids;
pub mod pack;
pub mod stz;

pub use grids::Grid;
pub use stz::{Stz, Tensor};
