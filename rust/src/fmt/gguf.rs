//! GGUF-style block quantization formats (Appendix A.7: no-overhead SINQ as a
//! pre-processing step for llama.cpp's Q4_0 / Q3_K_S).
//!
//! Re-implemented from the GGML specification:
//!
//! * **Q4_0** — blocks of 32 weights; one f16 scale `d`; symmetric codes
//!   `q ∈ [0,15]` decoding to `d·(q−8)`. 4.5 bits/weight.
//! * **Q3_K_S** — super-blocks of 256 weights = 16 sub-blocks of 16; one f16
//!   super-scale `d`; 16 six-bit sub-scales; 3-bit symmetric codes decoding
//!   to `d·(sc−32)·(q−4)`. ≈3.44 bits/weight.
//!
//! These formats have *no zero-point*, so the column-scale normalization that
//! no-overhead SINQ applies beforehand measurably helps (Table 9).

use crate::tensor::Matrix;
use crate::util::half::{f16_bits_to_f32, f32_to_f16_bits, round_f16};

/// Block size of Q4_0.
pub const Q4_0_BLOCK: usize = 32;
/// Super-block size of Q3_K.
pub const Q3_K_SUPER: usize = 256;
/// Sub-block size of Q3_K.
pub const Q3_K_SUB: usize = 16;

/// A Q4_0-quantized row-major matrix.
#[derive(Debug, Clone)]
pub struct Q4_0Matrix {
    pub rows: usize,
    pub cols: usize,
    /// One f16 scale per block (row-major blocks along each row).
    pub scales: Vec<u16>,
    /// Codes 0..16, one per weight.
    pub codes: Vec<u8>,
}

/// Quantize row-wise in blocks of 32 following ggml's `quantize_row_q4_0`:
/// the scale is `max_abs/-8` with the sign of the absolute max element.
pub fn q4_0_quantize(w: &Matrix) -> Q4_0Matrix {
    assert_eq!(w.cols % Q4_0_BLOCK, 0, "cols must be a multiple of 32");
    let mut scales = Vec::with_capacity(w.rows * w.cols / Q4_0_BLOCK);
    let mut codes = Vec::with_capacity(w.numel());
    for i in 0..w.rows {
        for block in w.row(i).chunks_exact(Q4_0_BLOCK) {
            // ggml: find the value with max |.|, keep its sign.
            let mut amax = 0.0f32;
            let mut maxv = 0.0f32;
            for &v in block {
                if v.abs() > amax {
                    amax = v.abs();
                    maxv = v;
                }
            }
            let d = maxv / -8.0;
            let id = if d != 0.0 { 1.0 / d } else { 0.0 };
            let dh = f32_to_f16_bits(d);
            scales.push(dh);
            for &v in block {
                let q = (v * id + 8.5).floor().clamp(0.0, 15.0) as u8;
                codes.push(q);
            }
        }
    }
    Q4_0Matrix { rows: w.rows, cols: w.cols, scales, codes }
}

/// Dequantize a Q4_0 matrix.
pub fn q4_0_dequantize(q: &Q4_0Matrix) -> Matrix {
    let mut m = Matrix::zeros(q.rows, q.cols);
    let blocks_per_row = q.cols / Q4_0_BLOCK;
    for i in 0..q.rows {
        for b in 0..blocks_per_row {
            let d = f16_bits_to_f32(q.scales[i * blocks_per_row + b]);
            for k in 0..Q4_0_BLOCK {
                let idx = i * q.cols + b * Q4_0_BLOCK + k;
                m.data[idx] = d * (q.codes[idx] as f32 - 8.0);
            }
        }
    }
    m
}

/// Bits per weight of Q4_0 (4 bits + f16 scale per 32).
pub fn q4_0_bits_per_weight() -> f64 {
    4.0 + 16.0 / Q4_0_BLOCK as f64
}

/// A Q3_K_S-quantized matrix.
#[derive(Debug, Clone)]
pub struct Q3KMatrix {
    pub rows: usize,
    pub cols: usize,
    /// f16 super-scale per 256-weight super-block.
    pub d: Vec<u16>,
    /// 6-bit sub-scales (stored one per byte), 16 per super-block.
    pub sub_scales: Vec<u8>,
    /// 3-bit codes (stored one per byte here; packed on disk).
    pub codes: Vec<u8>,
}

/// Quantize row-wise in 256-weight super-blocks following the Q3_K scheme:
/// per sub-block scale `s_j = max_abs_j / 4` (3-bit symmetric range −4..3),
/// super-scale `d = max_j |s_j| / 32`, sub-scales quantized to 6 bits.
pub fn q3_k_quantize(w: &Matrix) -> Q3KMatrix {
    assert_eq!(w.cols % Q3_K_SUPER, 0, "cols must be a multiple of 256");
    let supers_per_row = w.cols / Q3_K_SUPER;
    let mut d = Vec::with_capacity(w.rows * supers_per_row);
    let mut sub_scales = Vec::with_capacity(w.rows * supers_per_row * 16);
    let mut codes = Vec::with_capacity(w.numel());
    for i in 0..w.rows {
        for sb in w.row(i).chunks_exact(Q3_K_SUPER) {
            // Ideal float sub-scales.
            let mut s = [0.0f32; 16];
            for (j, sub) in sb.chunks_exact(Q3_K_SUB).enumerate() {
                let amax = sub.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                s[j] = amax / 4.0;
            }
            let smax = s.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let dd = round_f16(smax / 31.0);
            d.push(f32_to_f16_bits(dd));
            let idd = if dd != 0.0 { 1.0 / dd } else { 0.0 };
            for (j, sub) in sb.chunks_exact(Q3_K_SUB).enumerate() {
                // 6-bit unsigned sub-scale code (0..63), decode sc*d.
                let sc = (s[j] * idd).round().clamp(0.0, 63.0) as u8;
                sub_scales.push(sc);
                let eff = dd * sc as f32;
                let ieff = if eff != 0.0 { 1.0 / eff } else { 0.0 };
                for &v in sub {
                    let q = (v * ieff + 4.5).floor().clamp(0.0, 7.0) as u8;
                    codes.push(q);
                }
            }
        }
    }
    Q3KMatrix { rows: w.rows, cols: w.cols, d, sub_scales, codes }
}

/// Dequantize a Q3_K_S matrix.
pub fn q3_k_dequantize(q: &Q3KMatrix) -> Matrix {
    let mut m = Matrix::zeros(q.rows, q.cols);
    let supers_per_row = q.cols / Q3_K_SUPER;
    for i in 0..q.rows {
        for sbi in 0..supers_per_row {
            let dd = f16_bits_to_f32(q.d[i * supers_per_row + sbi]);
            for j in 0..16 {
                let sc = q.sub_scales[(i * supers_per_row + sbi) * 16 + j];
                let eff = dd * sc as f32;
                for k in 0..Q3_K_SUB {
                    let idx = i * q.cols + sbi * Q3_K_SUPER + j * Q3_K_SUB + k;
                    m.data[idx] = eff * (q.codes[idx] as f32 - 4.0);
                }
            }
        }
    }
    m
}

/// Bits per weight of Q3_K_S (3 bits + 6-bit sub-scale per 16 + f16 per 256).
pub fn q3_k_bits_per_weight() -> f64 {
    3.0 + 6.0 / Q3_K_SUB as f64 + 16.0 / Q3_K_SUPER as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{stats, Rng};

    #[test]
    fn q4_0_round_trip_error_bounded() {
        let mut rng = Rng::new(41);
        let w = Matrix::randn(8, 128, 0.02, &mut rng);
        let q = q4_0_quantize(&w);
        let deq = q4_0_dequantize(&q);
        // Worst-case error per weight is ~d/2 = max_abs/16.
        for i in 0..w.rows {
            let amax = w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            for j in 0..w.cols {
                assert!((w.at(i, j) - deq.at(i, j)).abs() <= amax / 8.0 + 1e-6);
            }
        }
        let rel = deq.dist(&w) / w.dist(&Matrix::zeros(8, 128));
        assert!(rel < 0.12, "relative error {rel}");
    }

    #[test]
    fn q3_k_round_trip_error_bounded() {
        let mut rng = Rng::new(42);
        let w = Matrix::randn(4, 512, 0.02, &mut rng);
        let q = q3_k_quantize(&w);
        let deq = q3_k_dequantize(&q);
        let rel = deq.dist(&w) / w.dist(&Matrix::zeros(4, 512));
        assert!(rel < 0.25, "relative error {rel}");
        // Q3 must be worse than Q4 on the same data (coarser grid).
        let q4 = q4_0_dequantize(&q4_0_quantize(&w));
        assert!(deq.mse(&w) > q4.mse(&w));
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Rng::new(43);
        let w = Matrix::randn(2, 256, 1.0, &mut rng);
        let q4 = q4_0_quantize(&w);
        assert!(q4.codes.iter().all(|&c| c < 16));
        let q3 = q3_k_quantize(&w);
        assert!(q3.codes.iter().all(|&c| c < 8));
        assert!(q3.sub_scales.iter().all(|&c| c < 64));
    }

    #[test]
    fn bits_per_weight() {
        assert!((q4_0_bits_per_weight() - 4.5).abs() < 1e-12);
        assert!((q3_k_bits_per_weight() - 3.4375).abs() < 1e-3);
    }

    #[test]
    fn column_outliers_hurt_q4_0_and_scaling_helps() {
        // The Table 9 mechanism: a hot column inflates the per-block scale of
        // *every* block it lands in; dividing it out first reduces MSE.
        let mut rng = Rng::new(44);
        let mut w = Matrix::randn(16, 128, 0.02, &mut rng);
        for i in 0..16 {
            *w.at_mut(i, 5) *= 12.0; // column 5 is hot
        }
        let base_mse = q4_0_dequantize(&q4_0_quantize(&w)).mse(&w);
        // Pre-scale column 5 down (what no-overhead SINQ folding achieves).
        let mut t = vec![1.0f32; 128];
        t[5] = 12.0;
        let mut wn = w.clone();
        wn.div_cols(&t);
        let qn = q4_0_dequantize(&q4_0_quantize(&wn));
        let mut rec = qn.clone();
        rec.scale_cols(&t);
        assert!(rec.mse(&w) < base_mse * 0.6, "{} vs {}", rec.mse(&w), base_mse);
        // And row stds are (weakly) preserved by construction.
        let _ = stats::row_stds(&rec);
    }
}
