//! `.stz` — the repo's tensor-archive format (safetensors-shaped, built from
//! scratch since neither safetensors nor serde is available offline).
//!
//! Layout:
//! ```text
//! [8 bytes]  little-endian u64: header length H
//! [H bytes]  JSON header: { "tensor-name": {"dtype": "f32"|"i32"|"u8",
//!                                           "shape": [..], "offset": o,
//!                                           "nbytes": n}, ...,
//!             "__meta__": { arbitrary json } }
//! [  ...  ]  raw little-endian tensor data, offsets relative to data start
//! ```
//! The Python trainer writes this format (see `python/compile/stz.py`); the
//! Rust side reads checkpoints and writes quantized models back.

use crate::tensor::Matrix;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// One stored tensor: f32 / i32 / u8 payloads cover every use in the repo.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U8 { shape: Vec<usize>, data: Vec<u8> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } | Tensor::U8 { shape, .. } => {
                shape
            }
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn nbytes(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len() * 4,
            Tensor::I32 { data, .. } => data.len() * 4,
            Tensor::U8 { data, .. } => data.len(),
        }
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "f32",
            Tensor::I32 { .. } => "i32",
            Tensor::U8 { .. } => "u8",
        }
    }

    /// View a rank-2 f32 tensor as a [`Matrix`].
    pub fn as_matrix(&self) -> Option<Matrix> {
        match self {
            Tensor::F32 { shape, data } if shape.len() == 2 => {
                Some(Matrix::from_vec(shape[0], shape[1], data.clone()))
            }
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn from_matrix(m: &Matrix) -> Tensor {
        Tensor::F32 { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }

    pub fn from_vec_f32(v: Vec<f32>) -> Tensor {
        Tensor::F32 { shape: vec![v.len()], data: v }
    }
}

/// An in-memory `.stz` archive: named tensors plus a JSON metadata object.
#[derive(Debug, Default)]
pub struct Stz {
    pub tensors: BTreeMap<String, Tensor>,
    pub meta: Option<Json>,
}

impl Stz {
    pub fn new() -> Stz {
        Stz::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    /// Required tensor fetch with a contextual error.
    pub fn require(&self, name: &str) -> anyhow::Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("tensor '{name}' missing from archive"))
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut header = BTreeMap::new();
        let mut blob: Vec<u8> = Vec::new();
        for (name, t) in &self.tensors {
            let offset = blob.len();
            match t {
                Tensor::F32 { data, .. } => {
                    for &v in data {
                        blob.extend_from_slice(&v.to_le_bytes());
                    }
                }
                Tensor::I32 { data, .. } => {
                    for &v in data {
                        blob.extend_from_slice(&v.to_le_bytes());
                    }
                }
                Tensor::U8 { data, .. } => blob.extend_from_slice(data),
            }
            header.insert(
                name.clone(),
                Json::obj(vec![
                    ("dtype", Json::Str(t.dtype_name().into())),
                    (
                        "shape",
                        Json::Arr(t.shape().iter().map(|&d| Json::Num(d as f64)).collect()),
                    ),
                    ("offset", Json::Num(offset as f64)),
                    ("nbytes", Json::Num(t.nbytes() as f64)),
                ]),
            );
        }
        if let Some(m) = &self.meta {
            header.insert("__meta__".into(), m.clone());
        }
        let header_json = Json::Obj(header).to_string_compact();
        let mut out = Vec::with_capacity(8 + header_json.len() + blob.len());
        out.extend_from_slice(&(header_json.len() as u64).to_le_bytes());
        out.extend_from_slice(header_json.as_bytes());
        out.extend_from_slice(&blob);
        out
    }

    /// Parse from bytes.
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Stz> {
        anyhow::ensure!(bytes.len() >= 8, "stz: truncated header length");
        let hlen = u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
        anyhow::ensure!(bytes.len() >= 8 + hlen, "stz: truncated header");
        let header = std::str::from_utf8(&bytes[8..8 + hlen])?;
        let header = Json::parse(header).map_err(|e| anyhow::anyhow!("stz header: {e}"))?;
        let data = &bytes[8 + hlen..];
        let mut stz = Stz::new();
        let obj = match &header {
            Json::Obj(m) => m,
            _ => anyhow::bail!("stz: header is not an object"),
        };
        for (name, desc) in obj {
            if name == "__meta__" {
                stz.meta = Some(desc.clone());
                continue;
            }
            let dtype = desc.get("dtype").and_then(|j| j.as_str()).unwrap_or("f32");
            let shape: Vec<usize> = desc
                .get("shape")
                .and_then(|j| j.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default();
            let offset = desc.get("offset").and_then(|j| j.as_usize()).unwrap_or(0);
            let nbytes = desc.get("nbytes").and_then(|j| j.as_usize()).unwrap_or(0);
            anyhow::ensure!(offset + nbytes <= data.len(), "stz: tensor '{name}' out of bounds");
            let raw = &data[offset..offset + nbytes];
            let t = match dtype {
                "f32" => Tensor::F32 {
                    shape,
                    data: raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                },
                "i32" => Tensor::I32 {
                    shape,
                    data: raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                },
                "u8" => Tensor::U8 { shape, data: raw.to_vec() },
                other => anyhow::bail!("stz: unsupported dtype '{other}'"),
            };
            stz.tensors.insert(name.clone(), t);
        }
        Ok(stz)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Stz> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.as_ref().display()))?
            .read_to_end(&mut bytes)?;
        Stz::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn round_trip_all_dtypes() {
        let mut rng = Rng::new(21);
        let mut stz = Stz::new();
        stz.insert("w", Tensor::from_matrix(&Matrix::randn(5, 7, 1.0, &mut rng)));
        stz.insert("q", Tensor::I32 { shape: vec![3], data: vec![-1, 0, 7] });
        stz.insert("packed", Tensor::U8 { shape: vec![4], data: vec![0, 255, 17, 3] });
        stz.meta = Some(Json::obj(vec![("name", Json::Str("tiny".into()))]));

        let bytes = stz.to_bytes();
        let back = Stz::from_bytes(&bytes).unwrap();
        assert_eq!(back.tensors.len(), 3);
        assert_eq!(back.get("w"), stz.get("w"));
        assert_eq!(back.get("q"), stz.get("q"));
        assert_eq!(back.get("packed"), stz.get("packed"));
        assert_eq!(back.meta.unwrap().get("name").unwrap().as_str(), Some("tiny"));
    }

    #[test]
    fn file_round_trip() {
        let mut stz = Stz::new();
        stz.insert("v", Tensor::from_vec_f32(vec![1.5, -2.5, 1e-8]));
        let dir = std::env::temp_dir().join("sinq_stz_test.stz");
        stz.save(&dir).unwrap();
        let back = Stz::load(&dir).unwrap();
        assert_eq!(back.get("v"), stz.get("v"));
        let _ = std::fs::remove_file(&dir);
    }

    #[test]
    fn rejects_truncation() {
        let mut stz = Stz::new();
        stz.insert("v", Tensor::from_vec_f32(vec![1.0; 16]));
        let bytes = stz.to_bytes();
        assert!(Stz::from_bytes(&bytes[..bytes.len() - 8]).is_err());
        assert!(Stz::from_bytes(&bytes[..4]).is_err());
    }

    #[test]
    fn matrix_view() {
        let m = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let t = Tensor::from_matrix(&m);
        assert_eq!(t.as_matrix().unwrap(), m);
        assert!(Tensor::from_vec_f32(vec![1.0]).as_matrix().is_none());
    }
}
