//! No-overhead SINQ (§2.3.1): the model-graph pass that absorbs the second
//! scale `t` into producer operations so inference cost is identical to
//! single-scale quantization.
//!
//! Consumer groups (layers sharing one input must share `t`, as in Qwen-3):
//!
//! | consumers                          | producer absorbing `t`     |
//! |------------------------------------|----------------------------|
//! | `wq, wk, wv` (layer l)             | `ln1` gain (layer l)       |
//! | `wo` (layer l)                     | `wv` output rows (layer l) |
//! | `wg, wu` [+ `router`, experts]     | `ln2` gain (layer l)       |
//! | `wd` (/ `expert_e.wd`)             | `wu` (/`expert_e.wu`) rows |
//! | `lm_head`                          | `ln_f` gain                |
//!
//! The fold itself is *exact* on the full-precision network (verified by the
//! `fold_preserves_fp_forward` test); quantization error then comes only
//! from the subsequent rounding.

use crate::model::store::ModelWeights;
use crate::quant::fold as qfold;
use crate::quant::{quantize_matrix, Method, QuantConfig};
use crate::quant::QuantizedLinear;
use std::collections::BTreeMap;

/// Apply the folding pass to a full-precision checkpoint: returns the
/// transformed weights (consumers normalized, producers scaled). The
/// transformed network computes exactly the same function.
pub fn fold_model(mw: &ModelWeights, iters: usize, clamp: (f32, f32)) -> ModelWeights {
    let mut out = mw.clone();
    let cfg = &mw.cfg;

    for l in 0..cfg.layers {
        let pre = format!("layers.{l}");

        // Group 1: q/k/v share ln1 output.
        let t = {
            let ws: Vec<&_> = ["wq", "wk", "wv"]
                .iter()
                .map(|s| &out.tensors[&format!("{pre}.{s}")])
                .collect();
            qfold::shared_col_scale(&ws, iters, clamp)
        };
        for s in ["wq", "wk", "wv"] {
            qfold::divide_consumer_cols(out.tensors.get_mut(&format!("{pre}.{s}")).unwrap(), &t);
        }
        qfold::fold_into_gain(out.vectors.get_mut(&format!("{pre}.ln1")).unwrap(), &t);

        // Group 2: wo consumes the attention context (wv output channels).
        let t = qfold::shared_col_scale(&[&out.tensors[&format!("{pre}.wo")]], iters, clamp);
        qfold::divide_consumer_cols(out.tensors.get_mut(&format!("{pre}.wo")).unwrap(), &t);
        qfold::fold_into_producer_rows(out.tensors.get_mut(&format!("{pre}.wv")).unwrap(), &t);

        if cfg.n_experts == 0 {
            // Group 3: gate/up share ln2 output.
            let t = {
                let ws: Vec<&_> = ["wg", "wu"]
                    .iter()
                    .map(|s| &out.tensors[&format!("{pre}.{s}")])
                    .collect();
                qfold::shared_col_scale(&ws, iters, clamp)
            };
            for s in ["wg", "wu"] {
                qfold::divide_consumer_cols(
                    out.tensors.get_mut(&format!("{pre}.{s}")).unwrap(),
                    &t,
                );
            }
            qfold::fold_into_gain(out.vectors.get_mut(&format!("{pre}.ln2")).unwrap(), &t);

            // Group 4: wd consumes silu(g)⊙u — fold into wu rows.
            let t = qfold::shared_col_scale(&[&out.tensors[&format!("{pre}.wd")]], iters, clamp);
            qfold::divide_consumer_cols(out.tensors.get_mut(&format!("{pre}.wd")).unwrap(), &t);
            qfold::fold_into_producer_rows(
                out.tensors.get_mut(&format!("{pre}.wu")).unwrap(),
                &t,
            );
        } else {
            // MoE: router + every expert's gate/up share ln2 output.
            let mut names: Vec<String> = vec![format!("{pre}.router")];
            for e in 0..cfg.n_experts {
                for s in ["wg", "wu"] {
                    names.push(format!("{pre}.expert{e}.{s}"));
                }
            }
            let t = {
                let ws: Vec<&_> = names.iter().map(|n| &out.tensors[n]).collect();
                qfold::shared_col_scale(&ws, iters, clamp)
            };
            for n in &names {
                qfold::divide_consumer_cols(out.tensors.get_mut(n).unwrap(), &t);
            }
            qfold::fold_into_gain(out.vectors.get_mut(&format!("{pre}.ln2")).unwrap(), &t);

            // Per-expert wd folds into that expert's wu rows.
            for e in 0..cfg.n_experts {
                let wd = format!("{pre}.expert{e}.wd");
                let t = qfold::shared_col_scale(&[&out.tensors[&wd]], iters, clamp);
                qfold::divide_consumer_cols(out.tensors.get_mut(&wd).unwrap(), &t);
                qfold::fold_into_producer_rows(
                    out.tensors.get_mut(&format!("{pre}.expert{e}.wu")).unwrap(),
                    &t,
                );
            }
        }
    }

    // lm_head consumes ln_f output.
    let t = qfold::shared_col_scale(&[&out.tensors["lm_head"]], iters, clamp);
    qfold::divide_consumer_cols(out.tensors.get_mut("lm_head").unwrap(), &t);
    qfold::fold_into_gain(out.vectors.get_mut("ln_f").unwrap(), &t);

    out
}

/// Quantize a folded model with single-scale RTN (+shift): the no-overhead
/// SINQ end product. Row Sinkhorn scales are subsumed by per-group scales.
pub fn quantize_folded(
    folded: &ModelWeights,
    bits: u32,
    group: usize,
) -> BTreeMap<String, QuantizedLinear> {
    let cfg = QuantConfig::new(Method::Rtn, bits).with_group(group);
    folded
        .cfg
        .quantizable_names()
        .into_iter()
        .map(|name| {
            let q = quantize_matrix(&folded.tensors[&name], &cfg, None).unwrap();
            (name, q)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::Forward;
    use crate::model::ModelConfig;

    #[test]
    fn fold_preserves_fp_forward() {
        let cfg = ModelConfig::family("pico").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 21);
        let folded = fold_model(&mw, 16, (0.5, 2.0));

        let f1 = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let f2 = Forward::new(&folded.cfg, &folded.tensors, &folded.vectors);
        let l1 = f1.forward(b"fold must be exact", None);
        let l2 = f2.forward(b"fold must be exact", None);
        let max_diff = l1
            .data
            .iter()
            .zip(&l2.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-3, "fold changed FP logits by {max_diff}");
    }

    #[test]
    fn fold_preserves_fp_forward_moe() {
        let cfg = ModelConfig::family("tiny_moe").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 22);
        let folded = fold_model(&mw, 16, (0.5, 2.0));
        let f1 = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let f2 = Forward::new(&folded.cfg, &folded.tensors, &folded.vectors);
        let l1 = f1.forward(b"moe fold", None);
        let l2 = f2.forward(b"moe fold", None);
        let max_diff = l1
            .data
            .iter()
            .zip(&l2.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-3, "moe fold changed FP logits by {max_diff}");
    }

    #[test]
    fn folded_rtn_beats_plain_rtn() {
        // The Table 8/9 mechanism: folding balances columns before
        // single-scale quantization.
        let cfg = ModelConfig::family("pico").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 23);
        let folded = fold_model(&mw, 16, (0.5, 2.0));

        let plain = quantize_folded(&mw, 3, 64); // plain RTN on raw weights
        let after_fold = quantize_folded(&folded, 3, 64);

        // Compare reconstruction error in the *original* weight space.
        let mut err_plain = 0.0f64;
        let mut err_fold = 0.0f64;
        for name in cfg.quantizable_names() {
            err_plain += plain[&name].dequantize().mse(&mw.tensors[&name]);
            // Folded reconstruction approximates the folded weight; compare
            // in folded space (the function computed is equivalent).
            err_fold += after_fold[&name].dequantize().mse(&folded.tensors[&name])
                * rel_scale(&folded.tensors[&name], &mw.tensors[&name]);
        }
        assert!(err_fold < err_plain, "fold {err_fold:.3e} vs plain {err_plain:.3e}");
    }

    /// Scale factor to make MSEs comparable across spaces (ratio of squared
    /// Frobenius norms).
    fn rel_scale(folded: &crate::tensor::Matrix, orig: &crate::tensor::Matrix) -> f64 {
        let nf: f64 = folded.data.iter().map(|&x| (x as f64).powi(2)).sum();
        let no: f64 = orig.data.iter().map(|&x| (x as f64).powi(2)).sum();
        no / nf.max(1e-30)
    }
}
