//! Weight stores: f32 checkpoints and quantized models, both `.stz`-backed.

use std::collections::BTreeMap;
use std::path::Path;

use crate::fmt::grids::Grid;
use crate::fmt::pack;
use crate::fmt::stz::{Stz, Tensor};
use crate::model::ModelConfig;
use crate::quant::{AuxPrecision, QuantizedLinear};
use crate::tensor::Matrix;
use crate::util::json::Json;

/// A full-precision checkpoint (as trained by `python/compile/train.py`).
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub cfg: ModelConfig,
    pub tensors: BTreeMap<String, Matrix>,
    /// 1-D tensors (norm gains) kept as vectors.
    pub vectors: BTreeMap<String, Vec<f32>>,
    pub meta: Option<Json>,
}

impl ModelWeights {
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<ModelWeights> {
        let stz = Stz::load(path)?;
        let meta = stz.meta.clone().ok_or_else(|| anyhow::anyhow!("checkpoint missing meta"))?;
        let cfg = ModelConfig::from_meta(&meta)?;
        let mut tensors = BTreeMap::new();
        let mut vectors = BTreeMap::new();
        for (name, t) in &stz.tensors {
            match t.shape().len() {
                2 => {
                    tensors.insert(name.clone(), t.as_matrix().unwrap());
                }
                1 => {
                    vectors.insert(name.clone(), t.as_f32().unwrap().to_vec());
                }
                d => anyhow::bail!("tensor {name} has unsupported rank {d}"),
            }
        }
        // Sanity: every expected weight present.
        for n in cfg.weight_names() {
            anyhow::ensure!(
                tensors.contains_key(&n) || vectors.contains_key(&n),
                "checkpoint missing weight '{n}'"
            );
        }
        Ok(ModelWeights { cfg, tensors, vectors, meta: Some(meta) })
    }

    pub fn matrix(&self, name: &str) -> &Matrix {
        &self.tensors[name]
    }

    pub fn vector(&self, name: &str) -> &[f32] {
        &self.vectors[name]
    }

    /// Synthesize an untrained checkpoint (tests / benches without artifacts).
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> ModelWeights {
        use crate::tensor::Rng;
        let mut rng = Rng::new(seed);
        let mut tensors = BTreeMap::new();
        let mut vectors = BTreeMap::new();
        for name in cfg.weight_names() {
            let last = name.rsplit('.').next().unwrap();
            if last.starts_with("ln") || last == "ln_f" {
                vectors.insert(name, vec![1.0f32; cfg.d]);
            } else {
                let (rows, cols) = shape_of(cfg, &name);
                // LLM-like statistics: heavy tails + column structure.
                let col_s: Vec<f32> =
                    (0..cols).map(|_| 0.3 + 2.0 * rng.uniform() as f32).collect();
                let mut m = Matrix::from_fn(rows, cols, |_, _| {
                    (0.6 * rng.student_t(5.0) as f32) / (cols as f32).sqrt()
                });
                m.scale_cols(&col_s);
                tensors.insert(name, m);
            }
        }
        ModelWeights { cfg: cfg.clone(), tensors, vectors, meta: None }
    }
}

/// Shape of a named weight.
pub fn shape_of(cfg: &ModelConfig, name: &str) -> (usize, usize) {
    let last = name.rsplit('.').next().unwrap();
    match last {
        "embed" => (cfg.vocab, cfg.d),
        "lm_head" => (cfg.vocab, cfg.d),
        "wq" | "wk" | "wv" | "wo" => (cfg.d, cfg.d),
        "wg" | "wu" => (cfg.ffn, cfg.d),
        "wd" => (cfg.d, cfg.ffn),
        "router" => (cfg.n_experts, cfg.d),
        _ => panic!("shape_of: not a matrix weight: {name}"),
    }
}

/// A quantized model: per-linear [`QuantizedLinear`] plus the f32 remainder
/// (embeddings, norm gains), serializable to `.stz` with bit-packed codes.
#[derive(Debug, Clone)]
pub struct QuantizedModel {
    pub cfg: ModelConfig,
    pub layers: BTreeMap<String, QuantizedLinear>,
    pub fweights: BTreeMap<String, Matrix>,
    pub fvectors: BTreeMap<String, Vec<f32>>,
    pub method: String,
    pub bits: u32,
}

impl QuantizedModel {
    /// Effective f32 weights (dequantize + unrotate) for evaluation.
    pub fn effective_weights(&self) -> BTreeMap<String, Matrix> {
        let mut out = self.fweights.clone();
        for (name, q) in &self.layers {
            out.insert(name.clone(), q.effective_weight());
        }
        out
    }

    /// Serialize: codes are bit-packed at the grid width.
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut stz = Stz::new();
        for (name, m) in &self.fweights {
            stz.insert(&format!("f.{name}"), Tensor::from_matrix(m));
        }
        for (name, v) in &self.fvectors {
            stz.insert(&format!("f.{name}"), Tensor::from_vec_f32(v.clone()));
        }
        for (name, q) in &self.layers {
            let bits = q.grid.bits();
            stz.insert(
                &format!("q.{name}.codes"),
                Tensor::U8 {
                    shape: vec![pack::packed_len(q.codes.len(), bits)],
                    data: pack::pack(&q.codes, bits),
                },
            );
            stz.insert(&format!("q.{name}.scales"), Tensor::from_matrix(&q.scales));
            if let Some(z) = &q.shifts {
                stz.insert(&format!("q.{name}.shifts"), Tensor::from_matrix(z));
            }
            if let Some(t) = &q.col_scale {
                stz.insert(&format!("q.{name}.t"), Tensor::from_vec_f32(t.clone()));
            }
            if let Some(cb) = &q.pair_codebook {
                stz.insert(&format!("q.{name}.codebook"), Tensor::from_vec_f32(cb.clone()));
            }
            let desc = Json::obj(vec![
                ("rows", Json::Num(q.rows as f64)),
                ("cols", Json::Num(q.cols as f64)),
                ("group", Json::Num(q.group_size as f64)),
                ("bits", Json::Num(bits as f64)),
                ("uniform", Json::Bool(q.grid.is_uniform())),
                ("hadamard", Json::Bool(q.hadamard)),
                ("hadamard_out", Json::Bool(q.hadamard_out)),
            ]);
            stz.insert(
                &format!("q.{name}.desc"),
                Tensor::U8 {
                    shape: vec![desc.to_string_compact().len()],
                    data: desc.to_string_compact().into_bytes(),
                },
            );
        }
        let mut cfg_meta = BTreeMap::new();
        cfg_meta.insert("name".to_string(), Json::Str(self.cfg.name.clone()));
        stz.meta = Some(Json::obj(vec![
            ("config", config_json(&self.cfg)),
            ("method", Json::Str(self.method.clone())),
            ("bits", Json::Num(self.bits as f64)),
        ]));
        stz.save(path)
    }

    /// Load a quantized model back (codes unpacked).
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<QuantizedModel> {
        let stz = Stz::load(path)?;
        let meta = stz.meta.clone().ok_or_else(|| anyhow::anyhow!("missing meta"))?;
        let cfg = ModelConfig::from_meta(&meta)?;
        let method =
            meta.get("method").and_then(|j| j.as_str()).unwrap_or("unknown").to_string();
        let bits = meta.get("bits").and_then(|j| j.as_usize()).unwrap_or(4) as u32;

        let mut layers = BTreeMap::new();
        let mut fweights = BTreeMap::new();
        let mut fvectors = BTreeMap::new();
        for (key, t) in &stz.tensors {
            if let Some(name) = key.strip_prefix("f.") {
                match t {
                    Tensor::F32 { shape, .. } if shape.len() == 2 => {
                        fweights.insert(name.to_string(), t.as_matrix().unwrap());
                    }
                    Tensor::F32 { data, .. } => {
                        fvectors.insert(name.to_string(), data.clone());
                    }
                    _ => {}
                }
            } else if let Some(rest) = key.strip_prefix("q.") {
                if !rest.ends_with(".desc") {
                    continue;
                }
                let name = rest.trim_end_matches(".desc").to_string();
                let desc_bytes = match t {
                    Tensor::U8 { data, .. } => data.clone(),
                    _ => anyhow::bail!("bad desc tensor"),
                };
                let desc = Json::parse(std::str::from_utf8(&desc_bytes)?)
                    .map_err(|e| anyhow::anyhow!("desc: {e}"))?;
                let rows = desc.get("rows").unwrap().as_usize().unwrap();
                let cols = desc.get("cols").unwrap().as_usize().unwrap();
                let group = desc.get("group").unwrap().as_usize().unwrap();
                let b = desc.get("bits").unwrap().as_usize().unwrap() as u32;
                let uniform = desc.get("uniform") == Some(&Json::Bool(true));
                let grid = if uniform { Grid::uniform(b) } else { Grid::nf(b) };
                let packed = match stz.require(&format!("q.{name}.codes"))? {
                    Tensor::U8 { data, .. } => data,
                    _ => anyhow::bail!("bad codes tensor"),
                };
                let codebook = stz.get(&format!("q.{name}.codebook")).and_then(|t| t.as_f32()).map(|v| v.to_vec());
                let n_codes = if codebook.is_some() { rows * cols / 2 } else { rows * cols };
                let codes = pack::unpack(packed, if codebook.is_some() { 8 } else { b }, n_codes);
                layers.insert(
                    name.clone(),
                    QuantizedLinear {
                        rows,
                        cols,
                        group_size: group,
                        grid,
                        codes,
                        scales: stz
                            .require(&format!("q.{name}.scales"))?
                            .as_matrix()
                            .ok_or_else(|| anyhow::anyhow!("bad scales"))?,
                        shifts: stz.get(&format!("q.{name}.shifts")).and_then(|t| t.as_matrix()),
                        col_scale: stz
                            .get(&format!("q.{name}.t"))
                            .and_then(|t| t.as_f32())
                            .map(|v| v.to_vec()),
                        hadamard: desc.get("hadamard") == Some(&Json::Bool(true)),
                        hadamard_out: desc.get("hadamard_out") == Some(&Json::Bool(true)),
                        pair_codebook: codebook,
                        aux: AuxPrecision::F16,
                    },
                );
            }
        }
        let _ = cfg_sanity(&cfg, &layers)?;
        Ok(QuantizedModel { cfg, layers, fweights, fvectors, method, bits })
    }
}

fn cfg_sanity(
    cfg: &ModelConfig,
    layers: &BTreeMap<String, QuantizedLinear>,
) -> anyhow::Result<()> {
    for (name, q) in layers {
        let (r, c) = shape_of(cfg, name);
        anyhow::ensure!(
            (q.rows, q.cols) == (r, c),
            "layer {name}: stored shape ({}, {}) != config shape ({r}, {c})",
            q.rows,
            q.cols
        );
    }
    Ok(())
}

pub(crate) fn config_json(cfg: &ModelConfig) -> Json {
    Json::obj(vec![
        ("name", Json::Str(cfg.name.clone())),
        ("d", Json::Num(cfg.d as f64)),
        ("layers", Json::Num(cfg.layers as f64)),
        ("heads", Json::Num(cfg.heads as f64)),
        ("ffn", Json::Num(cfg.ffn as f64)),
        ("vocab", Json::Num(cfg.vocab as f64)),
        ("n_experts", Json::Num(cfg.n_experts as f64)),
        ("rope_base", Json::Num(cfg.rope_base as f64)),
        ("eps", Json::Num(cfg.eps as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_matrix, Method, QuantConfig};

    #[test]
    fn synthetic_model_has_all_weights() {
        let cfg = ModelConfig::family("pico").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 1);
        for n in cfg.weight_names() {
            assert!(mw.tensors.contains_key(&n) || mw.vectors.contains_key(&n), "{n}");
        }
        assert_eq!(mw.matrix("embed").rows, 256);
        assert_eq!(mw.vector("ln_f").len(), 64);
    }

    #[test]
    fn quantized_model_save_load_round_trip() {
        let cfg = ModelConfig::family("pico").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 2);
        let qc = QuantConfig::new(Method::Sinq, 4);
        let mut layers = BTreeMap::new();
        for name in cfg.quantizable_names() {
            layers.insert(name.clone(), quantize_matrix(&mw.tensors[&name], &qc, None).unwrap());
        }
        let qm = QuantizedModel {
            cfg: cfg.clone(),
            layers,
            fweights: BTreeMap::from([("embed".into(), mw.matrix("embed").clone())]),
            fvectors: mw.vectors.clone(),
            method: "sinq".into(),
            bits: 4,
        };
        let path = std::env::temp_dir().join("sinq_qm_test.stz");
        qm.save(&path).unwrap();
        let back = QuantizedModel::load(&path).unwrap();
        assert_eq!(back.method, "sinq");
        assert_eq!(back.layers.len(), qm.layers.len());
        for (name, q) in &qm.layers {
            let b = &back.layers[name];
            assert_eq!(b.codes, q.codes, "{name} codes");
            assert!(b.scales.dist(&q.scales) < 1e-6);
            let (orig, loaded) = (q.dequantize(), b.dequantize());
            assert!(orig.dist(&loaded) < 1e-4, "{name} dequant mismatch");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shape_of_matches_synthetic() {
        let cfg = ModelConfig::family("tiny_moe").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 3);
        for name in cfg.quantizable_names() {
            let (r, c) = shape_of(&cfg, &name);
            let m = &mw.tensors[&name];
            assert_eq!((m.rows, m.cols), (r, c), "{name}");
        }
    }
}
