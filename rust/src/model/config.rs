//! Model configuration — mirrors `python/compile/model.py::Config`.

use crate::util::json::Json;

/// Decoder-only transformer hyperparameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    /// 0 ⇒ dense SwiGLU MLP; otherwise switch-style top-1 MoE.
    pub n_experts: usize,
    pub rope_base: f32,
    pub eps: f32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d / self.heads
    }

    /// The built-in family (matching `python/compile/model.py::FAMILY`).
    pub fn family(name: &str) -> Option<ModelConfig> {
        let (d, layers, heads, ffn, n_experts) = match name {
            "pico" => (64, 2, 2, 256, 0),
            "tiny" => (128, 4, 4, 512, 0),
            "small" => (256, 4, 8, 1024, 0),
            "tiny_moe" => (128, 2, 4, 256, 4),
            _ => return None,
        };
        Some(ModelConfig {
            name: name.to_string(),
            d,
            layers,
            heads,
            ffn,
            vocab: 256,
            n_experts,
            rope_base: 10000.0,
            eps: 1e-5,
        })
    }

    /// Parse from the `.stz` checkpoint metadata (`meta.config`).
    pub fn from_meta(meta: &Json) -> anyhow::Result<ModelConfig> {
        let c = meta.get("config").ok_or_else(|| anyhow::anyhow!("meta missing 'config'"))?;
        let get = |k: &str| -> anyhow::Result<f64> {
            c.get(k).and_then(|j| j.as_f64()).ok_or_else(|| anyhow::anyhow!("config missing '{k}'"))
        };
        Ok(ModelConfig {
            name: c.get("name").and_then(|j| j.as_str()).unwrap_or("unknown").to_string(),
            d: get("d")? as usize,
            layers: get("layers")? as usize,
            heads: get("heads")? as usize,
            ffn: get("ffn")? as usize,
            vocab: get("vocab")? as usize,
            n_experts: get("n_experts").unwrap_or(0.0) as usize,
            rope_base: get("rope_base").unwrap_or(10000.0) as f32,
            eps: get("eps").unwrap_or(1e-5) as f32,
        })
    }

    /// Canonical ordered weight list (HLO artifact argument order) —
    /// must match `python/compile/model.py::weight_names` exactly.
    pub fn weight_names(&self) -> Vec<String> {
        let mut names = vec!["embed".to_string()];
        for i in 0..self.layers {
            let p = format!("layers.{i}");
            for suffix in ["ln1", "wq", "wk", "wv", "wo", "ln2"] {
                names.push(format!("{p}.{suffix}"));
            }
            if self.n_experts == 0 {
                for suffix in ["wg", "wu", "wd"] {
                    names.push(format!("{p}.{suffix}"));
                }
            } else {
                names.push(format!("{p}.router"));
                for e in 0..self.n_experts {
                    for suffix in ["wg", "wu", "wd"] {
                        names.push(format!("{p}.expert{e}.{suffix}"));
                    }
                }
            }
        }
        names.push("ln_f".to_string());
        names.push("lm_head".to_string());
        names
    }

    /// The linears weight-only PTQ applies to.
    pub fn quantizable_names(&self) -> Vec<String> {
        self.weight_names()
            .into_iter()
            .filter(|n| {
                let last = n.rsplit('.').next().unwrap();
                last.starts_with('w') && last != "wq_norm" || n == "lm_head" || last == "router"
            })
            .filter(|n| n != "embed")
            .collect()
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        let per_layer_attn = 4 * self.d * self.d + 2 * self.d;
        let per_layer_mlp = if self.n_experts == 0 {
            3 * self.d * self.ffn
        } else {
            self.n_experts * 3 * self.d * self.ffn + self.n_experts * self.d
        };
        2 * self.vocab * self.d + self.d + self.layers * (per_layer_attn + per_layer_mlp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_members_exist() {
        for name in ["pico", "tiny", "small", "tiny_moe"] {
            let c = ModelConfig::family(name).unwrap();
            assert_eq!(c.name, name);
            assert_eq!(c.d % c.heads, 0);
            assert!(c.d.is_power_of_two() && c.ffn.is_power_of_two());
        }
        assert!(ModelConfig::family("qwen3").is_none());
    }

    #[test]
    fn weight_names_dense_structure() {
        let c = ModelConfig::family("pico").unwrap();
        let names = c.weight_names();
        assert_eq!(names[0], "embed");
        assert_eq!(names.last().unwrap(), "lm_head");
        // 1 embed + 2 layers × 9 + 2 tail = 21
        assert_eq!(names.len(), 1 + 2 * 9 + 2);
        assert!(names.contains(&"layers.1.wd".to_string()));
    }

    #[test]
    fn weight_names_moe_structure() {
        let c = ModelConfig::family("tiny_moe").unwrap();
        let names = c.weight_names();
        assert!(names.contains(&"layers.0.router".to_string()));
        assert!(names.contains(&"layers.1.expert3.wd".to_string()));
        // 1 + 2 layers × (6 + 1 router + 4 experts × 3) + 2
        assert_eq!(names.len(), 1 + 2 * (6 + 1 + 12) + 2);
    }

    #[test]
    fn quantizable_excludes_norms_and_embed() {
        let c = ModelConfig::family("tiny").unwrap();
        let q = c.quantizable_names();
        assert!(q.iter().all(|n| !n.contains("ln") && n != "embed"));
        assert!(q.contains(&"lm_head".to_string()));
        assert_eq!(q.len(), 4 * 7 + 1); // 7 linears per layer + lm_head
    }

    #[test]
    fn param_count_sane() {
        let c = ModelConfig::family("tiny").unwrap();
        let n = c.n_params();
        assert!(n > 1_000_000 && n < 1_300_000, "tiny params {n}");
    }

    #[test]
    fn meta_round_trip() {
        let c = ModelConfig::family("small").unwrap();
        let meta = Json::parse(
            r#"{"config":{"name":"small","d":256,"layers":4,"heads":8,"ffn":1024,
                "vocab":256,"n_experts":0,"rope_base":10000.0,"eps":1e-5}}"#,
        )
        .unwrap();
        assert_eq!(ModelConfig::from_meta(&meta).unwrap(), c);
    }
}
