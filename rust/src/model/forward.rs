//! Reference transformer forward pass (pure Rust, f32).
//!
//! Mirrors `python/compile/model.py::forward` exactly (RMSNorm → RoPE MHA →
//! residual → RMSNorm → SwiGLU/MoE → residual; final norm; lm_head). Used
//! for: cross-checking the PJRT artifacts, activation capture (μ_x for
//! Fig. 2a/AWQ calibration), and evaluation settings the AOT graph does not
//! cover (W4A8 activation quantization, Table 16).

use std::collections::BTreeMap;

use crate::model::ModelConfig;
use crate::quant::crossquant;
use crate::tensor::Matrix;

/// Activation capture: running mean |x| and a bounded sample of input rows
/// per linear layer.
#[derive(Debug, Default)]
pub struct Capture {
    pub mu_x: BTreeMap<String, Vec<f64>>,
    pub counts: BTreeMap<String, usize>,
    pub samples: BTreeMap<String, Vec<Vec<f32>>>,
    pub max_samples: usize,
}

impl Capture {
    pub fn new(max_samples: usize) -> Capture {
        Capture { max_samples, ..Default::default() }
    }

    fn record(&mut self, name: &str, x: &Matrix) {
        let mu = self.mu_x.entry(name.to_string()).or_insert_with(|| vec![0.0; x.cols]);
        for i in 0..x.rows {
            for (j, &v) in x.row(i).iter().enumerate() {
                mu[j] += v.abs() as f64;
            }
        }
        *self.counts.entry(name.to_string()).or_insert(0) += x.rows;
        let samples = self.samples.entry(name.to_string()).or_default();
        let mut i = 0;
        while samples.len() < self.max_samples && i < x.rows {
            samples.push(x.row(i).to_vec());
            i += 1;
        }
    }

    /// Final mean absolute input per column for a layer.
    pub fn mean_abs(&self, name: &str) -> Option<Vec<f32>> {
        let mu = self.mu_x.get(name)?;
        let n = *self.counts.get(name)? as f64;
        Some(mu.iter().map(|&s| (s / n.max(1.0)) as f32).collect())
    }

    /// Calibration matrix (sampled input rows) for a layer.
    pub fn calibration(&self, name: &str) -> Option<Matrix> {
        let rows = self.samples.get(name)?;
        if rows.is_empty() {
            return None;
        }
        let cols = rows[0].len();
        let mut m = Matrix::zeros(rows.len(), cols);
        for (i, r) in rows.iter().enumerate() {
            m.row_mut(i).copy_from_slice(r);
        }
        Some(m)
    }
}

/// Evaluation-time options.
#[derive(Debug, Clone, Default)]
pub struct ForwardOpts {
    /// Fake-quantize activations to this many bits before every linear
    /// (CrossQuant's W4A8 setting; None = full precision).
    pub act_bits: Option<u32>,
}

/// The forward pass over a weight map (f32 effective weights).
pub struct Forward<'a> {
    pub cfg: &'a ModelConfig,
    pub weights: &'a BTreeMap<String, Matrix>,
    pub vectors: &'a BTreeMap<String, Vec<f32>>,
    pub opts: ForwardOpts,
}

impl<'a> Forward<'a> {
    pub fn new(
        cfg: &'a ModelConfig,
        weights: &'a BTreeMap<String, Matrix>,
        vectors: &'a BTreeMap<String, Vec<f32>>,
    ) -> Forward<'a> {
        Forward { cfg, weights, vectors, opts: ForwardOpts::default() }
    }

    fn linear(&self, x: &Matrix, name: &str, capture: &mut Option<&mut Capture>) -> Matrix {
        if let Some(c) = capture.as_deref_mut() {
            c.record(name, x);
        }
        let x_eff;
        let x_ref = if let Some(bits) = self.opts.act_bits {
            x_eff = crossquant::quantize_activations(x, bits);
            &x_eff
        } else {
            x
        };
        x_ref.matmul_nt(&self.weights[name])
    }

    /// Full-sequence forward for one sequence. `tokens` length S; returns
    /// (S, vocab) logits. `capture` records linear inputs when provided.
    pub fn forward(&self, tokens: &[u8], mut capture: Option<&mut Capture>) -> Matrix {
        let cfg = self.cfg;
        let s = tokens.len();
        let d = cfg.d;
        let hd = cfg.head_dim();

        // Embedding lookup.
        let embed = &self.weights["embed"];
        let mut h = Matrix::zeros(s, d);
        for (p, &tok) in tokens.iter().enumerate() {
            h.row_mut(p).copy_from_slice(embed.row(tok as usize));
        }

        // RoPE tables.
        let half = hd / 2;
        let mut cos = Matrix::zeros(s, half);
        let mut sin = Matrix::zeros(s, half);
        for p in 0..s {
            for i in 0..half {
                let inv = (cfg.rope_base as f64).powf(-(i as f64) * 2.0 / hd as f64);
                let ang = p as f64 * inv;
                *cos.at_mut(p, i) = ang.cos() as f32;
                *sin.at_mut(p, i) = ang.sin() as f32;
            }
        }

        for l in 0..cfg.layers {
            let pre = format!("layers.{l}");
            // --- Attention block ---
            let x = rmsnorm(&h, &self.vectors[&format!("{pre}.ln1")], cfg.eps);
            let q = self.linear(&x, &format!("{pre}.wq"), &mut capture);
            let k = self.linear(&x, &format!("{pre}.wk"), &mut capture);
            let v = self.linear(&x, &format!("{pre}.wv"), &mut capture);
            let (q, k) = (rope(&q, &cos, &sin, cfg.heads), rope(&k, &cos, &sin, cfg.heads));

            // Per-head causal attention.
            let mut ctx = Matrix::zeros(s, d);
            let scale = 1.0 / (hd as f32).sqrt();
            let mut att_row = vec![0.0f32; s];
            for head in 0..cfg.heads {
                let off = head * hd;
                for qi in 0..s {
                    let qrow = &q.row(qi)[off..off + hd];
                    let mut maxv = f32::NEG_INFINITY;
                    for (ki, a) in att_row.iter_mut().enumerate().take(qi + 1) {
                        let krow = &k.row(ki)[off..off + hd];
                        let mut dot = 0.0f32;
                        for t in 0..hd {
                            dot += qrow[t] * krow[t];
                        }
                        *a = dot * scale;
                        maxv = maxv.max(*a);
                    }
                    let mut denom = 0.0f32;
                    for a in att_row.iter_mut().take(qi + 1) {
                        *a = (*a - maxv).exp();
                        denom += *a;
                    }
                    let out = ctx.row_mut(qi);
                    for ki in 0..=qi {
                        let wgt = att_row[ki] / denom;
                        let vrow = &v.row(ki)[off..off + hd];
                        for t in 0..hd {
                            out[off + t] += wgt * vrow[t];
                        }
                    }
                }
            }
            let o = self.linear(&ctx, &format!("{pre}.wo"), &mut capture);
            add_inplace(&mut h, &o);

            // --- MLP block ---
            let x = rmsnorm(&h, &self.vectors[&format!("{pre}.ln2")], cfg.eps);
            let y = if cfg.n_experts == 0 {
                let g = self.linear(&x, &format!("{pre}.wg"), &mut capture);
                let u = self.linear(&x, &format!("{pre}.wu"), &mut capture);
                let mut act = Matrix::zeros(s, cfg.ffn);
                for i in 0..s * cfg.ffn {
                    act.data[i] = silu(g.data[i]) * u.data[i];
                }
                self.linear(&act, &format!("{pre}.wd"), &mut capture)
            } else {
                self.moe(&x, &pre, &mut capture)
            };
            add_inplace(&mut h, &y);
        }

        let hf = rmsnorm(&h, &self.vectors["ln_f"], cfg.eps);
        self.linear(&hf, "lm_head", &mut capture)
    }

    fn moe(&self, x: &Matrix, pre: &str, capture: &mut Option<&mut Capture>) -> Matrix {
        let cfg = self.cfg;
        let logits = self.linear(x, &format!("{pre}.router"), capture);
        let mut out = Matrix::zeros(x.rows, cfg.d);
        for i in 0..x.rows {
            // Softmax over experts, top-1 selection (switch routing).
            let row = logits.row(i);
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|&v| (v - maxv).exp()).collect();
            let denom: f32 = exps.iter().sum();
            let (top, _) = exps
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let gate = exps[top] / denom;

            // One-row expert MLP (dense within the selected expert).
            let xr = Matrix::from_vec(1, x.cols, x.row(i).to_vec());
            let g = self.linear(&xr, &format!("{pre}.expert{top}.wg"), capture);
            let u = self.linear(&xr, &format!("{pre}.expert{top}.wu"), capture);
            let mut act = Matrix::zeros(1, cfg.ffn);
            for j in 0..cfg.ffn {
                act.data[j] = silu(g.data[j]) * u.data[j];
            }
            let y = self.linear(&act, &format!("{pre}.expert{top}.wd"), capture);
            for (o, &yv) in out.row_mut(i).iter_mut().zip(y.row(0)) {
                *o = gate * yv;
            }
        }
        out
    }
}

/// SwiGLU's gate activation (shared with the native backend).
#[inline]
pub(crate) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

pub(crate) fn add_inplace(a: &mut Matrix, b: &Matrix) {
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

/// RMSNorm with gain.
pub fn rmsnorm(x: &Matrix, gain: &[f32], eps: f32) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let row = x.row(i);
        let ms: f32 = row.iter().map(|&v| v * v).sum::<f32>() / x.cols as f32;
        let r = 1.0 / (ms + eps).sqrt();
        for (j, (&v, &g)) in row.iter().zip(gain).enumerate() {
            out.data[i * x.cols + j] = v * r * g;
        }
    }
    out
}

/// Split-half RoPE (matches `model.py::apply_rope`; shared with the native
/// backend so the two forwards cannot diverge on the rotation convention).
pub(crate) fn rope(x: &Matrix, cos: &Matrix, sin: &Matrix, heads: usize) -> Matrix {
    let s = x.rows;
    let hd = x.cols / heads;
    let half = hd / 2;
    let mut out = Matrix::zeros(s, x.cols);
    for p in 0..s {
        for h in 0..heads {
            let off = h * hd;
            for i in 0..half {
                let (c, sn) = (cos.at(p, i), sin.at(p, i));
                let x1 = x.at(p, off + i);
                let x2 = x.at(p, off + half + i);
                *out.at_mut(p, off + i) = x1 * c - x2 * sn;
                *out.at_mut(p, off + half + i) = x2 * c + x1 * sn;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::store::ModelWeights;
    use crate::tensor::Rng;

    fn pico() -> ModelWeights {
        ModelWeights::synthetic(&ModelConfig::family("pico").unwrap(), 11)
    }

    #[test]
    fn forward_shapes_and_finite() {
        let mw = pico();
        let f = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let logits = f.forward(b"hello world!", None);
        assert_eq!((logits.rows, logits.cols), (12, 256));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_is_causal() {
        let mw = pico();
        let f = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let l1 = f.forward(b"abcdefgh", None);
        let l2 = f.forward(b"abcdefgX", None);
        for p in 0..7 {
            for j in 0..256 {
                assert!((l1.at(p, j) - l2.at(p, j)).abs() < 1e-4, "pos {p}");
            }
        }
        assert!(l1.row(7) != l2.row(7));
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let mut rng = Rng::new(12);
        let x = Matrix::randn(4, 64, 3.0, &mut rng);
        let out = rmsnorm(&x, &vec![1.0; 64], 1e-5);
        for i in 0..4 {
            let ms: f32 = out.row(i).iter().map(|&v| v * v).sum::<f32>() / 64.0;
            assert!((ms - 1.0).abs() < 1e-3, "row {i} ms {ms}");
        }
    }

    #[test]
    fn rope_preserves_norm_and_zero_position() {
        let mut rng = Rng::new(13);
        let x = Matrix::randn(3, 64, 1.0, &mut rng); // 2 heads × 32
        let mut cos = Matrix::zeros(3, 16);
        let mut sin = Matrix::zeros(3, 16);
        for p in 0..3 {
            for i in 0..16 {
                let ang = p as f64 * (10000f64).powf(-(i as f64) / 16.0);
                *cos.at_mut(p, i) = ang.cos() as f32;
                *sin.at_mut(p, i) = ang.sin() as f32;
            }
        }
        let r = rope(&x, &cos, &sin, 2);
        // Position 0: identity.
        assert_eq!(r.row(0), x.row(0));
        // Norms preserved (rotation).
        for p in 0..3 {
            let n0: f32 = x.row(p).iter().map(|v| v * v).sum();
            let n1: f32 = r.row(p).iter().map(|v| v * v).sum();
            assert!((n0 - n1).abs() / n0 < 1e-5);
        }
    }

    #[test]
    fn capture_collects_mu_and_samples() {
        let mw = pico();
        let f = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let mut cap = Capture::new(8);
        let _ = f.forward(b"some captured text", Some(&mut cap));
        let mu = cap.mean_abs("layers.0.wq").unwrap();
        assert_eq!(mu.len(), 64);
        assert!(mu.iter().all(|&m| m > 0.0));
        let calib = cap.calibration("layers.0.wq").unwrap();
        assert_eq!(calib.rows, 8);
    }

    #[test]
    fn moe_forward_runs() {
        let cfg = ModelConfig::family("tiny_moe").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 14);
        let f = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let logits = f.forward(b"moe!", None);
        assert_eq!((logits.rows, logits.cols), (4, 256));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn act_quant_8bit_small_effect() {
        let mw = pico();
        let mut f = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let l_fp = f.forward(b"activation quant", None);
        f.opts.act_bits = Some(8);
        let l_a8 = f.forward(b"activation quant", None);
        let max_diff = l_fp
            .data
            .iter()
            .zip(&l_a8.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1.0, "A8 changed logits by {max_diff}");
        assert!(max_diff > 0.0);
    }
}
