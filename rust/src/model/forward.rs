//! Reference transformer forward pass (pure Rust, f32).
//!
//! Mirrors `python/compile/model.py::forward` exactly (RMSNorm → RoPE MHA →
//! residual → RMSNorm → SwiGLU/MoE → residual; final norm; lm_head). Used
//! for: cross-checking the PJRT artifacts, activation capture (μ_x for
//! Fig. 2a/AWQ calibration), and evaluation settings the AOT graph does not
//! cover (W4A8 activation quantization, Table 16).
//!
//! The block math itself lives once in [`crate::backend::fwd`]; [`Forward`]
//! is the **f32-reference instantiation** of that core ([`SeqModel`] over a
//! dense weight map via `matmul_nt`), threading activation capture and
//! fake-quant through the linear dispatch. Its logits are bit-identical to
//! the pre-refactor hand-written loop — `tests/unified_core.rs` freezes
//! that loop as a golden oracle.

use std::collections::BTreeMap;

use crate::backend::fwd::{self, Gain, LinId, SeqModel};
use crate::model::ModelConfig;
use crate::quant::crossquant;
use crate::tensor::Matrix;

pub use crate::backend::fwd::rmsnorm;

/// Activation capture: running mean |x| and a bounded sample of input rows
/// per linear layer.
#[derive(Debug, Default)]
pub struct Capture {
    pub mu_x: BTreeMap<String, Vec<f64>>,
    pub counts: BTreeMap<String, usize>,
    pub samples: BTreeMap<String, Vec<Vec<f32>>>,
    pub max_samples: usize,
}

impl Capture {
    pub fn new(max_samples: usize) -> Capture {
        Capture { max_samples, ..Default::default() }
    }

    fn record(&mut self, name: &str, x: &Matrix) {
        let mu = self.mu_x.entry(name.to_string()).or_insert_with(|| vec![0.0; x.cols]);
        for i in 0..x.rows {
            for (j, &v) in x.row(i).iter().enumerate() {
                mu[j] += v.abs() as f64;
            }
        }
        *self.counts.entry(name.to_string()).or_insert(0) += x.rows;
        let samples = self.samples.entry(name.to_string()).or_default();
        let mut i = 0;
        while samples.len() < self.max_samples && i < x.rows {
            samples.push(x.row(i).to_vec());
            i += 1;
        }
    }

    /// Final mean absolute input per column for a layer.
    pub fn mean_abs(&self, name: &str) -> Option<Vec<f32>> {
        let mu = self.mu_x.get(name)?;
        let n = *self.counts.get(name)? as f64;
        Some(mu.iter().map(|&s| (s / n.max(1.0)) as f32).collect())
    }

    /// Calibration matrix (sampled input rows) for a layer.
    pub fn calibration(&self, name: &str) -> Option<Matrix> {
        let rows = self.samples.get(name)?;
        if rows.is_empty() {
            return None;
        }
        let cols = rows[0].len();
        let mut m = Matrix::zeros(rows.len(), cols);
        for (i, r) in rows.iter().enumerate() {
            m.row_mut(i).copy_from_slice(r);
        }
        Some(m)
    }
}

/// Evaluation-time options.
#[derive(Debug, Clone, Default)]
pub struct ForwardOpts {
    /// Fake-quantize activations to this many bits before every linear
    /// (CrossQuant's W4A8 setting; None = full precision).
    pub act_bits: Option<u32>,
}

/// The forward pass over a weight map (f32 effective weights).
pub struct Forward<'a> {
    pub cfg: &'a ModelConfig,
    pub weights: &'a BTreeMap<String, Matrix>,
    pub vectors: &'a BTreeMap<String, Vec<f32>>,
    pub opts: ForwardOpts,
}

impl<'a> Forward<'a> {
    pub fn new(
        cfg: &'a ModelConfig,
        weights: &'a BTreeMap<String, Matrix>,
        vectors: &'a BTreeMap<String, Vec<f32>>,
    ) -> Forward<'a> {
        Forward { cfg, weights, vectors, opts: ForwardOpts::default() }
    }

    /// Full-sequence forward for one sequence. `tokens` length S; returns
    /// (S, vocab) logits. `capture` records linear inputs when provided.
    /// Panics on a missing weight/gain, exactly like the pre-core map
    /// indexing did.
    pub fn forward(&self, tokens: &[u8], capture: Option<&mut Capture>) -> Matrix {
        if tokens.is_empty() {
            // Pre-core behavior: an empty sequence yields an empty logits
            // matrix (the native backend's Result path still rejects it).
            return Matrix::zeros(0, self.cfg.vocab);
        }
        let mut m = RefSeq { fwd: self, capture };
        fwd::forward_seq(&mut m, tokens).expect("reference forward")
    }
}

/// The f32-reference [`SeqModel`] instantiation: dense `matmul_nt` per
/// linear, with activation capture and optional fake-quant threaded
/// through the dispatch.
struct RefSeq<'f, 'a, 'c> {
    fwd: &'f Forward<'a>,
    capture: Option<&'c mut Capture>,
}

impl SeqModel for RefSeq<'_, '_, '_> {
    fn cfg(&self) -> &ModelConfig {
        self.fwd.cfg
    }

    fn embed_row(&self, token: u8) -> anyhow::Result<&[f32]> {
        Ok(self.fwd.weights["embed"].row(token as usize))
    }

    fn gain(&self, g: Gain) -> anyhow::Result<&[f32]> {
        Ok(&self.fwd.vectors[&g.name()])
    }

    fn linear(&mut self, id: LinId, x: &Matrix) -> anyhow::Result<Matrix> {
        let name = id.name();
        if let Some(c) = self.capture.as_deref_mut() {
            c.record(&name, x);
        }
        let x_eff;
        let x_ref = if let Some(bits) = self.fwd.opts.act_bits {
            x_eff = crossquant::quantize_activations(x, bits);
            &x_eff
        } else {
            x
        };
        Ok(x_ref.matmul_nt(&self.fwd.weights[&name]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::fwd::rope;
    use crate::model::store::ModelWeights;
    use crate::tensor::Rng;

    fn pico() -> ModelWeights {
        ModelWeights::synthetic(&ModelConfig::family("pico").unwrap(), 11)
    }

    #[test]
    fn forward_shapes_and_finite() {
        let mw = pico();
        let f = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let logits = f.forward(b"hello world!", None);
        assert_eq!((logits.rows, logits.cols), (12, 256));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_is_causal() {
        let mw = pico();
        let f = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let l1 = f.forward(b"abcdefgh", None);
        let l2 = f.forward(b"abcdefgX", None);
        for p in 0..7 {
            for j in 0..256 {
                assert!((l1.at(p, j) - l2.at(p, j)).abs() < 1e-4, "pos {p}");
            }
        }
        assert!(l1.row(7) != l2.row(7));
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let mut rng = Rng::new(12);
        let x = Matrix::randn(4, 64, 3.0, &mut rng);
        let out = rmsnorm(&x, &vec![1.0; 64], 1e-5);
        for i in 0..4 {
            let ms: f32 = out.row(i).iter().map(|&v| v * v).sum::<f32>() / 64.0;
            assert!((ms - 1.0).abs() < 1e-3, "row {i} ms {ms}");
        }
    }

    #[test]
    fn rope_preserves_norm_and_zero_position() {
        let mut rng = Rng::new(13);
        let x = Matrix::randn(3, 64, 1.0, &mut rng); // 2 heads × 32
        let mut cos = Matrix::zeros(3, 16);
        let mut sin = Matrix::zeros(3, 16);
        for p in 0..3 {
            for i in 0..16 {
                let ang = p as f64 * (10000f64).powf(-(i as f64) / 16.0);
                *cos.at_mut(p, i) = ang.cos() as f32;
                *sin.at_mut(p, i) = ang.sin() as f32;
            }
        }
        let r = rope(&x, &cos, &sin, 2);
        // Position 0: identity.
        assert_eq!(r.row(0), x.row(0));
        // Norms preserved (rotation).
        for p in 0..3 {
            let n0: f32 = x.row(p).iter().map(|v| v * v).sum();
            let n1: f32 = r.row(p).iter().map(|v| v * v).sum();
            assert!((n0 - n1).abs() / n0 < 1e-5);
        }
    }

    #[test]
    fn capture_collects_mu_and_samples() {
        let mw = pico();
        let f = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let mut cap = Capture::new(8);
        let _ = f.forward(b"some captured text", Some(&mut cap));
        let mu = cap.mean_abs("layers.0.wq").unwrap();
        assert_eq!(mu.len(), 64);
        assert!(mu.iter().all(|&m| m > 0.0));
        let calib = cap.calibration("layers.0.wq").unwrap();
        assert_eq!(calib.rows, 8);
    }

    #[test]
    fn moe_forward_runs() {
        let cfg = ModelConfig::family("tiny_moe").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 14);
        let f = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let logits = f.forward(b"moe!", None);
        assert_eq!((logits.rows, logits.cols), (4, 256));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn act_quant_8bit_small_effect() {
        let mw = pico();
        let mut f = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let l_fp = f.forward(b"activation quant", None);
        f.opts.act_bits = Some(8);
        let l_a8 = f.forward(b"activation quant", None);
        let max_diff = l_fp
            .data
            .iter()
            .zip(&l_a8.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1.0, "A8 changed logits by {max_diff}");
        assert!(max_diff > 0.0);
    }
}
