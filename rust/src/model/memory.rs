//! Memory accounting — the "Mem." columns of Tables 1/3/4/8.
//!
//! The paper reports *actual total memory use including activations*. We
//! account: packed quantized weights + auxiliaries (per method) + the
//! non-quantized f16 remainder (embeddings, norm gains) + the activation
//! working set for the evaluation batch, exactly as a deployment would
//! allocate it.

use crate::model::{ModelConfig, QuantizedModel};

/// Bytes for the f16 baseline model (all weights half precision).
pub fn baseline_bytes(cfg: &ModelConfig) -> usize {
    cfg.n_params() * 2
}

/// Activation working set for a (batch, seq) evaluation: hidden + attention
/// scores + MLP intermediate, double-buffered, f16.
pub fn activation_bytes(cfg: &ModelConfig, batch: usize, seq: usize) -> usize {
    let hidden = batch * seq * cfg.d;
    let scores = batch * cfg.heads * seq * seq;
    let mlp = batch * seq * cfg.ffn;
    let logits = batch * seq * cfg.vocab;
    2 * (2 * hidden + scores + mlp + logits)
}

/// Total bytes of a quantized model + activations.
pub fn quantized_total_bytes(qm: &QuantizedModel, batch: usize, seq: usize) -> usize {
    let mut bytes = 0usize;
    for q in qm.layers.values() {
        bytes += q.total_bytes();
    }
    // Shared pair codebook (codebook method): counted once.
    if let Some(q) = qm.layers.values().find(|q| q.pair_codebook.is_some()) {
        bytes += q.pair_codebook.as_ref().unwrap().len() * 2;
    }
    // Non-quantized weights in f16.
    for m in qm.fweights.values() {
        bytes += m.numel() * 2;
    }
    for v in qm.fvectors.values() {
        bytes += v.len() * 2;
    }
    bytes + activation_bytes(&qm.cfg, batch, seq)
}

/// Scale a byte count the way the paper reports GB (model-size axis of the
/// Pareto plots).
pub fn gb(bytes: usize) -> f64 {
    bytes as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::store::ModelWeights;
    use crate::quant::{quantize_matrix, Method, QuantConfig};
    use std::collections::BTreeMap;

    fn quantized(bits: u32) -> QuantizedModel {
        let cfg = ModelConfig::family("pico").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 5);
        let qc = QuantConfig::new(Method::Sinq, bits);
        let mut layers = BTreeMap::new();
        for name in cfg.quantizable_names() {
            layers.insert(name.clone(), quantize_matrix(&mw.tensors[&name], &qc, None).unwrap());
        }
        QuantizedModel {
            cfg,
            layers,
            fweights: BTreeMap::from([("embed".into(), mw.matrix("embed").clone())]),
            fvectors: mw.vectors.clone(),
            method: "sinq".into(),
            bits,
        }
    }

    #[test]
    fn four_bit_under_half_of_baseline() {
        let qm = quantized(4);
        let q_bytes = quantized_total_bytes(&qm, 1, 1);
        let base = baseline_bytes(&qm.cfg) + activation_bytes(&qm.cfg, 1, 1);
        assert!(
            (q_bytes as f64) < base as f64 * 0.62,
            "4-bit {q_bytes} vs baseline {base}"
        );
    }

    #[test]
    fn three_bit_smaller_than_four_bit() {
        let q3 = quantized_total_bytes(&quantized(3), 1, 1);
        let q4 = quantized_total_bytes(&quantized(4), 1, 1);
        assert!(q3 < q4);
    }

    #[test]
    fn activations_grow_with_batch() {
        let cfg = ModelConfig::family("tiny").unwrap();
        assert!(activation_bytes(&cfg, 8, 128) > activation_bytes(&cfg, 1, 128) * 7);
    }
}
