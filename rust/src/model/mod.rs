//! Model layer: configuration, weight stores, the reference forward pass,
//! the no-overhead SINQ folding pass, and memory accounting.
//!
//! The architecture mirrors `python/compile/model.py` operation-for-operation
//! (pre-norm RMSNorm, RoPE MHA, SwiGLU / switch-MoE MLP); integration tests
//! cross-check the Rust forward against logits produced through the PJRT
//! artifact of the JAX forward.

pub mod config;
pub mod fold;
pub mod forward;
pub mod memory;
pub mod store;

pub use config::ModelConfig;
pub use store::{ModelWeights, QuantizedModel};
