//! Inference backends: one trait, two engines.
//!
//! [`InferenceBackend`] abstracts "a thing that turns token sequences into
//! logits (and optionally generates)", so the serving coordinator, the
//! evaluators, and the CLI dispatch without caring what executes the model:
//!
//! * [`NativeBackend`] — pure Rust, runs **directly on bit-packed SINQ/RTN
//!   weights** via the fused kernels in [`quantized`], whose inner loops
//!   dispatch to runtime-selected AVX2/NEON implementations in [`simd`];
//!   works on any box with zero artifacts, zero XLA, zero Python.
//! * [`crate::runtime::PjrtForward`] — executes AOT-compiled HLO artifacts
//!   through PJRT (requires `make artifacts` and a real `xla` binding).
//!
//! [`build`] is the one-stop factory the CLI's `--backend native|pjrt|auto`
//! flag resolves through; it handles checkpoint loading (with a
//! synthetic-model fallback so fresh machines still run), `.stz` quantized
//! models, and on-the-fly quantization via the coordinator pipeline.
//! `auto` probes for artifacts plus a usable PJRT client ([`resolve`]) and
//! falls back to the native engine when either is missing.

pub mod batch;
pub mod config;
pub mod fwd;
pub mod native;
pub mod paged;
pub mod quantized;
pub mod simd;

pub use batch::{ensure_fits, BatchDecoder, BatchStats, CancelOutcome, GenOutput, GenRequest};
pub use config::EngineConfig;
pub use fwd::{KvBits, KvStore, LinearOp, SampleCfg, TokenPicker};
pub use native::{NativeBackend, NativeDecoder};
pub use quantized::QuantizedTensor;
pub use simd::{kernel_name, Isa};

use crate::coordinator::{pipeline, scheduler};
use crate::data::Corpus;
use crate::eval::LogitsEngine;
use crate::model::QuantizedModel;
use crate::quant::QuantConfig;
use crate::runtime::{PjrtForward, PjrtRuntime};
use crate::tensor::Matrix;

/// A model execution engine: scoring (logits) plus optional generation.
///
/// Extends [`LogitsEngine`] (single-sequence scoring) with the batch and
/// decode entry points the serving path needs. Implementations must be
/// deterministic for a fixed weight set.
pub trait InferenceBackend: LogitsEngine {
    /// Short identifier ("native", "pjrt") for logs and error messages.
    fn name(&self) -> &'static str;

    /// Largest batch `forward_batch` can exploit; the dynamic batcher
    /// groups up to this many requests per dispatch.
    fn max_batch(&self) -> usize {
        1
    }

    /// Score a batch of sequences. The default loops `logits`; backends
    /// with true batched execution override it.
    fn forward_batch(&mut self, seqs: &[&[u8]]) -> anyhow::Result<Vec<Matrix>> {
        seqs.iter().map(|s| self.logits(s)).collect()
    }

    /// Greedy autoregressive generation from a prompt.
    fn generate(&mut self, _prompt: &[u8], _n: usize) -> anyhow::Result<Vec<u8>> {
        anyhow::bail!("backend '{}' does not support autoregressive generation", self.name())
    }

    /// Greedy generation for many prompts: `max_new[i]` tokens for
    /// `prompts[i]`, tokens identical to per-prompt
    /// [`InferenceBackend::generate`]. The default loops `generate`;
    /// backends with a continuous-batching decode engine override it.
    fn generate_batch(
        &mut self,
        prompts: &[&[u8]],
        max_new: &[usize],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        anyhow::ensure!(
            prompts.len() == max_new.len(),
            "generate_batch: {} prompts but {} max_new entries",
            prompts.len(),
            max_new.len()
        );
        prompts.iter().zip(max_new).map(|(p, &n)| self.generate(p, n)).collect()
    }
}

impl<T: InferenceBackend + ?Sized> LogitsEngine for Box<T> {
    fn logits(&mut self, tokens: &[u8]) -> anyhow::Result<Matrix> {
        (**self).logits(tokens)
    }

    fn vocab(&self) -> usize {
        (**self).vocab()
    }
}

impl<T: InferenceBackend + ?Sized> InferenceBackend for Box<T> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn max_batch(&self) -> usize {
        (**self).max_batch()
    }

    fn forward_batch(&mut self, seqs: &[&[u8]]) -> anyhow::Result<Vec<Matrix>> {
        (**self).forward_batch(seqs)
    }

    fn generate(&mut self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
        (**self).generate(prompt, n)
    }

    fn generate_batch(
        &mut self,
        prompts: &[&[u8]],
        max_new: &[usize],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        (**self).generate_batch(prompts, max_new)
    }
}

/// Which engine executes the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust fused-kernel engine (default; artifact-free).
    Native,
    /// PJRT execution of AOT artifacts.
    Pjrt,
    /// Probe at build time: PJRT when artifacts + a real client exist,
    /// native otherwise (see [`resolve`]).
    Auto,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "native" => Some(BackendKind::Native),
            "pjrt" => Some(BackendKind::Pjrt),
            "auto" => Some(BackendKind::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Auto => "auto",
        }
    }
}

/// Resolve [`BackendKind::Auto`] to a concrete engine: pick PJRT when the
/// artifact manifest is present *and* a PJRT client can actually be
/// constructed (the vendored offline `xla` stub cannot), otherwise fall
/// back to the native engine. Concrete kinds pass through unchanged.
pub fn resolve(kind: BackendKind, art_dir: &str) -> BackendKind {
    if kind != BackendKind::Auto {
        return kind;
    }
    let manifest = std::path::Path::new(art_dir).join("manifest.json");
    if manifest.exists() && PjrtRuntime::cpu(art_dir).is_ok() {
        BackendKind::Pjrt
    } else {
        BackendKind::Native
    }
}

/// Everything [`build`] needs to assemble a backend. Plain data
/// (`Clone + Send`) so it can cross into the serving thread.
#[derive(Debug, Clone)]
pub struct BackendSpec {
    pub kind: BackendKind,
    pub art_dir: String,
    pub model: String,
    /// Load a pre-quantized `.stz` model instead of the f32 checkpoint.
    pub quantized: Option<String>,
    /// Quantize the checkpoint in-process before serving (native only).
    pub quantize: Option<QuantConfig>,
    /// Engine defaults for the decode paths (KV precision, batch width,
    /// context cap, page geometry, sampling); threaded into the built
    /// backend so every decoder inherits one configuration.
    pub engine: EngineConfig,
}

impl BackendSpec {
    pub fn new(kind: BackendKind, art_dir: &str, model: &str) -> BackendSpec {
        BackendSpec {
            kind,
            art_dir: art_dir.to_string(),
            model: model.to_string(),
            quantized: None,
            quantize: None,
            engine: EngineConfig::default(),
        }
    }
}

/// Build the backend described by `spec`. [`BackendKind::Auto`] is resolved
/// here (see [`resolve`]); [`InferenceBackend::name`] on the result reports
/// the engine that was actually chosen.
pub fn build(spec: &BackendSpec) -> anyhow::Result<Box<dyn InferenceBackend>> {
    match resolve(spec.kind, &spec.art_dir) {
        BackendKind::Auto => unreachable!("resolve returns a concrete backend kind"),
        BackendKind::Native => Ok(Box::new(build_native(spec)?)),
        BackendKind::Pjrt => {
            anyhow::ensure!(
                spec.quantize.is_none(),
                "on-the-fly quantization is only supported by the native backend; \
                 quantize to .stz first and pass it via `quantized`"
            );
            let rt = PjrtRuntime::cpu(&spec.art_dir)?;
            let mw = scheduler::load_family_member(&spec.art_dir, &spec.model)?;
            let fwd = if let Some(path) = &spec.quantized {
                let qm = QuantizedModel::load(path)?;
                let eff = qm.effective_weights();
                PjrtForward::new(&rt, &mw.cfg, &eff, &qm.fvectors)?
            } else {
                PjrtForward::new(&rt, &mw.cfg, &mw.tensors, &mw.vectors)?
            };
            Ok(Box::new(fwd))
        }
    }
}

/// Build the native engine *concretely* from `spec` — the streaming serving
/// front-end ([`crate::serve`]) needs a `NativeBackend` value (not a boxed
/// trait object) because [`BatchDecoder`] borrows it for its incremental
/// decode sessions. Handles the same `.stz` / on-the-fly-quantize /
/// synthetic-fallback paths as [`build`]; errors if the spec resolves to a
/// non-native engine.
pub fn build_native(spec: &BackendSpec) -> anyhow::Result<NativeBackend> {
    let resolved = resolve(spec.kind, &spec.art_dir);
    anyhow::ensure!(
        resolved == BackendKind::Native,
        "this path requires the native engine but the backend spec resolves to '{}'; \
         rerun with --backend native",
        resolved.name()
    );
    if let Some(path) = &spec.quantized {
        let qm = QuantizedModel::load(path)?;
        return Ok(NativeBackend::from_quantized(&qm).with_engine(spec.engine));
    }
    let mw = scheduler::load_or_synthetic_checked(&spec.art_dir, &spec.model, 42)?;
    if let Some(qcfg) = &spec.quantize {
        let calib = if qcfg.method.needs_calibration() {
            let c = Corpus::load_or_synthetic(&spec.art_dir, "wiki", "train");
            Some(c.data[..768.min(c.data.len())].to_vec())
        } else {
            None
        };
        let opts = pipeline::PipelineOpts {
            schedule: scheduler::ScheduleOpts {
                threads: 2,
                calib_sample: calib,
                verbose: false,
            },
            no_overhead: false,
        };
        return pipeline::run_to_backend(&mw, qcfg, &opts, spec.engine);
    }
    Ok(NativeBackend::from_weights(&mw).with_engine(spec.engine))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Method;

    #[test]
    fn kind_parse_round_trip() {
        for k in [BackendKind::Native, BackendKind::Pjrt, BackendKind::Auto] {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("tpu"), None);
    }

    #[test]
    fn auto_resolves_to_native_without_artifacts() {
        assert_eq!(resolve(BackendKind::Auto, "/nonexistent"), BackendKind::Native);
        // Concrete kinds pass through untouched.
        assert_eq!(resolve(BackendKind::Pjrt, "/nonexistent"), BackendKind::Pjrt);
        // And `build` on an auto spec yields a working native engine.
        let spec = BackendSpec::new(BackendKind::Auto, "/nonexistent", "pico");
        let mut be = build(&spec).unwrap();
        assert_eq!(be.name(), "native");
        assert!(be.logits(b"auto").unwrap().data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn build_native_rejects_pjrt_spec_with_clear_error() {
        let spec = BackendSpec::new(BackendKind::Pjrt, "/nonexistent", "pico");
        let err = build_native(&spec).map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("--backend native"), "{err}");
        // And the concrete native build produces the same engine `build` boxes.
        let spec = BackendSpec::new(BackendKind::Native, "/nonexistent", "pico");
        let be = build_native(&spec).unwrap();
        assert!(be.forward(b"concrete").unwrap().data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn spec_engine_config_reaches_backend() {
        let mut spec = BackendSpec::new(BackendKind::Native, "/nonexistent", "pico");
        spec.engine = spec.engine.with_max_batch(9).with_kv_bits(KvBits::Q8);
        let be = build_native(&spec).unwrap();
        assert_eq!(InferenceBackend::max_batch(&be), 9);
        assert_eq!(be.kv_bits(), KvBits::Q8);
    }

    #[test]
    fn build_native_without_artifacts() {
        let spec = BackendSpec::new(BackendKind::Native, "/nonexistent", "pico");
        let mut be = build(&spec).unwrap();
        assert_eq!(be.name(), "native");
        let logits = be.logits(b"hello backend").unwrap();
        assert_eq!((logits.rows, logits.cols), (13, 256));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn build_native_quantized_on_the_fly() {
        let mut spec = BackendSpec::new(BackendKind::Native, "/nonexistent", "pico");
        spec.quantize = Some(QuantConfig::new(Method::Sinq, 4));
        let mut be = build(&spec).unwrap();
        let logits = be.logits(b"quantized").unwrap();
        assert!(logits.data.iter().all(|v| v.is_finite()));
        let gen = be.generate(b"abc", 4).unwrap();
        assert_eq!(gen.len(), 4);
    }

    #[test]
    fn build_unknown_model_errors() {
        let spec = BackendSpec::new(BackendKind::Native, "/nonexistent", "qwen3");
        assert!(build(&spec).is_err());
    }
}
