//! Paged KV allocator with prefix caching.
//!
//! [`PagedKv`] replaces the contiguous per-slot KV reservation with a
//! fixed pool of pages, each covering `page_size` positions across **all**
//! layers. A slot owns a page table mapping logical position `pos` to
//! physical row `table[pos / page_size] * page_size + pos % page_size`;
//! pages are claimed lazily as decode advances, so admission is charged by
//! pages actually allocated instead of the worst case.
//!
//! Exactness: the attend paths replicate the contiguous stores'
//! arithmetic ([`crate::backend::fwd::causal_attend`] and
//! [`crate::backend::fwd::KvQ8`]'s SIMD-dispatched loop) operation for
//! operation — only the row index is translated through the page table —
//! and writes reuse the same deterministic per-row quantizer, so paged
//! decode is bit-identical to the contiguous cache at both precisions.
//!
//! [`PrefixCache`] keys **full** pages by the token prefix that produced
//! them (position `p`'s KV depends only on tokens `0..=p`, and RoPE is
//! absolute, so equal prefixes yield equal pages). A new request whose
//! prompt starts with a cached prefix maps those pages copy-free
//! (refcounted) and skips prefill for the shared span. Eviction is
//! LRU over leaf entries, so a chain of pages is released deepest-first.

use crate::backend::fwd::{AttnScratch, KvArena, KvBits, KvQ8, ATTEND_PARALLEL_THRESHOLD};
use crate::backend::simd;
use crate::tensor::Matrix;
use crate::util::threadpool;

/// Backing storage for the page pool, at the engine's KV precision. Row
/// layout matches the contiguous stores with `capacity` replaced by the
/// pool's total rows, so the inner loops are index-for-index identical.
enum PagedStore {
    F32 {
        /// Per layer: `(pages_total * page_size, d)` K/V rows.
        k: Vec<Matrix>,
        v: Vec<Matrix>,
    },
    Q8 {
        /// Physical rows per layer (`pages_total * page_size`).
        rows: usize,
        k_codes: Vec<u8>,
        v_codes: Vec<u8>,
        k_scale: Vec<f32>,
        k_min: Vec<f32>,
        v_scale: Vec<f32>,
        v_min: Vec<f32>,
    },
}

/// Fixed-size page pool plus per-slot page tables; the [`KvArena`] the
/// continuous batcher decodes through.
pub(crate) struct PagedKv {
    page_size: usize,
    pages_total: usize,
    d: usize,
    heads: usize,
    hd: usize,
    layers: usize,
    store: PagedStore,
    /// Free page indices (stack; claiming pops).
    free: Vec<u32>,
    /// Per-page references: one per slot mapping it + one if a prefix-cache
    /// entry holds it. A page returns to `free` when this reaches zero.
    rc: Vec<u32>,
    /// Per-slot page tables (block index → page).
    tables: Vec<Vec<u32>>,
}

impl PagedKv {
    pub(crate) fn new(
        bits: KvBits,
        layers: usize,
        d: usize,
        heads: usize,
        slots: usize,
        page_size: usize,
        pages_total: usize,
    ) -> PagedKv {
        let (ps, pages) = (page_size.max(1), pages_total.max(1));
        let rows = pages * ps;
        let store = match bits {
            KvBits::F32 => PagedStore::F32 {
                k: (0..layers).map(|_| Matrix::zeros(rows, d)).collect(),
                v: (0..layers).map(|_| Matrix::zeros(rows, d)).collect(),
            },
            KvBits::Q8 => {
                let elems = layers * rows * d;
                let affines = layers * rows * heads;
                PagedStore::Q8 {
                    rows,
                    k_codes: vec![0; elems],
                    v_codes: vec![0; elems],
                    k_scale: vec![0.0; affines],
                    k_min: vec![0.0; affines],
                    v_scale: vec![0.0; affines],
                    v_min: vec![0.0; affines],
                }
            }
        };
        PagedKv {
            page_size: ps,
            pages_total: pages,
            d,
            heads,
            hd: d / heads,
            layers,
            store,
            // Reversed so the first claim pops page 0.
            free: (0..pages as u32).rev().collect(),
            rc: vec![0; pages],
            tables: (0..slots).map(|_| Vec::new()).collect(),
        }
    }

    /// Physical row of (`slot`, `pos`) through the slot's page table.
    fn phys(&self, slot: usize, pos: usize) -> usize {
        self.tables[slot][pos / self.page_size] as usize * self.page_size + pos % self.page_size
    }

    /// Does `slot`'s table already cover block `block`?
    pub(crate) fn has_block(&self, slot: usize, block: usize) -> bool {
        self.tables[slot].len() > block
    }

    /// Claim one free page as `slot`'s next block. `false` when the pool
    /// is dry — the caller evicts or preempts and retries.
    pub(crate) fn try_claim(&mut self, slot: usize) -> bool {
        match self.free.pop() {
            Some(p) => {
                debug_assert_eq!(self.rc[p as usize], 0, "free page with live references");
                self.rc[p as usize] = 1;
                self.tables[slot].push(p);
                true
            }
            None => false,
        }
    }

    /// Map prefix-cached pages as the leading blocks of an empty slot,
    /// copy-free (each page's refcount grows by one).
    pub(crate) fn assign_shared(&mut self, slot: usize, pages: &[u32]) {
        debug_assert!(self.tables[slot].is_empty(), "shared pages must lead the table");
        for &p in pages {
            self.rc[p as usize] += 1;
            self.tables[slot].push(p);
        }
    }

    /// Release every page `slot` maps; pages drop to the free list when
    /// no other slot or prefix-cache entry holds them.
    pub(crate) fn release_slot(&mut self, slot: usize) {
        let table = std::mem::take(&mut self.tables[slot]);
        for p in table {
            self.unref(p);
        }
    }

    /// Add a prefix-cache reference to `page`.
    pub(crate) fn cache_ref(&mut self, page: u32) {
        self.rc[page as usize] += 1;
    }

    /// Drop one reference to `page` (slot release or cache eviction).
    pub(crate) fn unref(&mut self, page: u32) {
        let rc = &mut self.rc[page as usize];
        debug_assert!(*rc > 0, "unref of a free page");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page);
        }
    }

    /// Pages held by `slot`, in block order.
    pub(crate) fn table(&self, slot: usize) -> &[u32] {
        &self.tables[slot]
    }

    pub(crate) fn page_size(&self) -> usize {
        self.page_size
    }

    pub(crate) fn pages_total(&self) -> usize {
        self.pages_total
    }

    pub(crate) fn pages_free(&self) -> usize {
        self.free.len()
    }

    pub(crate) fn kv_bits(&self) -> KvBits {
        match self.store {
            PagedStore::F32 { .. } => KvBits::F32,
            PagedStore::Q8 { .. } => KvBits::Q8,
        }
    }

    /// Resident bytes of one page (`page_size` positions × all layers) —
    /// what the pool multiplies and `/metrics` reports.
    pub(crate) fn bytes_per_page(&self) -> usize {
        let per_pos = match self.store {
            // K + V rows of f32.
            PagedStore::F32 { .. } => 2 * self.d * 4,
            // K + V codes plus 4 f32 affines per head.
            PagedStore::Q8 { .. } => 2 * self.d + 16 * self.heads,
        };
        self.page_size * self.layers * per_pos
    }
}

impl KvArena for PagedKv {
    fn write(&mut self, slot: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        crate::obs::fault::check_hard(crate::obs::fault::Site::KvWrite);
        let phys = self.phys(slot, pos);
        let (d, heads, hd) = (self.d, self.heads, self.hd);
        match &mut self.store {
            PagedStore::F32 { k: kc, v: vc } => {
                kc[layer].row_mut(phys).copy_from_slice(k);
                vc[layer].row_mut(phys).copy_from_slice(v);
            }
            PagedStore::Q8 { rows, k_codes, v_codes, k_scale, k_min, v_scale, v_min } => {
                let idx = layer * *rows + phys;
                let (c0, a0) = (idx * d, idx * heads);
                KvQ8::quant_row(
                    &mut k_codes[c0..c0 + d],
                    &mut k_scale[a0..a0 + heads],
                    &mut k_min[a0..a0 + heads],
                    k,
                    heads,
                    hd,
                );
                KvQ8::quant_row(
                    &mut v_codes[c0..c0 + d],
                    &mut v_scale[a0..a0 + heads],
                    &mut v_min[a0..a0 + heads],
                    v,
                    heads,
                    hd,
                );
            }
        }
    }

    fn attend(
        &self,
        slot: usize,
        layer: usize,
        q: &[f32],
        pos: usize,
        ctx: &mut [f32],
        s: &mut AttnScratch,
        threads: usize,
    ) {
        let (d, hd, heads, ps) = (self.d, self.hd, self.heads, self.page_size);
        let table = &self.tables[slot];
        let scale = 1.0 / (hd as f32).sqrt();
        let work = heads * (pos + 1) * hd;
        let par = if work < ATTEND_PARALLEL_THRESHOLD { 1 } else { threads.max(1).min(heads) };
        match &self.store {
            PagedStore::F32 { k, v } => {
                // `causal_attend` with the row index routed through the
                // page table; per-head float-op order is untouched, so this
                // is bit-identical to the contiguous f32 store at any
                // thread count (heads write disjoint ctx segments).
                let (kc, vc) = (&k[layer], &v[layer]);
                if par <= 1 {
                    for head in 0..heads {
                        let off = head * hd;
                        attend_head_f32(
                            kc,
                            vc,
                            table,
                            ps,
                            head,
                            hd,
                            q,
                            pos,
                            scale,
                            &mut ctx[off..off + hd],
                            &mut s.att,
                        );
                    }
                } else {
                    let lanes = s.lanes(heads);
                    let ctx_ptr = threadpool::SendPtr(ctx.as_mut_ptr());
                    let lane_ptr = threadpool::SendPtr(lanes.as_mut_ptr());
                    threadpool::global().for_each_index(heads, par, &|head| {
                        // SAFETY: each index is claimed exactly once; head
                        // `h` touches only `ctx[h*hd..(h+1)*hd]` and
                        // `lanes[h]`, both alive for the scoped loop.
                        let ctx_h =
                            unsafe { std::slice::from_raw_parts_mut(ctx_ptr.0.add(head * hd), hd) };
                        let lane = unsafe { &mut *lane_ptr.0.add(head) };
                        attend_head_f32(
                            kc, vc, table, ps, head, hd, q, pos, scale, ctx_h, &mut lane.att,
                        );
                    });
                }
            }
            PagedStore::Q8 { rows, k_codes, v_codes, k_scale, k_min, v_scale, v_min } => {
                // `KvQ8::attend` with the same index translation; the
                // per-head SIMD dequant + dot sequence is unchanged, so
                // results never depend on the thread count.
                let isa = simd::active();
                let base = layer * *rows;
                let s8 = Q8Slices { k_codes, v_codes, k_scale, k_min, v_scale, v_min };
                if par <= 1 {
                    for head in 0..heads {
                        let off = head * hd;
                        attend_head_q8(
                            &s8,
                            d,
                            heads,
                            base,
                            table,
                            ps,
                            head,
                            hd,
                            q,
                            pos,
                            scale,
                            isa,
                            &mut ctx[off..off + hd],
                            &mut s.att,
                            &mut s.row,
                        );
                    }
                } else {
                    let lanes = s.lanes(heads);
                    let ctx_ptr = threadpool::SendPtr(ctx.as_mut_ptr());
                    let lane_ptr = threadpool::SendPtr(lanes.as_mut_ptr());
                    threadpool::global().for_each_index(heads, par, &|head| {
                        // SAFETY: as in the F32 arm — disjoint ctx segment
                        // and scratch lane per claimed head index.
                        let ctx_h =
                            unsafe { std::slice::from_raw_parts_mut(ctx_ptr.0.add(head * hd), hd) };
                        let lane = unsafe { &mut *lane_ptr.0.add(head) };
                        attend_head_q8(
                            &s8,
                            d,
                            heads,
                            base,
                            table,
                            ps,
                            head,
                            hd,
                            q,
                            pos,
                            scale,
                            isa,
                            ctx_h,
                            &mut lane.att,
                            &mut lane.row,
                        );
                    });
                }
            }
        }
    }
}

/// Borrowed views over one [`PagedStore::Q8`] pool, so the per-head attend
/// helper stays below a screenful of parameters.
#[derive(Clone, Copy)]
struct Q8Slices<'a> {
    k_codes: &'a [u8],
    v_codes: &'a [u8],
    k_scale: &'a [f32],
    k_min: &'a [f32],
    v_scale: &'a [f32],
    v_min: &'a [f32],
}

/// One head of the paged f32 attend (`causal_attend` with the row index
/// routed through the page table). Serial and head-parallel callers run
/// exactly this body, so the thread count can never change results.
#[allow(clippy::too_many_arguments)]
fn attend_head_f32(
    kc: &Matrix,
    vc: &Matrix,
    table: &[u32],
    ps: usize,
    head: usize,
    hd: usize,
    q: &[f32],
    pos: usize,
    scale: f32,
    ctx_h: &mut [f32],
    att: &mut Vec<f32>,
) {
    let off = head * hd;
    let qh = &q[off..off + hd];
    att.clear();
    att.resize(pos + 1, 0.0);
    let mut maxv = f32::NEG_INFINITY;
    for ki in 0..=pos {
        let phys = table[ki / ps] as usize * ps + ki % ps;
        let krow = &kc.row(phys)[off..off + hd];
        let mut dotv = 0.0f32;
        for t in 0..hd {
            dotv += qh[t] * krow[t];
        }
        att[ki] = dotv * scale;
        maxv = maxv.max(att[ki]);
    }
    let mut denom = 0.0f32;
    for a in att.iter_mut() {
        *a = (*a - maxv).exp();
        denom += *a;
    }
    for ki in 0..=pos {
        let phys = table[ki / ps] as usize * ps + ki % ps;
        let wgt = att[ki] / denom;
        let vrow = &vc.row(phys)[off..off + hd];
        for t in 0..hd {
            ctx_h[t] += wgt * vrow[t];
        }
    }
}

/// One head of the paged q8 attend (`KvQ8::attend_head` with the row index
/// routed through the page table); see [`attend_head_f32`] for the
/// serial ≡ parallel contract.
#[allow(clippy::too_many_arguments)]
fn attend_head_q8(
    s8: &Q8Slices<'_>,
    d: usize,
    heads: usize,
    base: usize,
    table: &[u32],
    ps: usize,
    head: usize,
    hd: usize,
    q: &[f32],
    pos: usize,
    scale: f32,
    isa: simd::Isa,
    ctx_h: &mut [f32],
    att: &mut Vec<f32>,
    row: &mut simd::AlignedF32,
) {
    let off = head * hd;
    let qh = &q[off..off + hd];
    att.clear();
    att.resize(pos + 1, 0.0);
    row.resize(hd);
    let mut maxv = f32::NEG_INFINITY;
    for ki in 0..=pos {
        let idx = base + table[ki / ps] as usize * ps + ki % ps;
        let codes = &s8.k_codes[idx * d + off..idx * d + off + hd];
        simd::dequant_u8_with(
            isa,
            codes,
            s8.k_scale[idx * heads + head],
            s8.k_min[idx * heads + head],
            row.as_mut_slice(),
        );
        att[ki] = simd::dot_with(isa, qh, row.as_slice()) * scale;
        maxv = maxv.max(att[ki]);
    }
    let mut denom = 0.0f32;
    for a in att.iter_mut() {
        *a = (*a - maxv).exp();
        denom += *a;
    }
    for ki in 0..=pos {
        let idx = base + table[ki / ps] as usize * ps + ki % ps;
        let wgt = att[ki] / denom;
        let codes = &s8.v_codes[idx * d + off..idx * d + off + hd];
        simd::dequant_u8_with(
            isa,
            codes,
            s8.v_scale[idx * heads + head],
            s8.v_min[idx * heads + head],
            row.as_mut_slice(),
        );
        let vrow = row.as_slice();
        for t in 0..hd {
            ctx_h[t] += wgt * vrow[t];
        }
    }
}

/// One cached full page, keyed by the exact token prefix that produced it
/// (`key.len() == (block + 1) * page_size`).
struct PrefixEntry {
    key: Vec<u8>,
    page: u32,
    /// Monotonic use counter (bumped on hit); LRU eviction order.
    tick: u64,
}

/// Token-prefix → page cache over a [`PagedKv`]. Entries hold one
/// refcount on their page, so cached pages survive slot release and are
/// remapped copy-free by later requests with the same prompt prefix.
pub(crate) struct PrefixCache {
    entries: Vec<PrefixEntry>,
    tick: u64,
}

impl PrefixCache {
    pub(crate) fn new() -> PrefixCache {
        PrefixCache { entries: Vec::new(), tick: 0 }
    }

    /// Cached full pages currently held.
    pub(crate) fn len(&self) -> usize {
        self.entries.len()
    }

    /// Longest cached page run covering a prefix of `seq`, capped so at
    /// least one token remains to feed (the engine needs logits). Bumps
    /// each hit entry's LRU tick; the caller maps the pages via
    /// [`PagedKv::assign_shared`].
    pub(crate) fn lookup(&mut self, seq: &[u8], ps: usize) -> Vec<u32> {
        let mut pages = Vec::new();
        self.tick += 1;
        let tick = self.tick;
        loop {
            let span = (pages.len() + 1) * ps;
            if span > seq.len().saturating_sub(1) {
                break;
            }
            match self.entries.iter_mut().find(|e| e.key == &seq[..span]) {
                Some(e) => {
                    e.tick = tick;
                    pages.push(e.page);
                }
                None => break,
            }
        }
        pages
    }

    /// Cache the full pages of a retired sequence (`fed` positions were
    /// written; only whole pages are shareable). Existing entries win —
    /// their page already holds identical bytes — so refcounts stay one
    /// per entry.
    pub(crate) fn register(
        &mut self,
        seq: &[u8],
        table: &[u32],
        fed: usize,
        ps: usize,
        kv: &mut PagedKv,
    ) {
        let full = (fed / ps).min(table.len());
        for i in 0..full {
            let key = &seq[..(i + 1) * ps];
            if self.entries.iter().any(|e| e.key == key) {
                continue;
            }
            self.tick += 1;
            kv.cache_ref(table[i]);
            self.entries.push(PrefixEntry { key: key.to_vec(), page: table[i], tick: self.tick });
        }
    }

    /// Evict the least-recently-used **leaf** entry (no longer cached
    /// prefix extends it), releasing its page reference. `false` when the
    /// cache is empty. The page only returns to the free list if no live
    /// slot still maps it, so callers loop: evict until a page frees or
    /// nothing is left, then fall back to preemption.
    pub(crate) fn evict_one(&mut self, kv: &mut PagedKv) -> bool {
        let mut victim: Option<usize> = None;
        for (i, e) in self.entries.iter().enumerate() {
            let is_leaf = !self
                .entries
                .iter()
                .any(|o| o.key.len() > e.key.len() && o.key.starts_with(&e.key));
            if is_leaf && victim.map_or(true, |v| e.tick < self.entries[v].tick) {
                victim = Some(i);
            }
        }
        match victim {
            Some(i) => {
                let e = self.entries.swap_remove(i);
                kv.unref(e.page);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(slots: usize, pages: usize, ps: usize) -> PagedKv {
        PagedKv::new(KvBits::F32, 2, 8, 2, slots, ps, pages)
    }

    #[test]
    fn claim_release_recycles_pages() {
        let mut kv = pool(2, 3, 4);
        assert_eq!(kv.pages_free(), 3);
        assert!(kv.try_claim(0));
        assert!(kv.try_claim(0));
        assert!(kv.try_claim(1));
        assert_eq!(kv.pages_free(), 0);
        assert!(!kv.try_claim(1), "pool must report dry, not panic");
        kv.release_slot(0);
        assert_eq!(kv.pages_free(), 2);
        assert!(kv.has_block(1, 0));
        assert!(!kv.has_block(1, 1));
    }

    #[test]
    fn shared_pages_survive_one_release() {
        let mut kv = pool(2, 2, 4);
        assert!(kv.try_claim(0));
        let page = kv.table(0)[0];
        kv.assign_shared(1, &[page]);
        kv.release_slot(0);
        assert_eq!(kv.pages_free(), 1, "shared page still referenced by slot 1");
        kv.release_slot(1);
        assert_eq!(kv.pages_free(), 2);
    }

    #[test]
    fn prefix_cache_lookup_caps_and_lru_leaf_eviction() {
        let mut kv = pool(1, 4, 2);
        let mut pc = PrefixCache::new();
        // Slot decodes "abcdef" fully: 3 claimed pages, 6 fed positions.
        for _ in 0..3 {
            assert!(kv.try_claim(0));
        }
        let table = kv.table(0).to_vec();
        pc.register(b"abcdefg", &table, 6, 2, &mut kv);
        assert_eq!(pc.len(), 3);
        kv.release_slot(0);
        assert_eq!(kv.pages_free(), 1, "cached pages stay resident");

        // Full cover is capped: 5 tokens share 2 pages (one token left to feed).
        assert_eq!(pc.lookup(b"abcde", 2), table[..2].to_vec());
        // Diverging token stops the run after one page.
        assert_eq!(pc.lookup(b"abXde", 2), table[..1].to_vec());
        assert!(pc.lookup(b"Xbcde", 2).is_empty());

        // Eviction is leaf-first: deepest entry goes before its parents.
        assert!(pc.evict_one(&mut kv));
        assert_eq!(pc.len(), 2);
        assert_eq!(pc.lookup(b"abcdefg", 2), table[..2].to_vec());
        assert!(pc.evict_one(&mut kv));
        assert!(pc.evict_one(&mut kv));
        assert!(!pc.evict_one(&mut kv), "empty cache has nothing to evict");
        assert_eq!(kv.pages_free(), 4, "all pages recycled after eviction");
    }

    #[test]
    fn register_skips_existing_keys() {
        let mut kv = pool(2, 4, 2);
        let mut pc = PrefixCache::new();
        assert!(kv.try_claim(0));
        pc.register(b"abc", kv.table(0).to_vec().as_slice(), 2, 2, &mut kv);
        assert!(kv.try_claim(1));
        // Same prefix retired from another slot: existing entry wins.
        pc.register(b"abc", kv.table(1).to_vec().as_slice(), 2, 2, &mut kv);
        assert_eq!(pc.len(), 1);
        kv.release_slot(0);
        kv.release_slot(1);
        assert_eq!(kv.pages_free(), 3, "only the cached page stays resident");
    }
}
