//! The unified transformer-math core: **one** copy of the block arithmetic
//! (RMSNorm → RoPE MHA → residual → SwiGLU / switch-MoE → residual → final
//! norm → lm_head) that every forward and decode path in the repo
//! instantiates.
//!
//! Before this module existed the same math lived in four hand-synchronized
//! copies — `model::forward::Forward::forward` (f32 reference),
//! `NativeBackend::forward_with` (fused kernels), `NativeDecoder::step`
//! (incremental single sequence), and `BatchDecoder::step` (continuous
//! batching) — guarded only by parity tests. They are now thin wrappers
//! over two entry points here:
//!
//! * [`forward_seq`] — the full-sequence forward, parameterized over
//!   [`SeqModel`] (which dispatches every linear projection). The f32
//!   reference implements it with `matmul_nt` plus activation
//!   capture/fake-quant hooks; the native engine implements it with the
//!   fused dequant kernels. Both produce **bit-identical** logits to the
//!   pre-refactor copies: per-query attention over the full K/V matrices
//!   accumulates in exactly the old loop order.
//! * [`decode_rows`] — one fused decode step over stacked live rows (each
//!   at its own position), parameterized over a [`KvStore`] per sequence
//!   slot. The single-sequence decoder is the `rows.len() == 1` case; the
//!   continuous batcher passes every live slot. Both inherit the
//!   matvec ≡ shared-kernel bitwise contract, so greedy tokens at
//!   `--kv-bits 32` are unchanged from the pre-refactor decoders.
//!
//! Linear dispatch is the [`LinearOp`] trait: [`Matrix`] is the f32
//! reference implementation and [`QuantizedTensor`] the fused-quantized one
//! (with [`KernelScratch`]-reusing matvecs); `LayerWeight` in
//! [`crate::backend::native`] selects between them per layer.
//!
//! KV storage is the [`KvStore`] trait: [`KvF32`] keeps the pre-refactor
//! full-precision cache (bit-identical attention), [`KvQ8`] stores 8-bit
//! codes with per-head, per-position affine scales — roughly quartering
//! decode KV memory per slot — and dequantizes on read through the
//! SIMD-dispatched [`crate::backend::simd::dequant_u8_with`] kernel. The
//! [`KvCache`] enum picks one at runtime from the `--kv-bits 32|8` flag
//! ([`KvBits`]).
//!
//! Token selection is the [`TokenPicker`] hook: greedy argmax by default
//! (bit-identical to the pre-refactor decoders) or seeded temperature/top-k
//! sampling ([`SampleCfg`]) with a per-request RNG, so sampled sequences
//! are reproducible across runs *and* across batch placements.

use crate::backend::native::{MlpRefs, MlpWeights, ResolvedModel};
use crate::backend::quantized::QuantizedTensor;
use crate::backend::simd::{self, AlignedF32, KernelScratch};
use crate::model::ModelConfig;
use crate::obs::profiler::{self, Phase};
use crate::tensor::matrix::dot;
use crate::tensor::Matrix;
use crate::util::threadpool;

/// Below this many attention multiply-adds (`heads × kv_positions ×
/// head_dim`) an attend stays single-threaded — even a persistent-pool
/// hand-off costs more than the whole reduction at small contexts.
pub(crate) const ATTEND_PARALLEL_THRESHOLD: usize = 1 << 15;

// =====================================================================
// Shared block math
// =====================================================================

/// SwiGLU's gate activation.
#[inline]
pub(crate) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// `a += b` elementwise.
pub(crate) fn add_inplace(a: &mut Matrix, b: &Matrix) {
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

/// RMSNorm with gain over a batch of rows.
pub fn rmsnorm(x: &Matrix, gain: &[f32], eps: f32) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let row = x.row(i);
        let ms: f32 = row.iter().map(|&v| v * v).sum::<f32>() / x.cols as f32;
        let r = 1.0 / (ms + eps).sqrt();
        for (j, (&v, &g)) in row.iter().zip(gain).enumerate() {
            out.data[i * x.cols + j] = v * r * g;
        }
    }
    out
}

/// Split-half RoPE (matches `model.py::apply_rope`): row `p` of `x` is
/// rotated by row `p` of the angle tables, so full-sequence forwards pass
/// per-position tables and decode steps pass per-live-row tables.
pub(crate) fn rope(x: &Matrix, cos: &Matrix, sin: &Matrix, heads: usize) -> Matrix {
    let s = x.rows;
    let hd = x.cols / heads;
    let half = hd / 2;
    let mut out = Matrix::zeros(s, x.cols);
    for p in 0..s {
        for h in 0..heads {
            let off = h * hd;
            for i in 0..half {
                let (c, sn) = (cos.at(p, i), sin.at(p, i));
                let x1 = x.at(p, off + i);
                let x2 = x.at(p, off + half + i);
                *out.at_mut(p, off + i) = x1 * c - x2 * sn;
                *out.at_mut(p, off + half + i) = x2 * c + x1 * sn;
            }
        }
    }
    out
}

/// Causal attention for one query over K/V rows `0..=pos`, accumulating
/// the per-head context into `ctx` (zeroed by the caller). `att` is a
/// caller-owned score buffer (resized to `pos + 1` here) so the decode hot
/// loops do not allocate per layer. This is the one attention inner loop:
/// the full-sequence forward calls it per query position over the (S, d)
/// K/V matrices, and [`KvF32::attend`] calls it over the cache rows.
#[allow(clippy::too_many_arguments)]
pub(crate) fn causal_attend(
    q: &[f32],
    kc: &Matrix,
    vc: &Matrix,
    pos: usize,
    heads: usize,
    hd: usize,
    ctx: &mut [f32],
    att: &mut Vec<f32>,
) {
    let scale = 1.0 / (hd as f32).sqrt();
    att.clear();
    att.resize(pos + 1, 0.0);
    for head in 0..heads {
        let off = head * hd;
        let qh = &q[off..off + hd];
        let mut maxv = f32::NEG_INFINITY;
        for ki in 0..=pos {
            let krow = &kc.row(ki)[off..off + hd];
            let mut dotv = 0.0f32;
            for t in 0..hd {
                dotv += qh[t] * krow[t];
            }
            att[ki] = dotv * scale;
            maxv = maxv.max(att[ki]);
        }
        let mut denom = 0.0f32;
        for a in att.iter_mut() {
            *a = (*a - maxv).exp();
            denom += *a;
        }
        for ki in 0..=pos {
            let wgt = att[ki] / denom;
            let vrow = &vc.row(ki)[off..off + hd];
            for t in 0..hd {
                ctx[off + t] += wgt * vrow[t];
            }
        }
    }
}

/// Switch routing: softmax over expert logits, top-1 index and its gate.
pub(crate) fn route_top1(logits: &[f32]) -> (usize, f32) {
    let maxv = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&v| (v - maxv).exp()).collect();
    let denom: f32 = exps.iter().sum();
    let (top, _) = exps
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    (top, exps[top] / denom)
}

/// Dense or top-1-MoE MLP over one activation vector, reusing the caller's
/// kernel scratch for every quantized matvec (the batched decoder's MoE
/// rows route per sequence, so they take this per-row path).
pub(crate) fn mlp_forward(mlp: &MlpRefs, x: &[f32], scratch: &mut KernelScratch) -> Vec<f32> {
    match mlp {
        MlpRefs::Dense(w) => expert_forward(w, x, scratch),
        MlpRefs::Moe { router, experts } => {
            let logits = router.matvec(x, scratch);
            let (top, gate) = route_top1(&logits);
            let y = expert_forward(&experts[top], x, scratch);
            y.iter().map(|&v| gate * v).collect()
        }
    }
}

fn expert_forward(w: &MlpWeights, x: &[f32], scratch: &mut KernelScratch) -> Vec<f32> {
    let g = w.wg.matvec(x, scratch);
    let u = w.wu.matvec(x, scratch);
    let act: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| silu(gv) * uv).collect();
    w.wd.matvec(&act, scratch)
}

pub(crate) fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

// =====================================================================
// LinearOp: one linear projection, three execution shapes
// =====================================================================

/// A linear layer `W` as the core consumes it: full-sequence matmul,
/// single-row matvec, and the stacked-decode-row matmul (which must be
/// bitwise equal to the matvec applied row by row — the contract that keeps
/// batched and single-sequence decode in exact agreement).
pub trait LinearOp {
    /// Output features (rows of `W`).
    fn out_features(&self) -> usize;

    /// `y = x · Wᵀ` over a full-sequence batch with `threads` tile workers.
    fn matmul(&self, x: &Matrix, threads: usize) -> Matrix;

    /// `y = W · x` for one activation vector, with caller-owned kernel
    /// scratch (the f32 reference needs none and ignores it).
    fn matvec(&self, x: &[f32], scratch: &mut KernelScratch) -> Vec<f32>;

    /// `y = x · Wᵀ` for stacked decode rows, bitwise equal per row to
    /// [`LinearOp::matvec`], with caller-owned kernel scratch holding the
    /// folded activation rows (the f32 reference needs none and ignores
    /// it).
    fn decode_matmul(&self, x: &Matrix, threads: usize, scratch: &mut KernelScratch) -> Matrix;
}

/// The f32 reference implementation: a dense weight matrix.
impl LinearOp for Matrix {
    fn out_features(&self) -> usize {
        self.rows
    }

    fn matmul(&self, x: &Matrix, _threads: usize) -> Matrix {
        x.matmul_nt(self)
    }

    fn matvec(&self, x: &[f32], _scratch: &mut KernelScratch) -> Vec<f32> {
        (0..self.rows).map(|r| dot(x, self.row(r), x.len())).collect()
    }

    fn decode_matmul(&self, x: &Matrix, _threads: usize, _scratch: &mut KernelScratch) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.rows);
        for r in 0..x.rows {
            let xr = x.row(r);
            for j in 0..self.rows {
                y.data[r * self.rows + j] = dot(xr, self.row(j), x.cols);
            }
        }
        y
    }
}

/// The fused-quantized implementation: bit-packed codes executed by the
/// dequant kernels, with [`KernelScratch`]-reusing matvecs.
impl LinearOp for QuantizedTensor {
    fn out_features(&self) -> usize {
        self.rows
    }

    fn matmul(&self, x: &Matrix, threads: usize) -> Matrix {
        self.dequant_matmul(x, threads)
    }

    fn matvec(&self, x: &[f32], scratch: &mut KernelScratch) -> Vec<f32> {
        self.dequant_matvec_with(x, scratch)
    }

    fn decode_matmul(&self, x: &Matrix, threads: usize, scratch: &mut KernelScratch) -> Matrix {
        self.dequant_matmul_shared_with(x, threads, scratch)
    }
}

// =====================================================================
// Full-sequence forward
// =====================================================================

/// Identifies one linear projection of the transformer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinId {
    Wq(usize),
    Wk(usize),
    Wv(usize),
    Wo(usize),
    Gate(usize),
    Up(usize),
    Down(usize),
    Router(usize),
    ExpertGate(usize, usize),
    ExpertUp(usize, usize),
    ExpertDown(usize, usize),
    LmHead,
}

impl LinId {
    /// The profiler phase this projection's time accrues to. Per-expert
    /// MoE linears all route to `Moe` — the interesting split there is
    /// MoE-vs-dense, not which expert fired.
    pub fn phase(&self) -> Phase {
        match self {
            LinId::Wq(_) => Phase::LinWq,
            LinId::Wk(_) => Phase::LinWk,
            LinId::Wv(_) => Phase::LinWv,
            LinId::Wo(_) => Phase::LinWo,
            LinId::Gate(_) => Phase::LinWg,
            LinId::Up(_) => Phase::LinWu,
            LinId::Down(_) => Phase::LinWd,
            LinId::Router(_)
            | LinId::ExpertGate(_, _)
            | LinId::ExpertUp(_, _)
            | LinId::ExpertDown(_, _) => Phase::Moe,
            LinId::LmHead => Phase::LinLmHead,
        }
    }

    /// The weight-map key this projection has carried since the seed
    /// (`layers.{l}.wq`, `layers.{l}.expert{e}.wg`, `lm_head`, …).
    pub fn name(&self) -> String {
        match *self {
            LinId::Wq(l) => format!("layers.{l}.wq"),
            LinId::Wk(l) => format!("layers.{l}.wk"),
            LinId::Wv(l) => format!("layers.{l}.wv"),
            LinId::Wo(l) => format!("layers.{l}.wo"),
            LinId::Gate(l) => format!("layers.{l}.wg"),
            LinId::Up(l) => format!("layers.{l}.wu"),
            LinId::Down(l) => format!("layers.{l}.wd"),
            LinId::Router(l) => format!("layers.{l}.router"),
            LinId::ExpertGate(l, e) => format!("layers.{l}.expert{e}.wg"),
            LinId::ExpertUp(l, e) => format!("layers.{l}.expert{e}.wu"),
            LinId::ExpertDown(l, e) => format!("layers.{l}.expert{e}.wd"),
            LinId::LmHead => "lm_head".to_string(),
        }
    }
}

/// Identifies one norm gain vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gain {
    Ln1(usize),
    Ln2(usize),
    Final,
}

impl Gain {
    pub fn name(&self) -> String {
        match *self {
            Gain::Ln1(l) => format!("layers.{l}.ln1"),
            Gain::Ln2(l) => format!("layers.{l}.ln2"),
            Gain::Final => "ln_f".to_string(),
        }
    }
}

/// What [`forward_seq`] needs from a model: the config, embedding rows,
/// norm gains, and a dispatcher for every linear projection. The f32
/// reference threads activation capture / fake-quant through `linear`
/// (hence `&mut self`); the native engine routes it to the per-layer
/// [`LinearOp`].
pub trait SeqModel {
    fn cfg(&self) -> &ModelConfig;

    /// Embedding row for one token.
    fn embed_row(&self, token: u8) -> anyhow::Result<&[f32]>;

    /// Norm gain vector.
    fn gain(&self, g: Gain) -> anyhow::Result<&[f32]>;

    /// `y = x · Wᵀ` for the identified projection.
    fn linear(&mut self, id: LinId, x: &Matrix) -> anyhow::Result<Matrix>;
}

/// Full-sequence forward for one sequence: `tokens` (length S) → logits
/// `(S, vocab)`. This is the single source of the transformer block math;
/// every instantiation (f32 reference, fused native) reproduces its
/// pre-refactor logits bit-for-bit.
pub fn forward_seq<M: SeqModel + ?Sized>(m: &mut M, tokens: &[u8]) -> anyhow::Result<Matrix> {
    anyhow::ensure!(!tokens.is_empty(), "empty token sequence");
    let cfg = m.cfg().clone();
    let (s, d, hd) = (tokens.len(), cfg.d, cfg.head_dim());

    // Embedding lookup.
    let t0 = profiler::start();
    let mut h = Matrix::zeros(s, d);
    for (p, &tok) in tokens.iter().enumerate() {
        h.row_mut(p).copy_from_slice(m.embed_row(tok)?);
    }
    profiler::stop(Phase::Embed, t0);

    // RoPE tables, one row per position.
    let t0 = profiler::start();
    let half = hd / 2;
    let mut cos = Matrix::zeros(s, half);
    let mut sin = Matrix::zeros(s, half);
    for p in 0..s {
        for i in 0..half {
            let inv = (cfg.rope_base as f64).powf(-(i as f64) * 2.0 / hd as f64);
            let ang = p as f64 * inv;
            *cos.at_mut(p, i) = ang.cos() as f32;
            *sin.at_mut(p, i) = ang.sin() as f32;
        }
    }
    profiler::stop(Phase::Rope, t0);

    let mut att = Vec::with_capacity(s);
    for l in 0..cfg.layers {
        // --- Attention block ---
        let x = timed_norm(&h, m.gain(Gain::Ln1(l))?, cfg.eps);
        let q = timed_linear(m, LinId::Wq(l), &x)?;
        let k = timed_linear(m, LinId::Wk(l), &x)?;
        let v = timed_linear(m, LinId::Wv(l), &x)?;
        let t0 = profiler::start();
        let (q, k) = (rope(&q, &cos, &sin, cfg.heads), rope(&k, &cos, &sin, cfg.heads));
        profiler::stop(Phase::Rope, t0);

        // Per-query causal attention over the full K/V matrices — the same
        // inner loop the decode paths run over their caches.
        let t0 = profiler::start();
        let mut ctx = Matrix::zeros(s, d);
        for qi in 0..s {
            causal_attend(q.row(qi), &k, &v, qi, cfg.heads, hd, ctx.row_mut(qi), &mut att);
        }
        profiler::stop(Phase::Attend, t0);
        let o = timed_linear(m, LinId::Wo(l), &ctx)?;
        add_inplace(&mut h, &o);

        // --- MLP block ---
        let x = timed_norm(&h, m.gain(Gain::Ln2(l))?, cfg.eps);
        let y = if cfg.n_experts == 0 {
            let g = timed_linear(m, LinId::Gate(l), &x)?;
            let u = timed_linear(m, LinId::Up(l), &x)?;
            let t0 = profiler::start();
            let mut act = Matrix::zeros(s, cfg.ffn);
            for i in 0..s * cfg.ffn {
                act.data[i] = silu(g.data[i]) * u.data[i];
            }
            profiler::stop(Phase::Activation, t0);
            timed_linear(m, LinId::Down(l), &act)?
        } else {
            // The whole switch-MoE path (router + expert matvecs) accrues to
            // one phase; its inner linears are deliberately untimed so the
            // profiler never nests.
            let t0 = profiler::start();
            let y = moe_seq(m, &x, l, &cfg)?;
            profiler::stop(Phase::Moe, t0);
            y
        };
        add_inplace(&mut h, &y);
    }

    let hf = timed_norm(&h, m.gain(Gain::Final)?, cfg.eps);
    timed_linear(m, LinId::LmHead, &hf)
}

/// [`rmsnorm`] accruing to the `norm` profiler phase.
fn timed_norm(x: &Matrix, gain: &[f32], eps: f32) -> Matrix {
    let t0 = profiler::start();
    let out = rmsnorm(x, gain, eps);
    profiler::stop(Phase::Norm, t0);
    out
}

/// One [`SeqModel::linear`] dispatch accruing to its projection's phase.
fn timed_linear<M: SeqModel + ?Sized>(
    m: &mut M,
    id: LinId,
    x: &Matrix,
) -> anyhow::Result<Matrix> {
    let t0 = profiler::start();
    let y = m.linear(id, x);
    profiler::stop(id.phase(), t0);
    y
}

/// Switch-MoE MLP over a batch of rows: top-1 routing per row, one-row
/// expert matmuls (rows picking different experts cannot share a matmul).
fn moe_seq<M: SeqModel + ?Sized>(
    m: &mut M,
    x: &Matrix,
    l: usize,
    cfg: &ModelConfig,
) -> anyhow::Result<Matrix> {
    let logits = m.linear(LinId::Router(l), x)?;
    let mut out = Matrix::zeros(x.rows, cfg.d);
    for i in 0..x.rows {
        let (top, gate) = route_top1(logits.row(i));
        let xr = Matrix::from_vec(1, x.cols, x.row(i).to_vec());
        let g = m.linear(LinId::ExpertGate(l, top), &xr)?;
        let u = m.linear(LinId::ExpertUp(l, top), &xr)?;
        let mut act = Matrix::zeros(1, cfg.ffn);
        for j in 0..cfg.ffn {
            act.data[j] = silu(g.data[j]) * u.data[j];
        }
        let y = m.linear(LinId::ExpertDown(l, top), &act)?;
        for (o, &yv) in out.row_mut(i).iter_mut().zip(y.row(0)) {
            *o = gate * yv;
        }
    }
    Ok(out)
}

// =====================================================================
// KV stores
// =====================================================================

/// KV-cache element precision, the `--kv-bits 32|8` flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvBits {
    /// Full-precision f32 cache: attention is bit-identical to the seed.
    F32,
    /// 8-bit codes with per-head, per-position affine scales (~4× smaller;
    /// tolerance-gated, not bitwise).
    Q8,
}

impl KvBits {
    pub fn parse(s: &str) -> Option<KvBits> {
        match s {
            "32" | "f32" => Some(KvBits::F32),
            "8" | "q8" | "u8" => Some(KvBits::Q8),
            _ => None,
        }
    }

    /// Stored bits per cache element.
    pub fn bits(&self) -> u32 {
        match self {
            KvBits::F32 => 32,
            KvBits::Q8 => 8,
        }
    }
}

/// One head's worth of attend scratch: score buffer plus an aligned
/// dequant row. Head-parallel attends hand each head its own lane so
/// workers never share buffers.
#[derive(Default)]
pub struct AttnLane {
    /// Attention score buffer (`pos + 1` entries).
    pub att: Vec<f32>,
    /// Dequantized K/V head-segment scratch (aligned for the SIMD kernels).
    pub row: AlignedF32,
}

/// Reusable attention scratch shared by every [`KvStore`] implementation:
/// the per-head score buffer plus an aligned row for dequantized K/V
/// segments, so quantized attends allocate nothing per step. Head-parallel
/// attends additionally keep one [`AttnLane`] per head (grown on first
/// use, reused across steps).
#[derive(Default)]
pub struct AttnScratch {
    /// Attention score buffer (`pos + 1` entries) for serial attends.
    pub att: Vec<f32>,
    /// Dequantized K/V head-segment scratch (aligned for the SIMD kernels).
    pub row: AlignedF32,
    /// Per-head lanes for head-parallel attends.
    lanes: Vec<AttnLane>,
}

impl AttnScratch {
    pub fn new(capacity: usize) -> AttnScratch {
        AttnScratch { att: Vec::with_capacity(capacity), row: AlignedF32::new(), lanes: Vec::new() }
    }

    /// Per-head lanes for a head-parallel attend (grown on demand).
    pub(crate) fn lanes(&mut self, n: usize) -> &mut [AttnLane] {
        if self.lanes.len() < n {
            self.lanes.resize_with(n, AttnLane::default);
        }
        &mut self.lanes[..n]
    }
}

/// Per-sequence KV storage as [`decode_rows`] consumes it: write the K/V
/// projections for a position, then attend a query over everything stored
/// so far. Implementations own their precision; `bytes` is what one slot
/// costs resident, which the serving metrics report per slot.
pub trait KvStore {
    /// Record the K/V projections (length `d` each) for `layer` at `pos`.
    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]);

    /// Causal attention for one query over positions `0..=pos` of `layer`,
    /// accumulating per-head context into `ctx` (zeroed by the caller).
    /// `threads` bounds the head-parallel fan-out; heads write disjoint
    /// `ctx` segments with unchanged per-head arithmetic, so results never
    /// depend on the thread count.
    fn attend(
        &self,
        layer: usize,
        q: &[f32],
        pos: usize,
        ctx: &mut [f32],
        s: &mut AttnScratch,
        threads: usize,
    );

    /// Element precision of this store.
    fn kv_bits(&self) -> KvBits;

    /// Resident bytes of this store (one sequence slot).
    fn bytes(&self) -> usize;
}

/// Full-precision per-slot cache: one `(capacity, d)` matrix per layer for
/// K and V. Attention runs the exact pre-refactor arithmetic
/// ([`causal_attend`]), so `--kv-bits 32` decode is bit-identical to the
/// seed. Slots are recycled by resetting the position — attention only
/// ever reads rows `0..=pos`, so stale rows are never touched.
pub struct KvF32 {
    heads: usize,
    hd: usize,
    k: Vec<Matrix>,
    v: Vec<Matrix>,
}

impl KvF32 {
    pub fn new(layers: usize, capacity: usize, d: usize, heads: usize) -> KvF32 {
        KvF32 {
            heads,
            hd: d / heads,
            k: (0..layers).map(|_| Matrix::zeros(capacity, d)).collect(),
            v: (0..layers).map(|_| Matrix::zeros(capacity, d)).collect(),
        }
    }
}

impl KvStore for KvF32 {
    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.k[layer].row_mut(pos).copy_from_slice(k);
        self.v[layer].row_mut(pos).copy_from_slice(v);
    }

    fn attend(
        &self,
        layer: usize,
        q: &[f32],
        pos: usize,
        ctx: &mut [f32],
        s: &mut AttnScratch,
        _threads: usize,
    ) {
        // The f32 store is the bit-identical reference path; it stays
        // serial so its loop order is exactly the seed's.
        causal_attend(q, &self.k[layer], &self.v[layer], pos, self.heads, self.hd, ctx, &mut s.att);
    }

    fn kv_bits(&self) -> KvBits {
        KvBits::F32
    }

    fn bytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|m| m.data.len() * 4).sum()
    }
}

/// 8-bit per-slot cache: codes laid out `[layer][pos][d]` with one affine
/// `(scale, min)` pair per `(layer, pos, head)` — `value = min + scale *
/// code`. Writes quantize each head segment to its own range (the per-head
/// scales are what keep outlier heads from poisoning the rest, the same
/// observation OWQ makes for weight channels); reads dequantize head
/// segments through the SIMD-dispatched
/// [`crate::backend::simd::dequant_u8_with`] kernel and reduce with the
/// dispatched dot. Versus the f32 store this is `4d / (d + 8·heads)` ≈ 3.2–4×
/// smaller per slot.
pub struct KvQ8 {
    capacity: usize,
    d: usize,
    heads: usize,
    hd: usize,
    k_codes: Vec<u8>,
    v_codes: Vec<u8>,
    k_scale: Vec<f32>,
    k_min: Vec<f32>,
    v_scale: Vec<f32>,
    v_min: Vec<f32>,
}

impl KvQ8 {
    pub fn new(layers: usize, capacity: usize, d: usize, heads: usize) -> KvQ8 {
        debug_assert_eq!(d % heads, 0, "head_dim must divide d");
        let elems = layers * capacity * d;
        let affines = layers * capacity * heads;
        KvQ8 {
            capacity,
            d,
            heads,
            hd: d / heads,
            k_codes: vec![0; elems],
            v_codes: vec![0; elems],
            k_scale: vec![0.0; affines],
            k_min: vec![0.0; affines],
            v_scale: vec![0.0; affines],
            v_min: vec![0.0; affines],
        }
    }

    /// Quantize one row (`x.len() == d`) into per-head u8 codes + affines.
    /// Row-local and deterministic — the paged store reuses it, which is
    /// what makes prefix-shared kv8 pages bit-identical to a cold decode.
    pub(crate) fn quant_row(
        codes: &mut [u8],
        scales: &mut [f32],
        mins: &mut [f32],
        x: &[f32],
        heads: usize,
        hd: usize,
    ) {
        for h in 0..heads {
            let seg = &x[h * hd..(h + 1) * hd];
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &v in seg {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let scale = (hi - lo) / 255.0;
            // Degenerate segment (constant values): any code decodes to lo.
            let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
            scales[h] = scale;
            mins[h] = lo;
            for (c, &v) in codes[h * hd..(h + 1) * hd].iter_mut().zip(seg) {
                // `as u8` saturates, so rounding past 255 cannot wrap.
                *c = ((v - lo) * inv + 0.5) as u8;
            }
        }
    }

    /// One head's attend: scores over K codes, softmax, weighted V
    /// accumulation into this head's disjoint `ctx_h` segment. Both the
    /// serial and the head-parallel attend run exactly this body per head,
    /// so the thread count can never change results.
    #[allow(clippy::too_many_arguments)]
    fn attend_head(
        &self,
        base: usize,
        head: usize,
        q: &[f32],
        pos: usize,
        scale: f32,
        isa: simd::Isa,
        ctx_h: &mut [f32],
        att: &mut Vec<f32>,
        row: &mut AlignedF32,
    ) {
        let (d, hd, heads) = (self.d, self.hd, self.heads);
        let off = head * hd;
        let qh = &q[off..off + hd];
        att.clear();
        att.resize(pos + 1, 0.0);
        row.resize(hd);
        let mut maxv = f32::NEG_INFINITY;
        for ki in 0..=pos {
            let idx = base + ki;
            let codes = &self.k_codes[idx * d + off..idx * d + off + hd];
            simd::dequant_u8_with(
                isa,
                codes,
                self.k_scale[idx * heads + head],
                self.k_min[idx * heads + head],
                row.as_mut_slice(),
            );
            att[ki] = simd::dot_with(isa, qh, row.as_slice()) * scale;
            maxv = maxv.max(att[ki]);
        }
        let mut denom = 0.0f32;
        for a in att.iter_mut() {
            *a = (*a - maxv).exp();
            denom += *a;
        }
        for ki in 0..=pos {
            let idx = base + ki;
            let wgt = att[ki] / denom;
            let codes = &self.v_codes[idx * d + off..idx * d + off + hd];
            simd::dequant_u8_with(
                isa,
                codes,
                self.v_scale[idx * heads + head],
                self.v_min[idx * heads + head],
                row.as_mut_slice(),
            );
            let vrow = row.as_slice();
            for t in 0..hd {
                ctx_h[t] += wgt * vrow[t];
            }
        }
    }
}

impl KvStore for KvQ8 {
    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let idx = layer * self.capacity + pos;
        let (c0, a0) = (idx * self.d, idx * self.heads);
        KvQ8::quant_row(
            &mut self.k_codes[c0..c0 + self.d],
            &mut self.k_scale[a0..a0 + self.heads],
            &mut self.k_min[a0..a0 + self.heads],
            k,
            self.heads,
            self.hd,
        );
        KvQ8::quant_row(
            &mut self.v_codes[c0..c0 + self.d],
            &mut self.v_scale[a0..a0 + self.heads],
            &mut self.v_min[a0..a0 + self.heads],
            v,
            self.heads,
            self.hd,
        );
    }

    fn attend(
        &self,
        layer: usize,
        q: &[f32],
        pos: usize,
        ctx: &mut [f32],
        s: &mut AttnScratch,
        threads: usize,
    ) {
        let (hd, heads) = (self.hd, self.heads);
        let scale = 1.0 / (hd as f32).sqrt();
        let isa = simd::active();
        let base = layer * self.capacity;
        let work = heads * (pos + 1) * hd;
        let par = if work < ATTEND_PARALLEL_THRESHOLD { 1 } else { threads.max(1).min(heads) };
        if par <= 1 {
            for head in 0..heads {
                let off = head * hd;
                self.attend_head(
                    base,
                    head,
                    q,
                    pos,
                    scale,
                    isa,
                    &mut ctx[off..off + hd],
                    &mut s.att,
                    &mut s.row,
                );
            }
            return;
        }
        // Head-parallel: each head writes only its own disjoint ctx
        // segment and its own scratch lane, running the identical
        // `attend_head` body — bitwise-equal to the serial loop.
        let lanes = s.lanes(heads);
        let ctx_ptr = threadpool::SendPtr(ctx.as_mut_ptr());
        let lane_ptr = threadpool::SendPtr(lanes.as_mut_ptr());
        threadpool::global().for_each_index(heads, par, &|head| {
            // SAFETY: `for_each_index` hands out each index exactly once,
            // and head `h` touches only `ctx[h*hd..(h+1)*hd]` and
            // `lanes[h]` — disjoint ranges of live allocations that outlive
            // the scoped loop.
            let ctx_h = unsafe { std::slice::from_raw_parts_mut(ctx_ptr.0.add(head * hd), hd) };
            let lane = unsafe { &mut *lane_ptr.0.add(head) };
            self.attend_head(base, head, q, pos, scale, isa, ctx_h, &mut lane.att, &mut lane.row);
        });
    }

    fn kv_bits(&self) -> KvBits {
        KvBits::Q8
    }

    fn bytes(&self) -> usize {
        self.k_codes.len()
            + self.v_codes.len()
            + 4 * (self.k_scale.len() + self.k_min.len() + self.v_scale.len() + self.v_min.len())
    }
}

/// Runtime-selected KV store for one sequence slot (`--kv-bits 32|8`).
pub enum KvCache {
    F32(KvF32),
    Q8(KvQ8),
}

impl KvCache {
    pub fn new(bits: KvBits, layers: usize, capacity: usize, d: usize, heads: usize) -> KvCache {
        match bits {
            KvBits::F32 => KvCache::F32(KvF32::new(layers, capacity, d, heads)),
            KvBits::Q8 => KvCache::Q8(KvQ8::new(layers, capacity, d, heads)),
        }
    }
}

impl KvStore for KvCache {
    fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        match self {
            KvCache::F32(c) => c.write(layer, pos, k, v),
            KvCache::Q8(c) => c.write(layer, pos, k, v),
        }
    }

    fn attend(
        &self,
        layer: usize,
        q: &[f32],
        pos: usize,
        ctx: &mut [f32],
        s: &mut AttnScratch,
        threads: usize,
    ) {
        match self {
            KvCache::F32(c) => c.attend(layer, q, pos, ctx, s, threads),
            KvCache::Q8(c) => c.attend(layer, q, pos, ctx, s, threads),
        }
    }

    fn kv_bits(&self) -> KvBits {
        match self {
            KvCache::F32(c) => c.kv_bits(),
            KvCache::Q8(c) => c.kv_bits(),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            KvCache::F32(c) => c.bytes(),
            KvCache::Q8(c) => c.bytes(),
        }
    }
}

/// Slot-addressed KV storage as [`decode_rows`] consumes it: the fused
/// step names a `(slot, layer, pos)` triple and the arena decides where
/// those bytes live. A plain slice of per-slot [`KvStore`]s is the
/// contiguous layout (each slot owns a full-capacity reservation); the
/// paged allocator maps the same triples through per-slot page tables
/// into a shared fixed pool.
pub(crate) trait KvArena {
    /// Record the K/V projections (length `d` each) for `slot` at
    /// (`layer`, `pos`).
    fn write(&mut self, slot: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]);

    /// Causal attention for one query of `slot` over positions `0..=pos`
    /// of `layer`, accumulating per-head context into `ctx` (zeroed by
    /// the caller). `threads` bounds the head-parallel fan-out (results
    /// never depend on it).
    #[allow(clippy::too_many_arguments)]
    fn attend(
        &self,
        slot: usize,
        layer: usize,
        q: &[f32],
        pos: usize,
        ctx: &mut [f32],
        s: &mut AttnScratch,
        threads: usize,
    );
}

impl<K: KvStore> KvArena for [K] {
    fn write(&mut self, slot: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self[slot].write(layer, pos, k, v);
    }

    fn attend(
        &self,
        slot: usize,
        layer: usize,
        q: &[f32],
        pos: usize,
        ctx: &mut [f32],
        s: &mut AttnScratch,
        threads: usize,
    ) {
        self[slot].attend(layer, q, pos, ctx, s, threads);
    }
}

// =====================================================================
// Fused decode step
// =====================================================================

/// One live row of a fused decode step: the token it feeds, its position,
/// and which cache slot it owns.
#[derive(Debug, Clone, Copy)]
pub struct StepRow {
    pub token: u8,
    pub pos: usize,
    pub slot: usize,
}

/// Decoder-owned per-step scratch: the stacked activations, RoPE angles,
/// attention context, and MLP tiles every step reuses (`Matrix::reset`
/// instead of reallocation), plus the attention and kernel scratch shared
/// across layers.
pub struct DecodeScratch {
    /// Residual stream, one row per live sequence.
    h: Matrix,
    /// Per-sequence RoPE angles (each row at its own position).
    cos: Matrix,
    sin: Matrix,
    /// Attention context accumulator (zeroed per layer).
    ctx: Matrix,
    /// SwiGLU activation tile.
    act: Matrix,
    /// Per-row MoE output rows (switch-MoE routes per sequence).
    moe_y: Matrix,
    /// Attention score + dequant-row scratch.
    attn: AttnScratch,
    /// Fused-kernel scratch for the per-row MoE matvec path.
    kernel: KernelScratch,
}

impl DecodeScratch {
    pub fn new(capacity: usize) -> DecodeScratch {
        DecodeScratch {
            h: Matrix::zeros(0, 0),
            cos: Matrix::zeros(0, 0),
            sin: Matrix::zeros(0, 0),
            ctx: Matrix::zeros(0, 0),
            act: Matrix::zeros(0, 0),
            moe_y: Matrix::zeros(0, 0),
            attn: AttnScratch::new(capacity),
            kernel: KernelScratch::new(),
        }
    }
}

/// Stacked-rows linear for one decode step. The batch-of-one case takes
/// the matvec fast path — decoder-owned [`KernelScratch`], so the token
/// hot path performs no per-call unpack/fold allocations — while larger
/// batches amortize one weight-row unpack across all live rows through
/// the shared kernel. Bitwise-identical either way (matvec ≡ shared per
/// row), so which path ran can never change tokens.
fn decode_linear<L: LinearOp + ?Sized>(
    w: &L,
    x: &Matrix,
    threads: usize,
    kernel: &mut KernelScratch,
    phase: Phase,
) -> Matrix {
    let t0 = profiler::start();
    let y = if x.rows == 1 {
        let y = w.matvec(x.row(0), kernel);
        let cols = y.len();
        Matrix::from_vec(1, cols, y)
    } else {
        w.decode_matmul(x, threads, kernel)
    };
    profiler::stop(phase, t0);
    y
}

/// One fused decode step over stacked live rows: embed each row's token,
/// run every transformer block with fused stacked-row matmuls (one weight
/// tile unpack shared by all rows; batch 1 takes the scratch-reusing
/// matvec path), write/attend each row's slot in the [`KvArena`], and
/// return next-token logits, one row per input row.
///
/// The single-sequence decoder is the `rows.len() == 1` instantiation; the
/// continuous batcher passes all live slots. Every kernel this touches
/// keeps the matvec ≡ shared bitwise contract per row, so the two callers
/// agree exactly — at any batch size and any admission order — and both
/// reproduce the pre-refactor decoders at `--kv-bits 32`.
pub(crate) fn decode_rows<A: KvArena + ?Sized>(
    model: &ResolvedModel,
    rows: &[StepRow],
    kv: &mut A,
    scratch: &mut DecodeScratch,
) -> Matrix {
    let cfg = model.cfg;
    let (d, hd) = (cfg.d, cfg.head_dim());
    let b = rows.len();

    let DecodeScratch { h, cos, sin, ctx, act, moe_y, attn, kernel } = scratch;

    // Stack this step's input embeddings and RoPE angles, one row per live
    // sequence (each at its own position), into reused scratch.
    let t0 = profiler::start();
    h.reset(b, d);
    cos.reset(b, hd / 2);
    sin.reset(b, hd / 2);
    for (r, row) in rows.iter().enumerate() {
        h.row_mut(r).copy_from_slice(model.embed.row(row.token as usize));
        model.rope_angles_into(row.pos, cos.row_mut(r), sin.row_mut(r));
    }
    profiler::stop(Phase::Embed, t0);

    for (l, layer) in model.layers.iter().enumerate() {
        // --- Attention block: fused projections over all live rows ---
        let x = timed_norm(h, layer.ln1, cfg.eps);
        let q = decode_linear(layer.wq, &x, model.threads, kernel, Phase::LinWq);
        let k = decode_linear(layer.wk, &x, model.threads, kernel, Phase::LinWk);
        let v = decode_linear(layer.wv, &x, model.threads, kernel, Phase::LinWv);
        let t0 = profiler::start();
        let (q, k) = (rope(&q, cos, sin, cfg.heads), rope(&k, cos, sin, cfg.heads));
        profiler::stop(Phase::Rope, t0);

        ctx.reset(b, d);
        for (r, row) in rows.iter().enumerate() {
            let t0 = profiler::start();
            kv.write(row.slot, l, row.pos, k.row(r), v.row(r));
            profiler::stop(Phase::KvWrite, t0);
            let t0 = profiler::start();
            kv.attend(row.slot, l, q.row(r), row.pos, ctx.row_mut(r), attn, model.threads);
            profiler::stop(Phase::KvAttend, t0);
        }
        let o = decode_linear(layer.wo, ctx, model.threads, kernel, Phase::LinWo);
        add_inplace(h, &o);

        // --- MLP block ---
        let x = timed_norm(h, layer.ln2, cfg.eps);
        match &layer.mlp {
            MlpRefs::Dense(w) => {
                let g = decode_linear(w.wg, &x, model.threads, kernel, Phase::LinWg);
                let u = decode_linear(w.wu, &x, model.threads, kernel, Phase::LinWu);
                let t0 = profiler::start();
                act.reset(b, cfg.ffn);
                for i in 0..b * cfg.ffn {
                    act.data[i] = silu(g.data[i]) * u.data[i];
                }
                profiler::stop(Phase::Activation, t0);
                let y = decode_linear(w.wd, act, model.threads, kernel, Phase::LinWd);
                add_inplace(h, &y);
            }
            moe => {
                // Switch-MoE routes per sequence; rows picking different
                // experts cannot share a matmul, so keep the per-row path
                // (bitwise equal to the single-sequence decoder). The whole
                // routed path accrues to one phase.
                let t0 = profiler::start();
                moe_y.reset(b, d);
                for r in 0..b {
                    moe_y.row_mut(r).copy_from_slice(&mlp_forward(moe, x.row(r), kernel));
                }
                profiler::stop(Phase::Moe, t0);
                add_inplace(h, moe_y);
            }
        }
    }

    let hf = timed_norm(h, model.ln_f, cfg.eps);
    decode_linear(model.lm_head, &hf, model.threads, kernel, Phase::LinLmHead)
}

// =====================================================================
// Token selection
// =====================================================================

/// Seeded sampling parameters for one request. `temperature == 0` (or an
/// absent config) means greedy argmax — the bit-identical default.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampleCfg {
    /// Softmax temperature; must be > 0 to sample.
    pub temperature: f32,
    /// Keep only the `top_k` most likely tokens (0 = no cut).
    pub top_k: usize,
    /// RNG seed; the stream is per-request, so results do not depend on
    /// batch placement or admission order.
    pub seed: u64,
}

/// The unified core's token-selection hook: every decoder funnels its
/// next-token choice through one of these, so sampling lands once instead
/// of per decode path.
#[derive(Debug, Clone)]
pub enum TokenPicker {
    /// Greedy argmax (the default; bit-identical to the seed decoders).
    Greedy,
    /// Seeded temperature/top-k sampling with a per-request RNG state.
    Sample { cfg: SampleCfg, state: u64 },
}

impl TokenPicker {
    pub fn new(sample: Option<SampleCfg>) -> TokenPicker {
        match sample {
            // Subnormal temperatures would overflow 1/T to inf and poison
            // the softmax with NaN; anything that small means greedy anyway.
            Some(cfg) if cfg.temperature > 0.0 && (1.0 / cfg.temperature).is_finite() => {
                TokenPicker::Sample { cfg, state: cfg.seed }
            }
            _ => TokenPicker::Greedy,
        }
    }

    /// Pick the next token from a logits row. Greedy is pure argmax;
    /// sampling advances this picker's own RNG once per call, so a
    /// request's token stream depends only on (logits, seed) — never on
    /// which slot or step the batcher ran it in.
    pub fn pick(&mut self, logits: &[f32]) -> u8 {
        match self {
            TokenPicker::Greedy => argmax(logits) as u8,
            TokenPicker::Sample { cfg, state } => {
                let inv_t = 1.0 / cfg.temperature;
                // Stable descending sort: ties break by ascending index, so
                // the kept set is deterministic.
                let mut order: Vec<usize> = (0..logits.len()).collect();
                order.sort_by(|&a, &b| {
                    logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
                });
                let k = if cfg.top_k == 0 { order.len() } else { cfg.top_k.min(order.len()) };
                let kept = &order[..k.max(1)];
                let maxv = logits[kept[0]];
                let probs: Vec<f64> =
                    kept.iter().map(|&i| (((logits[i] - maxv) * inv_t) as f64).exp()).collect();
                let denom: f64 = probs.iter().sum();
                let u = splitmix(state) as f64 / (u64::MAX as f64 + 1.0) * denom;
                let mut acc = 0.0f64;
                for (j, p) in probs.iter().enumerate() {
                    acc += p;
                    if u < acc {
                        return kept[j] as u8;
                    }
                }
                kept[kept.len() - 1] as u8
            }
        }
    }
}

/// SplitMix64: advances the state and returns a uniform u64.
fn splitmix(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn kv_bits_parse_and_width() {
        assert_eq!(KvBits::parse("32"), Some(KvBits::F32));
        assert_eq!(KvBits::parse("8"), Some(KvBits::Q8));
        assert_eq!(KvBits::parse("16"), None);
        assert_eq!(KvBits::F32.bits(), 32);
        assert_eq!(KvBits::Q8.bits(), 8);
    }

    #[test]
    fn kv_q8_roundtrip_error_bounded_by_half_step() {
        let mut rng = Rng::new(5);
        let (layers, cap, d, heads) = (2usize, 6usize, 64usize, 2usize);
        let hd = d / heads;
        let mut store = KvQ8::new(layers, cap, d, heads);
        let row_k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let row_v: Vec<f32> = (0..d).map(|_| rng.normal_f32(1.0, 0.5)).collect();
        store.write(1, 3, &row_k, &row_v);
        let idx = cap + 3;
        for h in 0..heads {
            let (s, m) = (store.k_scale[idx * heads + h], store.k_min[idx * heads + h]);
            for t in 0..hd {
                let code = store.k_codes[idx * d + h * hd + t] as f32;
                let back = m + s * code;
                let err = (back - row_k[h * hd + t]).abs();
                assert!(err <= s * 0.5 + 1e-6, "head {h} elem {t}: err {err} > step/2 {s}");
            }
        }
    }

    #[test]
    fn kv_q8_handles_constant_segments() {
        let (d, heads) = (8usize, 2usize);
        let mut store = KvQ8::new(1, 2, d, heads);
        store.write(0, 0, &[3.5; 8], &[0.0; 8]);
        let mut ctx = vec![0.0f32; d];
        let mut s = AttnScratch::new(2);
        let q = vec![1.0f32; d];
        store.attend(0, &q, 0, &mut ctx, &mut s, 1);
        assert!(ctx.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn kv_q8_is_at_least_3x_smaller_than_f32() {
        for (d, heads) in [(64usize, 2usize), (128, 4), (256, 8)] {
            let f = KvF32::new(4, 128, d, heads);
            let q = KvQ8::new(4, 128, d, heads);
            let ratio = f.bytes() as f64 / q.bytes() as f64;
            assert!(ratio >= 3.0, "d={d} heads={heads}: only {ratio:.2}x smaller");
        }
    }

    #[test]
    fn kv_q8_attention_approximates_f32_attention() {
        let mut rng = Rng::new(17);
        let (layers, cap, d, heads) = (1usize, 8usize, 64usize, 2usize);
        let mut f32s = KvF32::new(layers, cap, d, heads);
        let mut q8s = KvQ8::new(layers, cap, d, heads);
        for pos in 0..cap {
            let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            f32s.write(0, pos, &k, &v);
            q8s.write(0, pos, &k, &v);
        }
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut s = AttnScratch::new(cap);
        let mut ctx_f = vec![0.0f32; d];
        let mut ctx_q = vec![0.0f32; d];
        f32s.attend(0, &q, cap - 1, &mut ctx_f, &mut s, 1);
        q8s.attend(0, &q, cap - 1, &mut ctx_q, &mut s, 1);
        let max_diff = ctx_f.iter().zip(&ctx_q).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_diff < 0.1, "q8 attention drifted {max_diff} from f32");
        assert!(max_diff > 0.0, "q8 attention suspiciously exact");
    }

    #[test]
    fn kv_q8_attend_is_threadcount_invariant() {
        let mut rng = Rng::new(23);
        let (layers, cap, d, heads) = (1usize, 128usize, 256usize, 8usize);
        // heads × positions × head_dim = 32768 ≥ the parallel threshold,
        // so multi-thread calls actually take the head-parallel path.
        assert!(heads * cap * (d / heads) >= ATTEND_PARALLEL_THRESHOLD);
        let mut store = KvQ8::new(layers, cap, d, heads);
        for pos in 0..cap {
            let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            store.write(0, pos, &k, &v);
        }
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut base = vec![0.0f32; d];
        let mut s = AttnScratch::new(cap);
        store.attend(0, &q, cap - 1, &mut base, &mut s, 1);
        for threads in [2usize, 8] {
            let mut ctx = vec![0.0f32; d];
            let mut s = AttnScratch::new(cap);
            store.attend(0, &q, cap - 1, &mut ctx, &mut s, threads);
            let same = base.iter().zip(&ctx).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "threads={threads} changed kv8 attend bits");
        }
    }

    #[test]
    fn greedy_picker_is_argmax_and_sampler_is_seed_deterministic() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut greedy = TokenPicker::new(None);
        assert_eq!(greedy.pick(&logits) as usize, argmax(&logits));
        // temperature 0 stays greedy, and so does a subnormal temperature
        // (1/T would overflow to inf and NaN the softmax).
        let mut t0 = TokenPicker::new(Some(SampleCfg { temperature: 0.0, top_k: 4, seed: 9 }));
        assert_eq!(t0.pick(&logits) as usize, argmax(&logits));
        let mut tiny = TokenPicker::new(Some(SampleCfg { temperature: 1e-39, top_k: 0, seed: 1 }));
        assert_eq!(tiny.pick(&logits) as usize, argmax(&logits));

        let cfg = SampleCfg { temperature: 0.8, top_k: 8, seed: 1234 };
        let mut a = TokenPicker::new(Some(cfg));
        let mut b = TokenPicker::new(Some(cfg));
        let seq_a: Vec<u8> = (0..32).map(|_| a.pick(&logits)).collect();
        let seq_b: Vec<u8> = (0..32).map(|_| b.pick(&logits)).collect();
        assert_eq!(seq_a, seq_b, "same seed must reproduce the same stream");
        let mut c = TokenPicker::new(Some(SampleCfg { seed: 99, ..cfg }));
        let seq_c: Vec<u8> = (0..32).map(|_| c.pick(&logits)).collect();
        assert_ne!(seq_a, seq_c, "different seeds should diverge");
    }

    #[test]
    fn sampler_respects_top_k() {
        let mut logits = vec![0.0f32; 16];
        logits[3] = 5.0;
        logits[7] = 4.0;
        let cfg = SampleCfg { temperature: 1.0, top_k: 2, seed: 7 };
        let mut p = TokenPicker::new(Some(cfg));
        for _ in 0..64 {
            let tok = p.pick(&logits);
            assert!(tok == 3 || tok == 7, "top-2 sampling drew token {tok}");
        }
    }

    #[test]
    fn lin_and_gain_names_match_the_weight_map_keys() {
        assert_eq!(LinId::Wq(2).name(), "layers.2.wq");
        assert_eq!(LinId::Router(0).name(), "layers.0.router");
        assert_eq!(LinId::ExpertDown(1, 3).name(), "layers.1.expert3.wd");
        assert_eq!(LinId::LmHead.name(), "lm_head");
        assert_eq!(Gain::Ln2(4).name(), "layers.4.ln2");
        assert_eq!(Gain::Final.name(), "ln_f");
    }
}
