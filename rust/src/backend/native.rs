//! The native inference engine: a pure-Rust transformer forward/decode that
//! executes **directly on packed quantized weights**.
//!
//! Where [`crate::model::forward::Forward`] is the f32 reference (it wants a
//! map of dense effective weights), [`NativeBackend`] holds each linear as a
//! [`LayerWeight`] — either a dense matrix or a bit-packed
//! [`QuantizedTensor`] — and routes every projection through the fused
//! dequant kernels. The layer-by-layer math itself lives **once** in
//! [`crate::backend::fwd`]: the full-sequence forward here is a thin
//! [`SeqModel`] instantiation of [`fwd::forward_seq`], so logits agree with
//! the reference to float tolerance (bit-identically on dense weights).
//!
//! [`NativeDecoder`] adds the autoregressive path: a preallocated
//! [`KvCache`] slot (`--kv-bits 32|8`) driven through the shared
//! [`fwd::decode_rows`] step — `generate` needs no artifacts, no XLA, and
//! no Python. Its continuous-batching sibling,
//! [`crate::backend::BatchDecoder`], shares the resolved weight references
//! ([`ResolvedModel`]) and the *same* decode-step function, so the two
//! decode paths produce bit-identical tokens by construction.

use std::collections::BTreeMap;

use crate::backend::batch::BatchDecoder;
use crate::backend::config::EngineConfig;
use crate::backend::fwd::{
    self, decode_rows, DecodeScratch, Gain, KvBits, KvCache, KvStore, LinId, LinearOp, SeqModel,
    StepRow,
};
use crate::backend::quantized::QuantizedTensor;
use crate::backend::simd::KernelScratch;
use crate::backend::InferenceBackend;
use crate::eval::LogitsEngine;
use crate::model::{ModelConfig, ModelWeights, QuantizedModel};
use crate::tensor::Matrix;
use crate::util::threadpool;

/// One linear layer's runtime representation: the per-layer selector
/// between the core's two [`LinearOp`] implementations.
#[derive(Debug, Clone)]
pub enum LayerWeight {
    /// Dense f32 (embeddings, FP serving, or fallback for representations
    /// the fused kernels cannot execute, e.g. Hadamard-rotated storage).
    Dense(Matrix),
    /// Bit-packed quantized weights executed by the fused kernels.
    Quant(QuantizedTensor),
}

impl LayerWeight {
    pub fn is_quantized(&self) -> bool {
        matches!(self, LayerWeight::Quant(_))
    }
}

/// [`LayerWeight`] delegates every execution shape to the [`LinearOp`]
/// implementation of its variant — f32-reference ([`Matrix`]) or
/// fused-quantized ([`QuantizedTensor`]).
impl LinearOp for LayerWeight {
    fn out_features(&self) -> usize {
        match self {
            LayerWeight::Dense(w) => w.out_features(),
            LayerWeight::Quant(q) => q.out_features(),
        }
    }

    fn matmul(&self, x: &Matrix, threads: usize) -> Matrix {
        match self {
            LayerWeight::Dense(w) => LinearOp::matmul(w, x, threads),
            LayerWeight::Quant(q) => LinearOp::matmul(q, x, threads),
        }
    }

    fn matvec(&self, x: &[f32], scratch: &mut KernelScratch) -> Vec<f32> {
        match self {
            LayerWeight::Dense(w) => LinearOp::matvec(w, x, scratch),
            LayerWeight::Quant(q) => LinearOp::matvec(q, x, scratch),
        }
    }

    fn decode_matmul(&self, x: &Matrix, threads: usize, scratch: &mut KernelScratch) -> Matrix {
        match self {
            LayerWeight::Dense(w) => LinearOp::decode_matmul(w, x, threads, scratch),
            LayerWeight::Quant(q) => LinearOp::decode_matmul(q, x, threads, scratch),
        }
    }
}

/// Default serving concurrency: scoring batch size and generation slots.
pub use crate::backend::config::DEFAULT_MAX_BATCH;

/// Pure-Rust inference backend over dense or packed-quantized weights.
pub struct NativeBackend {
    pub cfg: ModelConfig,
    layers: BTreeMap<String, LayerWeight>,
    vectors: BTreeMap<String, Vec<f32>>,
    /// Worker threads for the fused matmul tiles.
    pub threads: usize,
    /// Engine defaults every decoder built over this backend inherits
    /// (KV precision, batch width, context cap, page geometry, sampling).
    engine: EngineConfig,
    /// Build-time quantization-quality report (per-layer NMSE, Sinkhorn
    /// convergence); `None` when the backend was built from dense weights
    /// or a pre-quantized `.stz` whose build stats were not kept.
    quant_report: Option<crate::obs::QuantReport>,
}

/// Default tile-worker count: [`threadpool::resolve_threads`]`(0)` —
/// `SINQ_THREADS` when set, otherwise every available core. The former
/// `.min(8)` cap is gone: workers are persistent and condvar-parked, so
/// unused ones cost nothing, and capping silently wasted big machines.
fn default_threads() -> usize {
    threadpool::resolve_threads(0)
}

impl NativeBackend {
    /// FP backend: every weight dense f32 (bitwise-identical math to the
    /// reference forward — the `--backend native` baseline).
    pub fn from_weights(mw: &ModelWeights) -> NativeBackend {
        NativeBackend::from_parts(&mw.cfg, &mw.tensors, &mw.vectors)
    }

    /// Dense backend over bare parts (config + effective weights + norm
    /// gains) — the evaluation path the paper tables use to score any
    /// method's dequantized "effective" weights without PJRT artifacts.
    pub fn from_parts(
        cfg: &ModelConfig,
        tensors: &BTreeMap<String, Matrix>,
        vectors: &BTreeMap<String, Vec<f32>>,
    ) -> NativeBackend {
        let layers = tensors
            .iter()
            .map(|(n, m)| (n.clone(), LayerWeight::Dense(m.clone())))
            .collect();
        NativeBackend {
            cfg: cfg.clone(),
            layers,
            vectors: vectors.clone(),
            threads: default_threads(),
            engine: EngineConfig::default(),
            quant_report: None,
        }
    }

    /// Quantized backend: packs every packable layer; Hadamard/codebook
    /// layers fall back to a dense dequantized copy so any `.stz` serves.
    pub fn from_quantized(qm: &QuantizedModel) -> NativeBackend {
        let mut layers: BTreeMap<String, LayerWeight> = qm
            .fweights
            .iter()
            .map(|(n, m)| (n.clone(), LayerWeight::Dense(m.clone())))
            .collect();
        for (n, q) in &qm.layers {
            let lw = match QuantizedTensor::from_linear(q) {
                Some(t) => LayerWeight::Quant(t),
                None => LayerWeight::Dense(q.effective_weight()),
            };
            layers.insert(n.clone(), lw);
        }
        NativeBackend {
            cfg: qm.cfg.clone(),
            layers,
            vectors: qm.fvectors.clone(),
            threads: default_threads(),
            engine: EngineConfig::default(),
            quant_report: None,
        }
    }

    /// Set the engine defaults (KV precision, batch width, context cap,
    /// page geometry, sampling) every decoder built over this backend
    /// inherits — the one typed builder that replaced the per-knob
    /// `with_max_batch`/`with_kv_bits` sprawl.
    pub fn with_engine(mut self, engine: EngineConfig) -> NativeBackend {
        if engine.threads > 0 {
            // `--threads` (resolved through `SINQ_THREADS`) overrides the
            // all-cores default for every kernel this backend runs.
            self.threads = engine.effective_threads();
        }
        // Size the persistent worker pool at engine start (first sizing
        // wins); decoders and tiled matmuls reuse it from here on.
        threadpool::init_global(self.threads);
        self.engine = engine;
        self
    }

    /// The engine defaults decoders built over this backend inherit.
    pub fn engine(&self) -> EngineConfig {
        self.engine
    }

    /// The KV-cache precision decode entry points construct caches with.
    pub fn kv_bits(&self) -> KvBits {
        self.engine.kv_bits
    }

    /// Attach the build-time quantization-quality report (set by the
    /// quantize-and-serve pipeline; `.stz`-loaded backends have none).
    pub fn with_quant_report(
        mut self,
        report: Option<crate::obs::QuantReport>,
    ) -> NativeBackend {
        self.quant_report = report;
        self
    }

    /// Build-time quantization-quality report, if the backend was
    /// quantized in-process.
    pub fn quant_report(&self) -> Option<&crate::obs::QuantReport> {
        self.quant_report.as_ref()
    }

    /// How many linears run on packed codes (vs dense fallback).
    pub fn quantized_layer_count(&self) -> usize {
        self.layers.values().filter(|l| l.is_quantized()).count()
    }

    fn layer(&self, name: &str) -> anyhow::Result<&LayerWeight> {
        self.layers
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("native backend missing weight '{name}'"))
    }

    fn gain(&self, name: &str) -> anyhow::Result<&[f32]> {
        self.vectors
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("native backend missing vector '{name}'"))
    }

    fn embedding(&self) -> anyhow::Result<&Matrix> {
        match self.layer("embed")? {
            LayerWeight::Dense(m) => Ok(m),
            LayerWeight::Quant(_) => anyhow::bail!("embedding table must stay dense"),
        }
    }

    /// Full-sequence forward: `tokens` (length S) → logits `(S, vocab)`.
    /// A [`SeqModel`] instantiation of the unified core
    /// ([`fwd::forward_seq`]) with linears dispatched through
    /// [`LayerWeight`]'s [`LinearOp`].
    pub fn forward(&self, tokens: &[u8]) -> anyhow::Result<Matrix> {
        self.forward_with(tokens, self.threads)
    }

    /// [`NativeBackend::forward`] with an explicit tile-thread count —
    /// `forward_batch` runs one sequence per worker with `threads = 1` so
    /// total concurrency stays at the pool width.
    fn forward_with(&self, tokens: &[u8], threads: usize) -> anyhow::Result<Matrix> {
        fwd::forward_seq(&mut NativeSeq { be: self, threads }, tokens)
    }

    /// Batched scoring over `&self` (the body of the
    /// [`InferenceBackend::forward_batch`] impl): one worker per sequence,
    /// per-sequence tile parallelism disabled so total concurrency stays at
    /// the pool width. Taking `&self` lets a shared backend
    /// (`Arc<NativeBackend>`) serve the scoring router and the streaming
    /// decode engine from one weight set.
    pub fn forward_batch(&self, seqs: &[&[u8]]) -> anyhow::Result<Vec<Matrix>> {
        if seqs.len() <= 1 {
            return seqs.iter().map(|s| self.forward(s)).collect();
        }
        threadpool::map_indexed(seqs, self.threads, |_, s| self.forward_with(s, 1))
            .into_iter()
            .collect()
    }

    /// Greedy autoregressive generation over `&self` (the body of the
    /// [`InferenceBackend::generate`] impl).
    pub fn generate(&self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
        let mut dec = NativeDecoder::new(self, prompt.len() + n + 1)?;
        dec.generate(prompt, n)
    }

    /// Continuous-batched greedy generation over `&self` (the body of the
    /// [`InferenceBackend::generate_batch`] impl): all prompts share one
    /// [`BatchDecoder`], so every packed weight tile is unpacked once per
    /// step instead of once per sequence. Tokens are exactly those
    /// [`NativeBackend::generate`] would produce per prompt.
    pub fn generate_batch(
        &self,
        prompts: &[&[u8]],
        max_new: &[usize],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        anyhow::ensure!(
            prompts.len() == max_new.len(),
            "generate_batch: {} prompts but {} max_new entries",
            prompts.len(),
            max_new.len()
        );
        if prompts.is_empty() {
            return Ok(Vec::new());
        }
        let slots = self.engine.max_batch.min(prompts.len()).max(1);
        let capacity = prompts
            .iter()
            .zip(max_new)
            .map(|(p, &n)| p.len() + n + 1)
            .max()
            .unwrap_or(1);
        let mut dec = BatchDecoder::new(self, slots, capacity)?;
        for (i, (p, &n)) in prompts.iter().zip(max_new).enumerate() {
            dec.submit(i, p, n)?;
        }
        let outs = dec.run()?;
        Ok(outs.into_iter().map(|o| o.tokens).collect())
    }
}

/// The native engine's [`SeqModel`] instantiation: name lookups into the
/// [`LayerWeight`] map, execution through [`LinearOp`].
struct NativeSeq<'a> {
    be: &'a NativeBackend,
    threads: usize,
}

impl SeqModel for NativeSeq<'_> {
    fn cfg(&self) -> &ModelConfig {
        &self.be.cfg
    }

    fn embed_row(&self, token: u8) -> anyhow::Result<&[f32]> {
        Ok(self.be.embedding()?.row(token as usize))
    }

    fn gain(&self, g: Gain) -> anyhow::Result<&[f32]> {
        self.be.gain(&g.name())
    }

    fn linear(&mut self, id: LinId, x: &Matrix) -> anyhow::Result<Matrix> {
        Ok(self.be.layer(&id.name())?.matmul(x, self.threads))
    }
}

impl LogitsEngine for NativeBackend {
    fn logits(&mut self, tokens: &[u8]) -> anyhow::Result<Matrix> {
        self.forward(tokens)
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn max_batch(&self) -> usize {
        self.engine.max_batch.max(1)
    }

    fn forward_batch(&mut self, seqs: &[&[u8]]) -> anyhow::Result<Vec<Matrix>> {
        NativeBackend::forward_batch(self, seqs)
    }

    fn generate(&mut self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
        NativeBackend::generate(self, prompt, n)
    }

    fn generate_batch(
        &mut self,
        prompts: &[&[u8]],
        max_new: &[usize],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        NativeBackend::generate_batch(self, prompts, max_new)
    }
}

/// Per-MLP (dense or one expert) weight references resolved at build time.
pub(crate) struct MlpWeights<'a> {
    pub(crate) wg: &'a LayerWeight,
    pub(crate) wu: &'a LayerWeight,
    pub(crate) wd: &'a LayerWeight,
}

pub(crate) enum MlpRefs<'a> {
    Dense(MlpWeights<'a>),
    Moe { router: &'a LayerWeight, experts: Vec<MlpWeights<'a>> },
}

/// One layer's weights, resolved once so the per-token loop does no name
/// formatting or map lookups.
pub(crate) struct DecoderLayer<'a> {
    pub(crate) ln1: &'a [f32],
    pub(crate) ln2: &'a [f32],
    pub(crate) wq: &'a LayerWeight,
    pub(crate) wk: &'a LayerWeight,
    pub(crate) wv: &'a LayerWeight,
    pub(crate) wo: &'a LayerWeight,
    pub(crate) mlp: MlpRefs<'a>,
}

/// Every weight/gain reference plus the rotary frequency table of a
/// [`NativeBackend`], resolved once so decode hot paths do no name
/// formatting or map lookups. Shared by the single-sequence
/// [`NativeDecoder`] and the continuous-batching
/// [`crate::backend::BatchDecoder`] — both drive it through the unified
/// [`fwd::decode_rows`] step.
pub(crate) struct ResolvedModel<'a> {
    pub(crate) cfg: &'a ModelConfig,
    pub(crate) embed: &'a Matrix,
    pub(crate) ln_f: &'a [f32],
    pub(crate) lm_head: &'a LayerWeight,
    pub(crate) layers: Vec<DecoderLayer<'a>>,
    /// Rotary inverse frequencies, length `head_dim / 2`.
    pub(crate) inv_freq: Vec<f64>,
    /// Worker threads for the batched decode matmuls.
    pub(crate) threads: usize,
}

impl<'a> ResolvedModel<'a> {
    /// Resolve every weight reference; errors if the backend is missing one.
    pub(crate) fn new(be: &'a NativeBackend) -> anyhow::Result<ResolvedModel<'a>> {
        let cfg = &be.cfg;
        let mlp_refs = |pre: &str| -> anyhow::Result<MlpWeights<'a>> {
            Ok(MlpWeights {
                wg: be.layer(&format!("{pre}.wg"))?,
                wu: be.layer(&format!("{pre}.wu"))?,
                wd: be.layer(&format!("{pre}.wd"))?,
            })
        };
        let mut layers = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let pre = format!("layers.{l}");
            let mlp = if cfg.n_experts == 0 {
                MlpRefs::Dense(mlp_refs(&pre)?)
            } else {
                MlpRefs::Moe {
                    router: be.layer(&format!("{pre}.router"))?,
                    experts: (0..cfg.n_experts)
                        .map(|e| mlp_refs(&format!("{pre}.expert{e}")))
                        .collect::<anyhow::Result<Vec<_>>>()?,
                }
            };
            layers.push(DecoderLayer {
                ln1: be.gain(&format!("{pre}.ln1"))?,
                ln2: be.gain(&format!("{pre}.ln2"))?,
                wq: be.layer(&format!("{pre}.wq"))?,
                wk: be.layer(&format!("{pre}.wk"))?,
                wv: be.layer(&format!("{pre}.wv"))?,
                wo: be.layer(&format!("{pre}.wo"))?,
                mlp,
            });
        }
        let hd = cfg.head_dim();
        let inv_freq = (0..hd / 2)
            .map(|i| (cfg.rope_base as f64).powf(-(i as f64) * 2.0 / hd as f64))
            .collect();
        Ok(ResolvedModel {
            cfg,
            embed: be.embedding()?,
            ln_f: be.gain("ln_f")?,
            lm_head: be.layer("lm_head")?,
            layers,
            inv_freq,
            threads: be.threads,
        })
    }

    /// RoPE angles for one position (same formula as the forward pass).
    pub(crate) fn rope_angles_into(&self, pos: usize, cos: &mut [f32], sin: &mut [f32]) {
        for (i, &inv) in self.inv_freq.iter().enumerate() {
            let ang = pos as f64 * inv;
            cos[i] = ang.cos() as f32;
            sin[i] = ang.sin() as f32;
        }
    }
}

/// Autoregressive decoder: one preallocated [`KvCache`] slot driven through
/// the unified decode step ([`fwd::decode_rows`]) one row at a time.
///
/// Every weight/gain reference and the rotary frequency table are resolved
/// once at construction; `step` — the decode hot path — touches only
/// resolved references, the fused matvec/shared kernels, and the decoder's
/// own scratch.
pub struct NativeDecoder<'a> {
    model: ResolvedModel<'a>,
    /// Exactly one KV slot (the unified step addresses slots by index).
    cache: Vec<KvCache>,
    pub pos: usize,
    capacity: usize,
    scratch: DecodeScratch,
}

impl<'a> NativeDecoder<'a> {
    /// Resolve every weight reference and preallocate a KV slot of
    /// `capacity` positions at the backend's configured `--kv-bits`
    /// precision; errors if the backend is missing a weight.
    pub fn new(be: &'a NativeBackend, capacity: usize) -> anyhow::Result<NativeDecoder<'a>> {
        NativeDecoder::with_config(be, &be.engine().with_max_context(capacity))
    }

    /// [`NativeDecoder::new`] from a full [`EngineConfig`] (the KV
    /// precision and `max_context` apply; this decoder has one slot, so
    /// the page-pool knobs do not).
    pub fn with_config(
        be: &'a NativeBackend,
        cfg: &EngineConfig,
    ) -> anyhow::Result<NativeDecoder<'a>> {
        let mut model = ResolvedModel::new(be)?;
        if cfg.threads > 0 {
            model.threads = cfg.effective_threads();
        }
        threadpool::init_global(model.threads);
        let cap = cfg.max_context.max(1);
        let (layers, d, heads) = (model.cfg.layers, model.cfg.d, model.cfg.heads);
        Ok(NativeDecoder {
            model,
            cache: vec![KvCache::new(cfg.kv_bits, layers, cap, d, heads)],
            pos: 0,
            capacity: cap,
            scratch: DecodeScratch::new(cap),
        })
    }

    /// KV-cache precision of this decoder's slot.
    pub fn kv_bits(&self) -> KvBits {
        self.cache[0].kv_bits()
    }

    /// Resident bytes of this decoder's KV slot.
    pub fn kv_bytes(&self) -> usize {
        self.cache[0].bytes()
    }

    /// Feed one token; returns next-token logits (length vocab).
    pub fn step(&mut self, token: u8) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            self.pos < self.capacity,
            "decode context exhausted (KV capacity {})",
            self.capacity
        );
        let rows = [StepRow { token, pos: self.pos, slot: 0 }];
        let logits = decode_rows(&self.model, &rows, self.cache.as_mut_slice(), &mut self.scratch);
        self.pos += 1;
        Ok(logits.data)
    }

    /// Greedy generation: prefill `prompt`, then emit `n` tokens. The final
    /// token is emitted without a trailing step (its logits would be unused).
    ///
    /// Requests that cannot fit the preallocated KV cache are rejected up
    /// front with a clear error (prompt + generated tokens, minus the final
    /// unstepped one, must fit `capacity`).
    pub fn generate(&mut self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let needed = self.pos + prompt.len() + n.saturating_sub(1);
        anyhow::ensure!(
            needed <= self.capacity,
            "prompt of {} tokens + {n} generated needs {needed} KV positions but the \
             decoder preallocated {} (KV capacity); construct the decoder with a larger \
             capacity or shorten the request",
            prompt.len(),
            self.capacity
        );
        let mut last = Vec::new();
        for &t in prompt {
            last = self.step(t)?;
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let next = fwd::argmax(&last) as u8;
            out.push(next);
            if i + 1 < n {
                last = self.step(next)?;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::quantize_simple;
    use crate::model::forward::Forward;
    use crate::quant::{Method, QuantConfig};

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    fn pico() -> ModelWeights {
        ModelWeights::synthetic(&ModelConfig::family("pico").unwrap(), 21)
    }

    #[test]
    fn dense_native_matches_reference_forward() {
        let mw = pico();
        let reference = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"native backend parity";
        let l_ref = reference.forward(tokens, None);
        let l_nat = nb.forward(tokens).unwrap();
        assert_eq!((l_nat.rows, l_nat.cols), (l_ref.rows, l_ref.cols));
        assert!(
            max_abs_diff(&l_nat.data, &l_ref.data) < 1e-6,
            "dense native forward must match the reference"
        );
    }

    #[test]
    fn quantized_native_matches_effective_weight_forward() {
        let mw = pico();
        let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
        let eff = qm.effective_weights();
        let reference = Forward::new(&mw.cfg, &eff, &qm.fvectors);
        let nb = NativeBackend::from_quantized(&qm);
        assert!(nb.quantized_layer_count() > 0, "expected packed layers");
        let tokens = b"fused kernels vs reference";
        let l_ref = reference.forward(tokens, None);
        let l_nat = nb.forward(tokens).unwrap();
        assert!(
            max_abs_diff(&l_nat.data, &l_ref.data) < 1e-4,
            "fused forward diverged from effective-weight reference"
        );
    }

    #[test]
    fn moe_native_matches_reference() {
        let cfg = ModelConfig::family("tiny_moe").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 22);
        let reference = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"moe path";
        let l_ref = reference.forward(tokens, None);
        let l_nat = nb.forward(tokens).unwrap();
        assert!(max_abs_diff(&l_nat.data, &l_ref.data) < 1e-6);
    }

    #[test]
    fn decoder_matches_full_forward_last_position() {
        let mw = pico();
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"kv cache parity!";
        let full = nb.forward(tokens).unwrap();
        let mut dec = NativeDecoder::new(&nb, tokens.len() + 1).unwrap();
        let mut last = Vec::new();
        for &t in tokens.iter() {
            last = dec.step(t).unwrap();
        }
        assert_eq!(dec.pos, tokens.len());
        assert!(
            max_abs_diff(&last, full.row(tokens.len() - 1)) < 1e-3,
            "incremental decode diverged from full forward"
        );
    }

    #[test]
    fn decoder_context_exhaustion_errors() {
        let mw = pico();
        let nb = NativeBackend::from_weights(&mw);
        let mut dec = NativeDecoder::new(&nb, 2).unwrap();
        dec.step(b'a').unwrap();
        dec.step(b'b').unwrap();
        assert!(dec.step(b'c').is_err());
    }

    #[test]
    fn generate_rejects_request_beyond_capacity_up_front() {
        let mw = pico();
        let nb = NativeBackend::from_weights(&mw);
        let mut dec = NativeDecoder::new(&nb, 4).unwrap();
        let err = dec.generate(b"a prompt far beyond four positions", 2).unwrap_err();
        assert!(err.to_string().contains("KV"), "unclear capacity error: {err}");
        // Nothing was fed: the decoder remains usable for a fitting request.
        assert_eq!(dec.pos, 0);
        assert_eq!(dec.generate(b"ok", 3).unwrap().len(), 3);
    }

    #[test]
    fn generate_is_deterministic_and_respects_prompt() {
        let mw = pico();
        let qm = quantize_simple(&mw, &QuantConfig::new(Method::Rtn, 4), None).unwrap();
        let nb = NativeBackend::from_quantized(&qm);
        let a = nb.generate(b"hello", 12).unwrap();
        let b = nb.generate(b"hello", 12).unwrap();
        assert_eq!(a.len(), 12);
        assert_eq!(a, b, "greedy decode must be deterministic");
    }

    #[test]
    fn moe_decoder_runs() {
        let cfg = ModelConfig::family("tiny_moe").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 23);
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"moe decode";
        let full = nb.forward(tokens).unwrap();
        let mut dec = NativeDecoder::new(&nb, tokens.len() + 1).unwrap();
        let mut last = Vec::new();
        for &t in tokens.iter() {
            last = dec.step(t).unwrap();
        }
        assert!(max_abs_diff(&last, full.row(tokens.len() - 1)) < 1e-3);
    }

    #[test]
    fn kv8_decoder_shrinks_cache_and_stays_close_to_f32() {
        let mw = pico();
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"kv8 decode path";
        let cfg = EngineConfig::new().with_max_context(32);
        let mut d32 = NativeDecoder::with_config(&nb, &cfg.with_kv_bits(KvBits::F32)).unwrap();
        let mut d8 = NativeDecoder::with_config(&nb, &cfg.with_kv_bits(KvBits::Q8)).unwrap();
        assert_eq!(d32.kv_bits(), KvBits::F32);
        assert_eq!(d8.kv_bits(), KvBits::Q8);
        assert!(
            d32.kv_bytes() as f64 / d8.kv_bytes() as f64 >= 3.0,
            "q8 cache only {}B vs {}B",
            d8.kv_bytes(),
            d32.kv_bytes()
        );
        let (mut l32, mut l8) = (Vec::new(), Vec::new());
        for &t in tokens.iter() {
            l32 = d32.step(t).unwrap();
            l8 = d8.step(t).unwrap();
        }
        let diff = max_abs_diff(&l32, &l8);
        assert!(diff < 0.5, "kv8 logits drifted {diff} from f32");
        assert!(l8.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn engine_threads_override_flows_into_backend() {
        let mw = pico();
        let nb = NativeBackend::from_weights(&mw)
            .with_engine(EngineConfig::new().with_threads(2).with_max_batch(2));
        // A CI `SINQ_THREADS` matrix leg outranks the explicit request.
        match std::env::var("SINQ_THREADS").ok().and_then(|v| v.trim().parse::<usize>().ok()) {
            Some(n) if n > 0 => assert_eq!(nb.threads, n),
            _ => assert_eq!(nb.threads, 2),
        }
        // Generation still runs end to end with an explicit thread count.
        assert_eq!(nb.generate(b"abc", 4).unwrap().len(), 4);
    }

    #[test]
    fn backend_kv_bits_flows_into_decoders() {
        let mw = pico();
        let nb = NativeBackend::from_weights(&mw)
            .with_engine(EngineConfig::new().with_kv_bits(KvBits::Q8));
        assert_eq!(nb.kv_bits(), KvBits::Q8);
        let dec = NativeDecoder::new(&nb, 8).unwrap();
        assert_eq!(dec.kv_bits(), KvBits::Q8);
        // Generation still runs end to end on the quantized cache.
        let out = nb.generate(b"abc", 5).unwrap();
        assert_eq!(out.len(), 5);
    }
}
