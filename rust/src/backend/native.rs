//! The native inference engine: a pure-Rust transformer forward/decode that
//! executes **directly on packed quantized weights**.
//!
//! Where [`crate::model::forward::Forward`] is the f32 reference (it wants a
//! map of dense effective weights), [`NativeBackend`] holds each linear as a
//! [`LayerWeight`] — either a dense matrix or a bit-packed
//! [`QuantizedTensor`] — and routes every projection through the fused
//! dequant kernels. The layer-by-layer math (RMSNorm → RoPE MHA → residual →
//! SwiGLU / switch-MoE → residual → final norm → lm_head) mirrors the
//! reference operation-for-operation so logits agree to float tolerance.
//!
//! [`NativeDecoder`] adds the autoregressive path: per-layer K/V caches are
//! preallocated at construction and each step runs single-row matvecs
//! against the packed weights — `generate` needs no artifacts, no XLA, and
//! no Python. Its continuous-batching sibling,
//! [`crate::backend::BatchDecoder`], shares the resolved weight references
//! ([`ResolvedModel`]) and the attention/MLP helpers here, so the two decode
//! paths produce bit-identical tokens.

use std::collections::BTreeMap;

use crate::backend::batch::BatchDecoder;
use crate::backend::quantized::QuantizedTensor;
use crate::backend::simd::KernelScratch;
use crate::backend::InferenceBackend;
use crate::eval::LogitsEngine;
use crate::model::forward::{add_inplace, rmsnorm, rope, silu};
use crate::model::{ModelConfig, ModelWeights, QuantizedModel};
use crate::tensor::matrix::dot;
use crate::tensor::Matrix;
use crate::util::threadpool;

/// One linear layer's runtime representation.
#[derive(Debug, Clone)]
pub enum LayerWeight {
    /// Dense f32 (embeddings, FP serving, or fallback for representations
    /// the fused kernels cannot execute, e.g. Hadamard-rotated storage).
    Dense(Matrix),
    /// Bit-packed quantized weights executed by the fused kernels.
    Quant(QuantizedTensor),
}

impl LayerWeight {
    pub fn out_features(&self) -> usize {
        match self {
            LayerWeight::Dense(w) => w.rows,
            LayerWeight::Quant(q) => q.rows,
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, LayerWeight::Quant(_))
    }

    /// `y = x · Wᵀ` for a batch of activation rows.
    fn matmul(&self, x: &Matrix, threads: usize) -> Matrix {
        match self {
            LayerWeight::Dense(w) => x.matmul_nt(w),
            LayerWeight::Quant(q) => q.dequant_matmul(x, threads),
        }
    }

    /// `y = W · x` for one activation vector, with caller-owned kernel
    /// scratch — the decoders keep one scratch per session so quantized
    /// matvecs run without per-call unpack/fold allocations and the SIMD
    /// kernels write into stable aligned tiles (dense layers need no
    /// scratch and ignore it).
    pub(crate) fn matvec_with(&self, x: &[f32], scratch: &mut KernelScratch) -> Vec<f32> {
        match self {
            LayerWeight::Dense(w) => (0..w.rows).map(|r| dot(x, w.row(r), x.len())).collect(),
            LayerWeight::Quant(q) => q.dequant_matvec_with(x, scratch),
        }
    }

    /// `y = x · Wᵀ` for stacked decode rows (one row per live sequence).
    ///
    /// Quantized layers unpack each weight row once and share the decoded
    /// levels across every row via
    /// [`QuantizedTensor::dequant_matmul_shared`]; dense layers run the same
    /// per-row dot as [`LayerWeight::matvec_with`]. Either way the result is
    /// bitwise equal to the matvec applied row by row, which keeps batched
    /// and single-sequence decode in exact agreement.
    pub(crate) fn decode_matmul(&self, x: &Matrix, threads: usize) -> Matrix {
        match self {
            LayerWeight::Dense(w) => {
                let mut y = Matrix::zeros(x.rows, w.rows);
                for r in 0..x.rows {
                    let xr = x.row(r);
                    for j in 0..w.rows {
                        y.data[r * w.rows + j] = dot(xr, w.row(j), x.cols);
                    }
                }
                y
            }
            LayerWeight::Quant(q) => q.dequant_matmul_shared(x, threads),
        }
    }
}

/// Default serving concurrency: scoring batch size and generation slots.
pub const DEFAULT_MAX_BATCH: usize = 4;

/// Pure-Rust inference backend over dense or packed-quantized weights.
pub struct NativeBackend {
    pub cfg: ModelConfig,
    layers: BTreeMap<String, LayerWeight>,
    vectors: BTreeMap<String, Vec<f32>>,
    /// Worker threads for the fused matmul tiles.
    pub threads: usize,
    /// Serving concurrency cap: scoring batch size and generation slots.
    max_batch: usize,
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

impl NativeBackend {
    /// FP backend: every weight dense f32 (bitwise-identical math to the
    /// reference forward — the `--backend native` baseline).
    pub fn from_weights(mw: &ModelWeights) -> NativeBackend {
        NativeBackend::from_parts(&mw.cfg, &mw.tensors, &mw.vectors)
    }

    /// Dense backend over bare parts (config + effective weights + norm
    /// gains) — the evaluation path the paper tables use to score any
    /// method's dequantized "effective" weights without PJRT artifacts.
    pub fn from_parts(
        cfg: &ModelConfig,
        tensors: &BTreeMap<String, Matrix>,
        vectors: &BTreeMap<String, Vec<f32>>,
    ) -> NativeBackend {
        let layers = tensors
            .iter()
            .map(|(n, m)| (n.clone(), LayerWeight::Dense(m.clone())))
            .collect();
        NativeBackend {
            cfg: cfg.clone(),
            layers,
            vectors: vectors.clone(),
            threads: default_threads(),
            max_batch: DEFAULT_MAX_BATCH,
        }
    }

    /// Quantized backend: packs every packable layer; Hadamard/codebook
    /// layers fall back to a dense dequantized copy so any `.stz` serves.
    pub fn from_quantized(qm: &QuantizedModel) -> NativeBackend {
        let mut layers: BTreeMap<String, LayerWeight> = qm
            .fweights
            .iter()
            .map(|(n, m)| (n.clone(), LayerWeight::Dense(m.clone())))
            .collect();
        for (n, q) in &qm.layers {
            let lw = match QuantizedTensor::from_linear(q) {
                Some(t) => LayerWeight::Quant(t),
                None => LayerWeight::Dense(q.effective_weight()),
            };
            layers.insert(n.clone(), lw);
        }
        NativeBackend {
            cfg: qm.cfg.clone(),
            layers,
            vectors: qm.fvectors.clone(),
            threads: default_threads(),
            max_batch: DEFAULT_MAX_BATCH,
        }
    }

    /// Set the serving concurrency cap (scoring batch size and the number
    /// of continuous-batching generation slots). Minimum 1.
    pub fn with_max_batch(mut self, max_batch: usize) -> NativeBackend {
        self.max_batch = max_batch.max(1);
        self
    }

    /// How many linears run on packed codes (vs dense fallback).
    pub fn quantized_layer_count(&self) -> usize {
        self.layers.values().filter(|l| l.is_quantized()).count()
    }

    fn layer(&self, name: &str) -> anyhow::Result<&LayerWeight> {
        self.layers
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("native backend missing weight '{name}'"))
    }

    fn gain(&self, name: &str) -> anyhow::Result<&[f32]> {
        self.vectors
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("native backend missing vector '{name}'"))
    }

    fn linear(&self, name: &str, x: &Matrix, threads: usize) -> anyhow::Result<Matrix> {
        Ok(self.layer(name)?.matmul(x, threads))
    }

    fn embedding(&self) -> anyhow::Result<&Matrix> {
        match self.layer("embed")? {
            LayerWeight::Dense(m) => Ok(m),
            LayerWeight::Quant(_) => anyhow::bail!("embedding table must stay dense"),
        }
    }

    /// Full-sequence forward: `tokens` (length S) → logits `(S, vocab)`.
    /// Mirrors `model::forward::Forward::forward` with linears dispatched
    /// through [`LayerWeight`].
    pub fn forward(&self, tokens: &[u8]) -> anyhow::Result<Matrix> {
        self.forward_with(tokens, self.threads)
    }

    /// [`NativeBackend::forward`] with an explicit tile-thread count —
    /// `forward_batch` runs one sequence per worker with `threads = 1` so
    /// total concurrency stays at the pool width.
    fn forward_with(&self, tokens: &[u8], threads: usize) -> anyhow::Result<Matrix> {
        anyhow::ensure!(!tokens.is_empty(), "empty token sequence");
        let cfg = &self.cfg;
        let s = tokens.len();
        let d = cfg.d;
        let hd = cfg.head_dim();

        let embed = self.embedding()?;
        let mut h = Matrix::zeros(s, d);
        for (p, &tok) in tokens.iter().enumerate() {
            h.row_mut(p).copy_from_slice(embed.row(tok as usize));
        }

        let half = hd / 2;
        let mut cos = Matrix::zeros(s, half);
        let mut sin = Matrix::zeros(s, half);
        for p in 0..s {
            for i in 0..half {
                let inv = (cfg.rope_base as f64).powf(-(i as f64) * 2.0 / hd as f64);
                let ang = p as f64 * inv;
                *cos.at_mut(p, i) = ang.cos() as f32;
                *sin.at_mut(p, i) = ang.sin() as f32;
            }
        }

        for l in 0..cfg.layers {
            let pre = format!("layers.{l}");
            // --- Attention block ---
            let x = rmsnorm(&h, self.gain(&format!("{pre}.ln1"))?, cfg.eps);
            let q = self.linear(&format!("{pre}.wq"), &x, threads)?;
            let k = self.linear(&format!("{pre}.wk"), &x, threads)?;
            let v = self.linear(&format!("{pre}.wv"), &x, threads)?;
            let (q, k) = (rope(&q, &cos, &sin, cfg.heads), rope(&k, &cos, &sin, cfg.heads));

            let mut ctx = Matrix::zeros(s, d);
            let scale = 1.0 / (hd as f32).sqrt();
            let mut att_row = vec![0.0f32; s];
            for head in 0..cfg.heads {
                let off = head * hd;
                for qi in 0..s {
                    let qrow = &q.row(qi)[off..off + hd];
                    let mut maxv = f32::NEG_INFINITY;
                    for (ki, a) in att_row.iter_mut().enumerate().take(qi + 1) {
                        let krow = &k.row(ki)[off..off + hd];
                        let mut dotv = 0.0f32;
                        for t in 0..hd {
                            dotv += qrow[t] * krow[t];
                        }
                        *a = dotv * scale;
                        maxv = maxv.max(*a);
                    }
                    let mut denom = 0.0f32;
                    for a in att_row.iter_mut().take(qi + 1) {
                        *a = (*a - maxv).exp();
                        denom += *a;
                    }
                    let out = ctx.row_mut(qi);
                    for ki in 0..=qi {
                        let wgt = att_row[ki] / denom;
                        let vrow = &v.row(ki)[off..off + hd];
                        for t in 0..hd {
                            out[off + t] += wgt * vrow[t];
                        }
                    }
                }
            }
            let o = self.linear(&format!("{pre}.wo"), &ctx, threads)?;
            add_inplace(&mut h, &o);

            // --- MLP block ---
            let x = rmsnorm(&h, self.gain(&format!("{pre}.ln2"))?, cfg.eps);
            let y = if cfg.n_experts == 0 {
                let g = self.linear(&format!("{pre}.wg"), &x, threads)?;
                let u = self.linear(&format!("{pre}.wu"), &x, threads)?;
                let mut act = Matrix::zeros(s, cfg.ffn);
                for i in 0..s * cfg.ffn {
                    act.data[i] = silu(g.data[i]) * u.data[i];
                }
                self.linear(&format!("{pre}.wd"), &act, threads)?
            } else {
                self.moe(&x, &pre, threads)?
            };
            add_inplace(&mut h, &y);
        }

        let hf = rmsnorm(&h, self.gain("ln_f")?, cfg.eps);
        self.linear("lm_head", &hf, threads)
    }

    /// Batched scoring over `&self` (the body of the
    /// [`InferenceBackend::forward_batch`] impl): one worker per sequence,
    /// per-sequence tile parallelism disabled so total concurrency stays at
    /// the pool width. Taking `&self` lets a shared backend
    /// (`Arc<NativeBackend>`) serve the scoring router and the streaming
    /// decode engine from one weight set.
    pub fn forward_batch(&self, seqs: &[&[u8]]) -> anyhow::Result<Vec<Matrix>> {
        if seqs.len() <= 1 {
            return seqs.iter().map(|s| self.forward(s)).collect();
        }
        threadpool::map_indexed(seqs, self.threads, |_, s| self.forward_with(s, 1))
            .into_iter()
            .collect()
    }

    /// Greedy autoregressive generation over `&self` (the body of the
    /// [`InferenceBackend::generate`] impl).
    pub fn generate(&self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
        let mut dec = NativeDecoder::new(self, prompt.len() + n + 1)?;
        dec.generate(prompt, n)
    }

    /// Continuous-batched greedy generation over `&self` (the body of the
    /// [`InferenceBackend::generate_batch`] impl): all prompts share one
    /// [`BatchDecoder`], so every packed weight tile is unpacked once per
    /// step instead of once per sequence. Tokens are exactly those
    /// [`NativeBackend::generate`] would produce per prompt.
    pub fn generate_batch(
        &self,
        prompts: &[&[u8]],
        max_new: &[usize],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        anyhow::ensure!(
            prompts.len() == max_new.len(),
            "generate_batch: {} prompts but {} max_new entries",
            prompts.len(),
            max_new.len()
        );
        if prompts.is_empty() {
            return Ok(Vec::new());
        }
        let slots = self.max_batch.min(prompts.len()).max(1);
        let capacity = prompts
            .iter()
            .zip(max_new)
            .map(|(p, &n)| p.len() + n + 1)
            .max()
            .unwrap_or(1);
        let mut dec = BatchDecoder::new(self, slots, capacity)?;
        for (i, (p, &n)) in prompts.iter().zip(max_new).enumerate() {
            dec.submit(i, p, n)?;
        }
        let outs = dec.run()?;
        Ok(outs.into_iter().map(|o| o.tokens).collect())
    }

    fn moe(&self, x: &Matrix, pre: &str, threads: usize) -> anyhow::Result<Matrix> {
        let cfg = &self.cfg;
        let logits = self.linear(&format!("{pre}.router"), x, threads)?;
        let mut out = Matrix::zeros(x.rows, cfg.d);
        for i in 0..x.rows {
            let row = logits.row(i);
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|&v| (v - maxv).exp()).collect();
            let denom: f32 = exps.iter().sum();
            let (top, _) = exps
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let gate = exps[top] / denom;

            let xr = Matrix::from_vec(1, x.cols, x.row(i).to_vec());
            let g = self.linear(&format!("{pre}.expert{top}.wg"), &xr, threads)?;
            let u = self.linear(&format!("{pre}.expert{top}.wu"), &xr, threads)?;
            let mut act = Matrix::zeros(1, cfg.ffn);
            for j in 0..cfg.ffn {
                act.data[j] = silu(g.data[j]) * u.data[j];
            }
            let y = self.linear(&format!("{pre}.expert{top}.wd"), &act, threads)?;
            for (o, &yv) in out.row_mut(i).iter_mut().zip(y.row(0)) {
                *o = gate * yv;
            }
        }
        Ok(out)
    }
}

impl LogitsEngine for NativeBackend {
    fn logits(&mut self, tokens: &[u8]) -> anyhow::Result<Matrix> {
        self.forward(tokens)
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn forward_batch(&mut self, seqs: &[&[u8]]) -> anyhow::Result<Vec<Matrix>> {
        NativeBackend::forward_batch(self, seqs)
    }

    fn generate(&mut self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
        NativeBackend::generate(self, prompt, n)
    }

    fn generate_batch(
        &mut self,
        prompts: &[&[u8]],
        max_new: &[usize],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        NativeBackend::generate_batch(self, prompts, max_new)
    }
}

/// Per-MLP (dense or one expert) weight references resolved at build time.
pub(crate) struct MlpWeights<'a> {
    pub(crate) wg: &'a LayerWeight,
    pub(crate) wu: &'a LayerWeight,
    pub(crate) wd: &'a LayerWeight,
}

pub(crate) enum MlpRefs<'a> {
    Dense(MlpWeights<'a>),
    Moe { router: &'a LayerWeight, experts: Vec<MlpWeights<'a>> },
}

/// One layer's weights, resolved once so the per-token loop does no name
/// formatting or map lookups.
pub(crate) struct DecoderLayer<'a> {
    pub(crate) ln1: &'a [f32],
    pub(crate) ln2: &'a [f32],
    pub(crate) wq: &'a LayerWeight,
    pub(crate) wk: &'a LayerWeight,
    pub(crate) wv: &'a LayerWeight,
    pub(crate) wo: &'a LayerWeight,
    pub(crate) mlp: MlpRefs<'a>,
}

/// Every weight/gain reference plus the rotary frequency table of a
/// [`NativeBackend`], resolved once so decode hot paths do no name
/// formatting or map lookups. Shared by the single-sequence
/// [`NativeDecoder`] and the continuous-batching
/// [`crate::backend::BatchDecoder`].
pub(crate) struct ResolvedModel<'a> {
    pub(crate) cfg: &'a ModelConfig,
    pub(crate) embed: &'a Matrix,
    pub(crate) ln_f: &'a [f32],
    pub(crate) lm_head: &'a LayerWeight,
    pub(crate) layers: Vec<DecoderLayer<'a>>,
    /// Rotary inverse frequencies, length `head_dim / 2`.
    pub(crate) inv_freq: Vec<f64>,
    /// Worker threads for the batched decode matmuls.
    pub(crate) threads: usize,
}

impl<'a> ResolvedModel<'a> {
    /// Resolve every weight reference; errors if the backend is missing one.
    pub(crate) fn new(be: &'a NativeBackend) -> anyhow::Result<ResolvedModel<'a>> {
        let cfg = &be.cfg;
        let mlp_refs = |pre: &str| -> anyhow::Result<MlpWeights<'a>> {
            Ok(MlpWeights {
                wg: be.layer(&format!("{pre}.wg"))?,
                wu: be.layer(&format!("{pre}.wu"))?,
                wd: be.layer(&format!("{pre}.wd"))?,
            })
        };
        let mut layers = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let pre = format!("layers.{l}");
            let mlp = if cfg.n_experts == 0 {
                MlpRefs::Dense(mlp_refs(&pre)?)
            } else {
                MlpRefs::Moe {
                    router: be.layer(&format!("{pre}.router"))?,
                    experts: (0..cfg.n_experts)
                        .map(|e| mlp_refs(&format!("{pre}.expert{e}")))
                        .collect::<anyhow::Result<Vec<_>>>()?,
                }
            };
            layers.push(DecoderLayer {
                ln1: be.gain(&format!("{pre}.ln1"))?,
                ln2: be.gain(&format!("{pre}.ln2"))?,
                wq: be.layer(&format!("{pre}.wq"))?,
                wk: be.layer(&format!("{pre}.wk"))?,
                wv: be.layer(&format!("{pre}.wv"))?,
                wo: be.layer(&format!("{pre}.wo"))?,
                mlp,
            });
        }
        let hd = cfg.head_dim();
        let inv_freq = (0..hd / 2)
            .map(|i| (cfg.rope_base as f64).powf(-(i as f64) * 2.0 / hd as f64))
            .collect();
        Ok(ResolvedModel {
            cfg,
            embed: be.embedding()?,
            ln_f: be.gain("ln_f")?,
            lm_head: be.layer("lm_head")?,
            layers,
            inv_freq,
            threads: be.threads,
        })
    }

    /// RoPE angles for one position (same formula as the forward pass).
    pub(crate) fn rope_angles_into(&self, pos: usize, cos: &mut [f32], sin: &mut [f32]) {
        for (i, &inv) in self.inv_freq.iter().enumerate() {
            let ang = pos as f64 * inv;
            cos[i] = ang.cos() as f32;
            sin[i] = ang.sin() as f32;
        }
    }
}

/// Autoregressive decoder with preallocated per-layer K/V caches.
///
/// Every weight/gain reference and the rotary frequency table are resolved
/// once at construction; `step` — the decode hot path — touches only
/// resolved references and the fused matvec kernels.
pub struct NativeDecoder<'a> {
    model: ResolvedModel<'a>,
    /// Per-layer key cache, shape `(capacity, d)`.
    kcache: Vec<Matrix>,
    /// Per-layer value cache, shape `(capacity, d)`.
    vcache: Vec<Matrix>,
    pub pos: usize,
    capacity: usize,
    scratch: StepScratch,
}

/// Decoder-owned per-step scratch: every `vec![0.0; …]` the step loop used
/// to allocate per token lives here instead, and the fused kernels reuse
/// one [`KernelScratch`] across all layers so their unpack/level tiles stay
/// aligned and allocation-free on the token hot path.
struct StepScratch {
    /// Residual stream for the current token.
    h: Vec<f32>,
    /// RoPE angles for the current position.
    cosv: Vec<f32>,
    sinv: Vec<f32>,
    /// Attention context accumulator (zeroed per layer).
    ctxv: Vec<f32>,
    /// Attention score buffer (`pos + 1` entries).
    att: Vec<f32>,
    /// Fused-kernel scratch shared by every quantized matvec.
    kernel: KernelScratch,
}

impl<'a> NativeDecoder<'a> {
    /// Resolve every weight reference and preallocate caches for
    /// `capacity` positions; errors if the backend is missing a weight.
    pub fn new(be: &'a NativeBackend, capacity: usize) -> anyhow::Result<NativeDecoder<'a>> {
        let model = ResolvedModel::new(be)?;
        let cap = capacity.max(1);
        let (layers, d) = (model.cfg.layers, model.cfg.d);
        let half = model.cfg.head_dim() / 2;
        Ok(NativeDecoder {
            model,
            kcache: (0..layers).map(|_| Matrix::zeros(cap, d)).collect(),
            vcache: (0..layers).map(|_| Matrix::zeros(cap, d)).collect(),
            pos: 0,
            capacity: cap,
            scratch: StepScratch {
                h: Vec::with_capacity(d),
                cosv: vec![0.0; half],
                sinv: vec![0.0; half],
                ctxv: vec![0.0; d],
                att: Vec::with_capacity(cap),
                kernel: KernelScratch::new(),
            },
        })
    }

    /// Feed one token; returns next-token logits (length vocab).
    pub fn step(&mut self, token: u8) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            self.pos < self.capacity,
            "decode context exhausted (KV capacity {})",
            self.capacity
        );
        let model = &self.model;
        let cfg = model.cfg;
        let hd = cfg.head_dim();
        let pos = self.pos;

        // Split borrows: layer refs are read-only; caches and the step
        // scratch (all distinct fields of `self`) are written.
        let kcache = &mut self.kcache;
        let vcache = &mut self.vcache;
        let StepScratch { h, cosv, sinv, ctxv, att, kernel } = &mut self.scratch;

        h.clear();
        h.extend_from_slice(model.embed.row(token as usize));
        model.rope_angles_into(pos, cosv, sinv);

        for (l, layer) in model.layers.iter().enumerate() {
            let x = rmsnorm_vec(h, layer.ln1, cfg.eps);
            let mut q = layer.wq.matvec_with(&x, kernel);
            let mut k = layer.wk.matvec_with(&x, kernel);
            let v = layer.wv.matvec_with(&x, kernel);
            rope_vec(&mut q, cosv, sinv, cfg.heads, hd);
            rope_vec(&mut k, cosv, sinv, cfg.heads, hd);
            kcache[l].row_mut(pos).copy_from_slice(&k);
            vcache[l].row_mut(pos).copy_from_slice(&v);

            ctxv.fill(0.0);
            causal_attend(&q, &kcache[l], &vcache[l], pos, cfg.heads, hd, ctxv, att);
            let o = layer.wo.matvec_with(ctxv, kernel);
            for (a, b) in h.iter_mut().zip(&o) {
                *a += b;
            }

            let x = rmsnorm_vec(h, layer.ln2, cfg.eps);
            let y = mlp_forward(&layer.mlp, &x, kernel);
            for (a, b) in h.iter_mut().zip(&y) {
                *a += b;
            }
        }

        let hf = rmsnorm_vec(h, model.ln_f, cfg.eps);
        let logits = model.lm_head.matvec_with(&hf, kernel);
        self.pos += 1;
        Ok(logits)
    }

    /// Greedy generation: prefill `prompt`, then emit `n` tokens. The final
    /// token is emitted without a trailing step (its logits would be unused).
    ///
    /// Requests that cannot fit the preallocated KV cache are rejected up
    /// front with a clear error (prompt + generated tokens, minus the final
    /// unstepped one, must fit `capacity`).
    pub fn generate(&mut self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let needed = self.pos + prompt.len() + n.saturating_sub(1);
        anyhow::ensure!(
            needed <= self.capacity,
            "prompt of {} tokens + {n} generated needs {needed} KV positions but the \
             decoder preallocated {} (KV capacity); construct the decoder with a larger \
             capacity or shorten the request",
            prompt.len(),
            self.capacity
        );
        let mut last = Vec::new();
        for &t in prompt {
            last = self.step(t)?;
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let next = argmax(&last) as u8;
            out.push(next);
            if i + 1 < n {
                last = self.step(next)?;
            }
        }
        Ok(out)
    }
}

/// Causal attention for one query position over K/V cache rows `0..=pos`,
/// accumulating the per-head context into `ctx` (zeroed by the caller).
/// `att` is a caller-owned score buffer (resized to `pos + 1` here) so the
/// decode hot loops do not allocate per layer. Shared by the
/// single-sequence and batched decoders so the two attention paths cannot
/// diverge numerically.
#[allow(clippy::too_many_arguments)]
pub(crate) fn causal_attend(
    q: &[f32],
    kc: &Matrix,
    vc: &Matrix,
    pos: usize,
    heads: usize,
    hd: usize,
    ctx: &mut [f32],
    att: &mut Vec<f32>,
) {
    let scale = 1.0 / (hd as f32).sqrt();
    att.clear();
    att.resize(pos + 1, 0.0);
    for head in 0..heads {
        let off = head * hd;
        let qh = &q[off..off + hd];
        let mut maxv = f32::NEG_INFINITY;
        for ki in 0..=pos {
            let krow = &kc.row(ki)[off..off + hd];
            let mut dotv = 0.0f32;
            for t in 0..hd {
                dotv += qh[t] * krow[t];
            }
            att[ki] = dotv * scale;
            maxv = maxv.max(att[ki]);
        }
        let mut denom = 0.0f32;
        for a in att.iter_mut() {
            *a = (*a - maxv).exp();
            denom += *a;
        }
        for ki in 0..=pos {
            let wgt = att[ki] / denom;
            let vrow = &vc.row(ki)[off..off + hd];
            for t in 0..hd {
                ctx[off + t] += wgt * vrow[t];
            }
        }
    }
}

/// Dense or top-1-MoE MLP over one activation vector, reusing the caller's
/// kernel scratch for every quantized matvec. Shared with the batched
/// decoder, whose MoE rows route per sequence.
pub(crate) fn mlp_forward(mlp: &MlpRefs, x: &[f32], scratch: &mut KernelScratch) -> Vec<f32> {
    match mlp {
        MlpRefs::Dense(w) => expert_forward(w, x, scratch),
        MlpRefs::Moe { router, experts } => {
            let logits = router.matvec_with(x, scratch);
            let maxv = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = logits.iter().map(|&v| (v - maxv).exp()).collect();
            let denom: f32 = exps.iter().sum();
            let (top, _) = exps
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let gate = exps[top] / denom;
            let y = expert_forward(&experts[top], x, scratch);
            y.iter().map(|&v| gate * v).collect()
        }
    }
}

fn expert_forward(w: &MlpWeights, x: &[f32], scratch: &mut KernelScratch) -> Vec<f32> {
    let g = w.wg.matvec_with(x, scratch);
    let u = w.wu.matvec_with(x, scratch);
    let act: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| silu(gv) * uv).collect();
    w.wd.matvec_with(&act, scratch)
}

/// RMSNorm over one activation vector.
fn rmsnorm_vec(x: &[f32], gain: &[f32], eps: f32) -> Vec<f32> {
    let ms: f32 = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    x.iter().zip(gain).map(|(&v, &g)| v * r * g).collect()
}

/// Split-half RoPE applied in place to one position's projection.
fn rope_vec(x: &mut [f32], cos: &[f32], sin: &[f32], heads: usize, hd: usize) {
    let half = hd / 2;
    for h in 0..heads {
        let off = h * hd;
        for i in 0..half {
            let (c, sn) = (cos[i], sin[i]);
            let x1 = x[off + i];
            let x2 = x[off + half + i];
            x[off + i] = x1 * c - x2 * sn;
            x[off + half + i] = x2 * c + x1 * sn;
        }
    }
}

pub(crate) fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::quantize_simple;
    use crate::model::forward::Forward;
    use crate::quant::{Method, QuantConfig};

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    fn pico() -> ModelWeights {
        ModelWeights::synthetic(&ModelConfig::family("pico").unwrap(), 21)
    }

    #[test]
    fn dense_native_matches_reference_forward() {
        let mw = pico();
        let reference = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"native backend parity";
        let l_ref = reference.forward(tokens, None);
        let l_nat = nb.forward(tokens).unwrap();
        assert_eq!((l_nat.rows, l_nat.cols), (l_ref.rows, l_ref.cols));
        assert!(
            max_abs_diff(&l_nat.data, &l_ref.data) < 1e-6,
            "dense native forward must match the reference"
        );
    }

    #[test]
    fn quantized_native_matches_effective_weight_forward() {
        let mw = pico();
        let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
        let eff = qm.effective_weights();
        let reference = Forward::new(&mw.cfg, &eff, &qm.fvectors);
        let nb = NativeBackend::from_quantized(&qm);
        assert!(nb.quantized_layer_count() > 0, "expected packed layers");
        let tokens = b"fused kernels vs reference";
        let l_ref = reference.forward(tokens, None);
        let l_nat = nb.forward(tokens).unwrap();
        assert!(
            max_abs_diff(&l_nat.data, &l_ref.data) < 1e-4,
            "fused forward diverged from effective-weight reference"
        );
    }

    #[test]
    fn moe_native_matches_reference() {
        let cfg = ModelConfig::family("tiny_moe").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 22);
        let reference = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"moe path";
        let l_ref = reference.forward(tokens, None);
        let l_nat = nb.forward(tokens).unwrap();
        assert!(max_abs_diff(&l_nat.data, &l_ref.data) < 1e-6);
    }

    #[test]
    fn decoder_matches_full_forward_last_position() {
        let mw = pico();
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"kv cache parity!";
        let full = nb.forward(tokens).unwrap();
        let mut dec = NativeDecoder::new(&nb, tokens.len() + 1).unwrap();
        let mut last = Vec::new();
        for &t in tokens.iter() {
            last = dec.step(t).unwrap();
        }
        assert_eq!(dec.pos, tokens.len());
        assert!(
            max_abs_diff(&last, full.row(tokens.len() - 1)) < 1e-3,
            "incremental decode diverged from full forward"
        );
    }

    #[test]
    fn decoder_context_exhaustion_errors() {
        let mw = pico();
        let nb = NativeBackend::from_weights(&mw);
        let mut dec = NativeDecoder::new(&nb, 2).unwrap();
        dec.step(b'a').unwrap();
        dec.step(b'b').unwrap();
        assert!(dec.step(b'c').is_err());
    }

    #[test]
    fn generate_rejects_request_beyond_capacity_up_front() {
        let mw = pico();
        let nb = NativeBackend::from_weights(&mw);
        let mut dec = NativeDecoder::new(&nb, 4).unwrap();
        let err = dec.generate(b"a prompt far beyond four positions", 2).unwrap_err();
        assert!(err.to_string().contains("KV"), "unclear capacity error: {err}");
        // Nothing was fed: the decoder remains usable for a fitting request.
        assert_eq!(dec.pos, 0);
        assert_eq!(dec.generate(b"ok", 3).unwrap().len(), 3);
    }

    #[test]
    fn generate_is_deterministic_and_respects_prompt() {
        let mw = pico();
        let qm = quantize_simple(&mw, &QuantConfig::new(Method::Rtn, 4), None).unwrap();
        let nb = NativeBackend::from_quantized(&qm);
        let a = nb.generate(b"hello", 12).unwrap();
        let b = nb.generate(b"hello", 12).unwrap();
        assert_eq!(a.len(), 12);
        assert_eq!(a, b, "greedy decode must be deterministic");
    }

    #[test]
    fn moe_decoder_runs() {
        let cfg = ModelConfig::family("tiny_moe").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 23);
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"moe decode";
        let full = nb.forward(tokens).unwrap();
        let mut dec = NativeDecoder::new(&nb, tokens.len() + 1).unwrap();
        let mut last = Vec::new();
        for &t in tokens.iter() {
            last = dec.step(t).unwrap();
        }
        assert!(max_abs_diff(&last, full.row(tokens.len() - 1)) < 1e-3);
    }
}
