//! The native inference engine: a pure-Rust transformer forward/decode that
//! executes **directly on packed quantized weights**.
//!
//! Where [`crate::model::forward::Forward`] is the f32 reference (it wants a
//! map of dense effective weights), [`NativeBackend`] holds each linear as a
//! [`LayerWeight`] — either a dense matrix or a bit-packed
//! [`QuantizedTensor`] — and routes every projection through the fused
//! dequant kernels. The layer-by-layer math (RMSNorm → RoPE MHA → residual →
//! SwiGLU / switch-MoE → residual → final norm → lm_head) mirrors the
//! reference operation-for-operation so logits agree to float tolerance.
//!
//! [`NativeDecoder`] adds the autoregressive path: per-layer K/V caches are
//! preallocated at construction and each step runs single-row matvecs
//! against the packed weights — `generate` needs no artifacts, no XLA, and
//! no Python.

use std::collections::BTreeMap;

use crate::backend::quantized::QuantizedTensor;
use crate::backend::InferenceBackend;
use crate::eval::LogitsEngine;
use crate::model::forward::{add_inplace, rmsnorm, rope, silu};
use crate::model::{ModelConfig, ModelWeights, QuantizedModel};
use crate::tensor::matrix::dot;
use crate::tensor::Matrix;
use crate::util::threadpool;

/// One linear layer's runtime representation.
#[derive(Debug, Clone)]
pub enum LayerWeight {
    /// Dense f32 (embeddings, FP serving, or fallback for representations
    /// the fused kernels cannot execute, e.g. Hadamard-rotated storage).
    Dense(Matrix),
    /// Bit-packed quantized weights executed by the fused kernels.
    Quant(QuantizedTensor),
}

impl LayerWeight {
    pub fn out_features(&self) -> usize {
        match self {
            LayerWeight::Dense(w) => w.rows,
            LayerWeight::Quant(q) => q.rows,
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, LayerWeight::Quant(_))
    }

    /// `y = x · Wᵀ` for a batch of activation rows.
    fn matmul(&self, x: &Matrix, threads: usize) -> Matrix {
        match self {
            LayerWeight::Dense(w) => x.matmul_nt(w),
            LayerWeight::Quant(q) => q.dequant_matmul(x, threads),
        }
    }

    /// `y = W · x` for one activation vector.
    fn matvec(&self, x: &[f32]) -> Vec<f32> {
        match self {
            LayerWeight::Dense(w) => (0..w.rows).map(|r| dot(x, w.row(r), x.len())).collect(),
            LayerWeight::Quant(q) => q.dequant_matvec(x),
        }
    }
}

/// Pure-Rust inference backend over dense or packed-quantized weights.
pub struct NativeBackend {
    pub cfg: ModelConfig,
    layers: BTreeMap<String, LayerWeight>,
    vectors: BTreeMap<String, Vec<f32>>,
    /// Worker threads for the fused matmul tiles.
    pub threads: usize,
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

impl NativeBackend {
    /// FP backend: every weight dense f32 (bitwise-identical math to the
    /// reference forward — the `--backend native` baseline).
    pub fn from_weights(mw: &ModelWeights) -> NativeBackend {
        let layers = mw
            .tensors
            .iter()
            .map(|(n, m)| (n.clone(), LayerWeight::Dense(m.clone())))
            .collect();
        NativeBackend {
            cfg: mw.cfg.clone(),
            layers,
            vectors: mw.vectors.clone(),
            threads: default_threads(),
        }
    }

    /// Quantized backend: packs every packable layer; Hadamard/codebook
    /// layers fall back to a dense dequantized copy so any `.stz` serves.
    pub fn from_quantized(qm: &QuantizedModel) -> NativeBackend {
        let mut layers: BTreeMap<String, LayerWeight> = qm
            .fweights
            .iter()
            .map(|(n, m)| (n.clone(), LayerWeight::Dense(m.clone())))
            .collect();
        for (n, q) in &qm.layers {
            let lw = match QuantizedTensor::from_linear(q) {
                Some(t) => LayerWeight::Quant(t),
                None => LayerWeight::Dense(q.effective_weight()),
            };
            layers.insert(n.clone(), lw);
        }
        NativeBackend {
            cfg: qm.cfg.clone(),
            layers,
            vectors: qm.fvectors.clone(),
            threads: default_threads(),
        }
    }

    /// How many linears run on packed codes (vs dense fallback).
    pub fn quantized_layer_count(&self) -> usize {
        self.layers.values().filter(|l| l.is_quantized()).count()
    }

    fn layer(&self, name: &str) -> anyhow::Result<&LayerWeight> {
        self.layers
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("native backend missing weight '{name}'"))
    }

    fn gain(&self, name: &str) -> anyhow::Result<&[f32]> {
        self.vectors
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("native backend missing vector '{name}'"))
    }

    fn linear(&self, name: &str, x: &Matrix, threads: usize) -> anyhow::Result<Matrix> {
        Ok(self.layer(name)?.matmul(x, threads))
    }

    fn embedding(&self) -> anyhow::Result<&Matrix> {
        match self.layer("embed")? {
            LayerWeight::Dense(m) => Ok(m),
            LayerWeight::Quant(_) => anyhow::bail!("embedding table must stay dense"),
        }
    }

    /// Full-sequence forward: `tokens` (length S) → logits `(S, vocab)`.
    /// Mirrors `model::forward::Forward::forward` with linears dispatched
    /// through [`LayerWeight`].
    pub fn forward(&self, tokens: &[u8]) -> anyhow::Result<Matrix> {
        self.forward_with(tokens, self.threads)
    }

    /// [`NativeBackend::forward`] with an explicit tile-thread count —
    /// `forward_batch` runs one sequence per worker with `threads = 1` so
    /// total concurrency stays at the pool width.
    fn forward_with(&self, tokens: &[u8], threads: usize) -> anyhow::Result<Matrix> {
        anyhow::ensure!(!tokens.is_empty(), "empty token sequence");
        let cfg = &self.cfg;
        let s = tokens.len();
        let d = cfg.d;
        let hd = cfg.head_dim();

        let embed = self.embedding()?;
        let mut h = Matrix::zeros(s, d);
        for (p, &tok) in tokens.iter().enumerate() {
            h.row_mut(p).copy_from_slice(embed.row(tok as usize));
        }

        let half = hd / 2;
        let mut cos = Matrix::zeros(s, half);
        let mut sin = Matrix::zeros(s, half);
        for p in 0..s {
            for i in 0..half {
                let inv = (cfg.rope_base as f64).powf(-(i as f64) * 2.0 / hd as f64);
                let ang = p as f64 * inv;
                *cos.at_mut(p, i) = ang.cos() as f32;
                *sin.at_mut(p, i) = ang.sin() as f32;
            }
        }

        for l in 0..cfg.layers {
            let pre = format!("layers.{l}");
            // --- Attention block ---
            let x = rmsnorm(&h, self.gain(&format!("{pre}.ln1"))?, cfg.eps);
            let q = self.linear(&format!("{pre}.wq"), &x, threads)?;
            let k = self.linear(&format!("{pre}.wk"), &x, threads)?;
            let v = self.linear(&format!("{pre}.wv"), &x, threads)?;
            let (q, k) = (rope(&q, &cos, &sin, cfg.heads), rope(&k, &cos, &sin, cfg.heads));

            let mut ctx = Matrix::zeros(s, d);
            let scale = 1.0 / (hd as f32).sqrt();
            let mut att_row = vec![0.0f32; s];
            for head in 0..cfg.heads {
                let off = head * hd;
                for qi in 0..s {
                    let qrow = &q.row(qi)[off..off + hd];
                    let mut maxv = f32::NEG_INFINITY;
                    for (ki, a) in att_row.iter_mut().enumerate().take(qi + 1) {
                        let krow = &k.row(ki)[off..off + hd];
                        let mut dotv = 0.0f32;
                        for t in 0..hd {
                            dotv += qrow[t] * krow[t];
                        }
                        *a = dotv * scale;
                        maxv = maxv.max(*a);
                    }
                    let mut denom = 0.0f32;
                    for a in att_row.iter_mut().take(qi + 1) {
                        *a = (*a - maxv).exp();
                        denom += *a;
                    }
                    let out = ctx.row_mut(qi);
                    for ki in 0..=qi {
                        let wgt = att_row[ki] / denom;
                        let vrow = &v.row(ki)[off..off + hd];
                        for t in 0..hd {
                            out[off + t] += wgt * vrow[t];
                        }
                    }
                }
            }
            let o = self.linear(&format!("{pre}.wo"), &ctx, threads)?;
            add_inplace(&mut h, &o);

            // --- MLP block ---
            let x = rmsnorm(&h, self.gain(&format!("{pre}.ln2"))?, cfg.eps);
            let y = if cfg.n_experts == 0 {
                let g = self.linear(&format!("{pre}.wg"), &x, threads)?;
                let u = self.linear(&format!("{pre}.wu"), &x, threads)?;
                let mut act = Matrix::zeros(s, cfg.ffn);
                for i in 0..s * cfg.ffn {
                    act.data[i] = silu(g.data[i]) * u.data[i];
                }
                self.linear(&format!("{pre}.wd"), &act, threads)?
            } else {
                self.moe(&x, &pre, threads)?
            };
            add_inplace(&mut h, &y);
        }

        let hf = rmsnorm(&h, self.gain("ln_f")?, cfg.eps);
        self.linear("lm_head", &hf, threads)
    }

    fn moe(&self, x: &Matrix, pre: &str, threads: usize) -> anyhow::Result<Matrix> {
        let cfg = &self.cfg;
        let logits = self.linear(&format!("{pre}.router"), x, threads)?;
        let mut out = Matrix::zeros(x.rows, cfg.d);
        for i in 0..x.rows {
            let row = logits.row(i);
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|&v| (v - maxv).exp()).collect();
            let denom: f32 = exps.iter().sum();
            let (top, _) = exps
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let gate = exps[top] / denom;

            let xr = Matrix::from_vec(1, x.cols, x.row(i).to_vec());
            let g = self.linear(&format!("{pre}.expert{top}.wg"), &xr, threads)?;
            let u = self.linear(&format!("{pre}.expert{top}.wu"), &xr, threads)?;
            let mut act = Matrix::zeros(1, cfg.ffn);
            for j in 0..cfg.ffn {
                act.data[j] = silu(g.data[j]) * u.data[j];
            }
            let y = self.linear(&format!("{pre}.expert{top}.wd"), &act, threads)?;
            for (o, &yv) in out.row_mut(i).iter_mut().zip(y.row(0)) {
                *o = gate * yv;
            }
        }
        Ok(out)
    }
}

impl LogitsEngine for NativeBackend {
    fn logits(&mut self, tokens: &[u8]) -> anyhow::Result<Matrix> {
        self.forward(tokens)
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn max_batch(&self) -> usize {
        4
    }

    fn forward_batch(&mut self, seqs: &[&[u8]]) -> anyhow::Result<Vec<Matrix>> {
        if seqs.len() <= 1 {
            return seqs.iter().map(|s| self.forward(s)).collect();
        }
        // One worker per sequence; per-sequence tile parallelism is disabled
        // so total concurrency stays at the pool width.
        let be = &*self;
        threadpool::map_indexed(seqs, self.threads, |_, s| be.forward_with(s, 1))
            .into_iter()
            .collect()
    }

    fn generate(&mut self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
        let mut dec = NativeDecoder::new(self, prompt.len() + n + 1)?;
        dec.generate(prompt, n)
    }
}

/// Per-MLP (dense or one expert) weight references resolved at build time.
struct MlpWeights<'a> {
    wg: &'a LayerWeight,
    wu: &'a LayerWeight,
    wd: &'a LayerWeight,
}

enum MlpRefs<'a> {
    Dense(MlpWeights<'a>),
    Moe { router: &'a LayerWeight, experts: Vec<MlpWeights<'a>> },
}

/// One layer's weights, resolved once so the per-token loop does no name
/// formatting or map lookups.
struct DecoderLayer<'a> {
    ln1: &'a [f32],
    ln2: &'a [f32],
    wq: &'a LayerWeight,
    wk: &'a LayerWeight,
    wv: &'a LayerWeight,
    wo: &'a LayerWeight,
    mlp: MlpRefs<'a>,
}

/// Autoregressive decoder with preallocated per-layer K/V caches.
///
/// Every weight/gain reference and the rotary frequency table are resolved
/// once at construction; `step` — the decode hot path — touches only
/// resolved references and the fused matvec kernels.
pub struct NativeDecoder<'a> {
    cfg: &'a ModelConfig,
    embed: &'a Matrix,
    ln_f: &'a [f32],
    lm_head: &'a LayerWeight,
    layers: Vec<DecoderLayer<'a>>,
    /// Rotary inverse frequencies, length `head_dim / 2`.
    inv_freq: Vec<f64>,
    /// Per-layer key cache, shape `(capacity, d)`.
    kcache: Vec<Matrix>,
    /// Per-layer value cache, shape `(capacity, d)`.
    vcache: Vec<Matrix>,
    pub pos: usize,
    capacity: usize,
}

impl<'a> NativeDecoder<'a> {
    /// Resolve every weight reference and preallocate caches for
    /// `capacity` positions; errors if the backend is missing a weight.
    pub fn new(be: &'a NativeBackend, capacity: usize) -> anyhow::Result<NativeDecoder<'a>> {
        let cfg = &be.cfg;
        let mlp_refs = |pre: &str| -> anyhow::Result<MlpWeights<'a>> {
            Ok(MlpWeights {
                wg: be.layer(&format!("{pre}.wg"))?,
                wu: be.layer(&format!("{pre}.wu"))?,
                wd: be.layer(&format!("{pre}.wd"))?,
            })
        };
        let mut layers = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let pre = format!("layers.{l}");
            let mlp = if cfg.n_experts == 0 {
                MlpRefs::Dense(mlp_refs(&pre)?)
            } else {
                MlpRefs::Moe {
                    router: be.layer(&format!("{pre}.router"))?,
                    experts: (0..cfg.n_experts)
                        .map(|e| mlp_refs(&format!("{pre}.expert{e}")))
                        .collect::<anyhow::Result<Vec<_>>>()?,
                }
            };
            layers.push(DecoderLayer {
                ln1: be.gain(&format!("{pre}.ln1"))?,
                ln2: be.gain(&format!("{pre}.ln2"))?,
                wq: be.layer(&format!("{pre}.wq"))?,
                wk: be.layer(&format!("{pre}.wk"))?,
                wv: be.layer(&format!("{pre}.wv"))?,
                wo: be.layer(&format!("{pre}.wo"))?,
                mlp,
            });
        }
        let hd = cfg.head_dim();
        let inv_freq = (0..hd / 2)
            .map(|i| (cfg.rope_base as f64).powf(-(i as f64) * 2.0 / hd as f64))
            .collect();
        let cap = capacity.max(1);
        Ok(NativeDecoder {
            cfg,
            embed: be.embedding()?,
            ln_f: be.gain("ln_f")?,
            lm_head: be.layer("lm_head")?,
            layers,
            inv_freq,
            kcache: (0..cfg.layers).map(|_| Matrix::zeros(cap, cfg.d)).collect(),
            vcache: (0..cfg.layers).map(|_| Matrix::zeros(cap, cfg.d)).collect(),
            pos: 0,
            capacity: cap,
        })
    }

    /// Feed one token; returns next-token logits (length vocab).
    pub fn step(&mut self, token: u8) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            self.pos < self.capacity,
            "decode context exhausted (capacity {})",
            self.capacity
        );
        let cfg = self.cfg;
        let hd = cfg.head_dim();
        let half = hd / 2;
        let pos = self.pos;

        let mut h: Vec<f32> = self.embed.row(token as usize).to_vec();

        // RoPE angles for this position (same formula as the forward pass).
        let mut cosv = vec![0.0f32; half];
        let mut sinv = vec![0.0f32; half];
        for i in 0..half {
            let ang = pos as f64 * self.inv_freq[i];
            cosv[i] = ang.cos() as f32;
            sinv[i] = ang.sin() as f32;
        }

        // Split borrows: layer refs are read-only, caches are written.
        let layers = &self.layers;
        let kcache = &mut self.kcache;
        let vcache = &mut self.vcache;
        for (l, layer) in layers.iter().enumerate() {
            let x = rmsnorm_vec(&h, layer.ln1, cfg.eps);
            let mut q = layer.wq.matvec(&x);
            let mut k = layer.wk.matvec(&x);
            let v = layer.wv.matvec(&x);
            rope_vec(&mut q, &cosv, &sinv, cfg.heads, hd);
            rope_vec(&mut k, &cosv, &sinv, cfg.heads, hd);
            kcache[l].row_mut(pos).copy_from_slice(&k);
            vcache[l].row_mut(pos).copy_from_slice(&v);

            let mut ctxv = vec![0.0f32; cfg.d];
            let scale = 1.0 / (hd as f32).sqrt();
            let mut att = vec![0.0f32; pos + 1];
            for head in 0..cfg.heads {
                let off = head * hd;
                let qh = &q[off..off + hd];
                let mut maxv = f32::NEG_INFINITY;
                for ki in 0..=pos {
                    let krow = &kcache[l].row(ki)[off..off + hd];
                    let mut dotv = 0.0f32;
                    for t in 0..hd {
                        dotv += qh[t] * krow[t];
                    }
                    att[ki] = dotv * scale;
                    maxv = maxv.max(att[ki]);
                }
                let mut denom = 0.0f32;
                for a in att.iter_mut() {
                    *a = (*a - maxv).exp();
                    denom += *a;
                }
                for ki in 0..=pos {
                    let wgt = att[ki] / denom;
                    let vrow = &vcache[l].row(ki)[off..off + hd];
                    for t in 0..hd {
                        ctxv[off + t] += wgt * vrow[t];
                    }
                }
            }
            let o = layer.wo.matvec(&ctxv);
            for (a, b) in h.iter_mut().zip(&o) {
                *a += b;
            }

            let x = rmsnorm_vec(&h, layer.ln2, cfg.eps);
            let y = mlp_forward(&layer.mlp, &x);
            for (a, b) in h.iter_mut().zip(&y) {
                *a += b;
            }
        }

        let hf = rmsnorm_vec(&h, self.ln_f, cfg.eps);
        let logits = self.lm_head.matvec(&hf);
        self.pos += 1;
        Ok(logits)
    }

    /// Greedy generation: prefill `prompt`, then emit `n` tokens. The final
    /// token is emitted without a trailing step (its logits would be unused).
    pub fn generate(&mut self, prompt: &[u8], n: usize) -> anyhow::Result<Vec<u8>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let mut last = Vec::new();
        for &t in prompt {
            last = self.step(t)?;
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let next = argmax(&last) as u8;
            out.push(next);
            if i + 1 < n {
                last = self.step(next)?;
            }
        }
        Ok(out)
    }
}

/// Dense or top-1-MoE MLP over one activation vector.
fn mlp_forward(mlp: &MlpRefs, x: &[f32]) -> Vec<f32> {
    match mlp {
        MlpRefs::Dense(w) => expert_forward(w, x),
        MlpRefs::Moe { router, experts } => {
            let logits = router.matvec(x);
            let maxv = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = logits.iter().map(|&v| (v - maxv).exp()).collect();
            let denom: f32 = exps.iter().sum();
            let (top, _) = exps
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let gate = exps[top] / denom;
            let y = expert_forward(&experts[top], x);
            y.iter().map(|&v| gate * v).collect()
        }
    }
}

fn expert_forward(w: &MlpWeights, x: &[f32]) -> Vec<f32> {
    let g = w.wg.matvec(x);
    let u = w.wu.matvec(x);
    let act: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| silu(gv) * uv).collect();
    w.wd.matvec(&act)
}

/// RMSNorm over one activation vector.
fn rmsnorm_vec(x: &[f32], gain: &[f32], eps: f32) -> Vec<f32> {
    let ms: f32 = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    x.iter().zip(gain).map(|(&v, &g)| v * r * g).collect()
}

/// Split-half RoPE applied in place to one position's projection.
fn rope_vec(x: &mut [f32], cos: &[f32], sin: &[f32], heads: usize, hd: usize) {
    let half = hd / 2;
    for h in 0..heads {
        let off = h * hd;
        for i in 0..half {
            let (c, sn) = (cos[i], sin[i]);
            let x1 = x[off + i];
            let x2 = x[off + half + i];
            x[off + i] = x1 * c - x2 * sn;
            x[off + half + i] = x2 * c + x1 * sn;
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::quantize_simple;
    use crate::model::forward::Forward;
    use crate::quant::{Method, QuantConfig};

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    fn pico() -> ModelWeights {
        ModelWeights::synthetic(&ModelConfig::family("pico").unwrap(), 21)
    }

    #[test]
    fn dense_native_matches_reference_forward() {
        let mw = pico();
        let reference = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"native backend parity";
        let l_ref = reference.forward(tokens, None);
        let l_nat = nb.forward(tokens).unwrap();
        assert_eq!((l_nat.rows, l_nat.cols), (l_ref.rows, l_ref.cols));
        assert!(
            max_abs_diff(&l_nat.data, &l_ref.data) < 1e-6,
            "dense native forward must match the reference"
        );
    }

    #[test]
    fn quantized_native_matches_effective_weight_forward() {
        let mw = pico();
        let qm = quantize_simple(&mw, &QuantConfig::new(Method::Sinq, 4), None).unwrap();
        let eff = qm.effective_weights();
        let reference = Forward::new(&mw.cfg, &eff, &qm.fvectors);
        let nb = NativeBackend::from_quantized(&qm);
        assert!(nb.quantized_layer_count() > 0, "expected packed layers");
        let tokens = b"fused kernels vs reference";
        let l_ref = reference.forward(tokens, None);
        let l_nat = nb.forward(tokens).unwrap();
        assert!(
            max_abs_diff(&l_nat.data, &l_ref.data) < 1e-4,
            "fused forward diverged from effective-weight reference"
        );
    }

    #[test]
    fn moe_native_matches_reference() {
        let cfg = ModelConfig::family("tiny_moe").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 22);
        let reference = Forward::new(&mw.cfg, &mw.tensors, &mw.vectors);
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"moe path";
        let l_ref = reference.forward(tokens, None);
        let l_nat = nb.forward(tokens).unwrap();
        assert!(max_abs_diff(&l_nat.data, &l_ref.data) < 1e-6);
    }

    #[test]
    fn decoder_matches_full_forward_last_position() {
        let mw = pico();
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"kv cache parity!";
        let full = nb.forward(tokens).unwrap();
        let mut dec = NativeDecoder::new(&nb, tokens.len() + 1).unwrap();
        let mut last = Vec::new();
        for &t in tokens.iter() {
            last = dec.step(t).unwrap();
        }
        assert_eq!(dec.pos, tokens.len());
        assert!(
            max_abs_diff(&last, full.row(tokens.len() - 1)) < 1e-3,
            "incremental decode diverged from full forward"
        );
    }

    #[test]
    fn decoder_context_exhaustion_errors() {
        let mw = pico();
        let nb = NativeBackend::from_weights(&mw);
        let mut dec = NativeDecoder::new(&nb, 2).unwrap();
        dec.step(b'a').unwrap();
        dec.step(b'b').unwrap();
        assert!(dec.step(b'c').is_err());
    }

    #[test]
    fn generate_is_deterministic_and_respects_prompt() {
        let mw = pico();
        let qm = quantize_simple(&mw, &QuantConfig::new(Method::Rtn, 4), None).unwrap();
        let mut nb = NativeBackend::from_quantized(&qm);
        let a = nb.generate(b"hello", 12).unwrap();
        let b = nb.generate(b"hello", 12).unwrap();
        assert_eq!(a.len(), 12);
        assert_eq!(a, b, "greedy decode must be deterministic");
    }

    #[test]
    fn moe_decoder_runs() {
        let cfg = ModelConfig::family("tiny_moe").unwrap();
        let mw = ModelWeights::synthetic(&cfg, 23);
        let nb = NativeBackend::from_weights(&mw);
        let tokens = b"moe decode";
        let full = nb.forward(tokens).unwrap();
        let mut dec = NativeDecoder::new(&nb, tokens.len() + 1).unwrap();
        let mut last = Vec::new();
        for &t in tokens.iter() {
            last = dec.step(t).unwrap();
        }
        assert!(max_abs_diff(&last, full.row(tokens.len() - 1)) < 1e-3);
    }
}
