//! Portable scalar kernels — the parity oracle every SIMD path is tested
//! against, and the fallback for CPUs (or bit widths) without a
//! specialized implementation.
//!
//! `unpack_into` keeps the 8/4/2-bit specializations that previously lived
//! inline in `backend::quantized` (direct copy / nibble split / crumb
//! walk); generic widths (3/5/6/7-bit) share [`crate::fmt::pack`]'s bit
//! walk so the LSB-first layout has one source of truth. `dot` delegates
//! to the 4-accumulator reduction in [`crate::tensor::matrix::dot`] — the
//! exact arithmetic the fused kernels used before the SIMD dispatch
//! existed, which keeps the scalar path's numerics identical to the seed.

use crate::fmt::pack;

/// Unpack `out.len()` codes of `bits` width from `bytes` (LSB-first).
pub fn unpack_into(bytes: &[u8], bits: u32, out: &mut [u8]) {
    if out.is_empty() {
        return;
    }
    match bits {
        8 => out.copy_from_slice(&bytes[..out.len()]),
        4 => {
            let n = out.len();
            let mut j = 0;
            'bytes4: for &b in bytes {
                out[j] = b & 0x0F;
                j += 1;
                if j == n {
                    break 'bytes4;
                }
                out[j] = b >> 4;
                j += 1;
                if j == n {
                    break 'bytes4;
                }
            }
        }
        2 => {
            let n = out.len();
            let mut j = 0;
            'bytes2: for &b in bytes {
                let mut v = b;
                for _ in 0..4 {
                    out[j] = v & 0x03;
                    v >>= 2;
                    j += 1;
                    if j == n {
                        break 'bytes2;
                    }
                }
            }
        }
        // Generic widths (3/5/6/7-bit) share fmt::pack's bit walk.
        bits => pack::unpack_into(bytes, bits, out),
    }
}

/// Decode unpacked codes to grid levels through the decode LUT
/// (`levels[j] = lut[codes[j]]`). Exact: a lookup never rounds.
pub fn decode_levels(codes: &[u8], lut: &[f32], levels: &mut [f32]) {
    for (lv, &c) in levels.iter_mut().zip(codes.iter()) {
        *lv = lut[c as usize];
    }
}

/// Scalar dot product (4-accumulator reduction, auto-vectorizer friendly).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let k = a.len().min(b.len());
    crate::tensor::matrix::dot(a, b, k)
}

/// Two dot products against one shared left operand. The scalar oracle
/// defines the multi-row contract: each row is *exactly* [`dot`], so every
/// SIMD 2-/4-row microkernel must be bitwise-equal to its single-row dot
/// per row — amortization may only come from sharing loads of `a`.
pub fn dot2(a: &[f32], b0: &[f32], b1: &[f32]) -> (f32, f32) {
    (dot(a, b0), dot(a, b1))
}

/// Four dot products against one shared left operand; see [`dot2`].
pub fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    [dot(a, b0), dot(a, b1), dot(a, b2), dot(a, b3)]
}

/// Dequantize u8 codes with an affine (`out[j] = min + scale * codes[j]`) —
/// the quantized KV-cache read path. The SIMD variants use FMA, so their
/// roundings may differ from this by one ULP; kv8 consumers are
/// tolerance-gated, unlike the weight kernels' bitwise level contract.
pub fn dequant_u8(codes: &[u8], scale: f32, min: f32, out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(codes.iter()) {
        *o = min + scale * c as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn unpack_matches_pack_for_every_width_and_awkward_length() {
        let mut rng = Rng::new(41);
        for bits in 2u32..=8 {
            for n in [0usize, 1, 2, 3, 7, 8, 9, 31, 32, 33, 100] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
                let packed = pack::pack(&codes, bits);
                let mut out = vec![0u8; n];
                unpack_into(&packed, bits, &mut out);
                assert_eq!(out, codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn decode_levels_is_a_pure_lookup() {
        let lut: Vec<f32> = (0..256).map(|i| i as f32 * 0.25 - 8.0).collect();
        let codes = [0u8, 1, 255, 16, 7];
        let mut levels = [0.0f32; 5];
        decode_levels(&codes, &lut, &mut levels);
        for (lv, &c) in levels.iter().zip(codes.iter()) {
            assert_eq!(lv.to_bits(), lut[c as usize].to_bits());
        }
    }

    #[test]
    fn dot_handles_short_and_unequal_lengths() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        // Uses the shorter length (defensive; kernels pass equal slices).
        assert_eq!(dot(&[1.0, 1.0, 1.0], &[5.0, 5.0]), 10.0);
    }
}
