//! AVX2 + FMA kernels for the fused dequant hot path (x86_64).
//!
//! Selected at runtime by [`super::active`] when the CPU reports `avx2` and
//! `fma`. Contracts relative to [`super::scalar`]:
//!
//! * [`unpack4_into`] produces **bit-identical codes** (integer surgery).
//! * [`lut16_levels`] (4-bit: `vpermps` 16-entry LUT shuffle, blended on
//!   code bit 3) and [`gather_levels`] (any width: `vgatherdps` over the
//!   256-entry LUT) produce **bit-identical levels** — table lookups never
//!   round.
//! * [`dot`] uses 4×8-lane FMA accumulators, so its reduction *order*
//!   differs from scalar: results agree to float tolerance, not bitwise.
//!   Every decode entry point routes through this same `dot`, so batched
//!   and single-sequence decode remain bit-identical to each other.
//!
//! All loads/stores are unaligned (`loadu`/`storeu`): the decoder scratch
//! is cache-line aligned for the fast case, but the kernels stay correct
//! on arbitrary slices (tile tails, test inputs).

use std::arch::x86_64::*;

/// Unpack 4-bit codes (two per byte, low nibble first): each iteration
/// turns 16 packed bytes into 32 codes via byte masks + an interleave.
///
/// # Safety
/// The CPU must support AVX2 (see [`super::supported`]).
#[target_feature(enable = "avx2")]
pub unsafe fn unpack4_into(bytes: &[u8], out: &mut [u8]) {
    let n = out.len();
    debug_assert!(bytes.len() >= n.div_ceil(2));
    let mask = _mm_set1_epi8(0x0F);
    let mut j = 0;
    while j + 32 <= n {
        let chunk = _mm_loadu_si128(bytes.as_ptr().add(j / 2) as *const __m128i);
        let lo = _mm_and_si128(chunk, mask);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(chunk), mask);
        _mm_storeu_si128(out.as_mut_ptr().add(j) as *mut __m128i, _mm_unpacklo_epi8(lo, hi));
        _mm_storeu_si128(out.as_mut_ptr().add(j + 16) as *mut __m128i, _mm_unpackhi_epi8(lo, hi));
        j += 32;
    }
    // Tail (j is even here: the vector loop advances 32 codes at a time).
    let mut byte = j / 2;
    while j < n {
        out[j] = bytes[byte] & 0x0F;
        j += 1;
        if j < n {
            out[j] = bytes[byte] >> 4;
            j += 1;
        }
        byte += 1;
    }
}

/// Map 4-bit codes straight to f32 grid levels through a 16-entry LUT held
/// in two shuffle registers: `vpermps` indexes the low/high 8 entries with
/// the code's low 3 bits and a blend on bit 3 picks the half. Bit-identical
/// to the scalar LUT walk.
///
/// # Safety
/// The CPU must support AVX2; `lut` must hold at least 16 entries and every
/// code must be < 16.
#[target_feature(enable = "avx2")]
pub unsafe fn lut16_levels(codes: &[u8], lut: &[f32], levels: &mut [f32]) {
    debug_assert!(lut.len() >= 16);
    let lo_tbl = _mm256_loadu_ps(lut.as_ptr());
    let hi_tbl = _mm256_loadu_ps(lut.as_ptr().add(8));
    let seven = _mm256_set1_epi32(7);
    let n = levels.len().min(codes.len());
    let mut j = 0;
    while j + 8 <= n {
        let idx = _mm256_cvtepu8_epi32(_mm_loadl_epi64(codes.as_ptr().add(j) as *const __m128i));
        // vpermps reads only the low 3 index bits; bit 3 selects the half.
        let lo = _mm256_permutevar8x32_ps(lo_tbl, idx);
        let hi = _mm256_permutevar8x32_ps(hi_tbl, idx);
        let pick_hi = _mm256_castsi256_ps(_mm256_cmpgt_epi32(idx, seven));
        _mm256_storeu_ps(levels.as_mut_ptr().add(j), _mm256_blendv_ps(lo, hi, pick_hi));
        j += 8;
    }
    while j < n {
        levels[j] = lut[codes[j] as usize];
        j += 1;
    }
}

/// Decode arbitrary-width codes to levels by gathering from the 256-entry
/// LUT (`vgatherdps`). Bit-identical to the scalar LUT walk.
///
/// # Safety
/// The CPU must support AVX2; `lut` must hold at least 256 entries (codes
/// are `u8`, so every gathered offset stays in bounds).
#[target_feature(enable = "avx2")]
pub unsafe fn gather_levels(codes: &[u8], lut: &[f32], levels: &mut [f32]) {
    debug_assert!(lut.len() >= 256);
    let n = levels.len().min(codes.len());
    let mut j = 0;
    while j + 8 <= n {
        let idx = _mm256_cvtepu8_epi32(_mm_loadl_epi64(codes.as_ptr().add(j) as *const __m128i));
        _mm256_storeu_ps(levels.as_mut_ptr().add(j), _mm256_i32gather_ps::<4>(lut.as_ptr(), idx));
        j += 8;
    }
    while j < n {
        levels[j] = lut[codes[j] as usize];
        j += 1;
    }
}

/// Dequantize u8 codes with an affine (`min + scale * code`), 8 lanes per
/// iteration (`vpmovzxbd` widen → `vcvtdq2ps` → FMA). The fused
/// multiply-add may round differently from the scalar `min + scale * c`,
/// so the quantized-KV read path is tolerance-gated, not bitwise.
///
/// # Safety
/// The CPU must support AVX2 and FMA (see [`super::supported`]).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dequant_u8(codes: &[u8], scale: f32, min: f32, out: &mut [f32]) {
    let n = out.len().min(codes.len());
    let vs = _mm256_set1_ps(scale);
    let vm = _mm256_set1_ps(min);
    let mut j = 0;
    while j + 8 <= n {
        let idx = _mm256_cvtepu8_epi32(_mm_loadl_epi64(codes.as_ptr().add(j) as *const __m128i));
        let f = _mm256_cvtepi32_ps(idx);
        _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_fmadd_ps(vs, f, vm));
        j += 8;
    }
    while j < n {
        out[j] = min + scale * codes[j] as f32;
        j += 1;
    }
}

/// Dot product with 4×8-lane FMA accumulators (32 floats per iteration),
/// an 8-lane cleanup loop, and a scalar tail. Deterministic: the reduction
/// order is fixed for any given input length.
///
/// # Safety
/// The CPU must support AVX2 and FMA (see [`super::supported`]).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut i = 0;
    while i + 32 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 8)),
            _mm256_loadu_ps(pb.add(i + 8)),
            acc1,
        );
        acc2 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 16)),
            _mm256_loadu_ps(pb.add(i + 16)),
            acc2,
        );
        acc3 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 24)),
            _mm256_loadu_ps(pb.add(i + 24)),
            acc3,
        );
        i += 32;
    }
    while i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        i += 8;
    }
    let acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
    let quad = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps::<1>(acc));
    let pair = _mm_add_ps(quad, _mm_movehl_ps(quad, quad));
    let one = _mm_add_ss(pair, _mm_shuffle_ps::<1>(pair, pair));
    let mut s = _mm_cvtss_f32(one);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}
