//! AVX2 + FMA kernels for the fused dequant hot path (x86_64).
//!
//! Selected at runtime by [`super::active`] when the CPU reports `avx2` and
//! `fma`. Contracts relative to [`super::scalar`]:
//!
//! * [`unpack4_into`] produces **bit-identical codes** (integer surgery).
//! * [`lut16_levels`] (4-bit: `vpermps` 16-entry LUT shuffle, blended on
//!   code bit 3) and [`gather_levels`] (any width: `vgatherdps` over the
//!   256-entry LUT) produce **bit-identical levels** — table lookups never
//!   round.
//! * [`dot`] uses 4×8-lane FMA accumulators, so its reduction *order*
//!   differs from scalar: results agree to float tolerance, not bitwise.
//!   Every decode entry point routes through this same `dot`, so batched
//!   and single-sequence decode remain bit-identical to each other.
//! * [`dot2`] / [`dot4`] are the multi-row microkernels behind the batched
//!   shared decode: each activation row keeps its own 4-accumulator set
//!   and the exact [`dot`] reduction order (bitwise-equal per row), while
//!   the weight-level loads are shared across rows. With the optional
//!   `avx512` cargo feature, [`dot_best`]/[`dot2_best`]/[`dot4_best`]
//!   upgrade all three consistently to AVX-512 kernels behind runtime
//!   `avx512f` detection — consistently, because mixing widths across the
//!   single-row and multi-row paths would break the bitwise parity
//!   contract between them.
//!
//! All loads/stores are unaligned (`loadu`/`storeu`): the decoder scratch
//! is cache-line aligned for the fast case, but the kernels stay correct
//! on arbitrary slices (tile tails, test inputs).

use std::arch::x86_64::*;

/// Unpack 4-bit codes (two per byte, low nibble first): each iteration
/// turns 16 packed bytes into 32 codes via byte masks + an interleave.
///
/// # Safety
/// The CPU must support AVX2 (see [`super::supported`]).
#[target_feature(enable = "avx2")]
pub unsafe fn unpack4_into(bytes: &[u8], out: &mut [u8]) {
    let n = out.len();
    debug_assert!(bytes.len() >= n.div_ceil(2));
    let mask = _mm_set1_epi8(0x0F);
    let mut j = 0;
    while j + 32 <= n {
        let chunk = _mm_loadu_si128(bytes.as_ptr().add(j / 2) as *const __m128i);
        let lo = _mm_and_si128(chunk, mask);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(chunk), mask);
        _mm_storeu_si128(out.as_mut_ptr().add(j) as *mut __m128i, _mm_unpacklo_epi8(lo, hi));
        _mm_storeu_si128(out.as_mut_ptr().add(j + 16) as *mut __m128i, _mm_unpackhi_epi8(lo, hi));
        j += 32;
    }
    // Tail (j is even here: the vector loop advances 32 codes at a time).
    let mut byte = j / 2;
    while j < n {
        out[j] = bytes[byte] & 0x0F;
        j += 1;
        if j < n {
            out[j] = bytes[byte] >> 4;
            j += 1;
        }
        byte += 1;
    }
}

/// Map 4-bit codes straight to f32 grid levels through a 16-entry LUT held
/// in two shuffle registers: `vpermps` indexes the low/high 8 entries with
/// the code's low 3 bits and a blend on bit 3 picks the half. Bit-identical
/// to the scalar LUT walk.
///
/// # Safety
/// The CPU must support AVX2; `lut` must hold at least 16 entries and every
/// code must be < 16.
#[target_feature(enable = "avx2")]
pub unsafe fn lut16_levels(codes: &[u8], lut: &[f32], levels: &mut [f32]) {
    debug_assert!(lut.len() >= 16);
    let lo_tbl = _mm256_loadu_ps(lut.as_ptr());
    let hi_tbl = _mm256_loadu_ps(lut.as_ptr().add(8));
    let seven = _mm256_set1_epi32(7);
    let n = levels.len().min(codes.len());
    let mut j = 0;
    while j + 8 <= n {
        let idx = _mm256_cvtepu8_epi32(_mm_loadl_epi64(codes.as_ptr().add(j) as *const __m128i));
        // vpermps reads only the low 3 index bits; bit 3 selects the half.
        let lo = _mm256_permutevar8x32_ps(lo_tbl, idx);
        let hi = _mm256_permutevar8x32_ps(hi_tbl, idx);
        let pick_hi = _mm256_castsi256_ps(_mm256_cmpgt_epi32(idx, seven));
        _mm256_storeu_ps(levels.as_mut_ptr().add(j), _mm256_blendv_ps(lo, hi, pick_hi));
        j += 8;
    }
    while j < n {
        levels[j] = lut[codes[j] as usize];
        j += 1;
    }
}

/// Decode arbitrary-width codes to levels by gathering from the 256-entry
/// LUT (`vgatherdps`). Bit-identical to the scalar LUT walk.
///
/// # Safety
/// The CPU must support AVX2; `lut` must hold at least 256 entries (codes
/// are `u8`, so every gathered offset stays in bounds).
#[target_feature(enable = "avx2")]
pub unsafe fn gather_levels(codes: &[u8], lut: &[f32], levels: &mut [f32]) {
    debug_assert!(lut.len() >= 256);
    let n = levels.len().min(codes.len());
    let mut j = 0;
    while j + 8 <= n {
        let idx = _mm256_cvtepu8_epi32(_mm_loadl_epi64(codes.as_ptr().add(j) as *const __m128i));
        _mm256_storeu_ps(levels.as_mut_ptr().add(j), _mm256_i32gather_ps::<4>(lut.as_ptr(), idx));
        j += 8;
    }
    while j < n {
        levels[j] = lut[codes[j] as usize];
        j += 1;
    }
}

/// Dequantize u8 codes with an affine (`min + scale * code`), 8 lanes per
/// iteration (`vpmovzxbd` widen → `vcvtdq2ps` → FMA). The fused
/// multiply-add may round differently from the scalar `min + scale * c`,
/// so the quantized-KV read path is tolerance-gated, not bitwise.
///
/// # Safety
/// The CPU must support AVX2 and FMA (see [`super::supported`]).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dequant_u8(codes: &[u8], scale: f32, min: f32, out: &mut [f32]) {
    let n = out.len().min(codes.len());
    let vs = _mm256_set1_ps(scale);
    let vm = _mm256_set1_ps(min);
    let mut j = 0;
    while j + 8 <= n {
        let idx = _mm256_cvtepu8_epi32(_mm_loadl_epi64(codes.as_ptr().add(j) as *const __m128i));
        let f = _mm256_cvtepi32_ps(idx);
        _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_fmadd_ps(vs, f, vm));
        j += 8;
    }
    while j < n {
        out[j] = min + scale * codes[j] as f32;
        j += 1;
    }
}

/// Dot product with 4×8-lane FMA accumulators (32 floats per iteration),
/// an 8-lane cleanup loop, and a scalar tail. Deterministic: the reduction
/// order is fixed for any given input length.
///
/// # Safety
/// The CPU must support AVX2 and FMA (see [`super::supported`]).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut i = 0;
    while i + 32 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 8)),
            _mm256_loadu_ps(pb.add(i + 8)),
            acc1,
        );
        acc2 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 16)),
            _mm256_loadu_ps(pb.add(i + 16)),
            acc2,
        );
        acc3 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 24)),
            _mm256_loadu_ps(pb.add(i + 24)),
            acc3,
        );
        i += 32;
    }
    while i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        i += 8;
    }
    let mut s = hsum4(acc0, acc1, acc2, acc3);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Horizontal reduction of a 4-accumulator set — the exact sequence
/// [`dot`] has always used ((acc0+acc1)+(acc2+acc3), 128-bit fold, movehl,
/// shuffle). The multi-row kernels call this per row so each row's
/// reduction order is bitwise-identical to the single-row dot.
///
/// # Safety
/// The CPU must support AVX2 and FMA (see [`super::supported`]).
#[target_feature(enable = "avx2,fma")]
#[inline]
unsafe fn hsum4(acc0: __m256, acc1: __m256, acc2: __m256, acc3: __m256) -> f32 {
    let acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
    let quad = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps::<1>(acc));
    let pair = _mm_add_ps(quad, _mm_movehl_ps(quad, quad));
    let one = _mm_add_ss(pair, _mm_shuffle_ps::<1>(pair, pair));
    _mm_cvtss_f32(one)
}

/// Two dot products against one shared left operand (the decoded weight
/// levels): one pass over `a`, two independent 4-accumulator sets. Each
/// row's arithmetic — accumulator assignment, cleanup loop, horizontal
/// reduction, scalar tail — is exactly [`dot`]'s, so per-row results are
/// bitwise-equal to two single-row calls; only the `a` loads are shared.
///
/// # Safety
/// The CPU must support AVX2 and FMA (see [`super::supported`]).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot2(a: &[f32], b0: &[f32], b1: &[f32]) -> (f32, f32) {
    let n = a.len().min(b0.len()).min(b1.len());
    let (pa, p0, p1) = (a.as_ptr(), b0.as_ptr(), b1.as_ptr());
    let mut r0a = _mm256_setzero_ps();
    let mut r0b = _mm256_setzero_ps();
    let mut r0c = _mm256_setzero_ps();
    let mut r0d = _mm256_setzero_ps();
    let mut r1a = _mm256_setzero_ps();
    let mut r1b = _mm256_setzero_ps();
    let mut r1c = _mm256_setzero_ps();
    let mut r1d = _mm256_setzero_ps();
    let mut i = 0;
    while i + 32 <= n {
        let va0 = _mm256_loadu_ps(pa.add(i));
        let va1 = _mm256_loadu_ps(pa.add(i + 8));
        let va2 = _mm256_loadu_ps(pa.add(i + 16));
        let va3 = _mm256_loadu_ps(pa.add(i + 24));
        r0a = _mm256_fmadd_ps(va0, _mm256_loadu_ps(p0.add(i)), r0a);
        r0b = _mm256_fmadd_ps(va1, _mm256_loadu_ps(p0.add(i + 8)), r0b);
        r0c = _mm256_fmadd_ps(va2, _mm256_loadu_ps(p0.add(i + 16)), r0c);
        r0d = _mm256_fmadd_ps(va3, _mm256_loadu_ps(p0.add(i + 24)), r0d);
        r1a = _mm256_fmadd_ps(va0, _mm256_loadu_ps(p1.add(i)), r1a);
        r1b = _mm256_fmadd_ps(va1, _mm256_loadu_ps(p1.add(i + 8)), r1b);
        r1c = _mm256_fmadd_ps(va2, _mm256_loadu_ps(p1.add(i + 16)), r1c);
        r1d = _mm256_fmadd_ps(va3, _mm256_loadu_ps(p1.add(i + 24)), r1d);
        i += 32;
    }
    while i + 8 <= n {
        let va = _mm256_loadu_ps(pa.add(i));
        r0a = _mm256_fmadd_ps(va, _mm256_loadu_ps(p0.add(i)), r0a);
        r1a = _mm256_fmadd_ps(va, _mm256_loadu_ps(p1.add(i)), r1a);
        i += 8;
    }
    let mut s0 = hsum4(r0a, r0b, r0c, r0d);
    let mut s1 = hsum4(r1a, r1b, r1c, r1d);
    while i < n {
        s0 += a[i] * b0[i];
        s1 += a[i] * b1[i];
        i += 1;
    }
    (s0, s1)
}

/// Four dot products against one shared left operand, composed as two
/// [`dot2`] passes: a true single-pass 4-row kernel needs 16 accumulator
/// registers plus the shared loads, which spills the 16-register AVX2
/// file. Two passes keep `a` hot in L1 while preserving the per-row
/// bitwise contract. (The AVX-512 build gets the genuine single-pass
/// 4-row kernel — 32 registers.)
///
/// # Safety
/// The CPU must support AVX2 and FMA (see [`super::supported`]).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot4(
    a: &[f32],
    b0: &[f32],
    b1: &[f32],
    b2: &[f32],
    b3: &[f32],
) -> [f32; 4] {
    let (s0, s1) = dot2(a, b0, b1);
    let (s2, s3) = dot2(a, b2, b3);
    [s0, s1, s2, s3]
}

/// Is the optional AVX-512 dot path live? Compiled only with the `avx512`
/// cargo feature; runtime-gated on `avx512f` so the binary stays correct
/// on CPUs without it. Cached after the first probe.
#[cfg(feature = "avx512")]
pub fn avx512_available() -> bool {
    use std::sync::OnceLock;
    static OK: OnceLock<bool> = OnceLock::new();
    *OK.get_or_init(|| is_x86_feature_detected!("avx512f"))
}

/// Single-row dot for the dispatcher: the AVX-512 kernel when compiled in
/// and detected, else [`dot`]. The `*_best` trio switches together so the
/// single-row and multi-row paths always share one reduction family.
///
/// # Safety
/// The CPU must support AVX2 and FMA (see [`super::supported`]).
#[inline]
pub unsafe fn dot_best(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(feature = "avx512")]
    if avx512_available() {
        return avx512::dot(a, b);
    }
    dot(a, b)
}

/// Two-row dot for the dispatcher; see [`dot_best`].
///
/// # Safety
/// The CPU must support AVX2 and FMA (see [`super::supported`]).
#[inline]
pub unsafe fn dot2_best(a: &[f32], b0: &[f32], b1: &[f32]) -> (f32, f32) {
    #[cfg(feature = "avx512")]
    if avx512_available() {
        return avx512::dot2(a, b0, b1);
    }
    dot2(a, b0, b1)
}

/// Four-row dot for the dispatcher; see [`dot_best`].
///
/// # Safety
/// The CPU must support AVX2 and FMA (see [`super::supported`]).
#[inline]
pub unsafe fn dot4_best(
    a: &[f32],
    b0: &[f32],
    b1: &[f32],
    b2: &[f32],
    b3: &[f32],
) -> [f32; 4] {
    #[cfg(feature = "avx512")]
    if avx512_available() {
        return avx512::dot4(a, b0, b1, b2, b3);
    }
    dot4(a, b0, b1, b2, b3)
}

/// Optional AVX-512 dot kernels (`--features avx512`, runtime-gated on
/// `avx512f`). 16-lane zmm accumulators; the 32-register file fits the
/// genuine single-pass 4-row kernel that AVX2 cannot hold. Per-row
/// reduction order is shared across `dot`/`dot2`/`dot4` here exactly as in
/// the AVX2 family, so the matvec ≡ shared-matmul bitwise contract holds
/// whichever family the runtime probe picks — as long as it picks one
/// family for all three, which `*_best` guarantees.
#[cfg(feature = "avx512")]
mod avx512 {
    use std::arch::x86_64::*;

    /// Shared 4-accumulator reduction: pairwise combine, then the fixed
    /// `_mm512_reduce_add_ps` tree. Deterministic for a fixed length.
    ///
    /// # Safety
    /// The CPU must support AVX512F (see [`super::avx512_available`]).
    #[target_feature(enable = "avx512f")]
    #[inline]
    unsafe fn hsum4(acc0: __m512, acc1: __m512, acc2: __m512, acc3: __m512) -> f32 {
        let acc = _mm512_add_ps(_mm512_add_ps(acc0, acc1), _mm512_add_ps(acc2, acc3));
        _mm512_reduce_add_ps(acc)
    }

    /// 4×16-lane FMA dot (64 floats per iteration), 16-lane cleanup,
    /// scalar tail — the AVX-512 analogue of [`super::dot`].
    ///
    /// # Safety
    /// The CPU must support AVX512F (see [`super::avx512_available`]).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm512_setzero_ps();
        let mut acc1 = _mm512_setzero_ps();
        let mut acc2 = _mm512_setzero_ps();
        let mut acc3 = _mm512_setzero_ps();
        let mut i = 0;
        while i + 64 <= n {
            acc0 = _mm512_fmadd_ps(_mm512_loadu_ps(pa.add(i)), _mm512_loadu_ps(pb.add(i)), acc0);
            acc1 = _mm512_fmadd_ps(
                _mm512_loadu_ps(pa.add(i + 16)),
                _mm512_loadu_ps(pb.add(i + 16)),
                acc1,
            );
            acc2 = _mm512_fmadd_ps(
                _mm512_loadu_ps(pa.add(i + 32)),
                _mm512_loadu_ps(pb.add(i + 32)),
                acc2,
            );
            acc3 = _mm512_fmadd_ps(
                _mm512_loadu_ps(pa.add(i + 48)),
                _mm512_loadu_ps(pb.add(i + 48)),
                acc3,
            );
            i += 64;
        }
        while i + 16 <= n {
            acc0 = _mm512_fmadd_ps(_mm512_loadu_ps(pa.add(i)), _mm512_loadu_ps(pb.add(i)), acc0);
            i += 16;
        }
        let mut s = hsum4(acc0, acc1, acc2, acc3);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// Two-row AVX-512 dot: shared `a` loads, independent accumulator
    /// sets, per-row arithmetic identical to [`dot`].
    ///
    /// # Safety
    /// The CPU must support AVX512F (see [`super::avx512_available`]).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot2(a: &[f32], b0: &[f32], b1: &[f32]) -> (f32, f32) {
        let n = a.len().min(b0.len()).min(b1.len());
        let (pa, p0, p1) = (a.as_ptr(), b0.as_ptr(), b1.as_ptr());
        let mut r0a = _mm512_setzero_ps();
        let mut r0b = _mm512_setzero_ps();
        let mut r0c = _mm512_setzero_ps();
        let mut r0d = _mm512_setzero_ps();
        let mut r1a = _mm512_setzero_ps();
        let mut r1b = _mm512_setzero_ps();
        let mut r1c = _mm512_setzero_ps();
        let mut r1d = _mm512_setzero_ps();
        let mut i = 0;
        while i + 64 <= n {
            let va0 = _mm512_loadu_ps(pa.add(i));
            let va1 = _mm512_loadu_ps(pa.add(i + 16));
            let va2 = _mm512_loadu_ps(pa.add(i + 32));
            let va3 = _mm512_loadu_ps(pa.add(i + 48));
            r0a = _mm512_fmadd_ps(va0, _mm512_loadu_ps(p0.add(i)), r0a);
            r0b = _mm512_fmadd_ps(va1, _mm512_loadu_ps(p0.add(i + 16)), r0b);
            r0c = _mm512_fmadd_ps(va2, _mm512_loadu_ps(p0.add(i + 32)), r0c);
            r0d = _mm512_fmadd_ps(va3, _mm512_loadu_ps(p0.add(i + 48)), r0d);
            r1a = _mm512_fmadd_ps(va0, _mm512_loadu_ps(p1.add(i)), r1a);
            r1b = _mm512_fmadd_ps(va1, _mm512_loadu_ps(p1.add(i + 16)), r1b);
            r1c = _mm512_fmadd_ps(va2, _mm512_loadu_ps(p1.add(i + 32)), r1c);
            r1d = _mm512_fmadd_ps(va3, _mm512_loadu_ps(p1.add(i + 48)), r1d);
            i += 64;
        }
        while i + 16 <= n {
            let va = _mm512_loadu_ps(pa.add(i));
            r0a = _mm512_fmadd_ps(va, _mm512_loadu_ps(p0.add(i)), r0a);
            r1a = _mm512_fmadd_ps(va, _mm512_loadu_ps(p1.add(i)), r1a);
            i += 16;
        }
        let mut s0 = hsum4(r0a, r0b, r0c, r0d);
        let mut s1 = hsum4(r1a, r1b, r1c, r1d);
        while i < n {
            s0 += a[i] * b0[i];
            s1 += a[i] * b1[i];
            i += 1;
        }
        (s0, s1)
    }

    /// Genuine single-pass 4-row AVX-512 dot (16 zmm accumulators + 4
    /// shared loads fit the 32-register file); per-row arithmetic
    /// identical to [`dot`].
    ///
    /// # Safety
    /// The CPU must support AVX512F (see [`super::avx512_available`]).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot4(
        a: &[f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) -> [f32; 4] {
        let n = a.len().min(b0.len()).min(b1.len()).min(b2.len()).min(b3.len());
        let (pa, p0, p1, p2, p3) =
            (a.as_ptr(), b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr());
        let mut acc = [[_mm512_setzero_ps(); 4]; 4];
        let mut i = 0;
        while i + 64 <= n {
            let va = [
                _mm512_loadu_ps(pa.add(i)),
                _mm512_loadu_ps(pa.add(i + 16)),
                _mm512_loadu_ps(pa.add(i + 32)),
                _mm512_loadu_ps(pa.add(i + 48)),
            ];
            for (r, pr) in [p0, p1, p2, p3].into_iter().enumerate() {
                for (k, &vak) in va.iter().enumerate() {
                    acc[r][k] =
                        _mm512_fmadd_ps(vak, _mm512_loadu_ps(pr.add(i + k * 16)), acc[r][k]);
                }
            }
            i += 64;
        }
        while i + 16 <= n {
            let va = _mm512_loadu_ps(pa.add(i));
            for (r, pr) in [p0, p1, p2, p3].into_iter().enumerate() {
                acc[r][0] = _mm512_fmadd_ps(va, _mm512_loadu_ps(pr.add(i)), acc[r][0]);
            }
            i += 16;
        }
        let mut s = [
            hsum4(acc[0][0], acc[0][1], acc[0][2], acc[0][3]),
            hsum4(acc[1][0], acc[1][1], acc[1][2], acc[1][3]),
            hsum4(acc[2][0], acc[2][1], acc[2][2], acc[2][3]),
            hsum4(acc[3][0], acc[3][1], acc[3][2], acc[3][3]),
        ];
        while i < n {
            s[0] += a[i] * b0[i];
            s[1] += a[i] * b1[i];
            s[2] += a[i] * b2[i];
            s[3] += a[i] * b3[i];
            i += 1;
        }
        s
    }
}
