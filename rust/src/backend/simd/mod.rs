//! Explicit SIMD kernels with runtime dispatch for the fused dequant path.
//!
//! Every token the native engine produces bottoms out in three inner loops:
//! unpack packed codes, decode codes to grid levels through a LUT, and
//! reduce levels against an activation vector. This module gives those
//! loops explicit AVX2 (x86_64) and NEON (aarch64) implementations and a
//! runtime dispatcher; the scalar code remains both the portable fallback
//! and the **parity oracle** the SIMD paths are tested against.
//!
//! ## Exactness contract
//!
//! * **Unpacked codes and decoded levels are bit-identical across ISAs.**
//!   Unpacking is integer bit surgery and level decode is a table lookup —
//!   neither rounds, so `tests/simd_kernels.rs` asserts exact equality.
//! * **Dot products agree to float tolerance, not bitwise**, because SIMD
//!   lane accumulators change the reduction order. Both decode entry points
//!   ([`crate::backend::QuantizedTensor::dequant_matvec`] and
//!   [`crate::backend::QuantizedTensor::dequant_matmul_shared`]) route
//!   through the *same* dispatched [`dot_with`], so batched and
//!   single-sequence decode stay bit-identical **to each other** at any
//!   batch size — the contract the decoder parity tests depend on.
//!
//! ## Selection
//!
//! [`active`] picks the best supported ISA once per process:
//! `is_x86_feature_detected!("avx2")`+`fma` on x86_64, NEON unconditionally
//! on aarch64 (baseline feature), scalar elsewhere. The `SINQ_SIMD`
//! environment variable (`scalar|avx2|neon|auto`) overrides detection —
//! `SINQ_SIMD=scalar` is the supported way to force the fallback when
//! debugging — and [`force`] overrides both at runtime (used by the parity
//! tests and the scalar-vs-SIMD benches). Unsupported requests fall back to
//! scalar rather than faulting.

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(target_arch = "aarch64")]
pub mod neon;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// An instruction-set-specific kernel family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar loops (the parity oracle).
    Scalar,
    /// AVX2 + FMA (x86_64, runtime-detected).
    Avx2,
    /// NEON (aarch64 baseline).
    Neon,
}

impl Isa {
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    pub fn parse(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }
}

/// Whether this CPU can execute `isa`'s kernels.
pub fn supported(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma"),
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 => false,
        Isa::Neon => cfg!(target_arch = "aarch64"),
    }
}

/// Best ISA this CPU supports, ignoring overrides.
pub fn detect() -> Isa {
    if supported(Isa::Avx2) {
        Isa::Avx2
    } else if supported(Isa::Neon) {
        Isa::Neon
    } else {
        Isa::Scalar
    }
}

/// Runtime override installed by [`force`]: 0 = none, else `Isa` + 1.
static FORCED: AtomicU8 = AtomicU8::new(0);

/// Override the dispatched ISA process-wide (`None` restores automatic
/// selection). Intended for parity tests and scalar-vs-SIMD benchmarks;
/// forcing an ISA the CPU does not support falls back to scalar.
pub fn force(isa: Option<Isa>) {
    let v = match isa {
        None => 0,
        Some(Isa::Scalar) => 1,
        Some(Isa::Avx2) => 2,
        Some(Isa::Neon) => 3,
    };
    FORCED.store(v, Ordering::SeqCst);
}

/// The override currently installed by [`force`], if any. Callers that
/// temporarily force an ISA (the drift sentinel's scalar recompute) read
/// this first so they can restore the prior state instead of clobbering a
/// test harness's override.
pub fn forced() -> Option<Isa> {
    match FORCED.load(Ordering::SeqCst) {
        1 => Some(Isa::Scalar),
        2 => Some(Isa::Avx2),
        3 => Some(Isa::Neon),
        _ => None,
    }
}

/// Resolve the `SINQ_SIMD` environment variable (consulted once).
fn choose() -> Isa {
    let Ok(raw) = std::env::var("SINQ_SIMD") else {
        return detect();
    };
    let v = raw.trim().to_ascii_lowercase();
    if v.is_empty() || v == "auto" {
        return detect();
    }
    match Isa::parse(&v) {
        Some(isa) if supported(isa) => isa,
        Some(isa) => {
            eprintln!(
                "sinq: SINQ_SIMD={} is not supported on this CPU; using {}",
                isa.name(),
                detect().name()
            );
            detect()
        }
        None => {
            eprintln!(
                "sinq: unknown SINQ_SIMD value {raw:?} (expected scalar|avx2|neon|auto); \
                 using {}",
                detect().name()
            );
            detect()
        }
    }
}

/// The ISA the fused kernels dispatch to right now. Always returns a
/// supported ISA: [`force`] takes precedence, then `SINQ_SIMD`, then
/// [`detect`].
pub fn active() -> Isa {
    let isa = match FORCED.load(Ordering::SeqCst) {
        1 => Isa::Scalar,
        2 => Isa::Avx2,
        3 => Isa::Neon,
        _ => {
            static CHOSEN: OnceLock<Isa> = OnceLock::new();
            *CHOSEN.get_or_init(choose)
        }
    };
    if supported(isa) {
        isa
    } else {
        Isa::Scalar
    }
}

/// Name of the active kernel family ("scalar" / "avx2" / "neon") — surfaced
/// by `sinq serve` startup output and the `/healthz` endpoint so deployments
/// can verify which path is live.
pub fn kernel_name() -> &'static str {
    active().name()
}

/// Unpack `out.len()` codes of `bits` width from `bytes` with `isa`'s
/// kernels. Bit-identical to [`scalar::unpack_into`] for every ISA.
pub fn unpack_into_with(isa: Isa, bytes: &[u8], bits: u32, out: &mut [u8]) {
    let isa = if supported(isa) { isa } else { Isa::Scalar };
    match isa {
        Isa::Scalar => scalar::unpack_into(bytes, bits, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `supported(Isa::Avx2)` verified avx2+fma above.
        Isa::Avx2 => match bits {
            4 => unsafe { avx2::unpack4_into(bytes, out) },
            _ => scalar::unpack_into(bytes, bits, out),
        },
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 => scalar::unpack_into(bytes, bits, out),
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is an aarch64 baseline feature.
        Isa::Neon => match bits {
            4 => unsafe { neon::unpack4_into(bytes, out) },
            _ => scalar::unpack_into(bytes, bits, out),
        },
        #[cfg(not(target_arch = "aarch64"))]
        Isa::Neon => scalar::unpack_into(bytes, bits, out),
    }
}

/// Unpack one packed row and decode it to grid levels: fills `codes`
/// (unpacked, `codes.len() == levels.len()`) and `levels`
/// (`levels[j] = lut[codes[j]]`). The 4-bit path maps codes through a
/// 16-entry LUT shuffle (`vpermps` on AVX2, `tbl` on NEON); other widths
/// gather from the full 256-entry LUT (AVX2) or fall back to the scalar
/// walk. Codes and levels are bit-identical across ISAs.
pub fn decode_levels_with(
    isa: Isa,
    bytes: &[u8],
    bits: u32,
    lut: &[f32],
    codes: &mut [u8],
    levels: &mut [f32],
) {
    assert!(lut.len() >= 256, "decode LUT must cover all 8-bit codes");
    assert_eq!(codes.len(), levels.len(), "codes/levels scratch length mismatch");
    let isa = if supported(isa) { isa } else { Isa::Scalar };
    unpack_into_with(isa, bytes, bits, codes);
    match isa {
        Isa::Scalar => scalar::decode_levels(codes, lut, levels),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2+fma verified by `supported`; lut covers 256 entries.
        Isa::Avx2 => unsafe {
            if bits == 4 {
                avx2::lut16_levels(codes, lut, levels)
            } else {
                avx2::gather_levels(codes, lut, levels)
            }
        },
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 => scalar::decode_levels(codes, lut, levels),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => {
            if bits == 4 {
                // SAFETY: NEON is an aarch64 baseline feature.
                unsafe { neon::lut16_levels(codes, lut, levels) }
            } else {
                scalar::decode_levels(codes, lut, levels)
            }
        }
        #[cfg(not(target_arch = "aarch64"))]
        Isa::Neon => scalar::decode_levels(codes, lut, levels),
    }
}

/// Dot product of two equal-length slices with `isa`'s kernels.
/// Deterministic for a fixed ISA; reduction order (and therefore the exact
/// f32 result) differs between ISAs.
pub fn dot_with(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let isa = if supported(isa) { isa } else { Isa::Scalar };
    match isa {
        Isa::Scalar => scalar::dot(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2+fma verified by `supported`; the optional AVX-512
        // upgrade inside `dot_best` re-checks avx512f at runtime.
        Isa::Avx2 => unsafe { avx2::dot_best(a, b) },
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 => scalar::dot(a, b),
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is an aarch64 baseline feature.
        Isa::Neon => unsafe { neon::dot(a, b) },
        #[cfg(not(target_arch = "aarch64"))]
        Isa::Neon => scalar::dot(a, b),
    }
}

/// Two dot products of `b0`/`b1` against one shared `a` (the decoded
/// weight levels) — the 2-row microkernel behind the batched shared
/// decode. Contract: each returned value is **bitwise-equal** to
/// `dot_with(isa, a, bN)` — the multi-row kernels keep one accumulator set
/// and the single-row reduction order per row, sharing only the `a` loads
/// (`tests/simd_kernels.rs` asserts this per ISA).
pub fn dot2_with(isa: Isa, a: &[f32], b0: &[f32], b1: &[f32]) -> (f32, f32) {
    debug_assert_eq!(a.len(), b0.len());
    debug_assert_eq!(a.len(), b1.len());
    let isa = if supported(isa) { isa } else { Isa::Scalar };
    match isa {
        Isa::Scalar => scalar::dot2(a, b0, b1),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2+fma verified by `supported`.
        Isa::Avx2 => unsafe { avx2::dot2_best(a, b0, b1) },
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 => scalar::dot2(a, b0, b1),
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is an aarch64 baseline feature.
        Isa::Neon => unsafe { neon::dot2(a, b0, b1) },
        #[cfg(not(target_arch = "aarch64"))]
        Isa::Neon => scalar::dot2(a, b0, b1),
    }
}

/// Four dot products against one shared `a` — the 4-row microkernel; same
/// per-row bitwise contract as [`dot2_with`].
pub fn dot4_with(
    isa: Isa,
    a: &[f32],
    b0: &[f32],
    b1: &[f32],
    b2: &[f32],
    b3: &[f32],
) -> [f32; 4] {
    debug_assert_eq!(a.len(), b0.len());
    debug_assert_eq!(a.len(), b3.len());
    let isa = if supported(isa) { isa } else { Isa::Scalar };
    match isa {
        Isa::Scalar => scalar::dot4(a, b0, b1, b2, b3),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2+fma verified by `supported`.
        Isa::Avx2 => unsafe { avx2::dot4_best(a, b0, b1, b2, b3) },
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 => scalar::dot4(a, b0, b1, b2, b3),
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is an aarch64 baseline feature.
        Isa::Neon => unsafe { neon::dot4(a, b0, b1, b2, b3) },
        #[cfg(not(target_arch = "aarch64"))]
        Isa::Neon => scalar::dot4(a, b0, b1, b2, b3),
    }
}

/// Dequantize u8 codes with an affine (`out[j] = min + scale * codes[j]`)
/// using `isa`'s kernels — the quantized KV-cache read path. Deterministic
/// for a fixed ISA; the SIMD paths use FMA, so roundings may differ from
/// scalar by one ULP (the kv8 consumers are tolerance-gated, unlike the
/// weight kernels' bitwise unpack/level contract).
pub fn dequant_u8_with(isa: Isa, codes: &[u8], scale: f32, min: f32, out: &mut [f32]) {
    let isa = if supported(isa) { isa } else { Isa::Scalar };
    match isa {
        Isa::Scalar => scalar::dequant_u8(codes, scale, min, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `supported(Isa::Avx2)` verified avx2+fma above.
        Isa::Avx2 => unsafe { avx2::dequant_u8(codes, scale, min, out) },
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 => scalar::dequant_u8(codes, scale, min, out),
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is an aarch64 baseline feature.
        Isa::Neon => unsafe { neon::dequant_u8(codes, scale, min, out) },
        #[cfg(not(target_arch = "aarch64"))]
        Isa::Neon => scalar::dequant_u8(codes, scale, min, out),
    }
}

/// One 64-byte-aligned chunk of 16 f32 lanes.
#[derive(Clone, Copy)]
#[repr(C, align(64))]
struct Align64([f32; 16]);

/// Growable 64-byte-aligned f32 buffer: the SIMD kernels' scratch tiles
/// (levels, folded activations) live here so vector loads/stores hit
/// cache-line-aligned memory. `resize` reuses the allocation; contents
/// after a resize are unspecified (every kernel writes before reading).
#[derive(Default)]
pub struct AlignedF32 {
    chunks: Vec<Align64>,
    len: usize,
}

impl AlignedF32 {
    pub fn new() -> AlignedF32 {
        AlignedF32::default()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set the logical length, growing the backing allocation if needed.
    pub fn resize(&mut self, len: usize) {
        let chunks = len.div_ceil(16);
        if self.chunks.len() < chunks {
            self.chunks.resize(chunks, Align64([0.0; 16]));
        }
        self.len = len;
    }

    pub fn as_slice(&self) -> &[f32] {
        // SAFETY: the backing allocation holds `chunks.len() * 16 >= len`
        // contiguous f32s (Align64 is `repr(C)` over `[f32; 16]`).
        unsafe { std::slice::from_raw_parts(self.chunks.as_ptr() as *const f32, self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: as in `as_slice`, and `&mut self` guarantees uniqueness.
        unsafe { std::slice::from_raw_parts_mut(self.chunks.as_mut_ptr() as *mut f32, self.len) }
    }
}

/// Reusable per-decoder scratch for the fused decode kernels: unpack bytes,
/// level tiles, and the folded activation + per-group sums. Owning one of
/// these per decoder removes every per-matvec allocation from the token
/// hot path and gives the SIMD kernels stable aligned tiles to write into.
#[derive(Default)]
pub struct KernelScratch {
    /// Unpacked code bytes for one weight row.
    pub codes: Vec<u8>,
    /// Decoded grid levels for one weight row (aligned).
    pub levels: AlignedF32,
    /// Activation with the SINQ column scale folded in (aligned).
    pub xt: AlignedF32,
    /// Per-group sums of `xt` (carries the shift term).
    pub gsum: Vec<f32>,
    /// Folded activation rows for the batched shared kernel (aligned; row
    /// stride padded to a full 16-lane chunk so every row starts
    /// cache-line aligned).
    pub xt_rows: AlignedF32,
    /// Per-group sums for each batched activation row (row-major,
    /// `n_groups` per row).
    pub gsum_rows: Vec<f32>,
}

impl KernelScratch {
    pub fn new() -> KernelScratch {
        KernelScratch::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fmt::pack;

    #[test]
    fn active_is_always_supported_and_named() {
        let isa = active();
        assert!(supported(isa));
        assert!(["scalar", "avx2", "neon"].contains(&kernel_name()));
    }

    #[test]
    fn isa_parse_round_trips() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("sse"), None);
    }

    #[test]
    fn aligned_buffer_is_cache_line_aligned() {
        let mut buf = AlignedF32::new();
        buf.resize(100);
        assert_eq!(buf.len(), 100);
        assert_eq!(buf.as_slice().as_ptr() as usize % 64, 0);
        buf.as_mut_slice().fill(2.5);
        assert!(buf.as_slice().iter().all(|&v| v == 2.5));
        // Shrinking and regrowing reuses the allocation and keeps alignment.
        buf.resize(3);
        buf.resize(64);
        assert_eq!(buf.as_slice().as_ptr() as usize % 64, 0);
        assert_eq!(buf.len(), 64);
    }

    #[test]
    fn dequant_u8_matches_scalar_to_tolerance_on_every_supported_isa() {
        let codes: Vec<u8> = (0..37u8).map(|i| i.wrapping_mul(7)).collect();
        let (scale, min) = (0.0123f32, -1.5f32);
        let mut want = vec![0.0f32; codes.len()];
        scalar::dequant_u8(&codes, scale, min, &mut want);
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            if !supported(isa) {
                continue;
            }
            let mut got = vec![0.0f32; codes.len()];
            dequant_u8_with(isa, &codes, scale, min, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= 1e-5, "{}: {g} vs {w}", isa.name());
            }
        }
    }

    #[test]
    fn scalar_dispatch_matches_pack_layout() {
        let codes: Vec<u8> = (0..37u8).map(|i| i % 16).collect();
        let packed = pack::pack(&codes, 4);
        let mut out = vec![0u8; codes.len()];
        unpack_into_with(Isa::Scalar, &packed, 4, &mut out);
        assert_eq!(out, codes);
    }
}
