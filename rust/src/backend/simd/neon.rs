//! NEON kernels for the fused dequant hot path (aarch64).
//!
//! NEON is a baseline feature of every aarch64 target, so these kernels
//! are selected unconditionally there (`SINQ_SIMD=scalar` still forces the
//! fallback). Contracts relative to [`super::scalar`] mirror the AVX2
//! module: codes and levels are bit-identical (integer surgery + `tbl`
//! table lookups), while [`dot`]'s 4×4-lane FMA reduction order differs
//! from scalar so sums agree to float tolerance only.

use std::arch::aarch64::*;

/// Unpack 4-bit codes (two per byte, low nibble first): each iteration
/// turns 16 packed bytes into 32 codes via masks + a zip interleave.
///
/// # Safety
/// NEON must be available (always true on aarch64; kept `unsafe` to match
/// the intrinsics it wraps and the dispatch contract).
pub unsafe fn unpack4_into(bytes: &[u8], out: &mut [u8]) {
    let n = out.len();
    debug_assert!(bytes.len() >= n.div_ceil(2));
    let mask = vdupq_n_u8(0x0F);
    let mut j = 0;
    while j + 32 <= n {
        let chunk = vld1q_u8(bytes.as_ptr().add(j / 2));
        let lo = vandq_u8(chunk, mask);
        let hi = vshrq_n_u8::<4>(chunk);
        vst1q_u8(out.as_mut_ptr().add(j), vzip1q_u8(lo, hi));
        vst1q_u8(out.as_mut_ptr().add(j + 16), vzip2q_u8(lo, hi));
        j += 32;
    }
    // Tail (j is even here: the vector loop advances 32 codes at a time).
    let mut byte = j / 2;
    while j < n {
        out[j] = bytes[byte] & 0x0F;
        j += 1;
        if j < n {
            out[j] = bytes[byte] >> 4;
            j += 1;
        }
        byte += 1;
    }
}

/// Map 4-bit codes straight to f32 grid levels through a 16-entry LUT held
/// as a 64-byte `tbl` table (`vqtbl4q_u8`): each code's four level bytes
/// are gathered by byte index `4*code + 0..4`. Bit-identical to the scalar
/// LUT walk (aarch64 is little-endian, so gathered bytes reassemble the
/// exact f32 pattern).
///
/// # Safety
/// NEON must be available; `lut` must hold at least 16 entries and every
/// code must be < 16.
pub unsafe fn lut16_levels(codes: &[u8], lut: &[f32], levels: &mut [f32]) {
    debug_assert!(lut.len() >= 16);
    let lut_bytes = lut.as_ptr() as *const u8;
    let tbl = uint8x16x4_t(
        vld1q_u8(lut_bytes),
        vld1q_u8(lut_bytes.add(16)),
        vld1q_u8(lut_bytes.add(32)),
        vld1q_u8(lut_bytes.add(48)),
    );
    // REP[k] replicates codes 4k..4k+4 four times each; OFFS adds the byte
    // position within each replicated f32.
    const REP: [[u8; 16]; 4] = [
        [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3],
        [4, 4, 4, 4, 5, 5, 5, 5, 6, 6, 6, 6, 7, 7, 7, 7],
        [8, 8, 8, 8, 9, 9, 9, 9, 10, 10, 10, 10, 11, 11, 11, 11],
        [12, 12, 12, 12, 13, 13, 13, 13, 14, 14, 14, 14, 15, 15, 15, 15],
    ];
    const OFFS: [u8; 16] = [0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3];
    let offs = vld1q_u8(OFFS.as_ptr());
    let n = levels.len().min(codes.len());
    let mut j = 0;
    while j + 16 <= n {
        let c = vld1q_u8(codes.as_ptr().add(j));
        // Byte offset of each code's level in the 64-byte table (code * 4).
        let base = vshlq_n_u8::<2>(c);
        for (k, rep) in REP.iter().enumerate() {
            let sel = vqtbl1q_u8(base, vld1q_u8(rep.as_ptr()));
            let idx = vaddq_u8(sel, offs);
            vst1q_u8(levels.as_mut_ptr().add(j + k * 4) as *mut u8, vqtbl4q_u8(tbl, idx));
        }
        j += 16;
    }
    while j < n {
        levels[j] = lut[codes[j] as usize];
        j += 1;
    }
}

/// Dequantize u8 codes with an affine (`min + scale * code`), 8 lanes per
/// iteration (widen u8 → u16 → u32, convert, FMA). The fused multiply-add
/// may round differently from the scalar `min + scale * c`, so the
/// quantized-KV read path is tolerance-gated, not bitwise.
///
/// # Safety
/// NEON must be available (always true on aarch64).
pub unsafe fn dequant_u8(codes: &[u8], scale: f32, min: f32, out: &mut [f32]) {
    let n = out.len().min(codes.len());
    let vs = vdupq_n_f32(scale);
    let vm = vdupq_n_f32(min);
    let mut j = 0;
    while j + 8 <= n {
        let wide = vmovl_u8(vld1_u8(codes.as_ptr().add(j)));
        let lo = vcvtq_f32_u32(vmovl_u16(vget_low_u16(wide)));
        let hi = vcvtq_f32_u32(vmovl_u16(vget_high_u16(wide)));
        vst1q_f32(out.as_mut_ptr().add(j), vfmaq_f32(vm, vs, lo));
        vst1q_f32(out.as_mut_ptr().add(j + 4), vfmaq_f32(vm, vs, hi));
        j += 8;
    }
    while j < n {
        out[j] = min + scale * codes[j] as f32;
        j += 1;
    }
}

/// Dot product with 4×4-lane FMA accumulators (16 floats per iteration),
/// a 4-lane cleanup loop, and a scalar tail. Deterministic: the reduction
/// order is fixed for any given input length.
///
/// # Safety
/// NEON must be available (always true on aarch64).
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut i = 0;
    while i + 16 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
        acc2 = vfmaq_f32(acc2, vld1q_f32(pa.add(i + 8)), vld1q_f32(pb.add(i + 8)));
        acc3 = vfmaq_f32(acc3, vld1q_f32(pa.add(i + 12)), vld1q_f32(pb.add(i + 12)));
        i += 16;
    }
    while i + 4 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
        i += 4;
    }
    let mut s = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Two dot products against one shared left operand (the decoded weight
/// levels): one pass over `a`, two independent 4-accumulator sets. Each
/// row's arithmetic — accumulator assignment, cleanup loop, `vaddvq`
/// reduction, scalar tail — is exactly [`dot`]'s, so per-row results are
/// bitwise-equal to two single-row calls; only the `a` loads are shared.
///
/// # Safety
/// NEON must be available (always true on aarch64).
pub unsafe fn dot2(a: &[f32], b0: &[f32], b1: &[f32]) -> (f32, f32) {
    let n = a.len().min(b0.len()).min(b1.len());
    let (pa, p0, p1) = (a.as_ptr(), b0.as_ptr(), b1.as_ptr());
    let mut r0a = vdupq_n_f32(0.0);
    let mut r0b = vdupq_n_f32(0.0);
    let mut r0c = vdupq_n_f32(0.0);
    let mut r0d = vdupq_n_f32(0.0);
    let mut r1a = vdupq_n_f32(0.0);
    let mut r1b = vdupq_n_f32(0.0);
    let mut r1c = vdupq_n_f32(0.0);
    let mut r1d = vdupq_n_f32(0.0);
    let mut i = 0;
    while i + 16 <= n {
        let va0 = vld1q_f32(pa.add(i));
        let va1 = vld1q_f32(pa.add(i + 4));
        let va2 = vld1q_f32(pa.add(i + 8));
        let va3 = vld1q_f32(pa.add(i + 12));
        r0a = vfmaq_f32(r0a, va0, vld1q_f32(p0.add(i)));
        r0b = vfmaq_f32(r0b, va1, vld1q_f32(p0.add(i + 4)));
        r0c = vfmaq_f32(r0c, va2, vld1q_f32(p0.add(i + 8)));
        r0d = vfmaq_f32(r0d, va3, vld1q_f32(p0.add(i + 12)));
        r1a = vfmaq_f32(r1a, va0, vld1q_f32(p1.add(i)));
        r1b = vfmaq_f32(r1b, va1, vld1q_f32(p1.add(i + 4)));
        r1c = vfmaq_f32(r1c, va2, vld1q_f32(p1.add(i + 8)));
        r1d = vfmaq_f32(r1d, va3, vld1q_f32(p1.add(i + 12)));
        i += 16;
    }
    while i + 4 <= n {
        let va = vld1q_f32(pa.add(i));
        r0a = vfmaq_f32(r0a, va, vld1q_f32(p0.add(i)));
        r1a = vfmaq_f32(r1a, va, vld1q_f32(p1.add(i)));
        i += 4;
    }
    let mut s0 = vaddvq_f32(vaddq_f32(vaddq_f32(r0a, r0b), vaddq_f32(r0c, r0d)));
    let mut s1 = vaddvq_f32(vaddq_f32(vaddq_f32(r1a, r1b), vaddq_f32(r1c, r1d)));
    while i < n {
        s0 += a[i] * b0[i];
        s1 += a[i] * b1[i];
        i += 1;
    }
    (s0, s1)
}

/// Genuine single-pass 4-row dot: 16 accumulator registers plus 4 shared
/// loads fit aarch64's 32-register vector file (unlike AVX2's 16). Per-row
/// arithmetic is exactly [`dot`]'s, so each lane of the result is
/// bitwise-equal to the corresponding single-row call.
///
/// # Safety
/// NEON must be available (always true on aarch64).
pub unsafe fn dot4(
    a: &[f32],
    b0: &[f32],
    b1: &[f32],
    b2: &[f32],
    b3: &[f32],
) -> [f32; 4] {
    let n = a.len().min(b0.len()).min(b1.len()).min(b2.len()).min(b3.len());
    let (pa, p0, p1, p2, p3) = (a.as_ptr(), b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr());
    let mut acc = [[vdupq_n_f32(0.0); 4]; 4];
    let mut i = 0;
    while i + 16 <= n {
        let va = [
            vld1q_f32(pa.add(i)),
            vld1q_f32(pa.add(i + 4)),
            vld1q_f32(pa.add(i + 8)),
            vld1q_f32(pa.add(i + 12)),
        ];
        for (r, pr) in [p0, p1, p2, p3].into_iter().enumerate() {
            for (k, &vak) in va.iter().enumerate() {
                acc[r][k] = vfmaq_f32(acc[r][k], vak, vld1q_f32(pr.add(i + k * 4)));
            }
        }
        i += 16;
    }
    while i + 4 <= n {
        let va = vld1q_f32(pa.add(i));
        for (r, pr) in [p0, p1, p2, p3].into_iter().enumerate() {
            acc[r][0] = vfmaq_f32(acc[r][0], va, vld1q_f32(pr.add(i)));
        }
        i += 4;
    }
    let mut s = [
        vaddvq_f32(vaddq_f32(vaddq_f32(acc[0][0], acc[0][1]), vaddq_f32(acc[0][2], acc[0][3]))),
        vaddvq_f32(vaddq_f32(vaddq_f32(acc[1][0], acc[1][1]), vaddq_f32(acc[1][2], acc[1][3]))),
        vaddvq_f32(vaddq_f32(vaddq_f32(acc[2][0], acc[2][1]), vaddq_f32(acc[2][2], acc[2][3]))),
        vaddvq_f32(vaddq_f32(vaddq_f32(acc[3][0], acc[3][1]), vaddq_f32(acc[3][2], acc[3][3]))),
    ];
    while i < n {
        s[0] += a[i] * b0[i];
        s[1] += a[i] * b1[i];
        s[2] += a[i] * b2[i];
        s[3] += a[i] * b3[i];
        i += 1;
    }
    s
}
