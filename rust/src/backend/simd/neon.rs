//! NEON kernels for the fused dequant hot path (aarch64).
//!
//! NEON is a baseline feature of every aarch64 target, so these kernels
//! are selected unconditionally there (`SINQ_SIMD=scalar` still forces the
//! fallback). Contracts relative to [`super::scalar`] mirror the AVX2
//! module: codes and levels are bit-identical (integer surgery + `tbl`
//! table lookups), while [`dot`]'s 4×4-lane FMA reduction order differs
//! from scalar so sums agree to float tolerance only.

use std::arch::aarch64::*;

/// Unpack 4-bit codes (two per byte, low nibble first): each iteration
/// turns 16 packed bytes into 32 codes via masks + a zip interleave.
///
/// # Safety
/// NEON must be available (always true on aarch64; kept `unsafe` to match
/// the intrinsics it wraps and the dispatch contract).
pub unsafe fn unpack4_into(bytes: &[u8], out: &mut [u8]) {
    let n = out.len();
    debug_assert!(bytes.len() >= n.div_ceil(2));
    let mask = vdupq_n_u8(0x0F);
    let mut j = 0;
    while j + 32 <= n {
        let chunk = vld1q_u8(bytes.as_ptr().add(j / 2));
        let lo = vandq_u8(chunk, mask);
        let hi = vshrq_n_u8::<4>(chunk);
        vst1q_u8(out.as_mut_ptr().add(j), vzip1q_u8(lo, hi));
        vst1q_u8(out.as_mut_ptr().add(j + 16), vzip2q_u8(lo, hi));
        j += 32;
    }
    // Tail (j is even here: the vector loop advances 32 codes at a time).
    let mut byte = j / 2;
    while j < n {
        out[j] = bytes[byte] & 0x0F;
        j += 1;
        if j < n {
            out[j] = bytes[byte] >> 4;
            j += 1;
        }
        byte += 1;
    }
}

/// Map 4-bit codes straight to f32 grid levels through a 16-entry LUT held
/// as a 64-byte `tbl` table (`vqtbl4q_u8`): each code's four level bytes
/// are gathered by byte index `4*code + 0..4`. Bit-identical to the scalar
/// LUT walk (aarch64 is little-endian, so gathered bytes reassemble the
/// exact f32 pattern).
///
/// # Safety
/// NEON must be available; `lut` must hold at least 16 entries and every
/// code must be < 16.
pub unsafe fn lut16_levels(codes: &[u8], lut: &[f32], levels: &mut [f32]) {
    debug_assert!(lut.len() >= 16);
    let lut_bytes = lut.as_ptr() as *const u8;
    let tbl = uint8x16x4_t(
        vld1q_u8(lut_bytes),
        vld1q_u8(lut_bytes.add(16)),
        vld1q_u8(lut_bytes.add(32)),
        vld1q_u8(lut_bytes.add(48)),
    );
    // REP[k] replicates codes 4k..4k+4 four times each; OFFS adds the byte
    // position within each replicated f32.
    const REP: [[u8; 16]; 4] = [
        [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3],
        [4, 4, 4, 4, 5, 5, 5, 5, 6, 6, 6, 6, 7, 7, 7, 7],
        [8, 8, 8, 8, 9, 9, 9, 9, 10, 10, 10, 10, 11, 11, 11, 11],
        [12, 12, 12, 12, 13, 13, 13, 13, 14, 14, 14, 14, 15, 15, 15, 15],
    ];
    const OFFS: [u8; 16] = [0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3];
    let offs = vld1q_u8(OFFS.as_ptr());
    let n = levels.len().min(codes.len());
    let mut j = 0;
    while j + 16 <= n {
        let c = vld1q_u8(codes.as_ptr().add(j));
        // Byte offset of each code's level in the 64-byte table (code * 4).
        let base = vshlq_n_u8::<2>(c);
        for (k, rep) in REP.iter().enumerate() {
            let sel = vqtbl1q_u8(base, vld1q_u8(rep.as_ptr()));
            let idx = vaddq_u8(sel, offs);
            vst1q_u8(levels.as_mut_ptr().add(j + k * 4) as *mut u8, vqtbl4q_u8(tbl, idx));
        }
        j += 16;
    }
    while j < n {
        levels[j] = lut[codes[j] as usize];
        j += 1;
    }
}

/// Dequantize u8 codes with an affine (`min + scale * code`), 8 lanes per
/// iteration (widen u8 → u16 → u32, convert, FMA). The fused multiply-add
/// may round differently from the scalar `min + scale * c`, so the
/// quantized-KV read path is tolerance-gated, not bitwise.
///
/// # Safety
/// NEON must be available (always true on aarch64).
pub unsafe fn dequant_u8(codes: &[u8], scale: f32, min: f32, out: &mut [f32]) {
    let n = out.len().min(codes.len());
    let vs = vdupq_n_f32(scale);
    let vm = vdupq_n_f32(min);
    let mut j = 0;
    while j + 8 <= n {
        let wide = vmovl_u8(vld1_u8(codes.as_ptr().add(j)));
        let lo = vcvtq_f32_u32(vmovl_u16(vget_low_u16(wide)));
        let hi = vcvtq_f32_u32(vmovl_u16(vget_high_u16(wide)));
        vst1q_f32(out.as_mut_ptr().add(j), vfmaq_f32(vm, vs, lo));
        vst1q_f32(out.as_mut_ptr().add(j + 4), vfmaq_f32(vm, vs, hi));
        j += 8;
    }
    while j < n {
        out[j] = min + scale * codes[j] as f32;
        j += 1;
    }
}

/// Dot product with 4×4-lane FMA accumulators (16 floats per iteration),
/// a 4-lane cleanup loop, and a scalar tail. Deterministic: the reduction
/// order is fixed for any given input length.
///
/// # Safety
/// NEON must be available (always true on aarch64).
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut i = 0;
    while i + 16 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
        acc2 = vfmaq_f32(acc2, vld1q_f32(pa.add(i + 8)), vld1q_f32(pb.add(i + 8)));
        acc3 = vfmaq_f32(acc3, vld1q_f32(pa.add(i + 12)), vld1q_f32(pb.add(i + 12)));
        i += 16;
    }
    while i + 4 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
        i += 4;
    }
    let mut s = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}
